"""Paper Fig. 2: per-query breakdown into decode / filter / rest.

Methodology mirrors the paper's plan-rewriting trick: each query runs in
three engine configurations with identical plans —
  raw         decode + filter + query        (query on "Parquet")
  preloaded   filter + query (decode cached) ("pre-loaded tables")
  prefiltered query only (scan cached)       ("pre-filtered tables")
so  decode% = (t_raw - t_pre) / t_raw,  filter% = (t_pre - t_filt) / t_raw.

Paper's claims to compare against: decode ~46% of runtime, filter ~17% on
average; scan-heavy queries (q6/q14/q15) dominated by the two; agg/join
heavy (q1/q12/q19) less so.
"""

from __future__ import annotations

import os
from typing import Dict

from repro.core import BlockCache, DatapathEngine, tpch
from repro.core.queries import QUERIES, SCAN_HEAVY
from repro.lakeformat.reader import LakeReader

from benchmarks.common import DATA_DIR, row, timed


def setup(sf: float = 0.2, seed: int = 0):
    d = os.path.join(DATA_DIR, f"tpch_sf{sf}")
    if not os.path.exists(os.path.join(d, "lineitem.lake")):
        tpch.write_tables(d, sf=sf, seed=seed)
    return {k: LakeReader(os.path.join(d, f"{k}.lake")) for k in
            ("lineitem", "orders", "part")}


def run(sf: float = 0.2) -> Dict[str, dict]:
    readers = setup(sf)
    out = {}
    for name, q in QUERIES.items():
        engines = {}
        for offload in ("raw", "preloaded", "prefiltered"):
            eng = DatapathEngine(backend="ref", offload=offload, cache=BlockCache(4 << 30))
            if offload != "raw":
                q(eng, readers)  # warm the cache (pre-load / pre-filter pass)
            engines[offload] = eng
        t_raw = timed(lambda e=engines["raw"]: q(e, readers))
        t_pre = timed(lambda e=engines["preloaded"]: q(e, readers))
        t_filt = timed(lambda e=engines["prefiltered"]: q(e, readers))
        decode_pct = max(0.0, (t_raw - t_pre) / t_raw * 100)
        filter_pct = max(0.0, (t_pre - t_filt) / t_raw * 100)
        out[name] = {
            "t_raw_s": t_raw, "t_preloaded_s": t_pre, "t_prefiltered_s": t_filt,
            "decode_pct": decode_pct, "filter_pct": filter_pct,
            "rest_pct": 100 - decode_pct - filter_pct,
            "scan_heavy": name in SCAN_HEAVY,
        }
        row(f"breakdown.{name}.raw", t_raw,
            f"decode%={decode_pct:.0f};filter%={filter_pct:.0f}")
    scans = [out[n] for n in SCAN_HEAVY]
    alln = list(out.values())
    avg_decode = sum(r["decode_pct"] for r in alln) / len(alln)
    avg_filter = sum(r["filter_pct"] for r in alln) / len(alln)
    row("breakdown.avg", 0.0,
        f"decode%={avg_decode:.0f};filter%={avg_filter:.0f};paper=46/17")
    out["_avg"] = {"decode_pct": avg_decode, "filter_pct": avg_filter}
    return out


if __name__ == "__main__":
    run()
