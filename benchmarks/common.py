"""Shared benchmark utilities: timed medians, dataset setup."""

from __future__ import annotations

import time
from typing import Callable, Dict

import jax

DATA_DIR = "/tmp/repro_bench"


def timed(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds; blocks on jax results."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r) if r is not None else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn()
        if r is not None:
            jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds*1e6:.0f},{derived}"
    print(line, flush=True)
    return line
