"""Paper Fig. 3a: CSV/JSON vs columnar throughput.

Text parsing is host-serial by design (DESIGN.md §2 — no TPU analogue);
the benchmark quantifies the gap the paper reports as 14-16x for Parquet
over text formats.  Query: q6-style scan+aggregate over lineitem columns.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import DatapathEngine, tpch
from repro.core.queries import q6
from repro.lakeformat import textformat
from repro.lakeformat.reader import LakeReader

from benchmarks.common import DATA_DIR, row, timed


def run(sf: float = 0.25) -> dict:
    d = os.path.join(DATA_DIR, f"fmt_sf{sf}")
    os.makedirs(d, exist_ok=True)
    data = tpch.gen_tables(sf, seed=0)
    li_schema = tpch.lineitem_schema()
    lake = os.path.join(d, "lineitem.lake")
    csv = os.path.join(d, "lineitem.csv")
    jsonl = os.path.join(d, "lineitem.jsonl")
    if not os.path.exists(lake):
        from repro.lakeformat.writer import write_table

        write_table(lake, li_schema, data["lineitem"])
        textformat.write_csv(csv, li_schema, data["lineitem"])
        textformat.write_jsonl(jsonl, li_schema, data["lineitem"])

    n = len(data["lineitem"]["l_quantity"])

    def q6_np(cols):
        m = ((cols["l_shipdate"] >= 365) & (cols["l_shipdate"] <= 729)
             & (cols["l_discount"] >= 0.05 - 1e-4) & (cols["l_discount"] <= 0.07 + 1e-4)
             & (cols["l_quantity"] < 24))
        return float((cols["l_extendedprice"][m] * cols["l_discount"][m]).sum())

    t_csv = timed(lambda: q6_np(textformat.parse_csv(csv, li_schema)), repeats=1, warmup=0)
    t_json = timed(lambda: q6_np(textformat.parse_jsonl(jsonl, li_schema)), repeats=1, warmup=0)

    reader = LakeReader(lake)
    eng = DatapathEngine(backend="ref")
    t_lake = timed(lambda: q6(eng, {"lineitem": reader}), repeats=3)

    row("formats.csv", t_csv, f"rows={n}")
    row("formats.jsonl", t_json, f"rows={n}")
    row("formats.lake", t_lake, f"rows={n}")
    row("formats.speedup", 0.0,
        f"lake_vs_csv={t_csv/t_lake:.1f}x;lake_vs_json={t_json/t_lake:.1f}x;paper=14-16x")
    return {"csv_s": t_csv, "jsonl_s": t_json, "lake_s": t_lake,
            "speedup_csv": t_csv / t_lake, "speedup_json": t_json / t_lake}


if __name__ == "__main__":
    run()
