"""While-loop-aware HLO accounting for the roofline.

XLA's `compiled.cost_analysis()` counts every while-loop body ONCE (scan
trip counts are not multiplied in) and reports per-device numbers — both
verified empirically in tests/test_hlo_analysis.py.  Since this framework
scans over layers, microbatches, q-chunks and SSD chunks, a trip-aware
walk of the optimized HLO is required for truthful per-step FLOPs and
collective bytes.

Mechanics (per computation in `compiled.as_text()`):
  - build an SSA symbol table (value name -> shape) from definition lines
    and computation parameters,
  - dot FLOPs = 2 * prod(out_shape) * prod(lhs contracting dim sizes),
  - collective bytes = result bytes * ring factor
    (all-reduce 2x, gather/scatter/a2a/permute 1x),
  - call graph via to_apply= / calls= / body= / branch_computations=,
  - while trip counts from backend_config known_trip_count (fallback:
    largest int constant in the condition computation),
  - evaluate ENTRY recursively, multiplying while bodies by trip count.

All numbers are PER DEVICE (the optimized HLO is the per-device SPMD
program).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_PARAM_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*(\w+\[[\d,]*\])")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CONST_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_OP_RE = re.compile(
    r"\b(dot|while|fusion|call|conditional|custom-call|"
    r"all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)


def _split_operands(s: str) -> List[str]:
    """Split an operand list on top-level commas only — older XLA prints
    type-prefixed operands ("f32[256,256]{1,0} %x") whose shape literals
    contain commas of their own."""
    parts: List[str] = []
    depth, cur = 0, []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _parse_shape(txt: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.search(txt)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def _numel(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _nbytes(shape: Tuple[str, Tuple[int, ...]]) -> float:
    return _numel(shape[1]) * _DTYPE_BYTES[shape[0]]


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    whiles: List[Tuple[str, str, Optional[int]]] = dataclasses.field(default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)
    max_const: int = 1


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    current: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if current is None:
            if line.endswith("{") and "->" in line and ("(" in line):
                head = line
                is_entry = head.startswith("ENTRY")
                if is_entry:
                    head = head[len("ENTRY"):].strip()
                name = head.lstrip("%").split("(")[0].split()[0].strip()
                current = name
                comps[current] = [line]  # keep header (has param shapes)
                if is_entry:
                    entry = name
        else:
            if line == "}":
                current = None
            else:
                comps[current].append(line)
    return comps, entry


def _analyze_comp(lines: List[str]) -> CompStats:
    st = CompStats()
    sym: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    # computation parameters from the header line
    for pname, pshape in _PARAM_RE.findall(lines[0]):
        shp = _parse_shape(pshape)
        if shp:
            sym[pname] = shp

    for line in lines[1:]:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        shp = _parse_shape(rhs.split("(", 1)[0] if "(" in rhs else rhs)
        if shp:
            sym[name] = shp
        cm = _CONST_RE.search(line)
        if cm:
            st.max_const = max(st.max_const, int(cm.group(1)))
        # opcode: first known op token followed by '(' (tuple-typed results
        # start with '(', so "token before first paren" doesn't work)
        rhs_main = rhs.split(", metadata")[0]
        opm = _OP_RE.search(rhs_main)
        op = opm.group(1) if opm else ""
        if op.endswith("-start"):
            op = op[: -len("-start")]

        if op == "dot":
            out = _parse_shape(rhs)
            args = re.findall(r"dot\(([^)]*)\)", rhs)
            lhs_shape = None
            if args:
                # operands print as "%name" on newer XLA but
                # "f32[256,256]{1,0} %name" (type-prefixed) on older —
                # the value name is always the last token
                ops_names = [a.strip().split()[-1].lstrip("%")
                             for a in _split_operands(args[0]) if a.strip()]
                if ops_names:
                    lhs_shape = sym.get(ops_names[0])
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            if out and lhs_shape and cdims is not None:
                k = 1
                for ci in (int(x) for x in cdims.group(1).split(",") if x):
                    if ci < len(lhs_shape[1]):
                        k *= lhs_shape[1][ci]
                st.dot_flops += 2.0 * _numel(out[1]) * k
                st.dot_bytes += _nbytes(out) + (
                    _nbytes(lhs_shape) if lhs_shape else 0.0
                )
            continue

        matched_coll = False
        for kind in COLLECTIVES:
            if op == kind or op == kind + "-start":
                out = _parse_shape(rhs)
                if out:
                    nb = _nbytes(out) * _COLL_FACTOR[kind]
                    st.coll_bytes += nb
                    st.coll_by_kind[kind] = st.coll_by_kind.get(kind, 0.0) + nb
                matched_coll = True
                break
        if matched_coll:
            continue

        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", rhs)
            cm2 = re.search(r"condition=%?([\w\.\-]+)", rhs)
            tm = _TRIP_RE.search(rhs)
            if bm and cm2:
                st.whiles.append(
                    (bm.group(1), cm2.group(1), int(tm.group(1)) if tm else None)
                )
            continue

        for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", rhs):
            st.calls.append(m.group(1))
        bm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
        if bm:
            for b in bm.group(1).split(","):
                st.calls.append(b.strip().lstrip("%"))
    return st


@dataclasses.dataclass
class HloCost:
    flops: float
    dot_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]


def analyze_hlo(hlo_text: str) -> HloCost:
    comps, entry = _split_computations(hlo_text)
    stats = {name: _analyze_comp(lines) for name, lines in comps.items()}
    memo: Dict[str, Tuple[float, float, float, Dict[str, float]]] = {}

    def walk(name: str, depth=0):
        if name in memo:
            return memo[name]
        st = stats.get(name)
        if st is None or depth > 64:
            return (0.0, 0.0, 0.0, {})
        memo[name] = (0.0, 0.0, 0.0, {})
        f, db, cb = st.dot_flops, st.dot_bytes, st.coll_bytes
        kinds = dict(st.coll_by_kind)
        for callee in st.calls:
            cf, cdb, ccb, ck = walk(callee, depth + 1)
            f += cf; db += cdb; cb += ccb
            for k, v in ck.items():
                kinds[k] = kinds.get(k, 0.0) + v
        for body, cond, trip in st.whiles:
            if trip is None:
                trip = stats[cond].max_const if cond in stats else 1
            bf, bdb, bcb, bk = walk(body, depth + 1)
            f += bf * trip; db += bdb * trip; cb += bcb * trip
            for k, v in bk.items():
                kinds[k] = kinds.get(k, 0.0) + v * trip
        memo[name] = (f, db, cb, kinds)
        return memo[name]

    f, db, cb, kinds = walk(entry) if entry else (0.0, 0.0, 0.0, {})
    return HloCost(flops=f, dot_bytes=db, collective_bytes=cb,
                   collective_by_kind=kinds)


def analyze_compiled(compiled) -> HloCost:
    return analyze_hlo(compiled.as_text())
