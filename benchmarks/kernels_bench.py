"""Kernel decode rates (paper §3 "line-rate data decoding").

On this CPU container the meaningful numbers are the jnp reference-path
decode rates (bytes of decoded output per second) and the encoded:decoded
byte ratios (= DMA savings).  On a real TPU the Pallas kernels are HBM-
bound; their arithmetic intensity is reported for the roofline argument.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops
from repro.lakeformat import encodings as E

from benchmarks.common import row, timed


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    n = 1 << 20  # 1M values

    # bitunpack @ 18 bits (202k vocab tokens)
    v = rng.integers(0, 202048, size=n, dtype=np.uint64)
    p = jnp.asarray(E.bitpack_encode(v, 18))
    t = timed(lambda: ops.bitunpack(p, 18, n, backend="ref"))
    out["bitunpack18"] = {"decoded_GBps": n * 4 / t / 1e9, "ratio": 32 / 18}
    row("kernels.bitunpack18", t, f"GB/s={n*4/t/1e9:.2f};dma_ratio={32/18:.2f}")

    # dict decode (7 distinct values)
    v = rng.choice(np.array([1, 5, 9, 13, 20, 44, 90], dtype=np.int64), size=n)
    b = E.dict_encode(v); k = int(b.pop("_k")[0])
    pk, d = jnp.asarray(b["packed"]), jnp.asarray(b["dictionary"].astype(np.int32))
    t = timed(lambda: ops.dict_decode(pk, d, k, n, backend="ref"))
    out["dict"] = {"decoded_GBps": n * 4 / t / 1e9}
    row("kernels.dict_decode", t, f"GB/s={n*4/t/1e9:.2f};k={k}")

    # rle decode (runs ~64 long; n reduced: one-hot expansion is eager on CPU)
    nr = 1 << 18
    v = np.repeat(rng.integers(0, 100, size=nr // 64), 64).astype(np.int32)
    b = E.rle_encode(v)
    rv, re_ = jnp.asarray(b["rle_values"]), jnp.asarray(b["rle_ends"])
    t = timed(lambda: ops.rle_decode(rv, re_, len(v), backend="ref"))
    out["rle"] = {"decoded_GBps": len(v) * 4 / t / 1e9}
    row("kernels.rle_decode", t, f"GB/s={len(v)*4/t/1e9:.2f}")

    # delta decode
    v = np.cumsum(rng.integers(0, 16, size=n)).astype(np.int64)
    b = E.delta_encode(v); k = int(b.pop("_k")[0])
    pk, bs = jnp.asarray(b["packed"]), jnp.asarray(b["bases"].astype(np.int32))
    t = timed(lambda: ops.delta_decode(pk, bs, k, n, backend="ref"))
    out["delta"] = {"decoded_GBps": n * 4 / t / 1e9, "k": k}
    row("kernels.delta_decode", t, f"GB/s={n*4/t/1e9:.2f};k={k}")

    # fused scan (decode + predicate, nothing materialized)
    v = rng.integers(0, 2556, size=n, dtype=np.uint64)
    p = jnp.asarray(E.bitpack_encode(v, 12))
    t = timed(lambda: ops.fused_scan(p, 12, 365, 729, backend="ref"))
    out["fused_scan"] = {"decoded_GBps": n * 4 / t / 1e9}
    row("kernels.fused_scan", t, f"GB/s={n*4/t/1e9:.2f}")

    # filter_compact (n reduced: permutation one-hot is MXU work, eager on CPU)
    nf = 1 << 16
    vals = jnp.asarray(rng.standard_normal((nf // 1024, 1024)).astype(np.float32))
    mask = jnp.asarray(rng.random((nf // 1024, 1024)) < 0.2)
    t = timed(lambda: ops.filter_compact(vals, mask, backend="ref"))
    out["filter_compact"] = {"GBps": nf * 4 / t / 1e9}
    row("kernels.filter_compact", t, f"GB/s={nf*4/t/1e9:.2f}")

    # per-encoding calibration table — the SAME measurement the datapath
    # service's cost model runs (repro.datapath.costmodel), reported here so
    # the kernel roofline and the WFQ currency are visibly one number
    from repro.datapath.costmodel import CostModel

    cm = CostModel.calibrate(backend="ref", n=1 << 18, repeats=1)
    out["costmodel"] = {"rates_GBps": dict(sorted(cm.rates.items())),
                        "source": cm.source}
    row("kernels.costmodel", 0.0,
        ";".join(f"{k}={v:.2f}" for k, v in sorted(cm.rates.items()))
        + f";source={cm.source}")
    return out


if __name__ == "__main__":
    run()
