"""LM input-pipeline offload (paper §1 "smaller CPUs match throughput",
applied to the training workload).

Measures host-CPU work and DMA bytes per training token across the three
ingestion modes:
  host    host decodes + filters (traditional pipeline)
  engine  device decodes + filters (datapath offload)
  fused   raw encoded blocks straight to the jitted step (zero host work)
"""

from __future__ import annotations

import os
import time

from repro.data.corpus import write_corpus
from repro.data.pipeline import TokenPipeline

from benchmarks.common import DATA_DIR, row


def run(n_tokens: int = 2_000_000, vocab: int = 151_936) -> dict:
    d = os.path.join(DATA_DIR, "corpus")
    marker = os.path.join(d, "shard_00000.lake")
    if not os.path.exists(marker):
        write_corpus(d, n_tokens=n_tokens, vocab=vocab, n_shards=2)
    paths = [os.path.join(d, f) for f in sorted(os.listdir(d))]

    out = {}
    B, S, steps = 4, 4096, 8
    for mode in ("host", "engine", "fused"):
        pipe = TokenPipeline(paths, B, S, mode=mode,
                             quality_min=30 if mode != "fused" else None)
        t0 = time.perf_counter()
        for _ in range(steps):
            pipe.next_batch()
        dt = time.perf_counter() - t0
        toks = B * S * steps
        out[mode] = {
            "tokens_per_s": toks / dt,
            "host_bytes_per_token": pipe.stats["host_bytes_decoded"] / toks,
            "dma_bytes_per_token": pipe.stats["dma_bytes"] / toks,
        }
        row(f"pipeline.{mode}", dt / steps,
            f"tok/s={toks/dt:.0f};hostB/tok={out[mode]['host_bytes_per_token']:.2f};"
            f"dmaB/tok={out[mode]['dma_bytes_per_token']:.2f}")
    return out


if __name__ == "__main__":
    run()
