"""Paper Fig. 3b: sorted vs unsorted input ordering (zone-map pruning).

Sorting lineitem on l_shipdate / orders on o_orderdate (paper footnote 2)
lets zone maps prune row groups for date-selective scans; the paper reports
big wins for q6/q14/q15 and ~none for order-insensitive queries.
"""

from __future__ import annotations

import os

from repro.core import DatapathEngine, tpch
from repro.core.queries import QUERIES
from repro.lakeformat.reader import LakeReader

from benchmarks.common import DATA_DIR, row, timed


def run(sf: float = 0.2) -> dict:
    out = {}
    readers = {}
    for sorted_data in (False, True):
        tag = "sorted" if sorted_data else "unsorted"
        d = os.path.join(DATA_DIR, f"tpch_{tag}_sf{sf}")
        if not os.path.exists(os.path.join(d, "lineitem.lake")):
            tpch.write_tables(d, sf=sf, seed=0, sorted_data=sorted_data,
                              row_group_size=16384)
        readers[tag] = {k: LakeReader(os.path.join(d, f"{k}.lake"))
                        for k in ("lineitem", "orders", "part")}

    for name, q in QUERIES.items():
        ts = {}
        for tag in ("unsorted", "sorted"):
            eng = DatapathEngine(backend="ref")
            ts[tag] = timed(lambda e=eng, r=readers[tag]: q(e, r))
        speedup = ts["unsorted"] / ts["sorted"]
        out[name] = {"unsorted_s": ts["unsorted"], "sorted_s": ts["sorted"],
                     "speedup": speedup}
        row(f"pruning.{name}", ts["sorted"], f"speedup={speedup:.2f}x")
    return out


if __name__ == "__main__":
    run()
