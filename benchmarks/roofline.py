"""§Roofline: three-term analysis per (arch x shape) from the dry-run.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_dot_bytes_per_device / HBM_bw           [s]
    collective term = collective_bytes_per_device / ICI link bw   [s]

Sources: the dry-run JSON (benchmarks/dryrun_single.json), whose FLOPs /
bytes come from the trip-aware HLO walk (hlo_analysis.py) — raw
cost_analysis undercounts every lax.scan body (verified in tests) — and
whose collective bytes are ring-adjusted per-device traffic.

MODEL_FLOPS is the analytic useful work:
    train   6 * N_active * tokens  (+ attention 12*B*S^2*H*hd*L_attn)
    prefill 2 * N_active * tokens  (+ attention  4*B*S^2*H*hd*L_attn)
    decode  2 * N_active * B       (+ attention  4*B*S_kv*H*hd*L_attn)
MODEL_FLOPS/HLO_FLOPs is the useful-compute fraction; it exposes remat
recompute, causal-masking waste, MoE capacity padding and dispatch
overhead.

Usage:
    PYTHONPATH=src:. python benchmarks/roofline.py \
        --json benchmarks/dryrun_single.json --md EXPERIMENTS_roofline.md
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link
CHIPS = 256  # single-pod mesh

SHAPE_INFO = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def model_flops(cfg, shape_name: str) -> float:
    """Analytic useful FLOPs per step (global, all chips)."""
    kind, S, B = SHAPE_INFO[shape_name]
    n_active = cfg.n_active_params()
    d_attn = cfg.n_heads * cfg.head_dim
    # attention score+value flops per token pair: 4 * d_attn (fwd)
    n_attn_layers = 0 if cfg.family == "ssm" else cfg.n_layers
    if kind == "train":
        toks = B * S
        base = 6.0 * n_active * toks
        attn = 12.0 * B * S * S / 2 * d_attn * n_attn_layers  # causal half
        return base + attn
    if kind == "prefill":
        toks = B * S
        base = 2.0 * n_active * toks
        attn = 4.0 * B * S * S / 2 * d_attn * n_attn_layers
        return base + attn
    # decode: one token against an S-deep KV (or O(1) state for ssm)
    base = 2.0 * n_active * B
    if cfg.family == "ssm":
        attn = 0.0
    elif cfg.family == "hybrid":
        # SWA layers see `window` keys; globals see S
        n_glob = len(cfg.global_layers)
        attn = 4.0 * B * d_attn * (
            n_glob * S + (cfg.n_layers - n_glob) * min(cfg.window or S, S)
        )
    else:
        attn = 4.0 * B * S * d_attn * cfg.n_layers
    return base + attn


def analyze(rec: dict, cfg=None) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["dot_bytes"] / HBM_BW
    t_coll = rec["collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    out = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "peak_gib": (rec["memory"]["peak_bytes"] or 0) / 2**30,
    }
    if cfg is not None:
        mf = model_flops(cfg, rec["shape"]) / CHIPS  # per device
        out["model_flops_dev"] = mf
        out["hlo_flops_dev"] = rec["flops"]
        out["useful_frac"] = mf / rec["flops"] if rec["flops"] else 0.0
        # roofline fraction: useful work / time the dominant term implies
        t_star = max(terms.values())
        out["roofline_frac"] = (mf / PEAK_FLOPS) / t_star if t_star else 0.0
    return out


_ADVICE = {
    "compute": "cut non-useful FLOPs (causal-waste in chunked attention, "
               "MoE capacity padding, remat recompute) or raise MXU occupancy",
    "memory": "raise arithmetic intensity: larger microbatch per device, "
              "fused decode (skip materialized tokens), bf16 activations",
    "collective": "shrink or overlap collectives: hierarchical pod reduction, "
                  "int8 gradient compression, reduce-scatter instead of "
                  "all-reduce+all-gather, SP residual sharding",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="benchmarks/dryrun_single.json")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()

    from repro.configs import get_config

    with open(args.json) as f:
        recs = json.load(f)

    rows = []
    for rec in recs:
        cfg = get_config(rec["arch"]) if rec.get("status") == "ok" else None
        a = analyze(rec, cfg)
        if a:
            rows.append(a)

    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| MODEL/HLO flops | roofline frac | peak GiB | next lever |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for a in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3e} | "
            f"{a['t_memory_s']:.3e} | {a['t_collective_s']:.3e} | "
            f"**{a['bottleneck']}** | {a['useful_frac']:.2f} | "
            f"{a['roofline_frac']:.2f} | {a['peak_gib']:.2f} | "
            f"{_ADVICE[a['bottleneck']]} |"
        )
    table = "\n".join(lines)
    print(table)
    if args.md:
        with open(args.md, "w") as f:
            f.write(table + "\n")
    # summary for picking hillclimb targets
    worst = min(rows, key=lambda r: r["roofline_frac"])
    most_coll = max(rows, key=lambda r: r["t_collective_s"] /
                    max(r["t_compute_s"], 1e-12))
    print(f"\nworst roofline: {worst['arch']} x {worst['shape']} "
          f"({worst['roofline_frac']:.2f})")
    print(f"most collective-bound: {most_coll['arch']} x {most_coll['shape']}")


if __name__ == "__main__":
    main()
