"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.row).
Sections:
    breakdown       Fig. 2  decode/filter/rest per query
    throughput      Fig. 1  raw vs pre-loaded vs pre-filtered
    formats         Fig. 3a CSV/JSON vs columnar
    pruning         Fig. 3b sorted vs unsorted zone-map pruning
    kernels         §3      decode-core rates + DMA ratios
    pipeline        §1      LM ingestion offload (host/engine/fused)
    service         §SmartNIC-as-service: multi-tenant coalescing + policy
Roofline (§Roofline) runs separately off the dry-run JSON:
    python benchmarks/roofline.py
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller scale factors")
    ap.add_argument("--json", default=None, help="also dump results as JSON")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    sf = 0.1 if args.fast else 0.2
    results = {}
    sections = []

    from benchmarks import (
        breakdown,
        formats,
        kernels_bench,
        pipeline_bench,
        pruning,
        service_bench,
        throughput,
    )

    sections = [
        ("breakdown", lambda: breakdown.run(sf=sf)),
        ("throughput", lambda: throughput.run(sf=sf)),
        ("formats", lambda: formats.run(sf=0.1 if args.fast else 0.25)),
        ("pruning", lambda: pruning.run(sf=sf)),
        ("kernels", kernels_bench.run),
        ("pipeline", lambda: pipeline_bench.run(n_tokens=500_000 if args.fast else 2_000_000)),
        ("service", lambda: service_bench.run(sf=sf, n_tenants=4 if args.fast else 6)),
    ]

    if args.only and args.only not in {name for name, _ in sections}:
        ap.error(f"--only {args.only!r}: unknown section "
                 f"(choose from {', '.join(n for n, _ in sections)})")

    failed = 0
    for name, fn in sections:
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            results[name] = fn()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},FAILED,{type(e).__name__}", flush=True)
            failed += 1

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=float)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
