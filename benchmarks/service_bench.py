"""Multi-tenant service benchmark: shared-scan coalescing vs N independent
engines, the adaptive offload policy on a recurring workload, and the
fair-share scheduler under skew.

The coalescing workload is N tenants running TPC-H-style revenue scans
over the same lineitem table with per-tenant date windows (overlapping,
as concurrent dashboards do).  Independently, every tenant decodes every
hot column itself; through the service, one tick's DecodePool decodes
each (row group, column) once and feeds all N predicates — so fresh
decoded bytes stay near-flat while tenant count grows.

The `fairness` sub-report runs a skewed 1-elephant/3-mice workload (one
whole-table scan pinned behind three narrow window scans) under FIFO vs
WFQ with the same per-tick decode budget, reporting mice p99
ticks-to-complete against their solo value plus the Jain fairness index,
and measures the cross-tick coalescing hold window (decoded_bytes_saved
with hold_ticks=2 vs tick-scoped coalescing) on compatible requests that
arrive a tick apart.

The `costmodel` sub-report calibrates the per-encoding decode-rate table
(fast smoke sizes; nominal fallback) and runs the adversarial honesty
bench: an elephant whose requests under-estimate decode cost 4x competes
with an honest elephant under WFQ.  With actual-cost reconciliation on,
the cheat's decoded-byte share while both are backlogged must stay
within 10% of the honest baseline; with it off, the cheat pays off —
that delta is the reconciliation mechanism's measured value.

The `blockstore` sub-report exercises the unified tiered store: a LATE
partner arriving hold_ticks after a compatible scan dispatched serves
its overlapping row groups from the window-retained decoded tier
(re-decode seconds saved > 0 vs the old tick-scoped pool, which saves
exactly zero in the same scenario), and a capacity-pressured preloaded
workload shows the cost-ranked eviction keeping encoded pages (repeat
scans re-decode but never re-fetch) — per-tier hit/eviction rates come
from the store's ledger.

The `batchdecode` sub-report A/Bs the bucketed batch-decode dispatch
path (service batch_decode=True, the default) against the sequential
one-launch-per-(row group, column) loop on a >= 32-row-group,
multi-column whole-table scan: device dispatches (kernels.ops'
dispatch counter), wall time, decode launches, and — with the slice
pipeline — the netsim fetch/decode overlap at slice granularity.

The `trace` sub-report A/Bs the flight recorder (datapath/trace.py) on
the skewed elephant/mice workload: the same run with per-request span
tracing on (sample_rate=1) vs off (sample_rate=0), reporting the wall
overhead ratio (must stay under ~5%), result bit-identity, the Chrome-
trace event count, and the trace-derived per-request stage attribution
(decode/filter/rest % of wall) printed against the paper's Fig. 2
46/17/37 anchor — the observability claim as a measured point.

Reported rows:
    service.independent    N direct DatapathEngine.scan() calls
    service.coalesced      same scans through one DatapathService tick
    service.savings        fresh-decoded-byte ratio + wall speedup
    service.adaptive       repeated query mix under the adaptive policy
    service.fairness.*     solo / fifo / wfq mice latency + Jain index
    service.holdwindow     cross-tick vs tick-scoped coalescing savings
    service.costmodel.*    calibrated rates + 4x-under-estimator shares
    service.blockstore.*   late-partner retained reuse + tier ledger
    service.batchdecode.*  dispatch counts + wall, batched vs sequential
    service.pushdown       fused decode→aggregate vs scan-then-aggregate:
                           result-DMA bytes, wall, dispatch counts,
                           bit-identity of the grouped answer
    service.trace.*        tracing overhead + stage attribution vs Fig. 2
    service.kernels.roofline  rewritten-core rates vs the pre-rewrite
                           anchor + ladder-vs-pow2 pad-waste bytes
    service.fabric.*       pod-sharded fleet: aggregate simulated
                           throughput at 1/2/4 pods (makespan = max
                           per-pod busy seconds), scale-out peer-fetch
                           bytes vs the storage-hop equivalent, fleet
                           Jain index with the WFQ re-level on vs
                           per-pod local clocks, kill-one-pod
                           drain/replay with bit-identity
    service.faults.*       storage fault plane: fault-free vs 1%/5%
                           transient-error A/B (bit-identical results,
                           bounded p99 inflation, zero hung requests),
                           hedged-read tail seconds clawed back, and the
                           breaker-open load-shed rate with every
                           rejection typed Overloaded
"""

from __future__ import annotations

import os
import time

from repro.core import BlockCache, DatapathEngine, tpch
from repro.core.plan import Cmp, ScanPlan
from repro.core.queries import QUERIES, run_via_service
from repro.datapath import (
    PAPER_FIG2_PCT,
    AdaptiveOffloadPolicy,
    CostModel,
    DatapathService,
    StaticPolicy,
)
from repro.lakeformat.reader import LakeReader

from benchmarks.breakdown import setup
from benchmarks.common import DATA_DIR, row, timed


def tenant_plans(n_tenants: int):
    """Per-tenant revenue scans: same hot columns, shifted date windows."""
    plans = []
    for t in range(n_tenants):
        start = 200 + 45 * t  # overlapping year-long windows
        plans.append(
            ScanPlan(
                "lineitem",
                ["l_extendedprice", "l_discount"],
                Cmp("l_shipdate", "between", (start, start + 364)),
            )
        )
    return plans


def _run_independent(readers, plans):
    """One fresh raw engine per tenant — the seed library-call model."""
    fresh = 0
    for plan in plans:
        eng = DatapathEngine(backend="ref", offload="raw")
        res = eng.scan(readers["lineitem"], plan)
        fresh += res.stats.decoded_bytes_fresh
    return fresh


def _run_service(readers, plans):
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        batch_per_tick=len(plans),
        policy=StaticPolicy("raw"),  # isolate coalescing from caching
    )
    for t, plan in enumerate(plans):
        svc.submit(f"tenant{t}", readers["lineitem"], plan)
    svc.drain()
    return svc


# ---------------------------------------------------------------------------
# fairness sub-report: 1 elephant / 3 mice, FIFO vs WFQ, hold window
# ---------------------------------------------------------------------------

FAIR_RG_ROWS = 8192  # small row groups: the scheduler's preemption quantum


def fairness_setup(sf: float = 0.1):
    """A sorted lineitem with small row groups so narrow window scans prune
    to 1-2 groups while the elephant spans them all."""
    d = os.path.join(DATA_DIR, f"tpch_fair_sf{sf}")
    if not os.path.exists(os.path.join(d, "lineitem.lake")):
        tpch.write_tables(d, sf=sf, seed=0, sorted_data=True,
                          row_group_size=FAIR_RG_ROWS)
    return LakeReader(os.path.join(d, "lineitem.lake"))


def _elephant_plan():
    return ScanPlan("lineitem", ["l_extendedprice", "l_quantity"])  # every group


def _mouse_plan(day: int):
    return ScanPlan("lineitem", ["l_extendedprice"],
                    Cmp("l_shipdate", "between", (day, day + 200)))


def _fair_service(scheduler: str, hold_ticks: int = 0):
    rg_cost = FAIR_RG_ROWS * 4 * 2  # decoded bytes per elephant row group
    return DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        policy=StaticPolicy("raw"),  # isolate scheduling from caching
        scheduler=scheduler,
        tick_bytes=int(rg_cost * 1.5),
        hold_ticks=hold_ticks,
    )


def _run_skewed(reader, scheduler: str, with_elephant: bool) -> dict:
    """1 elephant + 3 mice; returns mice p99 ticks-to-complete and the
    fairness snapshot."""
    svc = _fair_service(scheduler)
    elephant = svc.submit("elephant", reader, _elephant_plan()) if with_elephant else None
    mice = [svc.submit(f"mouse{i}", reader, _mouse_plan(d))
            for i, d in enumerate((300, 900, 1500))]
    svc.drain()
    ticks = sorted(t.done_tick - t.submitted_tick for t in mice)
    # NOTE: cumulative decoded bytes (and hence the Jain index over them)
    # are workload-determined — identical under FIFO and WFQ, which only
    # reorder WHEN work runs.  The scheduler discriminator is latency:
    # mice ticks-to-complete.  Shares are returned for the workload's
    # skew profile, not as an A/B metric.
    fair = svc.telemetry.fairness()
    return {
        "mice_ticks": ticks,
        "mice_p99_ticks": ticks[-1],
        "elephant_ticks": (elephant.done_tick - elephant.submitted_tick)
        if elephant else 0,
        "tenant_share": fair["tenant_share"],
    }


def _run_hold_window(reader, hold_ticks: int) -> int:
    """Two compatible scans arriving a tick apart; returns the decoded
    bytes the shared pool saved."""
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        policy=StaticPolicy("raw"),
        hold_ticks=hold_ticks,
    )
    plan_a = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_shipdate", "between", (300, 700)))
    plan_b = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_shipdate", "between", (350, 750)))
    svc.submit("t0", reader, plan_a)
    svc.tick()  # without a hold, t0 decodes alone in this tick
    svc.submit("t1", reader, plan_b)
    svc.drain()
    return int(svc.telemetry.counters.get("decoded_bytes_saved", 0))


def run_fairness(sf: float = 0.1) -> dict:
    reader = fairness_setup(sf)
    solo = _run_skewed(reader, "wfq", with_elephant=False)
    fifo = _run_skewed(reader, "fifo", with_elephant=True)
    wfq = _run_skewed(reader, "wfq", with_elephant=True)
    saved_scoped = _run_hold_window(reader, hold_ticks=0)
    saved_window = _run_hold_window(reader, hold_ticks=2)

    row("service.fairness.solo", 0.0,
        f"mice_p99_ticks={solo['mice_p99_ticks']}")
    row("service.fairness.fifo", 0.0,
        f"mice_p99_ticks={fifo['mice_p99_ticks']};"
        f"elephant_ticks={fifo['elephant_ticks']}")
    row("service.fairness.wfq", 0.0,
        f"mice_p99_ticks={wfq['mice_p99_ticks']};"
        f"elephant_ticks={wfq['elephant_ticks']};"
        f"vs_solo={wfq['mice_p99_ticks'] / max(solo['mice_p99_ticks'], 1):.2f}x;"
        f"vs_fifo={fifo['mice_p99_ticks'] / max(wfq['mice_p99_ticks'], 1):.2f}x")
    row("service.holdwindow", 0.0,
        f"saved_tick_scoped={saved_scoped};saved_hold2={saved_window}")
    return {
        "solo": solo,
        "fifo": fifo,
        "wfq": wfq,
        "wfq_mice_p99_vs_solo": wfq["mice_p99_ticks"] / max(solo["mice_p99_ticks"], 1),
        "hold_window_saved_bytes": saved_window,
        "tick_scoped_saved_bytes": saved_scoped,
    }


# ---------------------------------------------------------------------------
# costmodel sub-report: calibration + the 4x-under-estimator honesty bench
# ---------------------------------------------------------------------------

def _run_adversarial(reader, cost_model, cheat: bool, reconcile: bool) -> dict:
    """Two whole-table elephants, one doctored to under-estimate its decode
    cost 4x.  Shares are measured while BOTH tenants stay backlogged (the
    only regime where scheduling decides anything)."""
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        policy=StaticPolicy("raw"), scheduler="wfq",
        tick_bytes=int(FAIR_RG_ROWS * 4 * 2 * 1.5),
        cost_model=cost_model, reconcile=reconcile,
    )
    svc.submit("cheat", reader, ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]))
    svc.submit("honest", reader, ScanPlan("lineitem", ["l_discount", "l_tax"]))
    if cheat:
        req = next(q for q in svc.queue if q.tenant == "cheat")
        req.rg_costs = tuple(c / 4 for c in req.rg_costs)
    while all(any(q.tenant == t and q.cursor < len(q.row_groups) for q in svc.queue)
              for t in ("cheat", "honest")):
        svc.tick()
    dec = svc.telemetry.tenant_decoded_bytes
    total = sum(dec.values())
    return {
        "cheat_share": dec.get("cheat", 0.0) / total if total else 0.0,
        "cost": svc.telemetry.cost_report(),
    }


def run_costmodel(sf: float = 0.1) -> dict:
    import time as _time

    reader = fairness_setup(sf)
    t0 = _time.perf_counter()
    cm = CostModel.calibrate(backend="ref", n=1 << 16, repeats=1)
    t_cal = _time.perf_counter() - t0
    rates = {k: round(v, 3) for k, v in sorted(cm.rates.items())}
    row("service.costmodel.calibration", t_cal,
        f"source={cm.source};rates_GBps={rates}")

    base = _run_adversarial(reader, cm, cheat=False, reconcile=True)
    recon_on = _run_adversarial(reader, cm, cheat=True, reconcile=True)
    recon_off = _run_adversarial(reader, cm, cheat=True, reconcile=False)
    gain_on = recon_on["cheat_share"] / max(base["cheat_share"], 1e-9)
    gain_off = recon_off["cheat_share"] / max(base["cheat_share"], 1e-9)
    cheat_err = recon_on["cost"]["cheat"]["rel_err"]
    row("service.costmodel.adversarial", 0.0,
        f"honest_share={base['cheat_share']:.3f};"
        f"cheat_share_recon={recon_on['cheat_share']:.3f} ({gain_on:.2f}x);"
        f"cheat_share_norecon={recon_off['cheat_share']:.3f} ({gain_off:.2f}x);"
        f"cheat_rel_err={cheat_err:.2f}")
    return {
        "rates_gbps": {k: cm.rates[k] for k in sorted(cm.rates)},
        "source": cm.source,
        "calibration_s": t_cal,
        "honest_share": base["cheat_share"],
        "cheat_share_reconcile_on": recon_on["cheat_share"],
        "cheat_share_reconcile_off": recon_off["cheat_share"],
        "cheat_gain_reconcile_on": gain_on,
        "cheat_gain_reconcile_off": gain_off,
        "cheat_rel_err_reconcile_on": cheat_err,
    }


# ---------------------------------------------------------------------------
# blockstore sub-report: retained-window reuse + tier ledger under pressure
# ---------------------------------------------------------------------------

def _run_late_partner(reader, hold_ticks: int) -> dict:
    """A scan dispatches alone (at its hold deadline); a compatible partner
    arrives AFTER it completed, within the hold window.  With the unified
    store the partner reuses the window-retained decodes; with the old
    tick-scoped pool (hold_ticks=0 control) it re-decodes everything."""
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        policy=StaticPolicy("raw"), hold_ticks=hold_ticks,
    )
    plan_a = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_shipdate", "between", (300, 700)))
    plan_b = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_shipdate", "between", (350, 750)))
    early = svc.submit("early", reader, plan_a)
    while early.status == "queued":
        svc.tick()
    late = svc.submit("late", reader, plan_b)
    svc.drain()
    c = svc.telemetry.counters
    return {
        "reuse_bytes": int(c.get("retained_reuse_bytes", 0)),
        "redecode_saved_s": float(c.get("retained_redecode_saved_s", 0.0)),
        "late_fresh_bytes": int(late.result.stats.decoded_bytes_fresh),
        "late_pool_hits": int(late.result.stats.pool_hits),
        "retained_charge_s": float(c.get("retained_charge_seconds", 0.0)),
    }


def _run_tier_pressure(reader) -> dict:
    """Preloaded repeats through a store sized well under the decoded
    footprint: cost-ranked eviction churns PLAIN decodes but keeps encoded
    pages, so the repeat pass re-decodes without re-fetching."""
    plan = ScanPlan("lineitem", ["l_extendedprice", "l_discount"])
    enc_total = sum(
        reader.row_group_meta(rg)["columns"][c]["encoded_bytes"]
        for rg in range(reader.n_row_groups)
        for c in ("l_extendedprice", "l_discount")
    )
    eng = DatapathEngine(backend="ref",
                         cache=BlockCache(enc_total + FAIR_RG_ROWS * 4 * 3))
    first = eng.scan(reader, plan, offload="preloaded")
    second = eng.scan(reader, plan, offload="preloaded")
    tiers = eng.cache.stats()["tiers"]
    return {
        "first_fetch_bytes": int(first.stats.encoded_bytes),
        "repeat_fetch_bytes": int(second.stats.encoded_bytes),
        "repeat_page_hits": int(second.stats.page_hits),
        "decoded_evictions": int(tiers["decoded"]["evictions"]),
        "encoded_hits": int(tiers["encoded"]["hits"]),
        "decoded_hits": int(tiers["decoded"]["hits"]),
    }


def run_blockstore(sf: float = 0.1) -> dict:
    reader = fairness_setup(sf)
    scoped = _run_late_partner(reader, hold_ticks=0)  # old tick-scoped pool
    window = _run_late_partner(reader, hold_ticks=2)
    pressure = _run_tier_pressure(reader)
    row("service.blockstore.latepartner", 0.0,
        f"reuse_bytes={window['reuse_bytes']};"
        f"redecode_saved_s={window['redecode_saved_s']:.6f};"
        f"tick_scoped_saved_s={scoped['redecode_saved_s']:.6f};"
        f"retained_charge_s={window['retained_charge_s']:.6f}")
    row("service.blockstore.tiers", 0.0,
        f"repeat_fetch_bytes={pressure['repeat_fetch_bytes']}"
        f"/{pressure['first_fetch_bytes']};"
        f"page_hits={pressure['repeat_page_hits']};"
        f"decoded_evictions={pressure['decoded_evictions']}")
    return {
        "late_partner_window": window,
        "late_partner_tick_scoped": scoped,
        "tier_pressure": pressure,
    }


# ---------------------------------------------------------------------------
# trace sub-report: flight-recorder overhead + paper-anchored attribution
# ---------------------------------------------------------------------------

def _run_traced_skew(reader, sample_rate: float):
    """The fairness elephant/mice workload with the flight recorder at
    `sample_rate`; returns (service, results, wall_s)."""
    import time as _time

    rg_cost = FAIR_RG_ROWS * 4 * 2
    t0 = _time.perf_counter()
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        policy=StaticPolicy("raw"), scheduler="wfq",
        tick_bytes=int(rg_cost * 1.5),
        trace_sample_rate=sample_rate, trace_capacity=16,
    )
    tickets = [svc.submit("elephant", reader, _elephant_plan())]
    tickets += [svc.submit(f"mouse{i}", reader, _mouse_plan(d))
                for i, d in enumerate((300, 900, 1500))]
    svc.drain()
    wall = _time.perf_counter() - t0
    return svc, tickets, wall


def run_trace(sf: float = 0.1) -> dict:
    import numpy as np

    reader = fairness_setup(sf)
    _run_traced_skew(reader, 0.0)  # warmup: jit compiles + file cache
    svc_off, res_off, wall_off = _run_traced_skew(reader, 0.0)
    svc_on, res_on, wall_on = _run_traced_skew(reader, 1.0)

    bit_identical = all(
        a.status == b.status == "done"
        and int(a.result.count) == int(b.result.count)
        and all(np.array_equal(np.asarray(a.result.columns[c]),
                               np.asarray(b.result.columns[c]))
                for c in a.result.columns)
        for a, b in zip(res_on, res_off)
    )
    overhead = wall_on / max(wall_off, 1e-9)

    rep = svc_on.telemetry.trace_report()
    pct = rep["stage_pct"]
    chrome_events = len(svc_on.tracer.recorder.to_chrome_trace()["traceEvents"])
    row("service.trace.overhead", wall_on,
        f"wall_off_s={wall_off:.3f};ratio={overhead:.3f}x;"
        f"bit_identical={bit_identical};"
        f"recorded={rep['recorded']}/{rep['completed']};"
        f"chrome_events={chrome_events}")
    row("service.trace.stages", 0.0,
        f"decode={pct['decode']:.1f}%;filter={pct['filter']:.1f}%;"
        f"rest={pct['rest']:.1f}%"
        f" (paper fig2: decode={PAPER_FIG2_PCT['decode']:.0f}%"
        f"/filter={PAPER_FIG2_PCT['filter']:.0f}%)")
    return {
        "wall_traced_s": wall_on,
        "wall_untraced_s": wall_off,
        "overhead_ratio": overhead,
        "bit_identical": bit_identical,
        "recorded": rep["recorded"],
        "completed": rep["completed"],
        "chrome_events": chrome_events,
        "decode_pct": pct["decode"],
        "filter_pct": pct["filter"],
        "rest_pct": pct["rest"],
        "stage_s": rep["stage_s"],
        "paper_fig2_pct": dict(sorted(PAPER_FIG2_PCT.items())),
    }


# ---------------------------------------------------------------------------
# batchdecode sub-report: bucketed batch launches vs per-(rg, column) loop
# ---------------------------------------------------------------------------

BATCH_COLS = ["l_extendedprice", "l_discount", "l_tax", "l_quantity"]


def batchdecode_setup(sf: float = 0.1):
    """A lineitem with SMALL row groups so a whole-table scan spans >= 32
    groups — the dispatch-amplification regime the batch path collapses."""
    d = os.path.join(DATA_DIR, f"tpch_batch_sf{sf}")
    if not os.path.exists(os.path.join(d, "lineitem.lake")):
        tpch.write_tables(d, sf=sf, seed=0, sorted_data=True,
                          row_group_size=1024)
    return LakeReader(os.path.join(d, "lineitem.lake"))


def _run_batchmode(reader, batch_decode: bool, cost_model,
                   tick_bytes=None) -> dict:
    from repro.kernels import ops

    def once():
        svc = DatapathService(
            engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
            policy=StaticPolicy("raw"), batch_decode=batch_decode,
            cost_model=cost_model, tick_bytes=tick_bytes,
        )
        svc.submit("t", reader, ScanPlan("lineitem", list(BATCH_COLS)))
        svc.drain()
        return svc

    once()  # warmup: jit compiles + file cache
    d0 = ops.dispatch_count()
    import time as _time
    t0 = _time.perf_counter()
    svc = once()
    wall = _time.perf_counter() - t0
    c = svc.telemetry.counters
    return {
        "dispatches": ops.dispatch_count() - d0,
        "wall_s": wall,
        "decode_launches": int(c.get("decode_launches", 0)),
        "batch_slices": int(c.get("batch_slices", 0)),
        "sim_serial_s": float(c.get("sim_pipe_serial_s",
                                    c.get("sim_fetch_serial_s", 0.0))),
        "sim_overlapped_s": float(c.get("sim_pipe_overlapped_s",
                                        c.get("sim_fetch_overlapped_s", 0.0))),
        "sim_saved_s": float(c.get("sim_pipe_saved_s",
                                   c.get("sim_fetch_saved_s", 0.0))),
    }


def run_batchdecode(sf: float = 0.1) -> dict:
    reader = batchdecode_setup(sf)
    assert reader.n_row_groups >= 32, reader.n_row_groups
    # calibrated-ish model (fast smoke) so the per-launch overhead term is
    # real and the slice-level pipeline numbers carry it
    cm = CostModel.calibrate(backend="ref", n=1 << 16, repeats=1)

    seq = _run_batchmode(reader, False, cm)
    bat = _run_batchmode(reader, True, cm)
    ratio = seq["dispatches"] / max(bat["dispatches"], 1)
    speedup = seq["wall_s"] / max(bat["wall_s"], 1e-9)
    row("service.batchdecode", bat["wall_s"],
        f"rgs={reader.n_row_groups};cols={len(BATCH_COLS)};"
        f"dispatch_seq={seq['dispatches']};dispatch_batch={bat['dispatches']}"
        f" ({ratio:.1f}x fewer);"
        f"wall_seq_s={seq['wall_s']:.3f};wall_batch_s={bat['wall_s']:.3f}"
        f" ({speedup:.2f}x)")

    # sliced dispatch: tick_bytes carves the scan into multiple WFQ slices
    # so the NEXT slice's fetch overlaps THIS slice's bucketed batch decode
    slice_bytes = reader.n_rows * 4 * len(BATCH_COLS) // 6
    seq_p = _run_batchmode(reader, False, cm, tick_bytes=slice_bytes)
    bat_p = _run_batchmode(reader, True, cm, tick_bytes=slice_bytes)
    row("service.batchdecode.pipeline", 0.0,
        f"slices={bat_p['batch_slices']};"
        f"pipe_overlapped_s={bat_p['sim_overlapped_s']:.5f}"
        f"/serial={bat_p['sim_serial_s']:.5f}"
        f" (fetch_hidden_s={bat_p['sim_saved_s']:.5f});"
        f"seq_overlapped_s={seq_p['sim_overlapped_s']:.5f}")
    return {
        "row_groups": reader.n_row_groups,
        "columns": len(BATCH_COLS),
        "dispatch_sequential": seq["dispatches"],
        "dispatch_batched": bat["dispatches"],
        "dispatch_ratio": ratio,
        "wall_sequential_s": seq["wall_s"],
        "wall_batched_s": bat["wall_s"],
        "wall_speedup": speedup,
        "decode_launches_sequential": seq["decode_launches"],
        "decode_launches_batched": bat["decode_launches"],
        "launch_overhead_s": cm.launch_overhead_s,
        "pipeline": {
            "batch_slices": bat_p["batch_slices"],
            "sim_serial_s": bat_p["sim_serial_s"],
            "sim_overlapped_s": bat_p["sim_overlapped_s"],
            "sim_saved_s": bat_p["sim_saved_s"],
            "sim_overlapped_sequential_s": seq_p["sim_overlapped_s"],
        },
    }


# Pre-rewrite decode-core rates: BENCH_service.json point 5 (c07f74a),
# the last calibration before the RLE/DELTA/DICT core rewrite.  The
# roofline row measures today's cores against this fixed anchor so the
# speedup claim survives future bench points shifting the history.
PRE_REWRITE_RATES_GBPS = {
    "rle": 0.004586833545906182,
    "delta": 0.01498013821972042,
    "dict": 0.04571737105787406,
    "bitpack": 0.0693417894320781,
}


def run_kernel_roofline() -> dict:
    """Rewritten-core rates vs the pre-rewrite anchor, plus the two-size
    ladder's pad-waste bytes against pow2 bucketing (launch counts are
    identical by construction — one dispatch per batch call either way —
    so pad bytes are the whole cost difference)."""
    from repro.kernels import ops
    from repro.lakeformat.encodings import PACK_BLOCK

    cm = CostModel.calibrate(backend="ref", n=1 << 16, repeats=1)
    speedup = {
        enc: cm.rates.get(enc, 0.0) / old
        for enc, old in PRE_REWRITE_RATES_GBPS.items()
    }
    # analytic pad sweep over the realistic multi-row-group range
    # (1..64 blocks per bucket), int32 PACK_BLOCK payloads
    blk_bytes = PACK_BLOCK * 4
    pad_ladder = sum(
        (ops.bucket_blocks(n, mode="ladder") - n) * blk_bytes
        for n in range(1, 65)
    )
    pad_pow2 = sum(
        (ops.bucket_blocks(n, mode="pow2") - n) * blk_bytes
        for n in range(1, 65)
    )
    rates_fmt = ";".join(
        f"{e}={cm.rates.get(e, 0.0):.4f}/{PRE_REWRITE_RATES_GBPS[e]:.4f}"
        f" ({speedup[e]:.1f}x)"
        for e in sorted(PRE_REWRITE_RATES_GBPS)
    )
    row("service.kernels.roofline", 0.0,
        f"source={cm.source};backend={cm.backend};"
        f"rates_new/old_gbps:{rates_fmt};"
        f"pad_bytes_ladder={pad_ladder};pad_bytes_pow2={pad_pow2}"
        f" ({pad_pow2 / max(pad_ladder, 1):.2f}x)")
    return {
        "source": cm.source,
        "backend": cm.backend,
        "rates_gbps": {e: cm.rates.get(e, 0.0)
                       for e in sorted(PRE_REWRITE_RATES_GBPS)},
        "pre_rewrite_rates_gbps": dict(PRE_REWRITE_RATES_GBPS),
        "speedup": speedup,
        "launch_overhead_s": cm.launch_overhead_s,
        "pad_bytes_ladder": pad_ladder,
        "pad_bytes_pow2": pad_pow2,
        "pad_bytes_ratio": pad_pow2 / max(pad_ladder, 1),
    }


# ---------------------------------------------------------------------------
# fabric sub-report: pod-sharded fleet — scaling, peer fetch, fairness, drain
# ---------------------------------------------------------------------------

FABRIC_RG_ROWS = 2048  # small groups so every fleet size splits the table


def fabric_setup(sf: float = 0.1):
    d = os.path.join(DATA_DIR, f"tpch_fabric_sf{sf}")
    if not os.path.exists(os.path.join(d, "lineitem.lake")):
        tpch.write_tables(d, sf=sf, seed=0, sorted_data=True,
                          row_group_size=FABRIC_RG_ROWS)
    return LakeReader(os.path.join(d, "lineitem.lake"))


def _fabric_busy_s(fab) -> dict:
    """Per-pod occupancy in SIMULATED seconds — the same scheduled +
    reconciled + retention currency the WFQ clocks charge.  Fleet
    makespan is the max (pods run concurrently in real deployments even
    though the bench ticks them serially)."""
    return {
        pid: (sum(fab.pods[pid].telemetry.tenant_sched_seconds.values())
              + sum(fab.pods[pid].telemetry.tenant_recon_seconds.values())
              + sum(fab.pods[pid].telemetry.tenant_retained_seconds.values()))
        for pid in fab.live_pods
    }


def _run_fleet(reader, n_pods: int) -> dict:
    from repro.datapath import ScanFabric

    fab = ScanFabric(n_pods=n_pods, policy=StaticPolicy("raw"))
    plans = [ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]),
             ScanPlan("lineitem", ["l_discount", "l_tax"]),
             ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_quantity", "le", 25))]
    for t, plan in enumerate(plans):
        fab.submit(f"tenant{t}", reader, plan)
    fab.drain()
    busy = _fabric_busy_s(fab)
    makespan = max(busy.values()) if busy else 0.0
    decoded = sum(sum(fab.pods[p].telemetry.tenant_decoded_bytes.values())
                  for p in fab.live_pods)
    return {
        "busy_s": busy,
        "makespan_s": makespan,
        "decoded_bytes": int(decoded),
        "throughput_gbps": decoded / max(makespan, 1e-12) / 1e9,
    }


def _run_fabric_peer(reader) -> dict:
    """Scale-out reuse: a 2-pod fleet warms its decoded/encoded tiers, a
    third pod joins and steals arcs — its cold misses pull warm blocks
    from the old owners over the inter-pod hop instead of re-fetching
    storage, and the hop is billed into the tenant's WFQ clock."""
    from repro.datapath import ScanFabric

    cm = CostModel()
    fab = ScanFabric(n_pods=2, policy=StaticPolicy("preloaded"),
                     cost_model=cm)
    plan = ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
                    Cmp("l_quantity", "le", 25))
    fab.scan(reader, plan)  # warm the original owners
    new_pid = fab.add_pod()
    res = fab.scan(reader, plan)  # stolen arcs peer-fetch
    store = fab.pods[new_pid].store
    peer_bytes = int(store.peer_hit_bytes)
    peer_s = float(store.peer_hit_seconds)
    # storage equivalent pays the round trip PER BLOCK, same as the peer
    # hop does (fetch_seconds is affine, so hits * latency + bytes / bw
    # is the exact per-block sum)
    lm = cm.link_model()
    storage_equiv_s = (store.peer_hits * lm.latency_us * 1e-6
                       + peer_bytes / (lm.bandwidth_gbps * 1e9))
    return {
        "peer_hits": int(store.peer_hits),
        "peer_bytes": peer_bytes,
        "peer_s": peer_s,
        "storage_equiv_s": storage_equiv_s,
        "hop_speedup": storage_equiv_s / max(peer_s, 1e-12),
        "billed_bytes": int(res.stats.peer_bytes),
        "billed_to_wfq": float(
            fab.pods[new_pid].telemetry.tenant_peer_seconds.get("default", 0.0)
        ) > 0.0,
    }


def _run_fabric_skew(reader, relevel: bool) -> dict:
    """1 elephant / 3 mice across a 2-pod fleet.  Without the fleet-level
    re-level, each pod's WFQ clock sees only LOCAL consumption, so a
    tenant spread over N pods gets up to N fresh clocks; the re-level
    charges queued tenants their foreign occupancy every tick."""
    from repro.datapath import ScanFabric, jain_index

    fab = ScanFabric(n_pods=2, policy=StaticPolicy("raw"),
                     tick_bytes=int(FABRIC_RG_ROWS * 4 * 2 * 1.5),
                     reconcile_fairness=relevel)
    fab.submit("elephant", reader,
               ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]))
    fab.submit("elephant", reader,
               ScanPlan("lineitem", ["l_discount", "l_tax"]))
    mice = [fab.submit(f"mouse{i}", reader,
                       ScanPlan("lineitem", ["l_extendedprice"],
                                Cmp("l_shipdate", "between", (d, d + 200))))
            for i, d in enumerate((300, 900, 1500))]
    done_tick = {}
    ticks = 0
    while fab.active:
        ticks += 1
        fab.tick()
        for i, m in enumerate(mice):
            if m.status == "done" and i not in done_tick:
                done_tick[i] = ticks
    occ = {}
    for pid in fab.live_pods:
        tel = fab.pods[pid].telemetry
        for t in tel.known_tenants():
            occ[t] = (occ.get(t, 0.0)
                      + tel.tenant_decoded_bytes.get(t, 0.0)
                      + tel.tenant_retained_bytes.get(t, 0.0))
    charged = sum(fab.pods[p].telemetry.counters.get("fleet_vtime_seconds", 0.0)
                  for p in fab.live_pods)
    return {
        "jain": jain_index(list(occ.values())),
        "tenant_bytes": {k: int(v) for k, v in sorted(occ.items())},
        "mice_p99_ticks": max(done_tick.values()) if done_tick else 0,
        "total_ticks": ticks,
        "fleet_vtime_charged_s": charged,
        # the mechanism itself: with the re-level each pod's elephant
        # clock carries the elephant's FLEET-wide consumption, not just
        # the local slice
        "elephant_vtime_s": max(
            fab.pods[p]._vtime.get("elephant", 0.0) for p in fab.live_pods
        ),
    }


def _run_fabric_drain(reader) -> dict:
    """Kill one of three pods mid-scan; the fabric re-partitions only the
    dead pod's uncollected sub-scans and the merged result must still be
    bit-identical to the single-node engine."""
    import numpy as np

    from repro.datapath import ScanFabric

    plan = ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
                    Cmp("l_quantity", "le", 25))
    want = DatapathEngine(backend="ref").scan(reader, plan)
    fab = ScanFabric(n_pods=3, policy=StaticPolicy("raw"),
                     tick_bytes=1 << 16)
    t = fab.submit("t0", reader, plan)
    fab.tick()
    victims = [s.pod_id for s in t.subs.values() if s.ticket.status == "queued"]
    if victims:
        fab.fail_pod(victims[0])
    fab.drain()
    identical = (
        int(t.result.count) == int(want.count)
        and np.array_equal(np.asarray(t.result.mask), np.asarray(want.mask))
        and all(np.array_equal(np.asarray(t.result.columns[c]),
                               np.asarray(want.columns[c]))
                for c in want.columns)
    )
    d = fab.report()["drains"]
    return {
        "killed": victims[0] if victims else None,
        "reassigned": d[-1]["reassigned"] if d else 0,
        "replayed": d[-1]["replayed"] if d else 0,
        "replays": t.replays,
        "bit_identical": bool(identical),
    }


def run_fabric(sf: float = 0.1) -> dict:
    reader = fabric_setup(sf)
    scaling = {n: _run_fleet(reader, n) for n in (1, 2, 4)}
    base = scaling[1]["throughput_gbps"]
    row("service.fabric.scaling", 0.0,
        ";".join(f"pods{n}={s['throughput_gbps']:.3f}GBps"
                 f" ({s['throughput_gbps'] / max(base, 1e-12):.2f}x)"
                 for n, s in sorted(scaling.items()))
        + f";rgs={reader.n_row_groups}")

    peer = _run_fabric_peer(reader)
    row("service.fabric.peer", peer["peer_s"],
        f"peer_bytes={peer['peer_bytes']};hits={peer['peer_hits']};"
        f"peer_s={peer['peer_s']:.6f}"
        f"/storage_equiv_s={peer['storage_equiv_s']:.6f}"
        f" ({peer['hop_speedup']:.2f}x);"
        f"billed_to_wfq={peer['billed_to_wfq']}")

    skew_on = _run_fabric_skew(reader, relevel=True)
    skew_off = _run_fabric_skew(reader, relevel=False)
    row("service.fabric.fairness", 0.0,
        f"mice_p99_ticks_relevel={skew_on['mice_p99_ticks']}"
        f"/local_clocks={skew_off['mice_p99_ticks']};"
        f"jain={skew_on['jain']:.4f};"
        f"elephant_vtime_relevel={skew_on['elephant_vtime_s']:.6f}"
        f"/local={skew_off['elephant_vtime_s']:.6f};"
        f"fleet_vtime_charged_s={skew_on['fleet_vtime_charged_s']:.6f}")

    drain = _run_fabric_drain(reader)
    row("service.fabric.drain", 0.0,
        f"killed={drain['killed']};reassigned={drain['reassigned']};"
        f"replayed={drain['replayed']};bit_identical={drain['bit_identical']}")

    return {
        "scaling": {f"pods{n}": s for n, s in sorted(scaling.items())},
        "throughput_speedup_4pod": scaling[4]["throughput_gbps"] / max(base, 1e-12),
        "peer": peer,
        "fairness_relevel": skew_on,
        "fairness_local_clocks": skew_off,
        "drain": drain,
    }


# ---------------------------------------------------------------------------
# faults sub-report: fault-free vs 1%/5% transient-error A/B — correctness
# (bit-identical, zero hangs), bounded p99 inflation, hedge tail win, shed
# rate under breaker-open pressure (DESIGN.md §17)
# ---------------------------------------------------------------------------

FAULT_MAX_TICKS = 4000  # hang guard for the bench drain loop


def _faults_workload(reader):
    return [ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
                     Cmp("l_quantity", "le", 25)),
            ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                     Cmp("l_shipdate", "between", (365, 729))),
            ScanPlan("lineitem", ["l_discount", "l_tax"]),
            ScanPlan("lineitem", ["l_quantity"],
                     Cmp("l_quantity", "le", 3))]


def _run_faulted(reader, rate: float, seed: int = 0):
    """One chaos pass: 4 tenants under a transient-error + latency-spike
    schedule at `rate`, hedged reads on.  Returns results + the metrics
    the A/B compares.  `hung` counts requests that never reached a
    terminal state inside the tick guard — the bar is zero."""
    from repro.datapath import FaultPlan, RetryPolicy

    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        fault_plan=FaultPlan(seed=seed, transient_rate=rate,
                             spike_rate=rate, spike_s=2e-3),
        retry_policy=RetryPolicy(max_attempts=10, hedge_after_s=1e-3),
    )
    plans = _faults_workload(reader)
    t0 = time.perf_counter()
    tickets = [svc.submit(f"tenant{t}", reader, p)
               for t, p in enumerate(plans)]
    for _ in range(FAULT_MAX_TICKS):
        svc.tick()
        if not svc.queue:
            break
    wall = time.perf_counter() - t0
    hung = sum(tk.status == "queued" for tk in tickets)
    results = [svc.result(tk) for tk in tickets if tk.status == "done"]
    snap = svc.telemetry.snapshot()
    f = snap["faults"]
    p99s = [v["p99_s"] for v in snap["tenants"].values()]
    return {
        "results": results,
        "wall_s": wall,
        "hung": int(hung),
        "p99_s": max(p99s) if p99s else 0.0,
        "retries": int(f["transient_errors"]),
        "retry_successes": int(f["retry_successes"]),
        "retries_exhausted": int(f["retries_exhausted"]),
        "hedged": int(f["hedged_fetches"]),
        "hedge_wins": int(f["hedge_wins"]),
        "hedge_saved_s": float(f["fault_seconds"].get("hedge_saved", 0.0)),
        "fault_wait_s": float(
            sum(f["tenant_fault_seconds"].values())),
    }


def _run_fault_shed(reader) -> dict:
    """Breaker-open pressure: a permanently failing storage target behind
    a small queue — the breaker trips, admission degrades, and past the
    shed threshold rejects with typed Overloaded instead of collapsing."""
    from repro.datapath import FaultPlan, Overloaded, RetryPolicy

    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        max_queue_depth=4,
        fault_plan=FaultPlan(transient_rate=1.0, fail_forever=True),
        retry_policy=RetryPolicy(max_attempts=5),
    )
    plan = _faults_workload(reader)[0]
    submitted = shed = other_reject = 0
    for i in range(16):
        try:
            svc.submit("t0", reader, plan)
            submitted += 1
        except Overloaded:
            shed += 1
        except Exception:  # noqa: BLE001 — QueueFull etc., also typed
            other_reject += 1
        if i % 4 == 3:
            svc.tick()
    for _ in range(FAULT_MAX_TICKS):
        if not svc.queue:
            break
        svc.tick()
    br = svc.telemetry.snapshot()["faults"]
    return {
        "submitted": submitted,
        "shed": shed,
        "other_rejected": other_reject,
        "shed_rate": shed / max(shed + submitted + other_reject, 1),
        "breaker_trips": int(br["breaker_trips"]),
        "degraded_admits": int(br["breaker_degraded_admits"]),
    }


def run_faults(sf: float = 0.1) -> dict:
    reader = fabric_setup(sf)
    _run_faulted(reader, 0.0)  # warmup: jit compilation out of the A/B
    base = _run_faulted(reader, 0.0)
    runs = {"rate1pct": _run_faulted(reader, 0.01),
            "rate5pct": _run_faulted(reader, 0.05)}

    def _identical(a, b):
        import numpy as np
        if len(a) != len(b):
            return False
        return all(
            int(x.count) == int(y.count)
            and np.array_equal(np.asarray(x.mask), np.asarray(y.mask))
            and all(np.array_equal(np.asarray(x.columns[c]),
                                   np.asarray(y.columns[c]))
                    for c in y.columns)
            for x, y in zip(a, b))

    row("service.faults.baseline", base["wall_s"],
        f"p99_ms={base['p99_s'] * 1e3:.3f};hung={base['hung']}")
    report = {}
    for name, r in runs.items():
        identical = _identical(r["results"], base["results"])
        inflation = r["p99_s"] / max(base["p99_s"], 1e-12)
        row(f"service.faults.{name}", r["wall_s"],
            f"p99_ms={r['p99_s'] * 1e3:.3f};p99_inflation={inflation:.2f}x;"
            f"retries={r['retries']};recovered={r['retry_successes']};"
            f"exhausted={r['retries_exhausted']};"
            f"fault_wait_s={r['fault_wait_s']:.6f};"
            f"identical={identical};hung={r['hung']}")
        report[name] = {k: v for k, v in r.items() if k != "results"}
        report[name]["identical"] = identical
        report[name]["p99_inflation"] = inflation

    hedge = runs["rate5pct"]
    row("service.faults.hedge", 0.0,
        f"hedged={hedge['hedged']};wins={hedge['hedge_wins']};"
        f"tail_saved_s={hedge['hedge_saved_s']:.6f}")

    shed = _run_fault_shed(reader)
    row("service.faults.shed", 0.0,
        f"submitted={shed['submitted']};shed={shed['shed']};"
        f"shed_rate={shed['shed_rate']:.2f};trips={shed['breaker_trips']};"
        f"typed=Overloaded")

    report["baseline"] = {k: v for k, v in base.items() if k != "results"}
    report["hedge"] = {"hedged": hedge["hedged"],
                       "wins": hedge["hedge_wins"],
                       "tail_saved_s": hedge["hedge_saved_s"]}
    report["shed"] = shed
    return report


def run_pushdown(sf: float = 0.1) -> dict:
    """Fused operator pushdown (DESIGN.md §16) vs scan-then-aggregate on
    a grouped revenue sum: the fused path DMAs only the (n_groups,)
    accumulator set where the post-scan path ships the filtered value +
    group columns and mask across the hop and aggregates on the consumer
    side with the SAME kernel — result-DMA bytes are the paper's
    PCIe-hop currency, and because both paths launch the same decode
    buckets plus one aggregate kernel, the dispatch count must not
    grow."""
    import time as _time

    import numpy as np

    from repro.core import agg
    from repro.core.plan import AggSpec
    from repro.kernels import ops

    from repro.lakeformat.encodings import PACK_BLOCK

    reader = setup(sf)["lineitem"]
    pred = Cmp("l_shipdate", "between", (365, 729))
    aplan = ScanPlan(
        "lineitem", [], pred,
        aggregates=(AggSpec("sum", "l_extendedprice"), AggSpec("count")),
        group_by="l_returnflag",
    )
    rplan = ScanPlan("lineitem", ["l_extendedprice", "l_returnflag"], pred)
    eng = DatapathEngine(backend="ref")
    n_groups = len(reader.string_dicts["l_returnflag"])

    def fused():
        return eng.scan(reader, aplan, batched=True)

    def post_scan():
        """Same aggregation math and launch count, but DOWNSTREAM of the
        result DMA: the scan ships filtered value + group columns + mask,
        then one grouped_agg_batch launch reduces them consumer-side with
        the canonical per-row-group fold (so the answer is bit-identical
        and the only difference is WHERE the hop sits)."""
        res = eng.scan(reader, rplan, batched=True)
        L = int(np.asarray(res.mask).shape[0])
        nb = L // PACK_BLOCK
        vals = np.asarray(res.columns["l_extendedprice"]).reshape(nb, PACK_BLOCK)
        gids = np.asarray(res.columns["l_returnflag"]).astype(np.int32).reshape(nb, PACK_BLOCK)
        m2 = np.asarray(res.mask).astype(np.int32).reshape(nb, PACK_BLOCK)
        planes = ops.grouped_agg_batch(vals, gids, m2, n_groups, backend="ref")
        from repro.core.engine import padded_rows
        from repro.core.zonemap import prune_row_groups
        from repro.core.plan import bind_expr
        rgs = prune_row_groups(reader, bind_expr(pred, reader))
        segs = [padded_rows(reader.row_group_meta(rg)["n"]) // PACK_BLOCK
                for rg in rgs]
        parts, off = [], 0
        for seg in segs:
            parts.append(agg.fold_blocks(
                tuple(np.asarray(p)[off:off + seg] for p in planes), True))
            off += seg
        merged = {"l_extendedprice": agg.merge_partials(parts)}
        return res, agg.finalize(aplan.aggregates, merged, n_groups)

    fused(); post_scan()  # warmup: jit compiles + file cache
    d0 = ops.dispatch_count()
    t0 = _time.perf_counter()
    fres = fused()
    t_fused = _time.perf_counter() - t0
    d_fused = ops.dispatch_count() - d0

    d0 = ops.dispatch_count()
    t0 = _time.perf_counter()
    rres, host_aggs = post_scan()
    t_post = _time.perf_counter() - t0
    d_post = ops.dispatch_count() - d0

    # the comparison is only meaningful if both answer identically
    identical = all(
        np.array_equal(np.asarray(fres.aggregates[k]), host_aggs[k])
        for k in host_aggs)
    dma_ratio = rres.stats.result_bytes / max(fres.stats.result_bytes, 1)
    row("service.pushdown", t_fused,
        f"dma_fused={fres.stats.result_bytes}"
        f"/post_scan={rres.stats.result_bytes} ({dma_ratio:.0f}x less);"
        f"dispatch_fused={d_fused}/post_scan={d_post};"
        f"wall_fused_s={t_fused:.4f}/post_scan_s={t_post:.4f};"
        f"bit_identical={identical}")
    return {
        "result_bytes_fused": int(fres.stats.result_bytes),
        "result_bytes_post_scan": int(rres.stats.result_bytes),
        "dma_reduction": float(dma_ratio),
        "dispatch_fused": d_fused,
        "dispatch_post_scan": d_post,
        "wall_fused_s": t_fused,
        "wall_post_scan_s": t_post,
        "bit_identical": bool(identical),
    }


def run(sf: float = 0.1, n_tenants: int = 6) -> dict:
    readers = setup(sf)
    plans = tenant_plans(n_tenants)

    t_ind = timed(lambda: _run_independent(readers, plans))
    ind_fresh = _run_independent(readers, plans)

    t_svc = timed(lambda: _run_service(readers, plans))
    svc = _run_service(readers, plans)
    counters = svc.telemetry.counters
    svc_fresh = int(counters["decoded_bytes_fresh"])
    saved = int(counters["decoded_bytes_saved"])

    row("service.independent", t_ind, f"fresh_decoded_bytes={ind_fresh}")
    row("service.coalesced", t_svc,
        f"fresh_decoded_bytes={svc_fresh};pool_saved_bytes={saved}")
    ratio = ind_fresh / max(svc_fresh, 1)
    row("service.savings", 0.0,
        f"decode_ratio={ratio:.2f}x;tenants={n_tenants};speedup={t_ind/t_svc:.2f}x")

    # adaptive policy on a recurring mix: all six queries, three rounds
    svc_a = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        batch_per_tick=8,
        policy=AdaptiveOffloadPolicy(),
    )

    def mix(service=svc_a):
        for name in QUERIES:
            run_via_service(service, name, readers, tenant=name)

    t_first = timed(mix, repeats=1, warmup=0)
    t_steady = timed(mix, repeats=3, warmup=0)
    decisions = dict(svc_a.policy.decisions)
    row("service.adaptive.first", t_first, f"decisions={decisions}")
    row("service.adaptive.steady", t_steady,
        f"speedup={t_first/max(t_steady,1e-9):.2f}x;"
        f"prefiltered_hits={int(svc_a.telemetry.counters.get('prefiltered_hits', 0))}")
    snap = svc_a.telemetry.snapshot()
    p99s = {t: round(v["p99_s"] * 1e3, 3) for t, v in snap["tenants"].items()}
    row("service.latency", snap["tick_p50_s"],
        f"tick_p99_ms={snap['tick_p99_s']*1e3:.2f};tenant_p99_ms={p99s}")
    row("service.netsim", 0.0,
        f"fetch_serial_s={counters['sim_fetch_serial_s']:.4f};"
        f"fetch_overlapped_s={counters['sim_fetch_overlapped_s']:.4f}")

    fairness = run_fairness(sf)
    costmodel = run_costmodel(sf)
    blockstore = run_blockstore(sf)
    batchdecode = run_batchdecode(sf)
    pushdown = run_pushdown(sf)
    tracing = run_trace(sf)
    kernels = run_kernel_roofline()
    fabric = run_fabric(sf)
    faults = run_faults(sf)

    return {
        "fabric": fabric,
        "faults": faults,
        "pushdown": pushdown,
        "fairness": fairness,
        "costmodel": costmodel,
        "blockstore": blockstore,
        "batchdecode": batchdecode,
        "trace": tracing,
        "kernels": kernels,
        "n_tenants": n_tenants,
        "independent_fresh_decoded_bytes": ind_fresh,
        "service_fresh_decoded_bytes": svc_fresh,
        "pool_saved_bytes": saved,
        "decode_ratio": ratio,
        "t_independent_s": t_ind,
        "t_service_s": t_svc,
        "adaptive_first_s": t_first,
        "adaptive_steady_s": t_steady,
        "adaptive_decisions": decisions,
        "tick_p50_s": snap["tick_p50_s"],
        "tick_p99_s": snap["tick_p99_s"],
        "sim_fetch_serial_s": counters["sim_fetch_serial_s"],
        "sim_fetch_overlapped_s": counters["sim_fetch_overlapped_s"],
        "sim_fetch_saved_s": counters["sim_fetch_saved_s"],
    }


if __name__ == "__main__":
    run()
