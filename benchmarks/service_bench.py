"""Multi-tenant service benchmark: shared-scan coalescing vs N independent
engines, plus the adaptive offload policy on a recurring workload.

The workload is N tenants running TPC-H-style revenue scans over the same
lineitem table with per-tenant date windows (overlapping, as concurrent
dashboards do).  Independently, every tenant decodes every hot column
itself; through the service, one tick's DecodePool decodes each
(row group, column) once and feeds all N predicates — so fresh decoded
bytes stay near-flat while tenant count grows.

Reported rows:
    service.independent   N direct DatapathEngine.scan() calls
    service.coalesced     same scans through one DatapathService tick
    service.savings       fresh-decoded-byte ratio + wall speedup
    service.adaptive      repeated query mix under the adaptive policy
"""

from __future__ import annotations

from repro.core import BlockCache, DatapathEngine
from repro.core.plan import Cmp, ScanPlan
from repro.core.queries import QUERIES, run_via_service
from repro.datapath import AdaptiveOffloadPolicy, DatapathService, StaticPolicy

from benchmarks.breakdown import setup
from benchmarks.common import row, timed


def tenant_plans(n_tenants: int):
    """Per-tenant revenue scans: same hot columns, shifted date windows."""
    plans = []
    for t in range(n_tenants):
        start = 200 + 45 * t  # overlapping year-long windows
        plans.append(
            ScanPlan(
                "lineitem",
                ["l_extendedprice", "l_discount"],
                Cmp("l_shipdate", "between", (start, start + 364)),
            )
        )
    return plans


def _run_independent(readers, plans):
    """One fresh raw engine per tenant — the seed library-call model."""
    fresh = 0
    for plan in plans:
        eng = DatapathEngine(backend="ref", offload="raw")
        res = eng.scan(readers["lineitem"], plan)
        fresh += res.stats.decoded_bytes_fresh
    return fresh


def _run_service(readers, plans):
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        batch_per_tick=len(plans),
        policy=StaticPolicy("raw"),  # isolate coalescing from caching
    )
    for t, plan in enumerate(plans):
        svc.submit(f"tenant{t}", readers["lineitem"], plan)
    svc.drain()
    return svc


def run(sf: float = 0.1, n_tenants: int = 6) -> dict:
    readers = setup(sf)
    plans = tenant_plans(n_tenants)

    t_ind = timed(lambda: _run_independent(readers, plans))
    ind_fresh = _run_independent(readers, plans)

    t_svc = timed(lambda: _run_service(readers, plans))
    svc = _run_service(readers, plans)
    counters = svc.telemetry.counters
    svc_fresh = int(counters["decoded_bytes_fresh"])
    saved = int(counters["decoded_bytes_saved"])

    row("service.independent", t_ind, f"fresh_decoded_bytes={ind_fresh}")
    row("service.coalesced", t_svc,
        f"fresh_decoded_bytes={svc_fresh};pool_saved_bytes={saved}")
    ratio = ind_fresh / max(svc_fresh, 1)
    row("service.savings", 0.0,
        f"decode_ratio={ratio:.2f}x;tenants={n_tenants};speedup={t_ind/t_svc:.2f}x")

    # adaptive policy on a recurring mix: all six queries, three rounds
    svc_a = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        batch_per_tick=8,
        policy=AdaptiveOffloadPolicy(),
    )

    def mix(service=svc_a):
        for name in QUERIES:
            run_via_service(service, name, readers, tenant=name)

    t_first = timed(mix, repeats=1, warmup=0)
    t_steady = timed(mix, repeats=3, warmup=0)
    decisions = dict(svc_a.policy.decisions)
    row("service.adaptive.first", t_first, f"decisions={decisions}")
    row("service.adaptive.steady", t_steady,
        f"speedup={t_first/max(t_steady,1e-9):.2f}x;"
        f"prefiltered_hits={int(svc_a.telemetry.counters.get('prefiltered_hits', 0))}")
    snap = svc_a.telemetry.snapshot()
    p99s = {t: round(v["p99_s"] * 1e3, 3) for t, v in snap["tenants"].items()}
    row("service.latency", snap["tick_p50_s"],
        f"tick_p99_ms={snap['tick_p99_s']*1e3:.2f};tenant_p99_ms={p99s}")
    row("service.netsim", 0.0,
        f"fetch_serial_s={counters['sim_fetch_serial_s']:.4f};"
        f"fetch_overlapped_s={counters['sim_fetch_overlapped_s']:.4f}")

    return {
        "n_tenants": n_tenants,
        "independent_fresh_decoded_bytes": ind_fresh,
        "service_fresh_decoded_bytes": svc_fresh,
        "pool_saved_bytes": saved,
        "decode_ratio": ratio,
        "t_independent_s": t_ind,
        "t_service_s": t_svc,
        "adaptive_first_s": t_first,
        "adaptive_steady_s": t_steady,
        "adaptive_decisions": decisions,
        "tick_p50_s": snap["tick_p50_s"],
        "tick_p99_s": snap["tick_p99_s"],
        "sim_fetch_serial_s": counters["sim_fetch_serial_s"],
        "sim_fetch_overlapped_s": counters["sim_fetch_overlapped_s"],
        "sim_fetch_saved_s": counters["sim_fetch_saved_s"],
    }


if __name__ == "__main__":
    run()
