"""Paper Fig. 1: query throughput for Parquet-resident vs pre-loaded vs
pre-filtered data.

The paper's x-axis is thread count on a 64-core CPU; this container has
one core, so the scaling claim is reported as the *compute-equivalence
factor*: throughput(prefiltered)/throughput(raw) = how much less compute
sustains the same query rate once the datapath hides decode+filter.  The
paper's headline is 16 threads on pre-filtered beating 64 cores on
Parquet (>= 4x equivalence); we report ours on the same query mix.
"""

from __future__ import annotations

from repro.core import BlockCache, DatapathEngine
from repro.core.queries import QUERIES

from benchmarks.breakdown import setup
from benchmarks.common import row, timed


def run(sf: float = 0.2) -> dict:
    readers = setup(sf)
    results = {}
    for offload in ("raw", "preloaded", "prefiltered"):
        eng = DatapathEngine(backend="ref", offload=offload, cache=BlockCache(4 << 30))
        if offload != "raw":
            for q in QUERIES.values():
                q(eng, readers)  # warm

        def suite(e=eng):
            for q in QUERIES.values():
                q(e, readers)

        t = timed(suite, repeats=3)
        qps = len(QUERIES) / t
        results[offload] = qps
        row(f"throughput.{offload}", t / len(QUERIES), f"qps={qps:.2f}")
    eq = results["prefiltered"] / results["raw"]
    eq_pre = results["preloaded"] / results["raw"]
    row("throughput.compute_equivalence", 0.0,
        f"prefiltered/raw={eq:.1f}x;preloaded/raw={eq_pre:.1f}x;paper>=4x")
    results["equivalence"] = eq

    # fourth offload mode (DESIGN.md §16): recurring aggregate-pushdown
    # queries under 'pre-aggregated' cache the whole accumulator result —
    # a few KB answers the entire scan on repeat, without seeding the
    # decoded tier with value columns pushdown never materializes
    from repro.core.plan import AggSpec, Cmp, ScanPlan

    agg_plans = [
        ScanPlan("lineitem", [], Cmp("l_shipdate", "between", (365, 729)),
                 aggregates=(AggSpec("sum", "l_extendedprice"),
                             AggSpec("count")),
                 group_by="l_returnflag"),
        ScanPlan("lineitem", [], Cmp("l_shipdate", "between", (365, 729)),
                 aggregates=(AggSpec("sum", "l_quantity"),
                             AggSpec("min", "l_quantity"),
                             AggSpec("max", "l_quantity"))),
    ]
    li = readers["lineitem"]
    for offload in ("raw", "pre-aggregated"):
        eng = DatapathEngine(backend="ref", offload=offload,
                             cache=BlockCache(4 << 30))
        if offload != "raw":
            for p in agg_plans:
                eng.scan(li, p, batched=True)  # warm: cache accumulators

        def agg_suite(e=eng):
            for p in agg_plans:
                e.scan(li, p, batched=True)

        t = timed(agg_suite, repeats=3)
        qps = len(agg_plans) / t
        results[f"agg_{offload}"] = qps
        row(f"throughput.agg.{offload}", t / len(agg_plans), f"qps={qps:.2f}")
    agg_eq = results["agg_pre-aggregated"] / results["agg_raw"]
    row("throughput.agg.equivalence", 0.0, f"pre-aggregated/raw={agg_eq:.1f}x")
    results["agg_equivalence"] = agg_eq
    return results


if __name__ == "__main__":
    run()
