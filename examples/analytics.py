"""The paper's experiment, end to end: TPC-H-like queries over raw encoded
files in the three offload configurations of Fig. 1/2.

    PYTHONPATH=src python examples/analytics.py [--sf 0.1]
"""

import argparse
import time

from repro.core import BlockCache, DatapathEngine, tpch
from repro.core.queries import QUERIES
from repro.lakeformat.reader import LakeReader


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.1)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas", "host"])
    args = ap.parse_args()

    paths = tpch.write_tables(f"/tmp/tpch_example_{args.sf}", sf=args.sf, seed=0)
    readers = {k: LakeReader(p) for k, p in paths.items()}

    print(f"{'query':8s} {'raw':>9s} {'preloaded':>10s} {'prefiltered':>12s}  decode% filter%")
    for name, q in QUERIES.items():
        times = {}
        for offload in ("raw", "preloaded", "prefiltered"):
            eng = DatapathEngine(backend=args.backend, offload=offload,
                                 cache=BlockCache(4 << 30))
            if offload != "raw":
                q(eng, readers)  # warm cache (the datapath's prepass)
            t0 = time.perf_counter()
            q(eng, readers)
            times[offload] = time.perf_counter() - t0
        d = max(0, (times["raw"] - times["preloaded"]) / times["raw"] * 100)
        f = max(0, (times["preloaded"] - times["prefiltered"]) / times["raw"] * 100)
        print(f"{name:8s} {times['raw']*1e3:8.1f}ms {times['preloaded']*1e3:9.1f}ms "
              f"{times['prefiltered']*1e3:11.1f}ms  {d:6.0f}% {f:6.0f}%")
    print("\npaper (Fig. 2): decode ~46%, filter ~17% on average; "
          "scan-heavy queries (q6/q14/q15) dominated by both.")


if __name__ == "__main__":
    main()
