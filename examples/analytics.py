"""The paper's experiment, end to end: TPC-H-like queries over raw encoded
files in the three offload configurations of Fig. 1/2.

    PYTHONPATH=src python examples/analytics.py [--sf 0.1]
"""

import argparse
import time

from repro.core import BlockCache, DatapathEngine, tpch
from repro.core.queries import QUERIES
from repro.lakeformat.reader import LakeReader


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.1)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas", "host"])
    args = ap.parse_args()

    paths = tpch.write_tables(f"/tmp/tpch_example_{args.sf}", sf=args.sf, seed=0)
    readers = {k: LakeReader(p) for k, p in paths.items()}

    print(f"{'query':8s} {'raw':>9s} {'preloaded':>10s} {'prefiltered':>12s}  decode% filter%")
    for name, q in QUERIES.items():
        times = {}
        for offload in ("raw", "preloaded", "prefiltered"):
            eng = DatapathEngine(backend=args.backend, offload=offload,
                                 cache=BlockCache(4 << 30))
            if offload != "raw":
                q(eng, readers)  # warm cache (the datapath's prepass)
            t0 = time.perf_counter()
            q(eng, readers)
            times[offload] = time.perf_counter() - t0
        d = max(0, (times["raw"] - times["preloaded"]) / times["raw"] * 100)
        f = max(0, (times["preloaded"] - times["prefiltered"]) / times["raw"] * 100)
        print(f"{name:8s} {times['raw']*1e3:8.1f}ms {times['preloaded']*1e3:9.1f}ms "
              f"{times['prefiltered']*1e3:11.1f}ms  {d:6.0f}% {f:6.0f}%")
    print("\npaper (Fig. 2): decode ~46%, filter ~17% on average; "
          "scan-heavy queries (q6/q14/q15) dominated by both.")

    # ------------------------------------------------------------------
    # operator pushdown (DESIGN.md §16): the grouped aggregate computed
    # INSIDE the scan vs shipped rows aggregated after — same answer,
    # result DMA shrinks from the filtered columns to the accumulators
    # ------------------------------------------------------------------
    import numpy as np

    from repro.core import agg
    from repro.core.plan import AggSpec, Cmp, ScanPlan

    li = readers["lineitem"]
    pred = Cmp("l_shipdate", "between", (365, 729))
    aplan = ScanPlan(
        "lineitem", [], pred,
        aggregates=(AggSpec("sum", "l_extendedprice"), AggSpec("count")),
        group_by="l_returnflag",
    )
    rplan = ScanPlan("lineitem", ["l_extendedprice", "l_returnflag"], pred)
    eng = DatapathEngine(backend=args.backend)

    t0 = time.perf_counter()
    ares = eng.scan(li, aplan, batched=True)
    t_push = time.perf_counter() - t0
    t0 = time.perf_counter()
    rres = eng.scan(li, rplan, batched=True)
    host = agg.aggregate_rows_host(
        {c: np.asarray(rres.columns[c]) for c in rplan.columns},
        np.asarray(rres.mask), aplan.aggregates, "l_returnflag",
        len(li.string_dicts["l_returnflag"]))
    t_post = time.perf_counter() - t0

    print("\ngrouped revenue by return flag (pushdown vs post-scan):")
    for g, flag in enumerate(li.string_dicts["l_returnflag"]):
        s = float(np.asarray(ares.aggregates["sum(l_extendedprice)"])[g])
        n = int(np.asarray(ares.aggregates["count(*)"])[g])
        print(f"  {flag}: sum={s:14.2f} count={n}")
    same = all(np.array_equal(np.asarray(ares.aggregates[k]), host[k])
               for k in host)
    print(f"pushdown {t_push*1e3:.1f}ms (result DMA {ares.stats.result_bytes} B)"
          f" vs post-scan {t_post*1e3:.1f}ms"
          f" (result DMA {rres.stats.result_bytes} B); bit-identical={same}")


if __name__ == "__main__":
    main()
