"""N concurrent tenants sharing one SmartNIC datapath service.

Each tenant interleaves its own mix of the six TPC-H-style queries plus a
per-tenant revenue window scan; everything funnels through ONE
DatapathService with admission control, per-tenant quotas, shared-scan
coalescing and the adaptive offload policy.  One deliberately
under-provisioned tenant ("freeloader") demonstrates quota rejection.

    PYTHONPATH=src python examples/multi_tenant.py [--tenants 4] [--sf 0.05]
"""

import argparse

from repro.core import BlockCache, DatapathEngine, tpch
from repro.core.plan import Cmp, ScanPlan
from repro.core.queries import QUERIES, run_via_service
from repro.datapath import DatapathService, QuotaExceeded, TenantQuota
from repro.lakeformat.reader import LakeReader


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--sf", type=float, default=0.05)
    ap.add_argument("--rounds", type=int, default=2)
    args = ap.parse_args()

    # sorted + small row groups: window scans prune, and a row group is a
    # meaningful preemption quantum for the fair scheduler (phase 4)
    paths = tpch.write_tables(f"/tmp/tpch_mt_{args.sf}_rg8192", sf=args.sf, seed=0,
                              sorted_data=True, row_group_size=8192)
    readers = {k: LakeReader(p) for k, p in paths.items()}

    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        batch_per_tick=2 * args.tenants,
        quotas={"freeloader": TenantQuota(max_bytes=10_000)},
    )

    qnames = list(QUERIES)
    rejected = 0

    # Phase 1 — a coalesced burst: every tenant's window scan lands in the
    # same tick, so shared row groups decode once for all of them.
    tickets = []
    for t in range(args.tenants):
        plan = ScanPlan(
            "lineitem",
            ["l_extendedprice", "l_discount"],
            Cmp("l_shipdate", "between", (200 + 50 * t, 564 + 50 * t)),
        )
        tickets.append((t, svc.submit(f"tenant{t}", readers["lineitem"], plan)))
    svc.drain()
    print("phase 1 — coalesced revenue-window burst:")
    for t, tk in tickets:
        print(f"  tenant{t}: {int(tk.result.count):6d} rows, "
              f"{tk.result.stats.pool_hits} shared decodes reused")

    # Phase 2 — steady mixed load through the service-client query path.
    for rnd in range(args.rounds):
        for t in range(args.tenants):
            name = qnames[(t + rnd) % len(qnames)]
            run_via_service(svc, name, readers, tenant=f"tenant{t}")

    # Phase 3 — an under-quota tenant is rejected at admission (no bytes move).
    try:
        svc.submit("freeloader", readers["lineitem"],
                   ScanPlan("lineitem", ["l_extendedprice"]))
    except QuotaExceeded as e:
        rejected += 1
        print(f"\nphase 3 — admission control: {e}")

    # Phase 4 — fair-share scheduling: a weight-2 elephant scan is sliced at
    # row-group granularity so equal-weight mice are never stuck behind it.
    rg_cost = 8192 * 4 * 2
    fair = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        quotas={"elephant": TenantQuota(weight=2.0)},
        tick_bytes=int(rg_cost * 1.5),
        hold_ticks=1,
    )
    el = fair.submit("elephant", readers["lineitem"],
                     ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]))
    mice = [
        fair.submit(f"mouse{i}", readers["lineitem"],
                    ScanPlan("lineitem", ["l_extendedprice"],
                             Cmp("l_shipdate", "between", (300 + 600 * i, 500 + 600 * i))))
        for i in range(args.tenants - 1)
    ]
    fair.drain()
    fsnap = fair.telemetry.fairness(weights={"elephant": 2.0})
    print("\nphase 4 — weighted fair queueing (elephant weight=2):")
    print(f"  elephant: {el.done_tick - el.submitted_tick} ticks "
          f"({int(fair.telemetry.counters.get('split_scans', 0))} scans split across ticks)")
    for i, m in enumerate(mice):
        print(f"  mouse{i}:   {m.done_tick - m.submitted_tick} ticks")
    print(f"  decoded-byte shares    : "
          + " ".join(f"{t}={s:.2f}" for t, s in fsnap["tenant_share"].items()))
    print(f"  jain index (weighted)  : {fsnap['jain_index']:.3f}")

    # Phase 5 — window-retained decodes: a LATE partner arriving after a
    # compatible scan already ran (but within hold_ticks) serves its
    # overlapping row groups from the store's retained decoded tier instead
    # of re-decoding — the unified BlockStore's cross-tick payoff.
    lake = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        hold_ticks=2,
    )
    plan_early = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                          Cmp("l_shipdate", "between", (300, 700)))
    plan_late = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                         Cmp("l_shipdate", "between", (350, 750)))
    early = lake.submit("early", readers["lineitem"], plan_early)
    while early.status == "queued":  # held to its deadline, dispatches alone
        lake.tick()
    late = lake.submit("late", readers["lineitem"], plan_late)
    lake.drain()
    c5 = lake.telemetry.counters
    st5 = lake.store.stats()
    print("\nphase 5 — late partner vs the retained decoded tier (hold=2):")
    print(f"  late partner waited    : {late.done_tick - late.submitted_tick} tick(s)"
          f" (dispatched immediately against the window)")
    print(f"  retained reuse         : {int(c5.get('retained_reuse_bytes', 0)):,} bytes"
          f" ({int(c5.get('retained_hits', 0))} blocks,"
          f" {c5.get('retained_redecode_saved_s', 0.0)*1e6:.1f}us re-decode saved)")
    print(f"  retention billed       : {c5.get('retained_charge_seconds', 0.0)*1e6:.1f}us"
          f" of vtime to the holder")
    print(f"  store ledger           : window_hits={st5['window_hits']} " + " ".join(
        f"{t}={v['hits']}h/{v['evictions']}e" for t, v in st5["tiers"].items()))

    # Phase 6 — the flight recorder: re-run the elephant/mice skew with
    # per-request span tracing, dump a Perfetto-loadable timeline of the
    # whole run, and print each tenant's decode/filter/rest split next to
    # the paper's Fig. 2 anchor (46% decode / 17% filter).
    rec = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(4 << 30)),
        quotas={"elephant": TenantQuota(weight=2.0)},
        tick_bytes=int(rg_cost * 1.5),
        hold_ticks=1,
        trace_capacity=32,
    )
    rec.submit("elephant", readers["lineitem"],
               ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]))
    for i in range(args.tenants - 1):
        rec.submit(f"mouse{i}", readers["lineitem"],
                   ScanPlan("lineitem", ["l_extendedprice"],
                            Cmp("l_shipdate", "between",
                                (300 + 600 * i, 500 + 600 * i))))
    rec.drain()
    trep = rec.telemetry.trace_report()
    trace_path = "/tmp/multi_tenant_trace.json"
    n_events = rec.tracer.recorder.save_chrome_trace(trace_path)
    print("\nphase 6 — flight recorder (per-request span tracing):")
    print(f"  requests traced        : {trep['recorded']}/{trep['completed']}"
          f" (ring capacity {trep['capacity']})")
    print(f"  timeline export        : {trace_path} ({n_events} events —"
          f" load in ui.perfetto.dev)")
    print("  stage attribution (% of request wall):")
    print(f"    {'tenant':10s} {'n':>3s} {'decode':>8s} {'filter':>8s}"
          f" {'fetch':>8s} {'wait':>8s} {'rest':>8s}")
    for t, bt in trep["by_tenant"].items():
        waits = bt["stage_pct"]["wfq_wait"] + bt["stage_pct"]["hold_window"]
        print(f"    {t:10s} {bt['n']:3d} {bt['decode_pct']:7.1f}%"
              f" {bt['filter_pct']:7.1f}% {bt['stage_pct']['fetch']:7.1f}%"
              f" {waits:7.1f}% {bt['rest_pct']:7.1f}%")
    fleet = trep["stage_pct"]
    anchor = trep["paper_fig2_pct"]
    print(f"    {'fleet':10s} {trep['recorded']:3d} {fleet['decode']:7.1f}%"
          f" {fleet['filter']:7.1f}%     ---      ---  {fleet['rest']:7.1f}%")
    print(f"  paper Fig. 2 anchor    : decode={anchor['decode']:.0f}%"
          f" filter={anchor['filter']:.0f}% rest={anchor['rest']:.0f}%"
          f"  (TPC-H on Parquet)")

    snap = svc.telemetry.snapshot()
    c = snap["counters"]
    print("\nservice telemetry")
    print(f"  admitted/completed     : {int(c.get('admitted', 0))}/{int(c.get('completed', 0))}"
          f"  (rejected: {rejected})")
    print(f"  queue depth max/mean   : {snap['queue_depth_max']}/{snap['queue_depth_mean']:.1f}")
    print(f"  coalesced groups       : {int(c.get('coalesced_groups', 0))}"
          f" ({int(c.get('coalesced_requests', 0))} requests)")
    print(f"  decoded bytes          : {int(c.get('decoded_bytes', 0)):,}"
          f" (fresh {int(c.get('decoded_bytes_fresh', 0)):,},"
          f" pool-saved {int(c.get('decoded_bytes_saved', 0)):,})")
    print(f"  offload decisions      : raw={int(c.get('offload_raw', 0))}"
          f" preloaded={int(c.get('offload_preloaded', 0))}"
          f" prefiltered={int(c.get('offload_prefiltered', 0))}"
          f" (prefiltered hits {int(c.get('prefiltered_hits', 0))})")
    print(f"  tick latency p50/p99   : {snap['tick_p50_s']*1e3:.1f}ms"
          f" / {snap['tick_p99_s']*1e3:.1f}ms")
    print(f"  netsim fetch serial    : {c.get('sim_fetch_serial_s', 0)*1e3:.2f}ms"
          f" -> overlapped {c.get('sim_fetch_overlapped_s', 0)*1e3:.2f}ms")
    print("  per-tenant latency (p50/p99 ms):")
    for t, v in sorted(snap["tenants"].items()):
        print(f"    {t:10s} n={v['n']:3d}  {v['p50_s']*1e3:8.1f} / {v['p99_s']*1e3:8.1f}")


if __name__ == "__main__":
    main()
