"""Quickstart: the datapath engine in 40 lines.

Writes a small lake table, runs a pushed-down scan (zone-map pruning +
on-device decode + predicate + compaction), and prints what the host CPU
never had to do.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Cmp, DatapathEngine, ScanPlan, and_
from repro.lakeformat.reader import LakeReader
from repro.lakeformat.schema import ColumnSchema, TableSchema
from repro.lakeformat.writer import write_table

# 1. a table in the lake: 500k rows, sorted by timestamp (zone-map friendly)
rng = np.random.default_rng(0)
n = 500_000
schema = TableSchema(
    "events",
    [
        ColumnSchema("ts", "int32", "auto"),        # sorted -> DELTA
        ColumnSchema("user", "int32", "bitpack"),
        ColumnSchema("score", "float32", "plain"),
        ColumnSchema("country", "str"),             # low-card -> DICT codes
    ],
)
table = {
    "ts": np.sort(rng.integers(0, 1_000_000, n)),
    "user": rng.integers(0, 10_000, n),
    "score": rng.random(n).astype(np.float32),
    "country": [["DE", "US", "JP", "BR"][i] for i in rng.integers(0, 4, n)],
}
path = write_table("/tmp/events.lake", schema, table)
reader = LakeReader(path)

# 2. a pushed-down scan: the engine decodes + filters on DEVICE
plan = ScanPlan(
    "events",
    columns=["user", "score"],
    predicate=and_(
        Cmp("ts", "between", (100_000, 150_000)),
        Cmp("country", "eq", "DE"),
    ),
    compact=True,
)
engine = DatapathEngine(backend="ref")  # 'pallas' on TPU
res = engine.scan(reader, plan)

print(f"rows total            : {res.stats.rows_total}")
print(f"row groups pruned     : {res.stats.row_groups_total - res.stats.row_groups_scanned}"
      f" / {res.stats.row_groups_total}  (zone maps, before any byte was read)")
print(f"encoded bytes touched : {res.stats.encoded_bytes:,}")
print(f"decoded on device     : {res.stats.decoded_bytes:,} bytes "
      f"(host CPU decoded: 0)")
print(f"rows delivered        : {int(res.count)} (pre-filtered, compacted)")
print(f"mean score            : {float(res.columns['score'][:int(res.count)].mean()):.4f}")
