"""End-to-end serving driver (the paper is a data-serving paper, so this is
the primary e2e example): a small model served with batched requests
through the slot-based continuous-batching engine.

    PYTHONPATH=src python examples/serve_batch.py --requests 12 --slots 4
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, n_slots=args.slots, max_len=256)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab, (8 + i % 17,)),
                           max_new_tokens=args.max_new))
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s) over {eng.steps} engine ticks "
          f"({args.slots} slots, continuous batching)")
    for r in done[:3]:
        print(f"  rid={r.rid} -> {r.out[:8]}...")


if __name__ == "__main__":
    main()
