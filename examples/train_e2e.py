"""End-to-end training driver: corpus in the lake -> fused bit-packed
batches -> decode inside the jitted step -> AdamW -> checkpoints.

Defaults are CPU-sized (a ~25M-param qwen3-family model); pass --arch and
--steps to scale up.  On re-run it resumes from the latest checkpoint.

    PYTHONPATH=src python examples/train_e2e.py --steps 30
"""

import argparse
import dataclasses
import os

from repro.configs import get_smoke_config
from repro.data.corpus import write_corpus
from repro.data.pipeline import TokenPipeline
from repro.train.loop import train
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--mode", default="fused", choices=["fused", "engine", "host"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, d_model=args.d_model, n_layers=args.layers,
                              d_ff=4 * args.d_model, vocab=8192)
    print(f"[e2e] {cfg.arch_id}: {cfg.n_params()/1e6:.1f}M params")

    corpus_dir = os.path.join(args.workdir, "corpus")
    if not os.path.exists(corpus_dir):
        write_corpus(corpus_dir, n_tokens=2_000_000, vocab=cfg.vocab, n_shards=2)
    paths = [os.path.join(corpus_dir, f) for f in sorted(os.listdir(corpus_dir))]

    pipe = TokenPipeline(paths, args.batch, args.seq, mode=args.mode,
                         quality_min=20 if args.mode != "fused" else None)
    optcfg = OptConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps,
                       weight_decay=0.1)
    out = train(cfg, optcfg, pipe, steps=args.steps,
                ckpt_dir=os.path.join(args.workdir, "ckpt"), ckpt_every=10)
    print(f"[e2e] loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"in {out['wall_s']:.0f}s; pipeline stats: {pipe.stats}")


if __name__ == "__main__":
    main()
