#!/usr/bin/env python
"""Append one perf-trajectory point to the benchmark history file.

    python scripts/append_bench_point.py <new_point.json> <history.json>

The history is a JSON LIST of points, newest last, each stamped with the
git revision that produced it.  PR 1 committed a bare single-point dict;
that legacy shape is migrated to a one-element list on first append, so
the trajectory keeps every point ever recorded.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys


def git_rev(root: pathlib.Path) -> str:
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=root, capture_output=True, text=True, check=True,
        ).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"],
            cwd=root, capture_output=True, text=True, check=True,
        ).stdout.strip()
        return f"{rev}-dirty" if dirty else rev
    except Exception:  # noqa: BLE001 — not in a checkout: still record the point
        return "unknown"


def main() -> int:
    src, dst = pathlib.Path(sys.argv[1]), pathlib.Path(sys.argv[2])
    point = json.loads(src.read_text())
    history = []
    if dst.exists():
        prior = json.loads(dst.read_text())
        history = prior if isinstance(prior, list) else [prior]  # legacy dict
    point = {"git": git_rev(dst.resolve().parent), **point}
    history.append(point)
    dst.write_text(json.dumps(history, indent=1, default=float) + "\n")
    print(f"bench: appended point {point['git']} -> {dst} ({len(history)} total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
