#!/usr/bin/env python
"""Tier-1 regression gate: run pytest and fail ONLY on new failures.

The seed ships with known-failing tests (scripts/tier1_baseline.txt);
plain `pytest && ...` would make CI permanently red.  This gate runs the
full suite, diffs the failure set against the baseline, and exits 1 iff
a test failed that the baseline does not excuse — "no worse than seed",
mechanically enforced.

With TIER1_RATCHET=1 in the environment, baseline entries that now PASS
are struck from tier1_baseline.txt, so the bar only ever moves up (a
fixed test can never silently regress again).  Ratcheting applies only
to full-suite runs — never when extra pytest args select a subset.

    python scripts/check_tier1.py [extra pytest args...]
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE = pathlib.Path(__file__).resolve().with_name("tier1_baseline.txt")
_RESULT = re.compile(r"^(FAILED|ERROR) (\S+)")


def load_baseline() -> set:
    out = set()
    for line in BASELINE.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def main() -> int:
    cmd = [
        sys.executable, "-m", "pytest", "-q", "--tb=no", "-p", "no:cacheprovider",
    ] + sys.argv[1:]
    proc = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-2000:])
    if proc.returncode not in (0, 1):  # collection error / interrupted / usage
        print(f"tier1: pytest exited {proc.returncode} (not a plain test failure)")
        return proc.returncode
    failures = set()
    for line in proc.stdout.splitlines():
        m = _RESULT.match(line.strip())
        if m:
            failures.add(m.group(2))
    baseline = load_baseline()
    new = sorted(failures - baseline)
    fixed = sorted(baseline - failures)
    if fixed:
        # Ratchet only on a FULL suite run: with extra pytest args (subset
        # selection) a baseline test that simply did not run would look
        # "fixed" and be struck while still failing.  (A test that becomes
        # environment-skipped is the remaining blind spot — the baseline
        # entries are plain asserts today, so a skip would be a deliberate
        # edit someone reviews anyway.)
        if os.environ.get("TIER1_RATCHET") and not sys.argv[1:]:
            kept = [line for line in BASELINE.read_text().splitlines()
                    if line.strip() not in set(fixed)]
            BASELINE.write_text("\n".join(kept).rstrip("\n") + "\n")
            print(f"tier1: ratcheted — struck {len(fixed)} now-passing "
                  f"failure(s) from the baseline: {fixed}")
        else:
            print(f"tier1: {len(fixed)} baseline failure(s) now pass "
                  f"(consider striking from tier1_baseline.txt): {fixed}")
    if new:
        print(f"tier1: REGRESSION — {len(new)} failure(s) not in the seed baseline:")
        for t in new:
            print(f"  {t}")
        return 1
    print(f"tier1: OK — {len(failures)} failure(s), all covered by the seed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
