#!/usr/bin/env python
"""Tier-1 regression gate: run pytest and fail ONLY on new failures.

The seed ships with known-failing tests (scripts/tier1_baseline.txt);
plain `pytest && ...` would make CI permanently red.  This gate runs the
full suite, diffs the failure set against the baseline, and exits 1 iff
a test failed that the baseline does not excuse — "no worse than seed",
mechanically enforced.

    python scripts/check_tier1.py [extra pytest args...]
"""

from __future__ import annotations

import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE = pathlib.Path(__file__).resolve().with_name("tier1_baseline.txt")
_RESULT = re.compile(r"^(FAILED|ERROR) (\S+)")


def load_baseline() -> set:
    out = set()
    for line in BASELINE.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def main() -> int:
    cmd = [
        sys.executable, "-m", "pytest", "-q", "--tb=no", "-p", "no:cacheprovider",
    ] + sys.argv[1:]
    proc = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-2000:])
    if proc.returncode not in (0, 1):  # collection error / interrupted / usage
        print(f"tier1: pytest exited {proc.returncode} (not a plain test failure)")
        return proc.returncode
    failures = set()
    for line in proc.stdout.splitlines():
        m = _RESULT.match(line.strip())
        if m:
            failures.add(m.group(2))
    baseline = load_baseline()
    new = sorted(failures - baseline)
    fixed = sorted(baseline - failures)
    if fixed:
        print(f"tier1: {len(fixed)} baseline failure(s) now pass "
              f"(consider striking from tier1_baseline.txt): {fixed}")
    if new:
        print(f"tier1: REGRESSION — {len(new)} failure(s) not in the seed baseline:")
        for t in new:
            print(f"  {t}")
        return 1
    print(f"tier1: OK — {len(failures)} failure(s), all covered by the seed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
