#!/usr/bin/env bash
# Tier-1 regression gate + the service benchmark (perf-trajectory point).
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# tier-1: fail only on failures NOT present in the seed baseline; strike
# baseline entries that now pass (the bar only moves up)
TIER1_RATCHET=1 python scripts/check_tier1.py

# cost-model calibration smoke: a fast per-encoding decode-rate table,
# persisted as the per-backend JSON artifact ({"format": "per-backend",
# "backends": {...}} — repeated runs merge, one entry per kernel backend).
# CostModel.calibrate falls back to the nominal table when kernels are
# slow or unavailable, so this step can degrade but not fail CI.
python -c "from repro.datapath.costmodel import main; import sys; sys.exit(main(['--n', '65536', '--repeats', '1', '--out', 'calibration_ci.json']))"

# service benchmark — includes the `fairness` sub-report (FIFO vs WFQ under
# 1-elephant/3-mice, hold-window savings), the `costmodel` sub-report
# (calibrated rates + 4x-under-estimator reconciliation A/B), the
# `blockstore` sub-report (late-partner retained-decode reuse vs the old
# tick-scoped pool + per-tier hit/eviction ledger under capacity pressure),
# the `batchdecode` sub-report (bucketed batch launches vs the
# per-(row group, column) loop: device dispatches, wall time, cross-tick
# fetch/decode pipelining), and the `trace` sub-report (flight-recorder
# A/B on the skewed workload: wall overhead ratio, result bit-identity,
# Chrome-trace event count, and the trace-derived decode/filter/rest
# stage attribution against the paper's Fig. 2 46/17/37 split), and the
# `kernels` sub-report (`service.kernels.roofline`: rewritten decode-core
# rates vs the pre-rewrite point-5 anchor, ladder-vs-pow2 pad-waste
# bytes), and the `fabric` sub-report (pod-sharded fleet: aggregate
# simulated throughput at 1/2/4 pods, scale-out peer-fetch vs storage
# bytes, fleet Jain fairness with the WFQ re-level on/off, kill-one-pod
# drain/replay bit-identity) — appended to the perf trajectory
python -m benchmarks.run --fast --only service --json BENCH_point.json
python scripts/append_bench_point.py BENCH_point.json BENCH_service.json
rm -f BENCH_point.json
