#!/usr/bin/env bash
# Tier-1 regression gate + the service benchmark (perf-trajectory point).
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# tier-1: fail only on failures NOT present in the seed baseline
python scripts/check_tier1.py

# service benchmark — includes the `fairness` sub-report (FIFO vs WFQ under
# 1-elephant/3-mice, hold-window savings) — appended to the perf trajectory
python -m benchmarks.run --fast --only service --json BENCH_point.json
python scripts/append_bench_point.py BENCH_point.json BENCH_service.json
rm -f BENCH_point.json
