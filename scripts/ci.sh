#!/usr/bin/env bash
# Tier-1 tests + the service benchmark (the perf-trajectory point).
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q
python -m benchmarks.run --fast --only service --json BENCH_service.json
