#!/usr/bin/env bash
# Tier-1 regression gate + the service benchmark (perf-trajectory point).
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# tier-1: fail only on failures NOT present in the seed baseline; strike
# baseline entries that now pass (the bar only moves up)
TIER1_RATCHET=1 python scripts/check_tier1.py

# cost-model calibration smoke: a fast per-encoding decode-rate table,
# persisted as the per-backend JSON artifact ({"format": "per-backend",
# "backends": {...}} — repeated runs merge, one entry per kernel backend).
# CostModel.calibrate falls back to the nominal table when kernels are
# slow or unavailable, so this step can degrade but not fail CI.
python -c "from repro.datapath.costmodel import main; import sys; sys.exit(main(['--n', '65536', '--repeats', '1', '--out', 'calibration_ci.json']))"

# seeded chaos smoke: one fixed-seed pass of the storage fault plane —
# recoverable transient/corrupt/spike schedule over a 2-pod fabric must
# complete bit-identically with zero exhausted retries (the full
# property sweep lives in tests/test_chaos_props.py; this is the
# always-on canary with a pinned seed)
python - <<'PY'
from tests.test_chaos_props import (
    PLANS, POLICY, RECOVERABLE, _assert_identical, _direct, _tables)
from repro.datapath import ScanFabric

readers = _tables()
fab = ScanFabric(n_pods=2, tick_bytes=1 << 14,
                 fault_plan=RECOVERABLE, retry_policy=POLICY)
tickets = [(i, fab.submit(f"t{i}", readers[p.table], p))
           for i, p in enumerate(PLANS)]
for _ in range(2000):
    fab.tick()
    if not fab.active:
        break
assert not fab.active, "chaos smoke: fabric did not drain (hang)"
for i, t in tickets:
    assert t.status == "done", (i, t.error)
    _assert_identical(t.result, _direct(i))
for pid in fab.live_pods:
    f = fab.pods[pid].telemetry.snapshot()["faults"]
    assert f["retries_exhausted"] == 0, (pid, f)
    print(f"ci.chaos.{pid},0,transients={int(f['transient_errors'])};"
          f"corrupt={int(f['corrupt_detected'])};"
          f"recovered={int(f['retry_successes'])};identical=True")
print(f"ci.chaos.fleet,0,breaker_drains={fab.report()['breaker_drains']};"
      f"live={len(fab.live_pods)}/2;identical=True")
PY

# service benchmark — includes the `fairness` sub-report (FIFO vs WFQ under
# 1-elephant/3-mice, hold-window savings), the `costmodel` sub-report
# (calibrated rates + 4x-under-estimator reconciliation A/B), the
# `blockstore` sub-report (late-partner retained-decode reuse vs the old
# tick-scoped pool + per-tier hit/eviction ledger under capacity pressure),
# the `batchdecode` sub-report (bucketed batch launches vs the
# per-(row group, column) loop: device dispatches, wall time, cross-tick
# fetch/decode pipelining), and the `trace` sub-report (flight-recorder
# A/B on the skewed workload: wall overhead ratio, result bit-identity,
# Chrome-trace event count, and the trace-derived decode/filter/rest
# stage attribution against the paper's Fig. 2 46/17/37 split), and the
# `kernels` sub-report (`service.kernels.roofline`: rewritten decode-core
# rates vs the pre-rewrite point-5 anchor, ladder-vs-pow2 pad-waste
# bytes), and the `fabric` sub-report (pod-sharded fleet: aggregate
# simulated throughput at 1/2/4 pods, scale-out peer-fetch vs storage
# bytes, fleet Jain fairness with the WFQ re-level on/off, kill-one-pod
# drain/replay bit-identity), and the `faults` sub-report
# (`service.faults.*`: fault-free vs 1%/5% transient-error A/B with
# bit-identical results and bounded p99 inflation, hedge tail win,
# breaker-open shed rate) — appended to the perf trajectory
python -m benchmarks.run --fast --only service --json BENCH_point.json
python scripts/append_bench_point.py BENCH_point.json BENCH_service.json
rm -f BENCH_point.json
