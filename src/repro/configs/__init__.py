"""Per-architecture configs (exact assigned numbers) + reduced smoke configs.

`get_config(arch_id)` / `get_smoke_config(arch_id)` — the registry the
launcher's --arch flag resolves through.
"""

from __future__ import annotations

import importlib
from typing import List

ARCH_IDS = [
    "llama4_maverick_400b",
    "deepseek_moe_16b",
    "qwen3_1_7b",
    "gemma_7b",
    "mistral_large_123b",
    "granite_3_8b",
    "mamba2_370m",
    "whisper_base",
    "llava_next_34b",
    "hymba_1_5b",
]

# external ids (as assigned) -> module names
ALIASES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-1.7b": "qwen3_1_7b",
    "gemma-7b": "gemma_7b",
    "mistral-large-123b": "mistral_large_123b",
    "granite-3-8b": "granite_3_8b",
    "mamba2-370m": "mamba2_370m",
    "whisper-base": "whisper_base",
    "llava-next-34b": "llava_next_34b",
    "hymba-1.5b": "hymba_1_5b",
}


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str):
    return _module(arch_id).config()


def get_smoke_config(arch_id: str):
    return _module(arch_id).smoke_config()


def list_archs() -> List[str]:
    return list(ARCH_IDS)
