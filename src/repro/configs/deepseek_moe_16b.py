"""deepseek-moe-16b [moe] — 28L d=2048 16H (kv=16, MHA) vocab=102400.
Fine-grained MoE: 64 routed top-6 + 2 shared experts, expert d_ff=1408;
first layer is a dense FFN (d_ff=10944) per arXiv:2401.06066.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv=16,
        head_dim=128,
        d_ff=10944,          # dense first layer (paper); experts use moe_d_ff
        vocab=102400,
        moe_experts=64,
        moe_top_k=6,
        moe_shared=2,
        moe_d_ff=1408,
        moe_period=1,
        moe_first_dense=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-moe-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv=4,
        head_dim=16,
        d_ff=160,
        vocab=512,
        moe_experts=8,
        moe_top_k=3,
        moe_shared=2,
        moe_d_ff=48,
        moe_period=1,
        moe_first_dense=1,
        remat=False,
    )
