"""gemma-7b [dense] — 28L d=3072 16H (kv=16, MHA) head_dim=256 d_ff=24576
vocab=256000.  GeGLU, sqrt(d) embedding scaling, (1+w) RMSNorm.
[arXiv:2403.08295; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv=16,
        head_dim=256,
        d_ff=24576,
        vocab=256000,
        act="geglu",
        embed_scale=True,
        norm_plus_one=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv=4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        act="geglu",
        embed_scale=True,
        norm_plus_one=True,
        tie_embeddings=True,
        remat=False,
    )
