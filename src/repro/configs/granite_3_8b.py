"""granite-3-8b [dense] — 40L d=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base; hf]  (Granite's logit/residual
multipliers omitted — standard pre-norm GQA stack, noted in DESIGN.md.)
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv=8,
        head_dim=128,
        d_ff=12800,
        vocab=49155,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=515,   # deliberately odd: exercises vocab padding
        tie_embeddings=True,
        remat=False,
    )
