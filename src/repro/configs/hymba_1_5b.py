"""hymba-1.5b [hybrid] — 32L d=1600 25H (GQA kv=5) d_ff=5504, ssm_state=16.
Parallel attention + mamba heads in every layer; sliding-window attention
(1024) except 3 global layers (first / middle / last).  Runs long_500k.
[arXiv:2411.13676; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        window=1024,
        global_layers=(0, 16, 31),
        ssm_state=16,
        ssm_heads=25,
        ssm_head_dim=128,   # d_inner = 3200 = 2*d
        conv_width=4,
        ssm_chunk=256,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="hymba-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        window=32,
        global_layers=(0, 3),
        ssm_state=8,
        ssm_heads=4,
        ssm_head_dim=32,
        conv_width=4,
        ssm_chunk=16,
        tie_embeddings=True,
        remat=False,
    )
