"""llama4-maverick-400b-a17b [moe] — 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 routed top-1 + 1 shared, interleaved every other layer
("early fusion" multimodal stack; text-only cells here).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv=8,
        head_dim=128,
        d_ff=8192,
        vocab=202048,
        rope_theta=500_000.0,
        moe_experts=128,
        moe_top_k=1,
        moe_shared=1,
        moe_d_ff=8192,
        moe_period=2,       # every 2nd layer is MoE (interleaved)
        moe_first_dense=0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama4-smoke",
        family="moe",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        moe_experts=8,
        moe_top_k=1,
        moe_shared=1,
        moe_d_ff=96,
        moe_period=2,
        remat=False,
    )
