"""llava-next-34b [vlm] — 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Anyres tiling frontend is a STUB: input_specs() provides precomputed patch
embeddings prepended to the token stream.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv=8,
        head_dim=128,
        d_ff=20480,
        vocab=64000,
        rope_theta=5_000_000.0,
        vision_tokens=576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llava-smoke",
        family="vlm",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        vision_tokens=16,
        remat=False,
    )
