"""mamba2-370m [ssm] — 48L d=1024, attention-free, ssm_state=128.
SSD (state-space duality) chunked scan.  [arXiv:2405.21060; unverified]
d_inner = 2*d = 2048, 32 heads x head_dim 64.  Runs long_500k.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        d_ff=0,             # attention-free, no MLP block
        vocab=50280,
        ssm_state=128,
        ssm_heads=32,
        ssm_head_dim=64,
        ssm_expand=2,
        conv_width=4,
        ssm_chunk=256,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-smoke",
        family="ssm",
        n_layers=3,
        d_model=64,
        d_ff=0,
        vocab=512,
        ssm_state=16,
        ssm_heads=4,
        ssm_head_dim=32,
        conv_width=4,
        ssm_chunk=32,
        tie_embeddings=True,
        remat=False,
    )
