"""mistral-large-123b [dense] — 88L d=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv=8,
        head_dim=128,
        d_ff=28672,
        vocab=32768,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mistral-large-smoke",
        family="dense",
        n_layers=4,
        d_model=96,
        n_heads=6,
        n_kv=2,
        head_dim=16,
        d_ff=256,
        vocab=512,
        remat=False,
    )
