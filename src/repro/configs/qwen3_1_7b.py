"""qwen3-1.7b [dense] — 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
qk_norm per head; tied embeddings.  [hf:Qwen/Qwen3-8B; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-1.7b",
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv=8,
        head_dim=128,
        d_ff=6144,
        vocab=151936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        qk_norm=True,
        tie_embeddings=True,
        remat=False,
    )
