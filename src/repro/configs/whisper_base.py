"""whisper-base [audio] — enc-dec, 6L encoder + 6L decoder, d=512 8H (kv=8)
d_ff=2048 vocab=51865.  Conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, 1500, 512).  [arXiv:2212.04356; unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-base",
        family="audio",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv=8,
        head_dim=64,
        d_ff=2048,
        vocab=51865,
        act="gelu",
        encoder_layers=6,
        encoder_seq=1500,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        act="gelu",
        encoder_layers=2,
        encoder_seq=48,
        remat=False,
    )
