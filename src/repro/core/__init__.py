"""core — the paper's primary contribution: the datapath offload engine.

plan.py      pushed-down scan plans + predicate algebra ("post-optimizer hook")
zonemap.py   metadata-only row-group pruning
engine.py    DatapathEngine: decode + filter + compact, on-device
cache.py     BlockCache ("SSD table cache")
queries.py   mini TPC-H analytical suite (the "DuckDB host")
tpch.py      synthetic TPC-H-like data generator
"""

from repro.core.cache import BlockCache  # noqa: F401
from repro.core.engine import (  # noqa: F401
    DatapathEngine,
    ResumableScan,
    ScanResult,
    ScanStats,
)
from repro.core.plan import (  # noqa: F401
    And,
    BloomProbe,
    Cmp,
    InSet,
    Or,
    ScanPlan,
    and_,
    or_,
)
from repro.core.zonemap import prune_row_groups  # noqa: F401
