"""Host-side partial-aggregate algebra for operator pushdown (DESIGN.md §16).

The kernels (kernels/agg_push.py) emit PER-BLOCK accumulators — count,
16-bit hi/lo split int sums, f32 float block sums, min, max — and this
module defines the ONE canonical way to reduce them: per row group,
blocks fold left-to-right; across row groups (and across pods), per-rg
partials fold left-to-right in global row-group order.  Int sums are
exact (the hi/lo split recombines losslessly in int64), so their merge
is order-independent by arithmetic; float sums are f64 left-folds whose
bit pattern is pinned by the canonical order — every path (sequential,
batched, sliced, fabric-merged) partitions at row-group granularity and
folds in the same order, which is what makes pushed-down aggregation
bit-identical to scan-then-aggregate everywhere the tests sweep.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.ref import (
    AGG_FLT_MAX_IDENT,
    AGG_FLT_MIN_IDENT,
    AGG_INT_MAX_IDENT,
    AGG_INT_MIN_IDENT,
    AGG_INT_SHIFT,
)
from repro.lakeformat.encodings import PACK_BLOCK


@dataclasses.dataclass
class ColPartial:
    """One column's merged accumulator over some set of blocks: cnt/sum
    are exact int64 (or canonical-order f64), mn/mx carry the value dtype
    with identity fill where no masked row contributed."""

    cnt: np.ndarray  # (G,) int64
    s: np.ndarray  # (G,) int64 (int values) | float64 (float values)
    mn: np.ndarray  # (G,) value dtype
    mx: np.ndarray  # (G,) value dtype
    is_float: bool


def identity_partial(n_groups: int, dtype) -> ColPartial:
    """The merge identity: what an all-pruned (or fully masked-out) scan
    contributes — zero counts/sums, min/max at the kernels' identity fills.
    Merging it into any partial on either side is a no-op bit-for-bit."""
    dtype = np.dtype(dtype)
    is_float = np.issubdtype(dtype, np.floating)
    if is_float:
        mn_f, mx_f = AGG_FLT_MIN_IDENT, AGG_FLT_MAX_IDENT
        s = np.zeros(n_groups, np.float64)
    else:
        mn_f, mx_f = AGG_INT_MIN_IDENT, AGG_INT_MAX_IDENT
        s = np.zeros(n_groups, np.int64)
    return ColPartial(
        np.zeros(n_groups, np.int64), s,
        np.full(n_groups, mn_f, dtype), np.full(n_groups, mx_f, dtype),
        is_float,
    )


def _seq_sum(a: np.ndarray) -> np.ndarray:
    """Left-fold over axis 0 — np.cumsum is sequential by definition, so
    this pins the f64 accumulation order (np.sum pairwise-reassociates)."""
    return a.cumsum(axis=0)[-1] if a.shape[0] else a.sum(axis=0)


def fold_blocks(planes: Tuple, is_float: bool) -> ColPartial:
    """Reduce the kernel's 5 x (nblocks, G) planes to one (G,) partial.
    `planes` is the (cnt, s0, s1, mn, mx) tuple from ops.grouped_agg_batch
    / ops.fused_agg_batch (device or host arrays)."""
    cnt, s0, s1, mn, mx = (np.asarray(p) for p in planes)
    out_cnt = _seq_sum(cnt.astype(np.int64))
    if is_float:
        s = _seq_sum(s0.astype(np.float64))
    else:
        # v == (v >> 16) * 2^16 + (v & 0xFFFF): both planes fit int32 per
        # block, and the int64 recombination is exact — merge order free
        s = _seq_sum(
            (s0.astype(np.int64) << AGG_INT_SHIFT) + s1.astype(np.int64)
        )
    return ColPartial(out_cnt, s, mn.min(axis=0), mx.max(axis=0), is_float)


def merge_partials(parts: Sequence[ColPartial]) -> ColPartial:
    """Left-fold per-rg (or per-pod) partials IN THE GIVEN ORDER — callers
    pass global row-group order, which pins the float-sum bit pattern."""
    assert parts, "merge_partials needs at least one partial"
    first = parts[0]
    cnt = first.cnt.copy()
    s = first.s.copy()
    mn = first.mn.copy()
    mx = first.mx.copy()
    for p in parts[1:]:
        cnt += p.cnt
        s += p.s
        np.minimum(mn, p.mn, out=mn)
        np.maximum(mx, p.mx, out=mx)
    return ColPartial(cnt, s, mn, mx, first.is_float)


def finalize(specs, merged: Dict[Optional[str], ColPartial],
             n_groups: int) -> Dict[str, np.ndarray]:
    """Per-spec (n_groups,) result arrays.  Empty groups keep the merge
    identities: count 0, sum 0, min/max at the identity fill (callers mask
    on count when they need SQL NULL semantics)."""
    out: Dict[str, np.ndarray] = {}
    any_part = next(iter(merged.values()))
    for spec in specs:
        p = merged.get(spec.column, any_part)
        if spec.op == "count":
            # row count is value-independent: any column's cnt plane works
            out[spec.out_name()] = (p if spec.column in merged else any_part).cnt
        elif spec.op == "sum":
            out[spec.out_name()] = p.s
        elif spec.op == "min":
            out[spec.out_name()] = p.mn
        else:
            out[spec.out_name()] = p.mx
    return out


def agg_sources(specs) -> List[Optional[str]]:
    """Distinct value columns the specs reduce, spec order; [None] when
    every spec is a bare count(*) (cnt is value-independent)."""
    value_cols = dict.fromkeys(s.column for s in specs if s.column is not None)
    return list(value_cols) or [None]


def rows_partials(cols: Dict[str, np.ndarray], mask: np.ndarray,
                  specs, group_by: Optional[str], n_groups: int,
                  segments: Optional[Sequence[int]] = None,
                  ) -> Dict[Optional[str], List[ColPartial]]:
    """Per-source, per-segment ColPartials from already-decoded rows,
    through the EXACT pushdown conventions: rows reshape into PACK_BLOCK
    blocks, each block reduces via the jnp oracle's hi/lo-split /
    f32-block-sum math, blocks fold in the canonical order.  `segments`
    gives the per-row-group block counts so fold boundaries match the
    engine's (None = one segment).  Shared by the engine's >MAX_GROUPS
    host fallback and the bit-identity comparator below."""
    import jax.numpy as jnp

    from repro.kernels import ref

    L = mask.shape[0]
    assert L % PACK_BLOCK == 0, L
    nb = L // PACK_BLOCK
    segments = list(segments) if segments is not None else [nb]
    assert sum(segments) == nb, (segments, nb)
    if group_by is not None:
        gids = np.asarray(cols[group_by]).astype(np.int32).reshape(nb, PACK_BLOCK)
    else:
        gids = np.zeros((nb, PACK_BLOCK), np.int32)
    m = np.asarray(mask).astype(np.int32).reshape(nb, PACK_BLOCK)
    out: Dict[Optional[str], List[ColPartial]] = {}
    for name in agg_sources(specs):
        if name is None:
            vals = gids  # pure count(*): cnt is value-independent
        else:
            vals = np.asarray(cols[name]).reshape(nb, PACK_BLOCK)
        is_float = np.issubdtype(vals.dtype, np.floating)
        parts: List[ColPartial] = []
        off = 0
        for seg in segments:
            planes = ref.grouped_agg(
                jnp.asarray(vals[off:off + seg]),
                jnp.asarray(gids[off:off + seg]),
                jnp.asarray(m[off:off + seg]), n_groups,
            )
            parts.append(fold_blocks(planes, is_float))
            off += seg
        out[name] = parts
    return out


def aggregate_rows_host(cols: Dict[str, np.ndarray], mask: np.ndarray,
                        specs, group_by: Optional[str], n_groups: int,
                        segments: Optional[Sequence[int]] = None,
                        ) -> Dict[str, np.ndarray]:
    """Scan-then-aggregate comparator: `rows_partials` merged per source in
    segment (= global row-group) order, then finalized.  This is what the
    bit-identity tests hold pushed-down results equal to."""
    by_src = rows_partials(cols, mask, specs, group_by, n_groups, segments)
    merged = {name: merge_partials(parts) for name, parts in by_src.items()}
    return finalize(specs, merged, n_groups)
