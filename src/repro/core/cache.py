"""BlockCache — the paper's "SSD table cache", host-memory edition.

Since the unified tiered block store (repro.datapath.blockstore) this is
a thin compatibility facade: every entry — encoded pages, decoded
row-group columns, whole pre-filtered ScanResults — lives in ONE
BlockStore with a single byte ledger and cost-aware eviction (victim =
lowest estimated re-creation seconds per byte, LRU tie-break), instead
of the old flat LRU dict.  The engine's key tuples carry the tier tag:

    ("page", path, rg, column)          -> encoded tier
    ("rg",   path, rg, column, backend) -> decoded tier
    ("scan", path, signature, ...)      -> prefiltered tier

Metadata and orchestration (which row groups are cached vs must be
fetched and decoded) is exactly the open challenge the paper flags for
the SSD cache; `plan_fetch()` returns the cached/missing split the
engine and the adaptive policy use to route work, now tier-scoped.

The import of the store is lazy: core must stay importable before
repro.datapath finishes initializing (datapath.service imports this
module back).
"""

from __future__ import annotations

from typing import Any, Dict, Hashable, List, Optional, Tuple

_TIER_BY_TAG = {"scan": "prefiltered", "page": "encoded"}


def _nbytes(obj) -> int:
    """Kept for compatibility; the store owns the billing rules."""
    from repro.datapath.blockstore import _nbytes as impl

    return impl(obj)


class BlockCache:
    def __init__(self, capacity_bytes: int = 2 << 30, store=None):
        if store is None:
            from repro.datapath.blockstore import BlockStore

            store = BlockStore(capacity_bytes=capacity_bytes)
        self.store = store
        # Fabric hook: a blockstore.PeerFetcher consulted when a COUNTING
        # get misses locally — a sibling pod's encoded/decoded tier serves
        # a copy over the inter-pod link.  None on single-node services;
        # probes (__contains__/plan_fetch) never cross pods either way.
        self.peer = None

    @staticmethod
    def _tier(key: Hashable) -> str:
        tag = key[0] if isinstance(key, tuple) and key else None
        return _TIER_BY_TAG.get(tag, "decoded")

    # -- legacy scalar surface (tests and callers read these) --------------
    @property
    def capacity(self) -> int:
        return self.store.capacity

    @property
    def used(self) -> int:
        return self.store.used

    def _total(self, field: str) -> int:
        return sum(getattr(s, field) for s in self.store._tier_stats.values())

    @property
    def hits(self) -> int:
        return self._total("hits")

    @property
    def misses(self) -> int:
        return self._total("misses")

    @property
    def evictions(self) -> int:
        return self._total("evictions")

    # -- ops ---------------------------------------------------------------
    def __contains__(self, key: Hashable) -> bool:
        """Presence check without touching LRU order or hit/miss counters."""
        return key in self.store

    def get(self, key: Hashable, stats=None):
        """Counting lookup.  On a local miss a fabric peer (if installed)
        may serve the block over the inter-pod hop; `stats` (a ScanStats)
        then receives the transferred bytes so the slice that triggered
        the fetch is the one WFQ bills for the hop."""
        v = self.store.get(key, tier=self._tier(key))
        if v is None and self.peer is not None:
            v = self.peer.fetch(key, self.store, stats=stats)
        return v

    def put(
        self,
        key: Hashable,
        value: Any,
        tier: Optional[str] = None,
        encoding: Optional[str] = None,
        decode_work: Optional[Dict[str, int]] = None,
        demote: Optional[Tuple[Hashable, Any]] = None,
    ) -> bool:
        """Persist one entry (never window-pinned, never ephemeral — the
        cache path is the promotion path).  `encoding` prices a decoded
        column's re-decode; `decode_work` prices a prefiltered result by
        the ground-truth work that produced it; `demote` is the (key,
        value) of the encoded pages an evicted decoded column falls back
        to instead of dropping to zero."""
        return self.store.put(
            key, value, tier=tier or self._tier(key),
            encoding=encoding, decode_work=decode_work, demote=demote,
        )

    def promote(self, key: Hashable, value: Any,
                encoding: Optional[str] = None) -> bool:
        """Persist a pool-served decode.  A no-op when the entry is already
        cache-owned (non-ephemeral) in this store — the common case for a
        store-backed pool, where every hit would otherwise re-run the put
        machinery just to clear an already-clear flag.  `encoding` keeps
        the promoted entry's honest eviction price; when absent, a price
        already recorded on the entry wins over the PLAIN fallback."""
        e = self.store.peek(key)
        if e is not None and not e.ephemeral:
            return True
        return self.put(key, value, tier="decoded",
                        encoding=encoding or (e.encoding if e is not None else None))

    def plan_fetch(
        self, keys: List[Hashable], tier: Optional[str] = None
    ) -> Tuple[List[Hashable], List[Hashable]]:
        """Split keys into (cached, missing) without touching LRU order;
        `tier` scopes residency to one tier of the store."""
        return self.store.plan_fetch(keys, tier=tier)

    def clear(self):
        self.store.clear()

    def stats(self) -> dict:
        st = self.store.stats()
        return {
            "entries": sum(t["entries"] for t in st["tiers"].values()),
            "bytes": st["used"],
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "tiers": st["tiers"],
        }
