"""BlockCache — the paper's "SSD table cache", host-memory edition.

Caches (a) decoded row-group columns ("pre-loaded" configuration) and
(b) whole pre-filtered scan results keyed by plan signature ("pre-filtered"
configuration), with LRU eviction under a byte budget.  On a real
deployment the same interface fronts host NVMe; here entries are jax
arrays in host/device memory (one CPU device — identical address space).

Metadata and orchestration (which row groups are cached vs must be fetched
and decoded) is exactly the open challenge the paper flags for the SSD
cache; `plan_fetch()` returns the cached/missing split the engine uses to
route work.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Hashable, List, Optional, Tuple


def _nbytes(obj) -> int:
    if hasattr(obj, "nbytes"):
        return int(obj.nbytes)
    if isinstance(obj, dict):
        return sum(_nbytes(v) for v in obj.values())
    if isinstance(obj, (list, tuple)):
        return sum(_nbytes(v) for v in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        # e.g. a whole prefiltered ScanResult: bill its column arrays + mask,
        # otherwise the LRU budget never sees them and the cache grows unbounded
        return sum(_nbytes(getattr(obj, f.name)) for f in dataclasses.fields(obj))
    return 64


class BlockCache:
    def __init__(self, capacity_bytes: int = 2 << 30):
        self.capacity = capacity_bytes
        self._store: "collections.OrderedDict[Hashable, Any]" = collections.OrderedDict()
        self._bytes: Dict[Hashable, int] = {}
        self.used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __contains__(self, key: Hashable) -> bool:
        """Presence check without touching LRU order or hit/miss counters."""
        return key in self._store

    def get(self, key: Hashable):
        if key in self._store:
            self._store.move_to_end(key)
            self.hits += 1
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: Any):
        nb = _nbytes(value)
        if nb > self.capacity:
            return  # never cache something bigger than the device
        if key in self._store:
            self.used -= self._bytes[key]
        self._store[key] = value
        self._store.move_to_end(key)
        self._bytes[key] = nb
        self.used += nb
        while self.used > self.capacity and self._store:
            k, _ = self._store.popitem(last=False)
            self.used -= self._bytes.pop(k)
            self.evictions += 1

    def plan_fetch(self, keys: List[Hashable]) -> Tuple[List[Hashable], List[Hashable]]:
        """Split keys into (cached, missing) without touching LRU order."""
        cached = [k for k in keys if k in self._store]
        missing = [k for k in keys if k not in self._store]
        return cached, missing

    def clear(self):
        self._store.clear()
        self._bytes.clear()
        self.used = 0

    def stats(self) -> dict:
        return {
            "entries": len(self._store),
            "bytes": self.used,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
