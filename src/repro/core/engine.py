"""DatapathEngine — the paper's data-processing SmartNIC, TPU edition.

Pipeline per scan (DESIGN.md §2):

    footer zone maps ──► row-group pruning (metadata only, host)
         │
    encoded bytes ────► on-device decode (Pallas kernels / jnp ref)
         │                    │
         │              pushed-down predicate (+ bloom semijoin)
         │                    │
         │              optional stream compaction (survivors packed)
         ▼                    ▼
    BlockStore  ◄──── pre-filtered columns + mask + count ──► consumer
    (tiered: encoded pages / decoded columns / prefiltered results)

Offload configurations reproduce the paper's Figure 1:
  'raw'         — decode + filter on every scan (query on Parquet)
  'preloaded'   — decoded row groups served from the store's decoded
                  tier (encoded pages cached too, so even an evicted
                  decode skips the storage->NIC re-fetch)
  'prefiltered' — whole filtered scans served from the prefiltered tier

Backends: 'ref' (pure jnp — also the multi-pod dry-run path), 'pallas'
(Pallas kernels; interpret off-TPU), 'host' (numpy on the host CPU — the
"no SmartNIC, the CPU does everything" baseline), 'auto' ('pallas' on TPU,
'ref' elsewhere — resolved per kernel call in kernels/ops.py).

The engine is also drivable at row-group granularity (`scan_row_group`)
by the shared service scheduler (repro.datapath): a tick-level decode
pool lets N concurrent scans over the same row groups decode each
(row group, column) pair once ("shared-scan coalescing", DESIGN.md §8).
`scan_row_groups_batched` is the batched form of the same contract
(DESIGN.md §12): a whole dispatch slice's pages are bucketed by
(encoding, k, dtype) and decoded in ONE kernel launch per bucket —
bit-identical results and accounting, ~an order of magnitude fewer
device dispatches.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agg as agg_merge
from repro.core.cache import BlockCache
from repro.core.plan import (
    And,
    BloomProbe,
    Cmp,
    Expr,
    InSet,
    Or,
    ScanPlan,
    bind_expr,
    expr_columns,
    pred_int_bounds,
)
from repro.core.zonemap import estimate_selectivity, prune_row_groups
from repro.kernels import ops
from repro.lakeformat.encodings import (
    PACK_BLOCK,
    RLE_OUT_BLOCK,
    EncodedColumn,
    Encoding,
    decode_column_host,
    padded_rows,
)
from repro.lakeformat.integrity import CorruptPageError, page_checksum

# Flight-recorder hook: the repro.datapath.trace MODULE, installed by the
# datapath scheduler at its import time (engine cannot import datapath —
# that would close an import cycle through the package __init__).  None
# for library users who never touch the service, so direct scans pay one
# module-attribute load and nothing else.
TRACE = None


def _tr():
    """The trace module iff a traced service slice is executing right
    now, else None.  Call sites gate EVERY span kwarg construction on
    this, which is what keeps the untraced hot path allocation-free."""
    t = TRACE
    return t if t is not None and t._CUR is not None else None


@dataclasses.dataclass
class ScanStats:
    row_groups_total: int = 0
    row_groups_scanned: int = 0
    encoded_bytes: int = 0
    decoded_bytes: int = 0  # decode output materialized for this scan
    decoded_bytes_fresh: int = 0  # subset actually decoded now (no pool/cache hit)
    # Fresh decode WORK by encoding, in output bytes — ground truth for the
    # service's cost reconciliation.  Keyed by the encoding of the buffers
    # actually read (not footer claims), it covers materializing decodes
    # AND the fused predicate column (processed at L*4 virtual output bytes
    # but never materialized); pool/cache hits do no decode work and are
    # excluded.
    decode_work: Dict[str, int] = dataclasses.field(default_factory=dict)
    pool_hits: int = 0  # (rg, column) decodes served by a shared decode pool
    pool_hit_bytes: int = 0
    page_hits: int = 0  # encoded pages served by the store's encoded tier
    page_hit_bytes: int = 0  # encoded bytes that skipped the storage->NIC hop
    rows_total: int = 0
    rows_out: int = 0
    # Bytes the scan's RESULT hands to the consumer (the result-DMA size):
    # projection columns + survivor mask for row scans; the finalized
    # (n_groups,) accumulator arrays for pushed-down aggregations — the
    # number operator pushdown exists to shrink (DESIGN.md §16).
    result_bytes: int = 0
    fused: bool = False
    cache_hit: bool = False
    # Device dispatches on the DECODE path only (column decodes, PLAIN device
    # puts, fused scans) — predicate eval and compaction launch identically
    # on both paths and are excluded.  The sequential path counts one per
    # fresh (row group, column); the batched path one per bucket launch.
    # This is the one ScanStats field batching is ALLOWED to change; the
    # cost model prices it via `launch_overhead_s` and reconciliation
    # refunds the batched path's savings.
    kernel_launches: int = 0
    # Batched-path shape telemetry: blocks of pure padding added to reach
    # each bucket's power-of-two size (the price of shape-stable jit).
    batch_pad_blocks: int = 0
    # Fabric peer fetches: bytes this scan pulled from a sibling pod's
    # block store instead of storage (cache.BlockCache.get threads the
    # stats object down to the PeerFetcher).  Priced per slice over the
    # inter-pod link at WFQ reconcile; always 0 on single-node services.
    peer_bytes: int = 0
    # Fault plane (datapath/faults.py).  `fault_wait_s` is MODELED extra
    # seconds the storage hop cost this scan beyond clean transfers —
    # failed attempts, retry backoff, latency spikes survived, hedge
    # exposure — billed into WFQ vtime at slice reconcile so a faulty
    # tenant's retries charge that tenant, not the fleet.  The counters
    # mirror telemetry but per-scan, and _merge_stats sums them across
    # fabric sub-scans like every other numeric field.
    retry_fetches: int = 0     # fetch attempts that failed and were retried
    fetch_timeouts: int = 0    # attempts abandoned at the policy timeout
    hedged_fetches: int = 0    # attempts that launched a hedged second read
    hedge_wins: int = 0        # hedges that beat the straggling primary
    corrupt_pages: int = 0     # checksum-detected pages (quarantined)
    fault_wait_s: float = 0.0  # modeled seconds of fault-plane delay


@dataclasses.dataclass
class ScanResult:
    columns: Dict[str, jax.Array]  # decoded (compacted iff plan.compact), padded
    mask: jax.Array  # (L,) bool — predicate & row-validity
    count: jax.Array  # scalar int32 — surviving rows
    stats: ScanStats
    # Operator pushdown (plans with `aggregates`): `aggregates` maps each
    # AggSpec.out_name() to its finalized (n_groups,) array; `agg_partials`
    # keeps the per-row-group ColPartials (core/agg.py) so the scan fabric
    # can merge pod sub-results in global row-group order bit-identically.
    # Both None for ordinary row scans; `columns`/`mask` are empty for
    # aggregate scans (nothing row-shaped crosses the result DMA).
    aggregates: Optional[Dict[str, np.ndarray]] = None
    agg_partials: Optional[Dict[int, dict]] = None


def _expr_blooms(e: Optional[Expr]) -> List[BloomProbe]:
    """Every BloomProbe node in a predicate tree, document order."""
    if e is None:
        return []
    if isinstance(e, BloomProbe):
        return [e]
    if isinstance(e, (And, Or)):
        out: List[BloomProbe] = []
        for c in e.children:
            out.extend(_expr_blooms(c))
        return out
    return []


def group_domain(reader, column: str) -> int:
    """Dense group-id domain size for a pushed-down GROUP BY column,
    from footer metadata alone.  String DICT columns decode to globally
    stable int codes (the writer grows one map across row groups), so the
    dictionary length IS the domain; int columns use the zone-map maximum
    (values must be small non-negative ids — asserted, not assumed)."""
    d = reader.string_dicts.get(column)
    if d is not None:
        return max(len(d), 1)
    zms = reader.zonemaps(column)
    lo = min(zm["min"] for zm in zms)
    hi = max(zm["max"] for zm in zms)
    assert lo >= 0, (
        f"group_by column {column!r} has negative values (min {lo}); "
        "pushdown grouping needs a dense non-negative id domain"
    )
    return int(hi) + 1


class DatapathEngine:
    def __init__(
        self,
        backend: str = "ref",
        offload: str = "raw",
        cache: Optional[BlockCache] = None,
    ):
        assert backend in ("ref", "pallas", "host", "auto")
        # 'pre-aggregated' (DESIGN.md §16) is the fourth offload mode: an
        # aggregate plan's tiny accumulator result is cached whole (same
        # tier as prefiltered), but decoded row-group columns are NOT —
        # pushdown exists to avoid materializing them, so seeding the
        # decoded tier with them would waste the store.
        assert offload in ("raw", "preloaded", "prefiltered", "pre-aggregated")
        self.backend = backend
        self.offload = offload
        self.cache = cache if cache is not None else BlockCache()
        # Storage fault plane (datapath/faults.FaultInjector), installed by
        # the service like the TRACE hook — duck-typed because core cannot
        # import datapath.  None = clean reads, still checksum-verified.
        self.faults = None
        self.verify_checksums = True

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_device(self, col: EncodedColumn, L: int) -> jax.Array:
        """Decode one encoded column on-device, padded to L rows."""
        be = self.backend if self.backend != "host" else "ref"
        e = col.encoding
        if e == Encoding.PLAIN:
            arr = ops.device_put(col.buffers["plain"])
        elif e == Encoding.BITPACK:
            arr = ops.bitunpack(jnp.asarray(col.buffers["packed"]), col.k, backend=be)
            arr = arr.reshape(-1)
        elif e == Encoding.DICT:
            d = col.buffers["dictionary"]
            d = jnp.asarray(d.astype(np.int32) if d.dtype.kind in "iu" else d)
            arr = ops.dict_decode(
                jnp.asarray(col.buffers["packed"]), d, col.k, backend=be
            ).reshape(-1)
        elif e == Encoding.DELTA:
            arr = ops.delta_decode(
                jnp.asarray(col.buffers["packed"]),
                jnp.asarray(col.buffers["bases"].astype(np.int32)),
                col.k,
                backend=be,
            ).reshape(-1)
        elif e == Encoding.RLE:
            arr = ops.rle_decode(
                jnp.asarray(col.buffers["rle_values"]),
                jnp.asarray(col.buffers["rle_ends"]),
                backend=be,
            ).reshape(-1)
        else:
            raise ValueError(e)
        if arr.shape[0] < L:
            arr = jnp.pad(arr, (0, L - arr.shape[0]))
        return arr[:L]

    def _decode_host(self, col: EncodedColumn, L: int) -> jax.Array:
        """Host (numpy) decode — the traditional 'CPU decodes' baseline."""
        arr = decode_column_host(col)
        out = np.zeros(L, dtype=arr.dtype)
        out[: arr.shape[0]] = arr
        return jnp.asarray(out)

    def rg_cache_key(self, reader, rg: int, name: str):
        """Decoded-tier / decode-pool key for one decoded row-group column."""
        return ("rg", reader.path, rg, name, self.backend)

    def page_cache_key(self, reader, rg: int, name: str):
        """Encoded-tier key for one column's raw encoded page.  No backend
        component: encoded bytes are backend-independent."""
        return ("page", reader.path, rg, name)

    @staticmethod
    def _pool_put(pool, key, arr, encoding: Optional[str] = None) -> None:
        """Insert into a shared decode pool.  Store-backed views take the
        source encoding so the window pin is priced honestly; a plain dict
        (legacy callers) just stores the array."""
        put = getattr(pool, "put", None)
        if put is not None:
            put(key, arr, encoding=encoding)
        else:
            pool[key] = arr

    def _decode_column(
        self,
        reader,
        rg: int,
        name: str,
        col: EncodedColumn,
        L: int,
        offload: Optional[str] = None,
        pool: Optional[Dict] = None,
        stats: Optional[ScanStats] = None,
        precomputed: Optional[jax.Array] = None,
    ):
        """Serve one decoded row-group column: pool hit, cache hit, or a
        fresh decode.  `precomputed` is the batched path's already-launched
        bucket slice for this (rg, column) — it substitutes for the kernel
        call only; every hit lookup, stats increment, and pool/cache put
        runs identically, which is what keeps batched ≡ sequential."""
        offload = offload or self.offload
        key = self.rg_cache_key(reader, rg, name)
        if pool is not None:
            hit = pool.get(key)
            if hit is not None:
                if offload in ("preloaded", "prefiltered"):
                    # pool hits must still persist: promote the (possibly
                    # ephemeral window-pinned) entry to a cache-owned one,
                    # carrying the pool's recorded encoding so the promoted
                    # decode keeps its honest eviction price
                    enc_of = getattr(pool, "encoding_of", None)
                    self.cache.promote(key, hit,
                                       encoding=enc_of(key) if enc_of else None)
                if stats is not None:
                    stats.decoded_bytes += int(hit.nbytes)
                    stats.pool_hits += 1
                    stats.pool_hit_bytes += int(hit.nbytes)
                return hit, True
        if offload in ("preloaded", "prefiltered"):
            hit = self.cache.get(key, stats=stats)
            if hit is not None:
                if pool is not None:
                    self._pool_put(pool, key, hit)
                if stats is not None:
                    stats.decoded_bytes += int(hit.nbytes)
                return hit, True
        if precomputed is not None:
            arr = precomputed  # bucket launch already counted by the caller
        else:
            tr = _tr()
            if tr is not None:
                tr.begin("decode_launch", rg=rg, column=name,
                         encoding=col.encoding.value, rows=L)
            arr = self._decode_host(col, L) if self.backend == "host" else self._decode_device(col, L)
            if tr is not None:
                tr.end(name="decode_launch", nbytes=int(arr.nbytes))
            if stats is not None:
                stats.kernel_launches += 1
        enc_name = col.encoding.value if col is not None else None
        if offload in ("preloaded", "prefiltered"):
            # demote payload: under pressure the decoded column falls back
            # to its encoded page (re-decode only) instead of dropping to
            # zero (re-fetch AND re-decode)
            self.cache.put(
                key, arr, encoding=enc_name,
                demote=(self.page_cache_key(reader, rg, name), col)
                if col is not None else None,
            )
        if pool is not None:
            self._pool_put(pool, key, arr, encoding=enc_name)
        if stats is not None:
            stats.decoded_bytes += int(arr.nbytes)
            stats.decoded_bytes_fresh += int(arr.nbytes)
            e = col.encoding.value
            stats.decode_work[e] = stats.decode_work.get(e, 0) + int(arr.nbytes)
        return arr, False

    # ------------------------------------------------------------------
    # predicate evaluation (on decoded device columns)
    # ------------------------------------------------------------------
    def _eval(self, e: Expr, cols: Dict[str, jax.Array], blooms: Dict[str, jax.Array],
              bmasks: Optional[Dict] = None):
        if isinstance(e, Cmp):
            v = cols[e.column]
            if e.op == "between":
                lo, hi = e.value
                return (v >= lo) & (v <= hi)
            val = e.value
            return {
                "lt": v < val,
                "le": v <= val,
                "gt": v > val,
                "ge": v >= val,
                "eq": v == val,
                "ne": v != val,
            }[e.op]
        if isinstance(e, InSet):
            v = cols[e.column]
            m = jnp.zeros(v.shape, jnp.bool_)
            for val in e.values:
                m = m | (v == val)
            return m
        if isinstance(e, BloomProbe):
            # the batched bucket pass pre-probes every slice page's keys in
            # ONE stacked ops.bloom_probe per filter (`_batch_bloom_probe`)
            # and hands the per-row-group slice down here — bit-identical
            # (the probe is elementwise per key), one dispatch instead of
            # one per row group
            if bmasks is not None:
                hit = bmasks.get((e.name, e.column))
                if hit is not None:
                    return hit
            keys = cols[e.column].astype(jnp.int32)
            L = keys.shape[0]
            pad = (-L) % RLE_OUT_BLOCK
            if pad:
                keys = jnp.pad(keys, (0, pad))
            m = ops.bloom_probe(
                keys.reshape(-1, RLE_OUT_BLOCK),
                blooms[e.name],
                e.n_hashes,
                backend=self.backend if self.backend != "host" else "ref",
            )
            return m.reshape(-1)[:L]
        if isinstance(e, And):
            m = self._eval(e.children[0], cols, blooms, bmasks)
            for c in e.children[1:]:
                m = m & self._eval(c, cols, blooms, bmasks)
            return m
        if isinstance(e, Or):
            m = self._eval(e.children[0], cols, blooms, bmasks)
            for c in e.children[1:]:
                m = m | self._eval(c, cols, blooms, bmasks)
            return m
        raise TypeError(e)

    def _eval_mask(self, pred: Optional[Expr], cols, blooms, L: int, rg: int,
                   bmasks: Optional[Dict] = None):
        """Predicate eval wrapped in a `filter` span (no predicate: an
        all-true validity mask, not filter work, so no span).  `bmasks`
        maps (bloom name, column) -> this row group's pre-probed (L,)
        membership mask from the batched path's stacked probe."""
        if pred is None:
            return jnp.ones((L,), jnp.bool_)
        tr = _tr()
        if tr is not None:
            tr.begin("filter", rg=rg, rows=L)
        mask = self._eval(pred, cols, blooms, bmasks)
        if tr is not None:
            tr.end(name="filter")
        return mask

    # ------------------------------------------------------------------
    # fused decode+filter fast path
    # ------------------------------------------------------------------
    @staticmethod
    def _fusable(pred: Optional[Expr], enc: Dict[str, EncodedColumn], projected: List[str]):
        """Single int range/eq predicate on a BITPACK or int-DICT column not in
        the projection -> the filter column need never be materialized.

        For DICT columns the predicate is rewritten onto the *codes*: the
        dictionary is sorted (np.unique), so a value range maps to a code
        range via two host-side binary searches — the decode step then
        operates on packed codes only and the dictionary is never touched.
        """
        if not isinstance(pred, Cmp) or pred.column in projected:
            return None
        col = enc.get(pred.column)
        if col is None or col.encoding not in (Encoding.BITPACK, Encoding.DICT):
            return None
        if col.encoding == Encoding.DICT and col.buffers["dictionary"].dtype.kind not in "iu":
            return None
        bounds = pred_int_bounds(pred)
        if bounds is None:
            return None
        lo, hi = bounds
        if col.encoding == Encoding.DICT:
            d = col.buffers["dictionary"]
            lo = int(np.searchsorted(d, lo, side="left"))
            hi = int(np.searchsorted(d, hi, side="right")) - 1
            if hi < lo:
                lo, hi = 1, 0  # empty range, still valid
        return lo, hi

    def _storage_read(self, reader, rg: int, columns,
                      stats: ScanStats) -> Dict[str, EncodedColumn]:
        """The ONLY path encoded pages take from storage into the engine —
        both fetch seams (`_prepare_row_group`, `_serve_resident`) route
        here.  With a fault injector installed (datapath/faults.py, set on
        `self.faults` by the service) the read runs the full retry /
        verify / quarantine / hedge loop.  Without one, pages are STILL
        checksum-verified against the footer before they can reach a
        decode kernel; a mismatch quarantines the page key in the block
        store and raises typed — never returns garbage.  Legacy footers
        without checksums verify trivially (unverified fallback)."""
        if self.faults is not None:
            return self.faults.read(self, reader, rg, columns, stats)
        got = reader.read_encoded(rg, columns)
        if self.verify_checksums:
            meta = getattr(reader, "page_checksum_meta", None)
            if meta is not None:
                for name, col in got.items():
                    expect = meta(rg, name)
                    if expect is not None and page_checksum(col) != expect:
                        stats.corrupt_pages += 1
                        store = getattr(self.cache, "store", None)
                        if store is not None and hasattr(store, "quarantine"):
                            store.quarantine(
                                self.page_cache_key(reader, rg, name))
                        raise CorruptPageError(
                            f"{reader.path} rg={rg} column={name}: page "
                            "failed checksum verification",
                            table=reader.path, rg=rg, column=name)
        return got

    def _prepare_row_group(self, reader, rg: int, plan: ScanPlan,
                           pred: Optional[Expr], mode: str, stats: ScanStats,
                           pool: Optional[Dict] = None):
        """The per-row-group front half shared VERBATIM by the sequential
        and batched dispatch paths (bit-identity by construction, not by
        mirroring): the fully-resident shortcut probe, the encoded-page
        tier lookups + storage->NIC fetch, and fusability.

        Returns (n, L, resident, enc, fuse, fetched).  When `resident` the
        remaining fields are empty — no encoded byte moves.  Fusable plans
        never take the shortcut (their predicate column is never decoded,
        so its key can never be resident), which keeps the resident mask
        an `_eval` over exactly the arrays a direct scan would produce.
        """
        need = plan.all_columns()
        n = reader.row_group_meta(rg)["n"]
        L = padded_rows(n)
        if pool is not None or mode in ("preloaded", "prefiltered"):
            keys = [self.rg_cache_key(reader, rg, name) for name in need]
            if (pool is not None and all(k in pool for k in keys)) or (
                mode in ("preloaded", "prefiltered")
                and all(k in self.cache for k in keys)
            ):
                return n, L, True, {}, None, False

        # Encoded-page tier: under preloaded/prefiltered the store keeps
        # raw encoded pages too, so a repeat scan whose decoded columns
        # were evicted (or never fit) at least skips the storage->NIC
        # re-fetch.  Page hits contribute no `encoded_bytes` — nothing
        # crossed the hop — which is also what keeps them out of netsim's
        # fetch simulation.
        enc: Dict[str, EncodedColumn] = {}
        missing = list(need)
        if mode in ("preloaded", "prefiltered"):
            missing = []
            for name in need:
                page = self.cache.get(self.page_cache_key(reader, rg, name),
                                      stats=stats)
                if page is None:
                    missing.append(name)
                else:
                    enc[name] = page
                    stats.page_hits += 1
                    stats.page_hit_bytes += page.encoded_bytes()
        fetched = False
        if missing:
            tr = _tr()
            if tr is not None:
                tr.begin("fetch", rg=rg, columns=len(missing))
            got = self._storage_read(reader, rg, missing, stats)
            nb = sum(c.encoded_bytes() for c in got.values())
            if tr is not None:
                tr.end(name="fetch", nbytes=nb)
            stats.encoded_bytes += nb
            enc.update(got)
            fetched = True
            if mode in ("preloaded", "prefiltered"):
                for name, col in got.items():
                    self.cache.put(self.page_cache_key(reader, rg, name), col,
                                   tier="encoded")
        fuse = None
        if self.backend in ("ref", "pallas", "auto"):
            fuse = self._fusable(pred, enc, plan.materialized_columns())
        return n, L, False, enc, fuse, fetched

    def _agg_skip(self, plan: ScanPlan, pred: Optional[Expr],
                  enc: Dict[str, EncodedColumn]) -> frozenset:
        """Aggregate value columns eligible for the fully-fused
        decode→aggregate kernel (ops.fused_agg_batch): BITPACK pages whose
        decoded values nothing else consumes — not projected, not the
        group key, not referenced by the predicate.  Those pages skip the
        decode bucket entirely; the unpack happens inside the aggregate
        kernel and the value column never exists outside VMEM.  Ungrouped
        plans only (the fused kernel has no group-id input), device
        backends only — the host baseline decodes then reduces."""
        if not plan.aggregates or plan.group_by is not None:
            return frozenset()
        if self.backend not in ("ref", "pallas", "auto"):
            return frozenset()
        keep = set(plan.columns) | set(expr_columns(pred))
        out = set()
        for spec in plan.aggregates:
            c = spec.column
            if c is None or c in keep:
                continue
            col = enc.get(c)
            if col is not None and col.encoding == Encoding.BITPACK:
                out.add(c)
        return frozenset(out)

    def _agg_skip_meta(self, plan: ScanPlan, pred: Optional[Expr],
                       meta_cols: Dict) -> frozenset:
        """`_agg_skip` predicted from footer metadata alone — the cost
        estimator's mirror (decode_footprint), column for column."""
        if not plan.aggregates or plan.group_by is not None:
            return frozenset()
        if self.backend not in ("ref", "pallas", "auto"):
            return frozenset()
        keep = set(plan.columns) | set(expr_columns(pred))
        out = set()
        for spec in plan.aggregates:
            c = spec.column
            if c is None or c in keep:
                continue
            cm = meta_cols.get(c)
            if cm is not None and cm.get("encoding") == "bitpack":
                out.add(c)
        return frozenset(out)

    @staticmethod
    def _fused_width(reader, rg: int, pred) -> int:
        """Footer dtype width of the fused predicate column — the honest
        per-row charge for its processed-but-unmaterialized decode work
        (mirrors decode_footprint's `L * itemsize` sizing; the old code
        hardcoded 4)."""
        cm = reader.row_group_meta(rg)["columns"][pred.column]
        return np.dtype(cm["dtype"]).itemsize

    @staticmethod
    def _charge_agg_page(stats: ScanStats, col: EncodedColumn, L: int) -> None:
        """Book a fused-aggregate page's processed-but-never-materialized
        decode work — the in-kernel unpack, charged at the decoded int32
        width under the page's encoding, exactly like the fused predicate
        column.  No decode launch: the aggregate launch is counted where
        it happens (ResumableScan._fold_agg)."""
        e = col.encoding.value
        stats.decode_work[e] = stats.decode_work.get(e, 0) + L * 4

    # ------------------------------------------------------------------
    # service hooks (metadata only — used by repro.datapath for admission
    # control and the adaptive offload policy)
    # ------------------------------------------------------------------
    def plan_cache_key(self, reader, plan: ScanPlan, blooms: Optional[Dict] = None,
                       tag=None):
        """Prefiltered-cache key for a whole scan: plan signature + backend +
        a digest of any probe-side bloom filters.  Blooms are per-caller
        state that the plan signature cannot see — leaving them out would
        let one tenant's semijoin result answer another tenant's probe.

        `tag` scopes the key beyond the plan: the scan fabric tags each
        pod sub-request with its owned row-group subset, so a cached
        sub-result can never answer a DIFFERENT subset of the same plan
        (e.g. after a drain re-hashes ownership).  None (every single-node
        caller) leaves the key exactly as before."""
        key = ("scan", reader.path, plan.signature(), self.backend)
        if blooms:
            digest = tuple(
                sorted(
                    (name, hashlib.sha1(np.asarray(bits).tobytes()).hexdigest()[:16])
                    for name, bits in blooms.items()
                )
            )
            key += (digest,)
        if tag is not None:
            key += (tag,)
        return key

    def estimate_selectivity(self, reader, plan: ScanPlan) -> float:
        """Estimated fraction of rows surviving the plan's predicate, from
        zone maps alone (uniform-within-row-group assumption)."""
        pred = bind_expr(plan.predicate, reader)
        return estimate_selectivity(reader, pred)

    def estimate_scan_bytes(self, reader, plan: ScanPlan, row_groups=None) -> int:
        """Encoded bytes the scan would pull over the storage->NIC hop,
        after zone-map pruning.  Metadata only.  Pass `row_groups` when the
        caller already pruned (the service does, at admission)."""
        if row_groups is None:
            pred = bind_expr(plan.predicate, reader)
            row_groups = prune_row_groups(reader, pred)
        need = plan.all_columns()
        total = 0
        for rg in row_groups:
            cols = reader.row_group_meta(rg)["columns"]
            total += sum(cols[c]["encoded_bytes"] for c in need if c in cols)
        return total

    def fused_column_meta(self, pred: Optional[Expr], meta_cols: Dict, projected) -> Optional[str]:
        """Predict, from footer metadata alone, the predicate column the
        fused decode+filter fast path would skip materializing — or None
        when the scan will not fuse.  Mirrors `_fusable` (which needs the
        encoded buffers) column for column: single integer Cmp on a
        BITPACK/int-DICT column outside the projection, device backends
        only.  `pred` must already be bound (string constants folded)."""
        if self.backend not in ("ref", "pallas", "auto"):
            return None
        if not isinstance(pred, Cmp) or pred.column in projected:
            return None
        cm = meta_cols.get(pred.column)
        if cm is None or cm.get("encoding") not in ("bitpack", "dict"):
            return None
        if cm["encoding"] == "dict" and np.dtype(cm["dtype"]).kind not in "iu":
            return None
        if pred_int_bounds(pred) is None:
            return None
        return pred.column

    def decode_footprint(self, reader, plan: ScanPlan, row_groups, pred=None) -> List[dict]:
        """Honest per-row-group decode footprint, metadata only: what the
        engine will MATERIALIZE (PACK_BLOCK-padded rows, true dtype widths,
        fused predicate column skipped) and what it will merely process.

        Returns one dict per row group:
            {"rg", "n", "rows": L, "columns": {name: {
                "nbytes": L * itemsize,   # decoded output if materialized
                "encoded_bytes": int,     # storage->NIC fetch size
                "encoding": str,          # footer encoding (cost-model key)
                "materialized": bool,     # False for the fused pred column
            }}}
        The datapath cost model (datapath/costmodel.py) prices this in
        decode-seconds; the scheduler's fetch simulation sizes transfers
        with it.  No data bytes move."""
        if pred is None:
            pred = bind_expr(plan.predicate, reader)
        need = plan.all_columns()
        proj = plan.materialized_columns()
        pred_cols = set(expr_columns(pred))
        # aggregate pushdown eligibility is metadata-visible: a group-by
        # domain over the kernels' MAX_GROUPS ceiling falls back to
        # scan-then-host-aggregate, which does no in-datapath agg work
        agg_push = bool(plan.aggregates) and (
            plan.group_by is None
            or group_domain(reader, plan.group_by) <= ops.MAX_GROUPS
        )
        agg_srcs = agg_merge.agg_sources(plan.aggregates) if agg_push else []
        out = []
        for rg in row_groups:
            meta = reader.row_group_meta(rg)
            cols = meta["columns"]
            L = padded_rows(meta["n"])
            fused_col = self.fused_column_meta(pred, cols, proj)
            askip = self._agg_skip_meta(plan, pred, cols) if agg_push else frozenset()
            fp = {}
            for c in need:
                if c not in cols:
                    continue
                cm = cols[c]
                if c == plan.group_by:
                    role = "group-key"
                elif c in {s for s in agg_srcs if s is not None}:
                    role = "agg-source"
                elif c in plan.columns:
                    role = "output"
                else:
                    role = "pred"  # decoded for the mask, dropped pre-DMA
                fp[c] = {
                    "nbytes": L * np.dtype(cm["dtype"]).itemsize,
                    "encoded_bytes": cm.get("encoded_bytes", 0),
                    "encoding": cm.get("encoding", "plain"),
                    # fused predicate columns and fused-aggregate pages are
                    # processed in-kernel, never materialized
                    "materialized": c != fused_col and c not in askip,
                    "role": role,
                }
            # one aggregate-launch pseudo-column per DECODED source (the
            # fused `askip` pages' reduction rides their entry above):
            # encoded_bytes 0 (nothing crosses the hop), nbytes L*4 of
            # processed-not-materialized work at the 'agg' rate + one
            # launch — exactly what ResumableScan._fold_agg books per
            # source per row group on the sequential path
            for src in agg_srcs:
                if src in askip:
                    continue
                if src is not None and src not in cols:
                    continue
                fp[f"agg:{src or '*'}"] = {
                    "nbytes": L * 4,
                    "encoded_bytes": 0,
                    "encoding": "agg",
                    "materialized": False,
                    "role": "agg",
                }
            out.append({"rg": rg, "n": meta["n"], "rows": L, "columns": fp})
        return out

    # ------------------------------------------------------------------
    # scan
    # ------------------------------------------------------------------
    def scan_row_group(
        self,
        reader,
        rg: int,
        plan: ScanPlan,
        pred: Optional[Expr],
        blooms: Dict[str, jax.Array],
        stats: ScanStats,
        pool: Optional[Dict] = None,
        offload: Optional[str] = None,
    ):
        """Decode + filter ONE row group; the entry point the service
        scheduler drives.  `pred` must already be bound (bind_expr).

        Returns (cols, mask): `cols` maps each needed column to its decoded
        array — None for a predicate-only column skipped under fusion, or
        the raw EncodedColumn for an aggregate value page the fused
        decode→aggregate kernel consumes without decoding (`_agg_skip`) —
        and `mask` is (L,) bool including row validity.  `pool` is an
        optional tick-level decode pool shared across coalesced scans.
        """
        need = plan.all_columns()
        proj = plan.materialized_columns()
        mode = offload or self.offload
        # front half (resident probe / page tier / fetch / fusability) is
        # the exact code the batched path runs — _prepare_row_group
        n, L, resident, enc, fuse, _fetched = self._prepare_row_group(
            reader, rg, plan, pred, mode, stats, pool=pool
        )
        if resident:
            # fully resident: every needed column already decoded in the
            # tick pool (coalescing) or, under preloaded/prefiltered, in
            # the BlockCache -> no encoded fetch at all
            cols = {}
            for name in need:
                arr, _ = self._decode_column(
                    reader, rg, name, None, L, offload=offload, pool=pool, stats=stats
                )
                cols[name] = arr
            mask = self._eval_mask(pred, cols, blooms, L, rg)
            mask = mask & (jnp.arange(L) < n)
            return cols, mask

        askip = self._agg_skip(plan, pred, enc)
        cols: Dict[str, Optional[jax.Array]] = {}
        if fuse is not None:
            stats.fused = True
            lo, hi = fuse
            fe = enc[pred.column].encoding.value
            # processed-but-never-materialized decode work, charged at the
            # column's TRUE footer dtype width (decode_footprint sizes the
            # estimate the same way, so estimate == actual stays exact for
            # fused scans whatever the predicate column's dtype)
            stats.decode_work[fe] = (
                stats.decode_work.get(fe, 0) + L * self._fused_width(reader, rg, pred)
            )
            stats.kernel_launches += 1
            tr = _tr()
            if tr is not None:
                tr.begin("decode_launch", rg=rg, encoding=fe, fused=True, rows=L)
            fmask, _ = ops.fused_scan(
                jnp.asarray(enc[pred.column].buffers["packed"]),
                enc[pred.column].k,
                lo,
                hi,
                backend=self.backend,
            )
            if tr is not None:
                tr.end(name="decode_launch")
            fmask = fmask.reshape(-1)[:L]
            for name in proj:
                if name in askip:
                    self._charge_agg_page(stats, enc[name], L)
                    cols[name] = enc[name]
                    continue
                arr, _ = self._decode_column(
                    reader, rg, name, enc[name], L, offload=offload, pool=pool, stats=stats
                )
                cols[name] = arr
            mask = fmask
        else:
            for name in need:
                if name in askip:
                    self._charge_agg_page(stats, enc[name], L)
                    cols[name] = enc[name]
                    continue
                arr, _ = self._decode_column(
                    reader, rg, name, enc[name], L, offload=offload, pool=pool, stats=stats
                )
                cols[name] = arr
            mask = self._eval_mask(pred, cols, blooms, L, rg)

        mask = mask & (jnp.arange(L) < n)  # row validity
        for name in need:
            cols.setdefault(name, None)  # predicate-only column under fusion
        return cols, mask

    # ------------------------------------------------------------------
    # batched multi-row-group scan (bucketed kernel launches)
    # ------------------------------------------------------------------
    def scan_row_groups_batched(
        self,
        reader,
        rgs,
        plan: ScanPlan,
        pred: Optional[Expr],
        blooms: Dict[str, jax.Array],
        stats: ScanStats,
        pool: Optional[Dict] = None,
        offload: Optional[str] = None,
    ):
        """Decode + filter MANY row groups with bucketed batch launches —
        bit-identical to calling `scan_row_group` per group, in order.

        Compatible pages are stacked along the block axis and decoded in
        ONE kernel launch per (encoding, k, dtype) bucket (`kernels.ops`
        `*_batch`), bucket-padded to power-of-two block counts so jit
        traces are reused across slices.  Everything that is NOT the
        kernel launch — residency lookups, page-tier fetches, stats
        increments, pool/cache puts — runs through the exact sequential
        code in strict (row group, column) order, so pool budgets and
        accounting cannot drift.  (The one documented divergence: all
        encoded fetches happen before any decoded put, so a cache evicting
        PRE-RESIDENT entries mid-slice can shift hit/fresh counters; the
        results stay bit-identical — a vanished entry is re-fetched and
        re-decoded singly.)

        Returns (per_rg, fetched): `per_rg` is [(cols, mask)] in `rgs`
        order with the same contract as `scan_row_group`; `fetched` lists
        the row groups that pulled encoded bytes over the storage->NIC hop
        (the scheduler feeds exactly these to the netsim pipeline).
        """
        rgs = list(rgs)
        mode = offload or self.offload
        if self.backend == "host" or len(rgs) <= 1:
            # the host baseline decodes on the CPU (nothing to batch-launch)
            # and a single group has nothing to bucket: the sequential path
            # IS the batched path
            per_rg, fetched = [], []
            for rg in rgs:
                enc0 = stats.encoded_bytes
                per_rg.append(self.scan_row_group(
                    reader, rg, plan, pred, blooms, stats, pool=pool, offload=offload
                ))
                if stats.encoded_bytes > enc0:
                    fetched.append(rg)
            return per_rg, fetched

        need = plan.all_columns()
        proj = plan.materialized_columns()

        # -- phase A: residency, page-tier fetch, fusability (rg order) ----
        # the front half is _prepare_row_group — the SAME code the
        # sequential scan_row_group runs, so the two paths cannot drift
        slots = []
        fetched: List[int] = []
        for rg in rgs:
            n, L, resident, enc, fuse, did_fetch = self._prepare_row_group(
                reader, rg, plan, pred, mode, stats, pool=pool
            )
            askip = self._agg_skip(plan, pred, enc) if not resident else frozenset()
            slot = {"rg": rg, "n": n, "L": L, "resident": resident,
                    "enc": enc, "fuse": fuse, "askip": askip, "decode": []}
            slots.append(slot)
            if did_fetch:
                fetched.append(rg)
            if resident:
                continue
            # columns needing a fresh decode — non-mutating residency peek
            # (presence checks touch no LRU order and count no hits; the
            # counting lookups run in the finalize pass, in order).  Fused-
            # aggregate pages (`askip`) never enter the decode buckets: the
            # aggregate kernel unpacks them in VMEM.
            for name in (proj if fuse is not None else need):
                if name in askip:
                    continue
                key = self.rg_cache_key(reader, rg, name)
                if pool is not None and key in pool:
                    continue
                if mode in ("preloaded", "prefiltered") and key in self.cache:
                    continue
                slot["decode"].append(name)

        # -- phase B: bucket compatible pages, one launch per bucket -------
        decoded, fmasks = self._launch_buckets(slots, pred, stats)

        # bloom semijoin probes ride the batched pass too: every slice
        # page's keys probe in ONE stacked launch per bloom filter
        bloom_by_rg = self._batch_bloom_probe(slots, pred, blooms, decoded)

        # -- finalize (strict rg order): hits, puts, stats, masks ----------
        per_rg = []
        for slot in slots:
            rg, n, L = slot["rg"], slot["n"], slot["L"]
            if slot["resident"]:
                cols = {}
                for name in need:
                    cols[name] = self._serve_resident(
                        reader, rg, name, L, mode, offload, pool, stats, fetched
                    )
                mask = self._eval_mask(pred, cols, blooms, L, rg)
                per_rg.append((cols, mask & (jnp.arange(L) < n)))
                continue
            enc = slot["enc"]
            askip = slot["askip"]
            cols = {}
            if slot["fuse"] is not None:
                stats.fused = True
                fe = enc[pred.column].encoding.value
                stats.decode_work[fe] = (
                    stats.decode_work.get(fe, 0)
                    + L * self._fused_width(reader, rg, pred)
                )
                for name in proj:
                    if name in askip:
                        self._charge_agg_page(stats, enc[name], L)
                        cols[name] = enc[name]
                        continue
                    arr, _ = self._decode_column(
                        reader, rg, name, enc[name], L, offload=offload,
                        pool=pool, stats=stats, precomputed=decoded.get((0, rg, name)),
                    )
                    cols[name] = arr
                mask = fmasks[(0, rg)]
            else:
                for name in need:
                    if name in askip:
                        self._charge_agg_page(stats, enc[name], L)
                        cols[name] = enc[name]
                        continue
                    arr, _ = self._decode_column(
                        reader, rg, name, enc[name], L, offload=offload,
                        pool=pool, stats=stats, precomputed=decoded.get((0, rg, name)),
                    )
                    cols[name] = arr
                mask = self._eval_mask(pred, cols, blooms, L, rg,
                                       bmasks=bloom_by_rg.get((0, rg)))
            mask = mask & (jnp.arange(L) < n)
            for name in need:
                cols.setdefault(name, None)
            per_rg.append((cols, mask))
        return per_rg, fetched

    def _batch_bloom_probe(self, slots, pred, blooms, decoded) -> Dict[tuple, Dict]:
        """Stack every freshly-decoded slice page's keys and probe each
        bloom filter in ONE `ops.bloom_probe` dispatch (the semijoin leg
        of the fused bucket pass).  Returns {(item, rg): {(name, column):
        (L,) mask}} for `_eval` to consume; pages served from the pool or
        cache at finalize time are absent and fall back to the per-row-
        group probe — bit-identical either way, the probe is elementwise.
        """
        if pred is None or self.backend == "host" or not blooms:
            return {}
        probes = {(p.name, p.column): p for p in _expr_blooms(pred)
                  if p.name in blooms}
        out: Dict[tuple, Dict] = {}
        for (name, column), probe in sorted(probes.items()):
            entries = []  # (item, rg, L, nblk)
            keys = []
            for slot in slots:
                if slot["resident"] or slot["fuse"] is not None:
                    continue
                item = slot.get("item", 0)
                arr = decoded.get((item, slot["rg"], column))
                if arr is None:
                    continue  # pool/cache-served at finalize: per-rg probe
                L = slot["L"]
                entries.append((item, slot["rg"], L, L // RLE_OUT_BLOCK))
                keys.append(arr.astype(jnp.int32).reshape(-1, RLE_OUT_BLOCK))
            if not entries:
                continue
            m = ops.bloom_probe(
                jnp.concatenate(keys, axis=0), blooms[name], probe.n_hashes,
                backend=self.backend,
            )
            s = 0
            for item, rg, L, nblk in entries:
                out.setdefault((item, rg), {})[(name, column)] = (
                    m[s:s + nblk].reshape(-1)[:L]
                )
                s += nblk
        return out

    def _serve_resident(self, reader, rg, name, L, mode, offload, pool, stats,
                        fetched):
        """Finalize-time lookup for a phase-A-resident column.  If the
        entry was evicted between the phases (cache pressure from this
        slice's own puts), fall back to a fetch + single decode — the
        sequential path would have seen the same miss at its later
        residency check, so results stay identical."""
        key = self.rg_cache_key(reader, rg, name)
        still = (pool is not None and key in pool) or (
            mode in ("preloaded", "prefiltered") and key in self.cache
        )
        col = None
        if not still:
            # same lookup ladder as _prepare_row_group: the encoded-page
            # tier first — a page still resident contributes page_hit
            # bytes, NOT encoded_bytes (nothing re-crosses the hop, so
            # netsim must not price a transfer)
            if mode in ("preloaded", "prefiltered"):
                col = self.cache.get(self.page_cache_key(reader, rg, name),
                                     stats=stats)
                if col is not None:
                    stats.page_hits += 1
                    stats.page_hit_bytes += col.encoded_bytes()
            if col is None:
                tr = _tr()
                if tr is not None:
                    tr.begin("fetch", rg=rg, columns=1)
                col = self._storage_read(reader, rg, [name], stats)[name]
                if tr is not None:
                    tr.end(name="fetch", nbytes=col.encoded_bytes())
                stats.encoded_bytes += col.encoded_bytes()
                if rg not in fetched:
                    fetched.append(rg)
                if mode in ("preloaded", "prefiltered"):
                    self.cache.put(self.page_cache_key(reader, rg, name), col,
                                   tier="encoded")
        arr, _ = self._decode_column(
            reader, rg, name, col, L, offload=offload, pool=pool, stats=stats
        )
        return arr

    def _launch_buckets(self, slots, pred, stats):
        """Group every pending (row group, column) page by its launch
        signature and decode each bucket in ONE device dispatch.  Returns
        ({(item, rg, name): decoded (L,) array}, {(item, rg): fused mask}).

        Slots from a single scan leave `item`/`pred`/`stats` unset (they
        default to 0 and the arguments).  The cross-request group path
        (`scan_group_batched`) sets all three per slot: pages from MANY
        requests stack into the same buckets, each slot's fusability uses
        its own predicate, and a bucket's launch/pad counters are charged
        to the stats of its first contributing request (reconciliation
        refunds the others their share — kernel_launches is the one field
        batching is allowed to move)."""
        buckets: Dict[tuple, List[dict]] = {}
        fused_items: Dict[int, List[dict]] = {}
        for slot in slots:
            if slot["resident"]:
                continue
            rg, L = slot["rg"], slot["L"]
            item = slot.get("item", 0)
            spred = slot.get("pred", pred)
            sstats = slot.get("stats", stats)
            if slot["fuse"] is not None:
                col = slot["enc"][spred.column]
                lo, hi = slot["fuse"]
                fused_items.setdefault(col.k, []).append(
                    {"rg": rg, "L": L, "packed": col.buffers["packed"],
                     "lo": lo, "hi": hi, "item": item, "stats": sstats}
                )
            for name in slot["decode"]:
                col = slot["enc"][name]
                e = col.encoding
                if e == Encoding.PLAIN:
                    bkey = ("plain", str(col.buffers["plain"].dtype))
                elif e == Encoding.BITPACK:
                    bkey = ("bitpack", col.k)
                elif e == Encoding.DICT:
                    d = col.buffers["dictionary"]
                    bkey = ("dict", col.k,
                            "int32" if d.dtype.kind in "iu" else str(d.dtype))
                elif e == Encoding.DELTA:
                    bkey = ("delta", col.k)
                else:
                    bkey = ("rle", str(col.buffers["rle_values"].dtype))
                buckets.setdefault(bkey, []).append(
                    {"rg": rg, "name": name, "col": col, "L": L,
                     "item": item, "stats": sstats}
                )

        be = self.backend
        decoded: Dict[tuple, jax.Array] = {}
        for bkey, items in buckets.items():
            bstats = items[0]["stats"]
            tr = _tr()
            if tr is not None:
                launches0 = bstats.kernel_launches
                pad0 = bstats.batch_pad_blocks
                tr.begin("decode_launch",
                         bucket="/".join(str(p) for p in bkey),
                         pages=len(items))
            decoded.update(self._decode_bucket(bkey, items, be, bstats))
            if tr is not None:
                tr.end(name="decode_launch",
                       launches=bstats.kernel_launches - launches0,
                       pad_blocks=bstats.batch_pad_blocks - pad0)
        fmasks: Dict[tuple, jax.Array] = {}
        for k, items in sorted(fused_items.items()):
            bstats = items[0]["stats"]
            tr = _tr()
            if tr is not None:
                pad0 = bstats.batch_pad_blocks
                tr.begin("decode_launch", bucket=f"fused/k{k}",
                         pages=len(items), fused=True)
            packed = np.concatenate([it["packed"] for it in items], axis=0)
            blocks = [it["packed"].shape[0] for it in items]
            lo = np.concatenate(
                [np.full(b, it["lo"], np.int32) for b, it in zip(blocks, items)])
            hi = np.concatenate(
                [np.full(b, it["hi"], np.int32) for b, it in zip(blocks, items)])
            mask = ops.fused_scan_batch(packed, k, lo, hi, backend=be)
            bstats.kernel_launches += 1
            bstats.batch_pad_blocks += ops.bucket_blocks(packed.shape[0]) - packed.shape[0]
            s = 0
            for b, it in zip(blocks, items):
                fmasks[(it["item"], it["rg"])] = mask[s:s + b].reshape(-1)[: it["L"]]
                s += b
            if tr is not None:
                tr.end(name="decode_launch", launches=1,
                       pad_blocks=bstats.batch_pad_blocks - pad0)
        return decoded, fmasks

    @staticmethod
    def _split_flat(out, items, blocks) -> Dict[tuple, jax.Array]:
        """Slice one bucket's stacked decode back into per-page (L,)
        columns, replicating the sequential pad-to-L / truncate-to-L."""
        res = {}
        s = 0
        for b, it in zip(blocks, items):
            flat = out[s:s + b].reshape(-1)
            L = it["L"]
            if flat.shape[0] < L:
                flat = jnp.pad(flat, (0, L - flat.shape[0]))
            res[(it.get("item", 0), it["rg"], it["name"])] = flat[:L]
            s += b
        return res

    def _decode_bucket(self, bkey, items, be, stats) -> Dict[tuple, jax.Array]:
        kind = bkey[0]
        if kind == "plain":
            # one host gather + ONE device put for the whole bucket (plain
            # has no kernel, so there is no jit trace to keep shape-stable
            # — no power-of-two padding, just the stacked transfer)
            total = sum(it["L"] for it in items)
            buf = np.zeros((total,), dtype=np.dtype(bkey[1]))
            s = 0
            for it in items:
                v = it["col"].buffers["plain"]
                buf[s:s + v.shape[0]] = v
                s += it["L"]
            out = ops.device_put(buf)
            stats.kernel_launches += 1
            res, s = {}, 0
            for it in items:
                res[(it.get("item", 0), it["rg"], it["name"])] = out[s:s + it["L"]]
                s += it["L"]
            return res
        stats.kernel_launches += 1
        if kind == "bitpack":
            packed = np.concatenate([it["col"].buffers["packed"] for it in items], axis=0)
            blocks = [it["col"].buffers["packed"].shape[0] for it in items]
            out = ops.bitunpack_batch(packed, bkey[1], backend=be)
        elif kind == "dict":
            packed = np.concatenate([it["col"].buffers["packed"] for it in items], axis=0)
            blocks = [it["col"].buffers["packed"].shape[0] for it in items]
            dicts_np = [
                d.astype(np.int32) if d.dtype.kind in "iu" else d
                for d in (it["col"].buffers["dictionary"] for it in items)
            ]
            # the dictionary axis is bucket-padded like the block axis: a
            # raw per-call max width would re-trace the jitted batch decode
            # once per distinct cardinality mix (per-block clip bounds make
            # the zero padding unreachable, so this is free bit-wise)
            dmax = ops.bucket_blocks(max(d.shape[0] for d in dicts_np))
            dicts = np.zeros((len(items), dmax), dtype=np.dtype(bkey[2]))
            sizes = np.zeros((len(items),), np.int32)
            for i, d in enumerate(dicts_np):
                dicts[i, : d.shape[0]] = d
                sizes[i] = d.shape[0]
            page = np.concatenate(
                [np.full(b, i, np.int32) for i, b in enumerate(blocks)])
            out = ops.dict_decode_batch(packed, dicts, sizes, page, bkey[1], backend=be)
        elif kind == "delta":
            packed = np.concatenate([it["col"].buffers["packed"] for it in items], axis=0)
            blocks = [it["col"].buffers["packed"].shape[0] for it in items]
            bases = np.concatenate(
                [it["col"].buffers["bases"].astype(np.int32) for it in items])
            out = ops.delta_decode_batch(packed, bases, bkey[1], backend=be)
        else:  # rle
            values = np.concatenate([it["col"].buffers["rle_values"] for it in items], axis=0)
            ends = np.concatenate([it["col"].buffers["rle_ends"] for it in items], axis=0)
            blocks = [it["col"].buffers["rle_values"].shape[0] for it in items]
            out = ops.rle_decode_batch(values, ends, backend=be)
        stats.batch_pad_blocks += ops.bucket_blocks(sum(blocks)) - sum(blocks)
        return self._split_flat(out, items, blocks)

    # ------------------------------------------------------------------
    # cross-request bucket stacking (DESIGN.md §15)
    # ------------------------------------------------------------------
    def scan_group_batched(self, items, pool=None):
        """Decode the slices of SEVERAL coalesced scans over one table in
        a single bucketed launch pass.

        Each item is one request's slice: {"reader", "rgs", "plan",
        "pred", "blooms", "stats", "offload", "owner", "trace"} — the
        per-request state `ResumableScan.advance_batched` would have
        passed to `scan_row_groups_batched`.  Returns [(per_rg, fetched)]
        aligned with items, each element carrying that request's own
        columns/masks and fetched row groups, ready for
        `ResumableScan.ingest_batched`.

        Where this beats per-request batching: before this entry point,
        same-tick requests over the same table launched their buckets
        separately and shared decodes only through pool hits at finalize
        time.  Here every request's pages stack into ONE set of buckets
        (fewer dispatches), and a page two requests both need decodes
        exactly once — the later request skips it in phase A (`pending`)
        and serves it as a pool hit at its finalize, which is precisely
        the accounting the sequential order would have produced.

        Attribution rules: `pool.owner` and the trace slice context are
        rebound per item around its phase-A and finalize work, so window
        billing and the flight recorder see per-request activity; a
        stacked bucket's launch is charged to its first contributor's
        stats (WFQ reconciliation refunds the difference)."""
        tr_mod = TRACE

        def _ctx(it):
            if tr_mod is not None:
                t = it.get("trace")
                tr_mod.set_slice(*(t if t else (None, None)))

        def _owner(it):
            if pool is not None and hasattr(pool, "owner"):
                pool.owner = it.get("owner", pool.owner)

        if self.backend == "host":
            # the host baseline has no device launches to stack: run each
            # request through the normal batched entry (which itself falls
            # back to sequential on host), sharing only the pool
            out = []
            for it in items:
                _owner(it)
                _ctx(it)
                out.append(self.scan_row_groups_batched(
                    it["reader"], it["rgs"], it["plan"], it["pred"],
                    it["blooms"], it["stats"], pool=pool, offload=it["offload"],
                ))
            if tr_mod is not None:
                tr_mod.set_slice(None, None)
            return out

        # -- phase A per item, in order: residency / page tier / fetch ----
        slots_by_item: List[List[dict]] = []
        fetched_by_item: List[List[int]] = [[] for _ in items]
        pending: set = set()  # keys an EARLIER item decodes in this pass
        for i, it in enumerate(items):
            reader, plan, pred = it["reader"], it["plan"], it["pred"]
            mode = it["offload"] or self.offload
            stats = it["stats"]
            need = plan.all_columns()
            proj = plan.materialized_columns()
            _owner(it)
            _ctx(it)
            slots = []
            for rg in it["rgs"]:
                keys = [self.rg_cache_key(reader, rg, name) for name in need]
                if (pool is not None
                        and all(k in pool or k in pending for k in keys)
                        and any(k in pending for k in keys)):
                    # every needed column is pooled or scheduled by an
                    # earlier request in THIS pass: by this item's
                    # finalize (strict item order) they are pool entries
                    # — the same full residency the sequential order
                    # would have seen after the earlier request's puts
                    n = reader.row_group_meta(rg)["n"]
                    slots.append({"rg": rg, "n": n, "L": padded_rows(n),
                                  "resident": True, "enc": {}, "fuse": None,
                                  "askip": frozenset(), "decode": [],
                                  "item": i, "pred": pred, "stats": stats})
                    continue
                n, L, resident, enc, fuse, did_fetch = self._prepare_row_group(
                    reader, rg, plan, pred, mode, stats, pool=pool
                )
                askip = self._agg_skip(plan, pred, enc) if not resident else frozenset()
                slot = {"rg": rg, "n": n, "L": L, "resident": resident,
                        "enc": enc, "fuse": fuse, "askip": askip, "decode": [],
                        "item": i, "pred": pred, "stats": stats}
                slots.append(slot)
                if did_fetch:
                    fetched_by_item[i].append(rg)
                if resident:
                    continue
                for name in (proj if fuse is not None else need):
                    if name in askip:
                        continue  # fused-aggregate page: unpacked in-kernel
                    key = self.rg_cache_key(reader, rg, name)
                    if pool is not None and key in pool:
                        continue
                    if mode in ("preloaded", "prefiltered") and key in self.cache:
                        continue
                    if pool is not None and key in pending:
                        continue  # an earlier request decodes it; our
                        # finalize serves it as a pool hit
                    slot["decode"].append(name)
                    pending.add(key)
            slots_by_item.append(slots)

        # -- phase B: ONE bucket pass across every request's pages --------
        # (bucket launch spans attribute to the first traced item)
        if tr_mod is not None:
            first = next((it.get("trace") for it in items if it.get("trace")),
                         None)
            tr_mod.set_slice(*(first if first else (None, None)))
        all_slots = [s for slots in slots_by_item for s in slots]
        decoded, fmasks = self._launch_buckets(all_slots, None, None)

        # -- finalize per item, in order: hits, puts, stats, masks --------
        out = []
        for i, it in enumerate(items):
            reader, plan, pred = it["reader"], it["plan"], it["pred"]
            blooms, stats = it["blooms"], it["stats"]
            mode = it["offload"] or self.offload
            offload = it["offload"]
            need = plan.all_columns()
            proj = plan.materialized_columns()
            _owner(it)
            _ctx(it)
            per_rg = []
            for slot in slots_by_item[i]:
                rg, n, L = slot["rg"], slot["n"], slot["L"]
                if slot["resident"]:
                    cols = {}
                    for name in need:
                        cols[name] = self._serve_resident(
                            reader, rg, name, L, mode, offload, pool, stats,
                            fetched_by_item[i],
                        )
                    mask = self._eval_mask(pred, cols, blooms, L, rg)
                    per_rg.append((cols, mask & (jnp.arange(L) < n)))
                    continue
                enc = slot["enc"]
                askip = slot["askip"]
                cols = {}
                if slot["fuse"] is not None:
                    stats.fused = True
                    fe = enc[pred.column].encoding.value
                    stats.decode_work[fe] = (
                        stats.decode_work.get(fe, 0)
                        + L * self._fused_width(reader, rg, pred)
                    )
                    for name in proj:
                        if name in askip:
                            self._charge_agg_page(stats, enc[name], L)
                            cols[name] = enc[name]
                            continue
                        arr, _ = self._decode_column(
                            reader, rg, name, enc[name], L, offload=offload,
                            pool=pool, stats=stats,
                            precomputed=decoded.get((i, rg, name)),
                        )
                        cols[name] = arr
                    mask = fmasks[(i, rg)]
                else:
                    for name in need:
                        if name in askip:
                            self._charge_agg_page(stats, enc[name], L)
                            cols[name] = enc[name]
                            continue
                        arr, _ = self._decode_column(
                            reader, rg, name, enc[name], L, offload=offload,
                            pool=pool, stats=stats,
                            precomputed=decoded.get((i, rg, name)),
                        )
                        cols[name] = arr
                    mask = self._eval_mask(pred, cols, blooms, L, rg)
                mask = mask & (jnp.arange(L) < n)
                for name in need:
                    cols.setdefault(name, None)
                per_rg.append((cols, mask))
            out.append((per_rg, fetched_by_item[i]))
        if tr_mod is not None:
            tr_mod.set_slice(None, None)
        return out

    def scan(
        self,
        reader,
        plan: ScanPlan,
        blooms: Optional[Dict[str, jax.Array]] = None,
        offload: Optional[str] = None,
        pool: Optional[Dict] = None,
        row_groups=None,
        batched: bool = False,
    ) -> ScanResult:
        """Full pushed-down scan.  `offload` overrides the engine-wide mode
        for this call (the adaptive policy's per-request knob); `pool` is a
        tick-level decode pool shared across coalesced scans; `row_groups`
        skips re-pruning when the caller already did it (service admission).

        Implemented as a ResumableScan driven to completion in one shot, so
        a scan the service slices across ticks is structurally guaranteed to
        produce the same result as a direct call.  `batched=True` routes the
        row-group work through `scan_row_groups_batched` (bucketed batch
        kernel launches) instead of one launch per (row group, column)."""
        rs = ResumableScan(
            self, reader, plan, blooms=blooms, offload=offload, row_groups=row_groups
        )
        if rs.result is None:
            if batched:
                rs.advance_batched(tuple(rs.pending), pool=pool)
            else:
                rs.advance(tuple(rs.pending), pool=pool)
        return rs.result

    # ------------------------------------------------------------------
    def resumable_scan(
        self,
        reader,
        plan: ScanPlan,
        blooms: Optional[Dict[str, jax.Array]] = None,
        offload: Optional[str] = None,
        row_groups=None,
    ) -> "ResumableScan":
        """A scan that can be advanced a few row groups at a time — the
        service scheduler's preemption point (DESIGN.md §9)."""
        return ResumableScan(
            self, reader, plan, blooms=blooms, offload=offload, row_groups=row_groups
        )

    # ------------------------------------------------------------------
    def _compact(self, cols: Dict[str, jax.Array], mask: jax.Array):
        """Global stream compaction: per-block kernel compaction + stitch."""
        L = mask.shape[0]
        nblk = L // RLE_OUT_BLOCK
        m2 = mask.reshape(nblk, RLE_OUT_BLOCK)
        out = {}
        counts = None
        for name, arr in cols.items():
            comp, counts = ops.filter_compact(
                arr.reshape(nblk, RLE_OUT_BLOCK), m2, backend=self.backend if self.backend != "host" else "ref"
            )
            offs = jnp.cumsum(counts) - counts
            slot = jnp.arange(RLE_OUT_BLOCK, dtype=jnp.int32)[None, :]
            valid = slot < counts[:, None]
            tgt = jnp.where(valid, offs[:, None] + slot, L)
            flat = jnp.zeros((L,), arr.dtype).at[tgt.reshape(-1)].set(
                comp.reshape(-1), mode="drop"
            )
            out[name] = flat
        total = jnp.sum(counts)
        new_mask = jnp.arange(L) < total
        return out, new_mask, total


class ResumableScan:
    """One pushed-down scan, resumable at row-group granularity.

    The service's fair scheduler slices big scans across ticks: each tick it
    calls `advance(next_few_row_groups, pool=tick_pool)` and, once the last
    group lands, `result` holds the assembled ScanResult.  The per-row-group
    work and the final assembly (concatenate → count → optional compaction →
    prefiltered-cache put) are the exact code path `DatapathEngine.scan`
    runs, so sliced results are bit-identical to single-shot scans no matter
    where the preemption points fall.

    `result` is non-None immediately after construction when no row-group
    work is needed: a prefiltered-cache hit, or every group pruned.
    """

    def __init__(
        self,
        engine: DatapathEngine,
        reader,
        plan: ScanPlan,
        blooms: Optional[Dict[str, jax.Array]] = None,
        offload: Optional[str] = None,
        row_groups=None,
        scan_tag=None,
    ):
        assert offload in (None, "raw", "preloaded", "prefiltered",
                           "pre-aggregated"), offload
        self.engine = engine
        self.reader = reader
        self.plan = plan
        self.offload = offload or engine.offload
        self.blooms = blooms or {}
        # fabric sub-requests tag their prefiltered key with the owned
        # row-group subset (plan_cache_key `tag`): identical subsets hit,
        # different subsets (e.g. post-drain re-hash) can never collide
        self.scan_tag = scan_tag
        self.stats = ScanStats(row_groups_total=reader.n_row_groups, rows_total=reader.n_rows)
        self.result: Optional[ScanResult] = None

        # operator pushdown (DESIGN.md §16): the scan reduces to per-group
        # accumulators instead of rows.  Beyond the kernels' MAX_GROUPS
        # ceiling it falls back to accumulating the decoded value rows and
        # reducing host-side at finish — through the same block math and
        # fold order, so results stay bit-identical either way.
        self._agg = bool(plan.aggregates)
        if self._agg:
            assert not plan.compact, "aggregate scans return no rows to compact"
            self._n_groups = (
                group_domain(reader, plan.group_by)
                if plan.group_by is not None else 1
            )
            self._agg_push = self._n_groups <= ops.MAX_GROUPS
            # src -> {rg: ColPartial}; None source = bare count(*)
            self._agg_parts: Dict[Optional[str], Dict[int, object]] = {}
        else:
            self._agg_push = False
        if self.offload in ("prefiltered", "pre-aggregated"):
            key = engine.plan_cache_key(reader, plan, self.blooms, tag=scan_tag)
            hit = engine.cache.get(key)
            if hit is not None:
                self.stats.cache_hit = True
                self.stats.rows_out = int(hit.count)
                self.stats.result_bytes = hit.stats.result_bytes
                self._pending: List[int] = []
                self.result = ScanResult(
                    hit.columns, hit.mask, hit.count, self.stats,
                    aggregates=hit.aggregates, agg_partials=hit.agg_partials,
                )
                return

        self.pred = bind_expr(plan.predicate, reader)
        rgs = list(row_groups) if row_groups is not None else prune_row_groups(reader, self.pred)
        self.stats.row_groups_scanned = len(rgs)
        self._rgs = rgs
        self._pending = list(rgs)
        self._need = plan.all_columns()
        self._per_rg_cols: Dict[str, List[Optional[jax.Array]]] = {c: [] for c in self._need}
        self._per_rg_mask: List[jax.Array] = []
        if not self._pending:  # everything pruned: assemble the empty result
            self._finish()

    @property
    def pending(self) -> tuple:
        """Row groups not yet scanned, in scan order."""
        return tuple(self._pending)

    def advance(self, row_groups, pool: Optional[Dict] = None) -> Optional[ScanResult]:
        """Scan the given row groups (must be the next groups in order) and
        fold them into the accumulated partial result.  `pool` is the
        current tick's shared DecodePool.  Returns the final ScanResult once
        the last group is folded in, else None."""
        assert self.result is None, "scan already complete"
        for rg in row_groups:
            assert self._pending and rg == self._pending[0], (
                f"row group {rg} dispatched out of order (next is "
                f"{self._pending[0] if self._pending else None})"
            )
            self._pending.pop(0)
            cols, mask = self.engine.scan_row_group(
                self.reader, rg, self.plan, self.pred, self.blooms, self.stats,
                pool=pool, offload=self.offload,
            )
            self._fold([rg], [(cols, mask)])
        if not self._pending:
            self._finish()
        return self.result

    def advance_batched(self, row_groups, pool: Optional[Dict] = None):
        """`advance`, but through the engine's bucketed batch-decode path:
        the slice's row groups are fetched, bucketed by (encoding, k,
        dtype) and decoded in one kernel launch per bucket — bit-identical
        fold-in, same preemption contract.  Returns (result-or-None,
        fetched): `fetched` lists the row groups that actually pulled
        encoded bytes, which is what the scheduler's netsim pipeline
        prices (store-resident groups fetch nothing)."""
        assert self.result is None, "scan already complete"
        rgs = list(row_groups)
        for rg in rgs:
            assert self._pending and rg == self._pending[0], (
                f"row group {rg} dispatched out of order (next is "
                f"{self._pending[0] if self._pending else None})"
            )
            self._pending.pop(0)
        per_rg, fetched = self.engine.scan_row_groups_batched(
            self.reader, rgs, self.plan, self.pred, self.blooms, self.stats,
            pool=pool, offload=self.offload,
        )
        self._fold(rgs, per_rg)
        if not self._pending:
            self._finish()
        return self.result, fetched

    def ingest_batched(self, row_groups, per_rg):
        """Fold in a slice the engine already scanned on this request's
        behalf via `scan_group_batched` (cross-request bucket stacking).
        Same preemption contract as `advance_batched` — the groups must be
        the next pending ones in order — but the per-row-group work
        happened inside the shared group pass, so this only does the
        fold + finish half.  Returns the final result once complete."""
        assert self.result is None, "scan already complete"
        for rg in row_groups:
            assert self._pending and rg == self._pending[0], (
                f"row group {rg} dispatched out of order (next is "
                f"{self._pending[0] if self._pending else None})"
            )
            self._pending.pop(0)
        self._fold(list(row_groups), per_rg)
        if not self._pending:
            self._finish()
        return self.result

    def _fold(self, rgs: List[int], per_rg) -> None:
        """Fold one advanced slice into the accumulated partial result.
        Row scans (and the >MAX_GROUPS aggregate fallback) stash decoded
        columns and masks per row group; pushed-down aggregates reduce the
        slice to (n_groups,) partials right here and keep nothing
        row-shaped."""
        if self._agg and self._agg_push:
            self._fold_agg(rgs, per_rg)
            return
        for cols, mask in per_rg:
            for name in self._need:
                self._per_rg_cols[name].append(cols[name])
            self._per_rg_mask.append(mask)

    def _fold_agg(self, rgs: List[int], per_rg) -> None:
        """Reduce an advanced slice to per-row-group ColPartials — ONE
        aggregate launch per value source per call.  Sequential `advance`
        passes single row groups (a launch per rg, mirroring its one-
        launch-per-page decodes); the batched paths pass whole slices, so
        every row group's blocks stack into one launch per source exactly
        like the decode buckets (WFQ reconciliation refunds the
        difference).  Splitting the stacked planes back per row group
        before folding keeps the canonical per-rg fold boundary, so both
        cadences produce bit-identical partials."""
        be = self.engine.backend if self.engine.backend != "host" else "ref"
        # per-rg block counts, 2-d group ids and survivor masks
        metas = []  # (nblk, gids2d, mask2d)
        for cols, mask in per_rg:
            L = int(mask.shape[0])
            nblk = L // PACK_BLOCK
            if self.plan.group_by is not None:
                gids = cols[self.plan.group_by].astype(jnp.int32).reshape(
                    nblk, PACK_BLOCK)
            else:
                gids = jnp.zeros((nblk, PACK_BLOCK), jnp.int32)
            metas.append((nblk, gids, mask.astype(jnp.int32).reshape(
                nblk, PACK_BLOCK)))
        tr = _tr()
        for src in agg_merge.agg_sources(self.plan.aggregates):
            # partition the slice: decoded pages (and the gids-as-values
            # bare count) stack into one grouped launch; never-decoded
            # BITPACK pages (`_agg_skip`) into one in-kernel-unpack launch
            # per k.  Blocks reduce independently, so stacking cannot
            # change any per-block accumulator row.
            dec: List[int] = []
            fused: Dict[int, List[int]] = {}
            for i, (cols, _m) in enumerate(per_rg):
                v = cols[src] if src is not None else None
                if isinstance(v, EncodedColumn):
                    fused.setdefault(v.k, []).append(i)
                else:
                    dec.append(i)
            planes_by_i: Dict[int, tuple] = {}
            fdtype: Dict[int, np.dtype] = {}
            if dec:
                vals = jnp.concatenate([
                    (per_rg[i][0][src] if src is not None else metas[i][1])
                    .reshape(metas[i][0], PACK_BLOCK)
                    for i in dec
                ], axis=0)
                gids = jnp.concatenate([metas[i][1] for i in dec], axis=0)
                m2 = jnp.concatenate([metas[i][2] for i in dec], axis=0)
                if tr is not None:
                    tr.begin("agg_launch", source=src or "*", pages=len(dec),
                             rows=int(vals.shape[0]) * PACK_BLOCK)
                planes = ops.grouped_agg_batch(
                    vals, gids, m2, self._n_groups, backend=be)
                if tr is not None:
                    tr.end(name="agg_launch")
                self.stats.kernel_launches += 1
                nb = int(vals.shape[0])
                self.stats.batch_pad_blocks += ops.bucket_blocks(nb) - nb
                s = 0
                for i in dec:
                    planes_by_i[i] = tuple(p[s:s + metas[i][0]] for p in planes)
                    fdtype[i] = np.dtype(vals.dtype)
                    s += metas[i][0]
                    # the in-launch reduction processes the decoded values
                    # once more — ground-truth work the cost model prices
                    # under its own 'agg' rate (decode_footprint mirrors
                    # this as an agg:{src} pseudo-column)
                    self.stats.decode_work["agg"] = (
                        self.stats.decode_work.get("agg", 0)
                        + metas[i][0] * PACK_BLOCK * 4
                    )
            for k, idxs in sorted(fused.items()):
                packed = np.concatenate([
                    np.asarray(per_rg[i][0][src].buffers["packed"])
                    for i in idxs
                ], axis=0)
                m2 = jnp.concatenate([metas[i][2] for i in idxs], axis=0)
                if tr is not None:
                    tr.begin("agg_launch", source=src, pages=len(idxs),
                             fused=True, rows=int(packed.shape[0]) * PACK_BLOCK)
                planes = ops.fused_agg_batch(packed, k, m2, backend=be)
                if tr is not None:
                    tr.end(name="agg_launch")
                self.stats.kernel_launches += 1
                nb = int(packed.shape[0])
                self.stats.batch_pad_blocks += ops.bucket_blocks(nb) - nb
                s = 0
                for i in idxs:
                    planes_by_i[i] = tuple(p[s:s + metas[i][0]] for p in planes)
                    fdtype[i] = np.dtype(np.int32)
                    s += metas[i][0]
            parts = self._agg_parts.setdefault(src, {})
            for i, rg in enumerate(rgs):
                parts[rg] = agg_merge.fold_blocks(
                    planes_by_i[i],
                    np.issubdtype(fdtype[i], np.floating),
                )

    def _finish(self) -> None:
        if self._agg:
            self._finish_agg()
            return
        proj = self.plan.columns
        if not self._rgs:  # everything pruned — never cached (nothing scanned)
            # Empty columns must keep the schema's decoded dtypes (float32
            # stays float32, ints/string codes stay int32): a jnp.zeros((0,))
            # default would force float32 and break the sliced ≡ single-shot
            # contract's dtype half for all-pruned scans.
            empty = {c: jnp.zeros((0,), self.reader.decoded_dtype(c)) for c in proj}
            z = jnp.zeros((0,), jnp.bool_)
            self.result = ScanResult(empty, z, jnp.int32(0), self.stats)
            return
        out_cols = {
            c: jnp.concatenate(v)
            for c, v in self._per_rg_cols.items()
            if v[0] is not None and c in proj
        }
        mask = jnp.concatenate(self._per_rg_mask)
        count = jnp.sum(mask.astype(jnp.int32))
        if self.plan.compact:
            tr = _tr()
            if tr is not None:
                tr.begin("filter", compact=True, rows=int(mask.shape[0]))
            out_cols, mask, count = self.engine._compact(out_cols, mask)
            if tr is not None:
                tr.end(name="filter")
        # result-DMA size: the projected columns + survivor mask actually
        # handed to the consumer (pred-only columns were dropped above —
        # decode→project)
        self.stats.result_bytes = (
            sum(int(a.nbytes) for a in out_cols.values()) + int(mask.nbytes)
        )
        result = ScanResult(out_cols, mask, count, self.stats)
        self.stats.rows_out = int(count)
        if self.offload == "prefiltered":
            # decode_work prices the entry's eviction rank by the ground-
            # truth work that produced it (re-creating the result costs at
            # least that much again)
            self.engine.cache.put(
                self.engine.plan_cache_key(self.reader, self.plan, self.blooms,
                                           tag=self.scan_tag),
                result, tier="prefiltered", decode_work=dict(self.stats.decode_work),
            )
        self.result = result

    def _finish_agg(self) -> None:
        """Assemble an aggregate scan's result: merge per-row-group
        partials in global row-group order (the canonical fold), finalize
        to (n_groups,) arrays, and hand over ONLY the accumulators — the
        result DMA is their footprint, not the value column's."""
        sources = agg_merge.agg_sources(self.plan.aggregates)
        if not self._rgs:
            # everything pruned: pure merge identities per source
            parts_by_rg: Dict[int, dict] = {}
            merged = {
                src: agg_merge.identity_partial(
                    self._n_groups,
                    self.reader.decoded_dtype(src) if src is not None
                    else np.int32,
                )
                for src in sources
            }
        elif self._agg_push:
            parts_by_rg = {
                rg: {src: self._agg_parts[src][rg] for src in sources}
                for rg in self._rgs
            }
            merged = {
                src: agg_merge.merge_partials(
                    [self._agg_parts[src][rg] for rg in self._rgs])
                for src in sources
            }
        else:
            # >MAX_GROUPS host fallback: the value rows were accumulated
            # like a row scan; reduce them through the same block math and
            # per-rg fold boundaries (segments) host-side
            cols = {
                c: jnp.concatenate(v)
                for c, v in self._per_rg_cols.items()
                if v and v[0] is not None
            }
            mask = jnp.concatenate(self._per_rg_mask)
            segments = [int(m.shape[0]) // PACK_BLOCK for m in self._per_rg_mask]
            by_src = agg_merge.rows_partials(
                cols, mask, self.plan.aggregates, self.plan.group_by,
                self._n_groups, segments=segments,
            )
            parts_by_rg = {
                rg: {src: by_src[src][j] for src in sources}
                for j, rg in enumerate(self._rgs)
            }
            merged = {
                src: agg_merge.merge_partials(parts)
                for src, parts in by_src.items()
            }
        aggs = agg_merge.finalize(self.plan.aggregates, merged, self._n_groups)
        count = int(next(iter(merged.values())).cnt.sum())
        self.stats.rows_out = count
        self.stats.result_bytes = sum(int(a.nbytes) for a in aggs.values())
        result = ScanResult(
            {}, jnp.zeros((0,), jnp.bool_), jnp.int32(count), self.stats,
            aggregates=aggs, agg_partials=parts_by_rg,
        )
        if self.offload in ("prefiltered", "pre-aggregated"):
            # the pre-aggregated tier caches the WHOLE accumulator result:
            # a few KB answering a scan that would otherwise re-read and
            # re-reduce every row group (DESIGN.md §16)
            self.engine.cache.put(
                self.engine.plan_cache_key(self.reader, self.plan, self.blooms,
                                           tag=self.scan_tag),
                result, tier="prefiltered", decode_work=dict(self.stats.decode_work),
            )
        self.result = result
