"""DatapathEngine — the paper's data-processing SmartNIC, TPU edition.

Pipeline per scan (DESIGN.md §2):

    footer zone maps ──► row-group pruning (metadata only, host)
         │
    encoded bytes ────► on-device decode (Pallas kernels / jnp ref)
         │                    │
         │              pushed-down predicate (+ bloom semijoin)
         │                    │
         │              optional stream compaction (survivors packed)
         ▼                    ▼
    BlockCache  ◄──── pre-filtered columns + mask + count ──► consumer

Offload configurations reproduce the paper's Figure 1:
  'raw'         — decode + filter on every scan (query on Parquet)
  'preloaded'   — decoded row groups served from the BlockCache
  'prefiltered' — whole filtered scans served from the BlockCache

Backends: 'ref' (pure jnp — also the multi-pod dry-run path), 'pallas'
(Pallas kernels; interpret off-TPU), 'host' (numpy on the host CPU — the
"no SmartNIC, the CPU does everything" baseline).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import BlockCache
from repro.core.plan import And, BloomProbe, Cmp, Expr, InSet, Or, ScanPlan, bind_expr
from repro.core.zonemap import prune_row_groups
from repro.kernels import ops
from repro.lakeformat.encodings import (
    PACK_BLOCK,
    RLE_OUT_BLOCK,
    EncodedColumn,
    Encoding,
    decode_column_host,
)

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)


@dataclasses.dataclass
class ScanStats:
    row_groups_total: int = 0
    row_groups_scanned: int = 0
    encoded_bytes: int = 0
    decoded_bytes: int = 0
    rows_total: int = 0
    rows_out: int = 0
    fused: bool = False
    cache_hit: bool = False


@dataclasses.dataclass
class ScanResult:
    columns: Dict[str, jax.Array]  # decoded (compacted iff plan.compact), padded
    mask: jax.Array  # (L,) bool — predicate & row-validity
    count: jax.Array  # scalar int32 — surviving rows
    stats: ScanStats


class DatapathEngine:
    def __init__(
        self,
        backend: str = "ref",
        offload: str = "raw",
        cache: Optional[BlockCache] = None,
    ):
        assert backend in ("ref", "pallas", "host", "auto")
        assert offload in ("raw", "preloaded", "prefiltered")
        self.backend = backend
        self.offload = offload
        self.cache = cache if cache is not None else BlockCache()

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_device(self, col: EncodedColumn, L: int) -> jax.Array:
        """Decode one encoded column on-device, padded to L rows."""
        be = self.backend if self.backend != "host" else "ref"
        e = col.encoding
        if e == Encoding.PLAIN:
            arr = jnp.asarray(col.buffers["plain"])
        elif e == Encoding.BITPACK:
            arr = ops.bitunpack(jnp.asarray(col.buffers["packed"]), col.k, backend=be)
            arr = arr.reshape(-1)
        elif e == Encoding.DICT:
            d = col.buffers["dictionary"]
            d = jnp.asarray(d.astype(np.int32) if d.dtype.kind in "iu" else d)
            arr = ops.dict_decode(
                jnp.asarray(col.buffers["packed"]), d, col.k, backend=be
            ).reshape(-1)
        elif e == Encoding.DELTA:
            arr = ops.delta_decode(
                jnp.asarray(col.buffers["packed"]),
                jnp.asarray(col.buffers["bases"].astype(np.int32)),
                col.k,
                backend=be,
            ).reshape(-1)
        elif e == Encoding.RLE:
            arr = ops.rle_decode(
                jnp.asarray(col.buffers["rle_values"]),
                jnp.asarray(col.buffers["rle_ends"]),
                backend=be,
            ).reshape(-1)
        else:
            raise ValueError(e)
        if arr.shape[0] < L:
            arr = jnp.pad(arr, (0, L - arr.shape[0]))
        return arr[:L]

    def _decode_host(self, col: EncodedColumn, L: int) -> jax.Array:
        """Host (numpy) decode — the traditional 'CPU decodes' baseline."""
        arr = decode_column_host(col)
        out = np.zeros(L, dtype=arr.dtype)
        out[: arr.shape[0]] = arr
        return jnp.asarray(out)

    def _decode_column(self, reader, rg: int, name: str, col: EncodedColumn, L: int):
        key = ("rg", reader.path, rg, name, self.backend)
        if self.offload in ("preloaded", "prefiltered"):
            hit = self.cache.get(key)
            if hit is not None:
                return hit, True
        arr = self._decode_host(col, L) if self.backend == "host" else self._decode_device(col, L)
        if self.offload in ("preloaded", "prefiltered"):
            self.cache.put(key, arr)
        return arr, False

    # ------------------------------------------------------------------
    # predicate evaluation (on decoded device columns)
    # ------------------------------------------------------------------
    def _eval(self, e: Expr, cols: Dict[str, jax.Array], blooms: Dict[str, jax.Array]):
        if isinstance(e, Cmp):
            v = cols[e.column]
            if e.op == "between":
                lo, hi = e.value
                return (v >= lo) & (v <= hi)
            val = e.value
            return {
                "lt": v < val,
                "le": v <= val,
                "gt": v > val,
                "ge": v >= val,
                "eq": v == val,
                "ne": v != val,
            }[e.op]
        if isinstance(e, InSet):
            v = cols[e.column]
            m = jnp.zeros(v.shape, jnp.bool_)
            for val in e.values:
                m = m | (v == val)
            return m
        if isinstance(e, BloomProbe):
            keys = cols[e.column].astype(jnp.int32)
            L = keys.shape[0]
            pad = (-L) % RLE_OUT_BLOCK
            if pad:
                keys = jnp.pad(keys, (0, pad))
            m = ops.bloom_probe(
                keys.reshape(-1, RLE_OUT_BLOCK),
                blooms[e.name],
                e.n_hashes,
                backend=self.backend if self.backend != "host" else "ref",
            )
            return m.reshape(-1)[:L]
        if isinstance(e, And):
            m = self._eval(e.children[0], cols, blooms)
            for c in e.children[1:]:
                m = m & self._eval(c, cols, blooms)
            return m
        if isinstance(e, Or):
            m = self._eval(e.children[0], cols, blooms)
            for c in e.children[1:]:
                m = m | self._eval(c, cols, blooms)
            return m
        raise TypeError(e)

    # ------------------------------------------------------------------
    # fused decode+filter fast path
    # ------------------------------------------------------------------
    @staticmethod
    def _fusable(pred: Optional[Expr], enc: Dict[str, EncodedColumn], projected: List[str]):
        """Single int range/eq predicate on a BITPACK or int-DICT column not in
        the projection -> the filter column need never be materialized.

        For DICT columns the predicate is rewritten onto the *codes*: the
        dictionary is sorted (np.unique), so a value range maps to a code
        range via two host-side binary searches — the decode step then
        operates on packed codes only and the dictionary is never touched.
        """
        if not isinstance(pred, Cmp) or pred.column in projected:
            return None
        col = enc.get(pred.column)
        if col is None or col.encoding not in (Encoding.BITPACK, Encoding.DICT):
            return None
        if col.encoding == Encoding.DICT and col.buffers["dictionary"].dtype.kind not in "iu":
            return None
        if pred.op == "between":
            lo, hi = pred.value
        elif pred.op in ("ge", "gt"):
            lo = pred.value + (pred.op == "gt")
            hi = INT32_MAX
        elif pred.op in ("le", "lt"):
            lo = INT32_MIN
            hi = pred.value - (pred.op == "lt")
        elif pred.op == "eq":
            lo = hi = pred.value
        else:
            return None
        if not (isinstance(lo, (int, np.integer)) and isinstance(hi, (int, np.integer))):
            return None
        lo, hi = int(lo), int(hi)
        if col.encoding == Encoding.DICT:
            d = col.buffers["dictionary"]
            lo = int(np.searchsorted(d, lo, side="left"))
            hi = int(np.searchsorted(d, hi, side="right")) - 1
            if hi < lo:
                lo, hi = 1, 0  # empty range, still valid
        return lo, hi

    # ------------------------------------------------------------------
    # scan
    # ------------------------------------------------------------------
    def scan(self, reader, plan: ScanPlan, blooms: Optional[Dict[str, jax.Array]] = None) -> ScanResult:
        stats = ScanStats(row_groups_total=reader.n_row_groups, rows_total=reader.n_rows)
        pred = bind_expr(plan.predicate, reader)
        blooms = blooms or {}

        if self.offload == "prefiltered":
            key = ("scan", reader.path, plan.signature(), self.backend)
            hit = self.cache.get(key)
            if hit is not None:
                stats.cache_hit = True
                stats.rows_out = int(hit.count)
                return ScanResult(hit.columns, hit.mask, hit.count, stats)

        # 1) zone-map pruning (host, metadata only)
        rgs = prune_row_groups(reader, pred)
        stats.row_groups_scanned = len(rgs)

        need = plan.all_columns()
        proj = plan.columns
        per_rg_cols: Dict[str, List[jax.Array]] = {c: [] for c in need}
        per_rg_mask: List[jax.Array] = []

        for rg in rgs:
            enc = reader.read_encoded(rg, need)
            n = reader.row_group_meta(rg)["n"]
            L = -(-n // PACK_BLOCK) * PACK_BLOCK
            stats.encoded_bytes += sum(c.encoded_bytes() for c in enc.values())

            fuse = None
            if self.backend in ("ref", "pallas", "auto"):
                fuse = self._fusable(pred, enc, proj)

            cols: Dict[str, jax.Array] = {}
            if fuse is not None:
                stats.fused = True
                lo, hi = fuse
                fmask, _ = ops.fused_scan(
                    jnp.asarray(enc[pred.column].buffers["packed"]),
                    enc[pred.column].k,
                    lo,
                    hi,
                    backend=self.backend,
                )
                fmask = fmask.reshape(-1)[:L]
                for name in proj:
                    arr, _ = self._decode_column(reader, rg, name, enc[name], L)
                    cols[name] = arr
                    stats.decoded_bytes += int(arr.nbytes)
                mask = fmask
            else:
                for name in need:
                    arr, _ = self._decode_column(reader, rg, name, enc[name], L)
                    cols[name] = arr
                    stats.decoded_bytes += int(arr.nbytes)
                mask = (
                    self._eval(pred, cols, blooms)
                    if pred is not None
                    else jnp.ones((L,), jnp.bool_)
                )

            mask = mask & (jnp.arange(L) < n)  # row validity
            for name in need:
                if name in cols:
                    per_rg_cols[name].append(cols[name])
                else:  # predicate-only column under fusion: keep placeholder
                    per_rg_cols[name].append(None)
            per_rg_mask.append(mask)

        if not rgs:  # everything pruned
            empty = {c: jnp.zeros((0,)) for c in proj}
            z = jnp.zeros((0,), jnp.bool_)
            return ScanResult(empty, z, jnp.int32(0), stats)

        out_cols = {
            c: jnp.concatenate(v) for c, v in per_rg_cols.items() if v[0] is not None and c in proj
        }
        mask = jnp.concatenate(per_rg_mask)
        count = jnp.sum(mask.astype(jnp.int32))

        if plan.compact:
            out_cols, mask, count = self._compact(out_cols, mask)

        result = ScanResult(out_cols, mask, count, stats)
        stats.rows_out = int(count)
        if self.offload == "prefiltered":
            self.cache.put(("scan", reader.path, plan.signature(), self.backend), result)
        return result

    # ------------------------------------------------------------------
    def _compact(self, cols: Dict[str, jax.Array], mask: jax.Array):
        """Global stream compaction: per-block kernel compaction + stitch."""
        L = mask.shape[0]
        nblk = L // RLE_OUT_BLOCK
        m2 = mask.reshape(nblk, RLE_OUT_BLOCK)
        out = {}
        counts = None
        for name, arr in cols.items():
            comp, counts = ops.filter_compact(
                arr.reshape(nblk, RLE_OUT_BLOCK), m2, backend=self.backend if self.backend != "host" else "ref"
            )
            offs = jnp.cumsum(counts) - counts
            slot = jnp.arange(RLE_OUT_BLOCK, dtype=jnp.int32)[None, :]
            valid = slot < counts[:, None]
            tgt = jnp.where(valid, offs[:, None] + slot, L)
            flat = jnp.zeros((L,), arr.dtype).at[tgt.reshape(-1)].set(
                comp.reshape(-1), mode="drop"
            )
            out[name] = flat
        total = jnp.sum(counts)
        new_mask = jnp.arange(L) < total
        return out, new_mask, total
