"""Scan plans and the pushdown predicate algebra.

This is the framework's "post-optimizer hook" (paper §2): a query's plan is
rewritten so that its filtered table scans become DatapathEngine scans —
decode + predicate + projection evaluated in the datapath — and the host
query only ever sees pre-filtered columns.

Predicate expressions form a small algebra (Cmp / And / Or / InSet /
BloomProbe) that the engine can evaluate entirely on-device.  String
constants are folded to dictionary codes at bind time (bind_plan), mirroring
how real engines constant-fold against file metadata.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

Value = Union[int, float, str]

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)


@dataclasses.dataclass(frozen=True)
class Cmp:
    column: str
    op: str  # 'lt','le','gt','ge','eq','ne','between'
    value: Union[Value, Tuple[Value, Value]]


@dataclasses.dataclass(frozen=True)
class InSet:
    column: str
    values: Tuple[Value, ...]


@dataclasses.dataclass(frozen=True)
class BloomProbe:
    """Probe-side semijoin filter: keep rows whose `column` hits the bloom."""

    column: str
    n_bits: int = 1 << 15
    n_hashes: int = 4
    name: str = "bloom"  # key into ScanRequest.blooms


@dataclasses.dataclass(frozen=True)
class And:
    children: Tuple["Expr", ...]


@dataclasses.dataclass(frozen=True)
class Or:
    children: Tuple["Expr", ...]


Expr = Union[Cmp, InSet, BloomProbe, And, Or]


def and_(*children: Expr) -> Expr:
    return And(tuple(children))


def or_(*children: Expr) -> Expr:
    return Or(tuple(children))


def expr_columns(e: Optional[Expr]) -> List[str]:
    if e is None:
        return []
    if isinstance(e, (Cmp, InSet, BloomProbe)):
        return [e.column]
    cols: List[str] = []
    for c in e.children:
        cols.extend(expr_columns(c))
    return cols


AGG_OPS = ("sum", "min", "max", "count")


@dataclasses.dataclass(frozen=True)
class AggSpec:
    """One pushed-down aggregate: `op` over `column` (None for a bare row
    count).  The engine reduces these per block inside the bucket launch
    and only partial accumulators — never the value column — cross the
    result DMA."""

    op: str  # 'sum' | 'min' | 'max' | 'count'
    column: Optional[str] = None  # None only for count
    name: Optional[str] = None  # result key override

    def __post_init__(self):
        assert self.op in AGG_OPS, self.op
        assert self.column is not None or self.op == "count", self

    def out_name(self) -> str:
        if self.name is not None:
            return self.name
        return f"{self.op}({self.column})" if self.column else "count(*)"


@dataclasses.dataclass
class ScanPlan:
    """One pushed-down table scan."""

    table: str  # reader key / path
    columns: List[str]  # projection the consumer needs (post-filter)
    predicate: Optional[Expr] = None
    compact: bool = False  # materialize survivors packed to the front
    # operator pushdown (DESIGN.md §16): when `aggregates` is set the scan
    # returns (n_groups,) accumulators instead of row columns, optionally
    # keyed by `group_by` (a DICT/string column whose decoded form is
    # already a dense int code — groups never require decoding strings)
    aggregates: Optional[Tuple[AggSpec, ...]] = None
    group_by: Optional[str] = None

    def all_columns(self) -> List[str]:
        seen = dict.fromkeys(self.columns)
        for spec in self.aggregates or ():
            if spec.column is not None:
                seen.setdefault(spec.column)
        if self.group_by is not None:
            seen.setdefault(self.group_by)
        for c in expr_columns(self.predicate):
            seen.setdefault(c)
        return list(seen)

    def materialized_columns(self) -> List[str]:
        """Columns whose decoded VALUES the scan consumes (projection +
        aggregate inputs + group key) — as opposed to predicate-only
        columns, which exist solely to produce the mask and are dropped
        before the result DMA (decode→project)."""
        seen = dict.fromkeys(self.columns)
        for spec in self.aggregates or ():
            if spec.column is not None:
                seen.setdefault(spec.column)
        if self.group_by is not None:
            seen.setdefault(self.group_by)
        return list(seen)

    def signature(self) -> str:
        """Stable id for prefiltered-cache keys."""
        sig = {
            "table": self.table,
            "columns": self.columns,
            "pred": _expr_repr(self.predicate),
            "compact": self.compact,
        }
        if self.aggregates:
            sig["aggs"] = [[s.op, s.column, s.name] for s in self.aggregates]
            sig["group_by"] = self.group_by
        blob = json.dumps(sig, sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _expr_repr(e: Optional[Expr]):
    if e is None:
        return None
    if isinstance(e, Cmp):
        return ["cmp", e.column, e.op, e.value]
    if isinstance(e, InSet):
        return ["in", e.column, list(e.values)]
    if isinstance(e, BloomProbe):
        return ["bloom", e.column, e.n_bits, e.n_hashes, e.name]
    tag = "and" if isinstance(e, And) else "or"
    return [tag] + [_expr_repr(c) for c in e.children]


def pred_int_bounds(e: Optional[Expr]) -> Optional[Tuple[int, int]]:
    """Closed integer interval [lo, hi] equivalent to a single comparison,
    or None when the predicate is not a bounds-expressible integer Cmp.
    This is the predicate half of the engine's fused decode+filter
    eligibility test, shared with the metadata-only cost estimator
    (datapath/costmodel.py) so both agree on what will fuse."""
    if not isinstance(e, Cmp):
        return None
    if e.op == "between":
        lo, hi = e.value
    elif e.op in ("ge", "gt"):
        lo = e.value + (e.op == "gt")
        hi = INT32_MAX
    elif e.op in ("le", "lt"):
        lo = INT32_MIN
        hi = e.value - (e.op == "lt")
    elif e.op == "eq":
        lo = hi = e.value
    else:
        return None
    if not (isinstance(lo, (int, np.integer)) and isinstance(hi, (int, np.integer))):
        return None
    return int(lo), int(hi)


def bind_expr(e: Optional[Expr], reader) -> Optional[Expr]:
    """Fold string constants to dictionary codes using file metadata."""
    if e is None:
        return None
    if isinstance(e, Cmp):
        v = e.value
        if isinstance(v, str):
            v = reader.string_code(e.column, v)
        elif isinstance(v, tuple):
            v = tuple(
                reader.string_code(e.column, x) if isinstance(x, str) else x for x in v
            )
        return Cmp(e.column, e.op, v)
    if isinstance(e, InSet):
        vals = tuple(
            reader.string_code(e.column, x) if isinstance(x, str) else x
            for x in e.values
        )
        return InSet(e.column, vals)
    if isinstance(e, BloomProbe):
        return e
    children = tuple(bind_expr(c, reader) for c in e.children)
    return And(children) if isinstance(e, And) else Or(children)
