"""Mini analytical query suite over the datapath engine ("the DuckDB host").

Six TPC-H-shaped queries spanning the paper's spectrum (Fig. 2):
scan-heavy (Q6, Q14, Q15 — decode+filter dominate) through aggregation/
join-heavy (Q1, Q12, Q19).  Every filtered scan is pushed down to the
DatapathEngine; the host side only sees pre-filtered columns, masks and
counts.  Joins where the build side fits on-chip are expressed as device
gathers against the engine-decoded build table, and Q19 uses a pushed-down
bloom semijoin — the two streaming-join forms the paper's SmartNIC engine
supports.

Each query returns plain floats/dicts so results can be asserted against
the numpy oracles in tests/test_queries.py.
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.engine import DatapathEngine
from repro.core.plan import And, BloomProbe, Cmp, InSet, Or, ScanPlan, and_, or_
from repro.kernels import ops

EPS = 1e-4  # float32 predicate tolerance on 2-decimal columns


def _msum(x, mask):
    return jnp.sum(jnp.where(mask, x.astype(jnp.float32), 0.0))


# ---------------------------------------------------------------------------
# Q1 — pricing summary report (aggregation-heavy)
# ---------------------------------------------------------------------------


def q1(engine: DatapathEngine, readers: Dict, delta_days: int = 90) -> dict:
    r = readers["lineitem"]
    plan = ScanPlan(
        "lineitem",
        ["l_returnflag", "l_linestatus", "l_quantity", "l_extendedprice",
         "l_discount", "l_tax"],
        Cmp("l_shipdate", "le", 2556 - delta_days),
    )
    res = engine.scan(r, plan)
    c, m = res.columns, res.mask
    gid = c["l_returnflag"] * 2 + c["l_linestatus"]  # codes are small ints
    ngroups = 6
    onehot = (gid[:, None] == jnp.arange(ngroups)[None, :]) & m[:, None]
    ohf = onehot.astype(jnp.float32)
    disc_price = c["l_extendedprice"] * (1 - c["l_discount"])
    charge = disc_price * (1 + c["l_tax"])
    sums = {
        "sum_qty": ohf.T @ c["l_quantity"].astype(jnp.float32),
        "sum_base_price": ohf.T @ c["l_extendedprice"],
        "sum_disc_price": ohf.T @ disc_price,
        "sum_charge": ohf.T @ charge,
        "count": jnp.sum(onehot, axis=0).astype(jnp.float32),
    }
    rf_dict = r.string_dicts["l_returnflag"]
    ls_dict = r.string_dicts["l_linestatus"]
    out = {}
    for rf in range(min(3, len(rf_dict))):
        for ls in range(min(2, len(ls_dict))):
            g = rf * 2 + ls
            cnt = float(sums["count"][g])
            if cnt == 0:
                continue
            out[(rf_dict[rf], ls_dict[ls])] = {
                k: float(v[g]) for k, v in sums.items()
            }
    return out


# ---------------------------------------------------------------------------
# Q6 — forecasting revenue change (scan-heavy: pure filter + sum)
# ---------------------------------------------------------------------------


def q6(engine: DatapathEngine, readers: Dict, year_start: int = 365) -> dict:
    plan = ScanPlan(
        "lineitem",
        ["l_extendedprice", "l_discount"],
        and_(
            Cmp("l_shipdate", "between", (year_start, year_start + 364)),
            Cmp("l_discount", "between", (0.05 - EPS, 0.07 + EPS)),
            Cmp("l_quantity", "lt", 24),
        ),
    )
    res = engine.scan(readers["lineitem"], plan)
    rev = _msum(res.columns["l_extendedprice"] * res.columns["l_discount"], res.mask)
    return {"revenue": float(rev), "rows": int(res.count)}


# ---------------------------------------------------------------------------
# Q12 — shipping modes and order priority (join via on-chip build side)
# ---------------------------------------------------------------------------


def q12(engine: DatapathEngine, readers: Dict, year_start: int = 730) -> dict:
    ro, rl = readers["orders"], readers["lineitem"]
    # Build side: whole orders priority column, decoded in the datapath.
    build = engine.scan(ro, ScanPlan("orders", ["o_orderkey", "o_orderpriority"]))
    prio = build.columns["o_orderpriority"]  # dense by orderkey (generator invariant)

    plan = ScanPlan(
        "lineitem",
        ["l_orderkey", "l_shipmode"],
        and_(
            InSet("l_shipmode", ("MAIL", "SHIP")),
            Cmp("l_receiptdate", "between", (year_start, year_start + 364)),
        ),
    )
    res = engine.scan(rl, plan)
    c, m = res.columns, res.mask
    l_prio = jnp.take(prio, c["l_orderkey"].astype(jnp.int32), mode="clip")
    pr_dict = ro.string_dicts["o_orderpriority"]
    high_codes = [i for i, s in enumerate(pr_dict) if s.startswith(("1-", "2-"))]
    is_high = jnp.zeros(l_prio.shape, jnp.bool_)
    for hc in high_codes:
        is_high = is_high | (l_prio == hc)
    out = {}
    sm_dict = rl.string_dicts["l_shipmode"]
    for mode in ("MAIL", "SHIP"):
        code = sm_dict.index(mode)
        sel = m & (c["l_shipmode"] == code)
        out[mode] = {
            "high": int(jnp.sum(sel & is_high)),
            "low": int(jnp.sum(sel & ~is_high)),
        }
    return out


# ---------------------------------------------------------------------------
# Q14 — promotion effect (join + arithmetic projection; scan-heavy)
# ---------------------------------------------------------------------------


def q14(engine: DatapathEngine, readers: Dict, month_start: int = 1000) -> dict:
    rp, rl = readers["part"], readers["lineitem"]
    build = engine.scan(rp, ScanPlan("part", ["p_partkey", "p_type"]))
    type_codes = build.columns["p_type"]  # dense by partkey
    tdict = rp.string_dicts["p_type"]
    promo = jnp.asarray(
        np.array([s.startswith("PROMO") for s in tdict], dtype=np.bool_)
    )
    part_is_promo = jnp.take(promo, type_codes.astype(jnp.int32), mode="clip")

    plan = ScanPlan(
        "lineitem",
        ["l_partkey", "l_extendedprice", "l_discount"],
        Cmp("l_shipdate", "between", (month_start, month_start + 29)),
    )
    res = engine.scan(rl, plan)
    c, m = res.columns, res.mask
    rev = c["l_extendedprice"] * (1 - c["l_discount"])
    is_promo = jnp.take(part_is_promo, c["l_partkey"].astype(jnp.int32), mode="clip")
    promo_rev = _msum(rev, m & is_promo)
    total_rev = _msum(rev, m)
    return {
        "promo_revenue_pct": float(100.0 * promo_rev / jnp.maximum(total_rev, 1e-9)),
        "total_revenue": float(total_rev),
    }


# ---------------------------------------------------------------------------
# Q15 — top supplier (scan-heavy + group-by)
# ---------------------------------------------------------------------------


def q15(engine: DatapathEngine, readers: Dict, quarter_start: int = 365, n_supp: int = None) -> dict:
    rl = readers["lineitem"]
    plan = ScanPlan(
        "lineitem",
        ["l_suppkey", "l_extendedprice", "l_discount"],
        Cmp("l_shipdate", "between", (quarter_start, quarter_start + 89)),
    )
    res = engine.scan(rl, plan)
    c, m = res.columns, res.mask
    if n_supp is None:
        n_supp = int(rl.zonemaps("l_suppkey")[0]["max"]) + 1
        for zm in rl.zonemaps("l_suppkey"):
            n_supp = max(n_supp, int(zm["max"]) + 1)
    rev = jnp.where(m, c["l_extendedprice"] * (1 - c["l_discount"]), 0.0)
    per_supp = jnp.zeros((n_supp,), jnp.float32).at[
        c["l_suppkey"].astype(jnp.int32)
    ].add(rev, mode="drop")
    best = int(jnp.argmax(per_supp))
    return {"suppkey": best, "revenue": float(per_supp[best])}


# ---------------------------------------------------------------------------
# Q19 — discounted revenue (disjunctive predicate + bloom semijoin pushdown)
# ---------------------------------------------------------------------------

_Q19_BRANCHES = [
    # (brand, containers, qty_lo, qty_hi, size_hi)
    ("Brand#12", ("SM CASE", "SM BOX", "SM PACK", "SM PKG"), 1, 11, 5),
    ("Brand#23", ("MED BOX", "MED PACK", "MED PKG", "MED CASE"), 10, 20, 10),
    ("Brand#34", ("LG CASE", "LG BOX", "LG PACK", "LG PKG"), 20, 30, 15),
]


def q19(engine: DatapathEngine, readers: Dict) -> dict:
    rp, rl = readers["part"], readers["lineitem"]

    # Build side: parts matching ANY branch -> bloom of partkeys (pushdown),
    # plus dense per-part attributes for the exact residual check.
    part_pred = or_(
        *[
            and_(Cmp("p_brand", "eq", b), InSet("p_container", c), Cmp("p_size", "le", s))
            for b, c, _, _, s in _Q19_BRANCHES
        ]
    )
    build = engine.scan(
        rp, ScanPlan("part", ["p_partkey"], part_pred, compact=True)
    )
    keys = build.columns["p_partkey"].astype(jnp.int32)
    nkeys = int(build.count)
    bloom = ops.bloom_build(keys[:nkeys], n_bits=1 << 15)

    attrs = engine.scan(rp, ScanPlan("part", ["p_brand", "p_container", "p_size"]))
    p_brand, p_cont, p_size = (
        attrs.columns["p_brand"],
        attrs.columns["p_container"],
        attrs.columns["p_size"],
    )

    plan = ScanPlan(
        "lineitem",
        ["l_partkey", "l_quantity", "l_extendedprice", "l_discount"],
        and_(
            BloomProbe("l_partkey", n_bits=1 << 15, name="q19"),
            Cmp("l_quantity", "between", (1, 30)),
            InSet("l_shipinstruct", ("DELIVER IN PERSON",)),
            InSet("l_shipmode", ("AIR", "REG AIR")),
        ),
    )
    res = engine.scan(rl, plan, blooms={"q19": bloom})
    c, m = res.columns, res.mask
    pk = c["l_partkey"].astype(jnp.int32)
    lb = jnp.take(p_brand, pk, mode="clip")
    lc = jnp.take(p_cont, pk, mode="clip")
    ls = jnp.take(p_size, pk, mode="clip")

    bdict = rp.string_dicts["p_brand"]
    cdict = rp.string_dicts["p_container"]
    keep = jnp.zeros(m.shape, jnp.bool_)
    for brand, containers, qlo, qhi, shi in _Q19_BRANCHES:
        bcode = bdict.index(brand) if brand in bdict else -1
        ccodes = [cdict.index(x) for x in containers if x in cdict]
        cm = jnp.zeros(m.shape, jnp.bool_)
        for cc in ccodes:
            cm = cm | (lc == cc)
        keep = keep | (
            (lb == bcode) & cm & (c["l_quantity"] >= qlo) & (c["l_quantity"] <= qhi)
            & (ls >= 1) & (ls <= shi)
        )
    rev = _msum(c["l_extendedprice"] * (1 - c["l_discount"]), m & keep)
    return {"revenue": float(rev), "rows": int(jnp.sum(m & keep))}


QUERIES = {"q1": q1, "q6": q6, "q12": q12, "q14": q14, "q15": q15, "q19": q19}
SCAN_HEAVY = ("q6", "q14", "q15")
AGG_HEAVY = ("q1", "q12", "q19")


# ---------------------------------------------------------------------------
# Service-client path: run any query through the shared DatapathService
# ---------------------------------------------------------------------------


def run_via_service(service, name: str, readers: Dict, tenant: str = "default", **kwargs):
    """Run one of the six queries through a repro.datapath.DatapathService.

    The service client is engine-compatible (`.scan(reader, plan, blooms)`),
    so every pushed-down scan in the query goes through admission control,
    the tick scheduler and shared-scan coalescing instead of calling the
    engine directly.  Results are bit-identical to the direct-engine path
    (tests/test_datapath.py)."""
    return QUERIES[name](service.client(tenant), readers, **kwargs)
