"""Synthetic TPC-H-like data generator (lineitem / orders / part).

Mirrors the distributions the paper's benchmark queries exercise (dates
uniform over 1992-1998, discount 0..0.10, small categorical domains) at a
configurable mini scale factor: sf=1.0 -> 600k lineitem rows (1/10 of real
TPC-H SF1, sized for the single-core container; fractions are what matter).

Dates are stored as int32 days since 1992-01-01 (DATE_EPOCH).  `sorted_by`
reproduces the paper's Fig. 3b sorted-vs-unsorted Parquet comparison
(lineitem on l_shipdate, orders on o_orderdate).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.lakeformat.schema import ColumnSchema, TableSchema
from repro.lakeformat.writer import write_table

DAYS = 2556  # 1992-01-01 .. 1998-12-31
LI_PER_SF = 600_000
ORDERS_PER_SF = 150_000
PARTS_PER_SF = 20_000
SUPPS_PER_SF = 1_000

SHIPMODES = ["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"]
SHIPINSTRUCT = ["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"]
RETURNFLAGS = ["A", "N", "R"]
LINESTATUS = ["O", "F"]
PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
BRANDS = [f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)]
CONTAINERS = [f"{s} {t}" for s in ["SM", "MED", "LG", "JUMBO"] for t in ["CASE", "BOX", "PACK", "PKG"]]
TYPES = [f"{a} {b} {c}" for a in ["STANDARD", "SMALL", "MEDIUM", "LARGE", "PROMO"]
         for b in ["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"]
         for c in ["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"]]


def lineitem_schema() -> TableSchema:
    return TableSchema(
        "lineitem",
        [
            ColumnSchema("l_orderkey", "int32", "auto"),
            ColumnSchema("l_partkey", "int32", "bitpack"),
            ColumnSchema("l_suppkey", "int32", "bitpack"),
            ColumnSchema("l_quantity", "int32", "bitpack"),
            ColumnSchema("l_extendedprice", "float32", "plain"),
            ColumnSchema("l_discount", "float32", "dict"),
            ColumnSchema("l_tax", "float32", "dict"),
            ColumnSchema("l_returnflag", "str"),
            ColumnSchema("l_linestatus", "str"),
            ColumnSchema("l_shipdate", "int32", "auto"),
            ColumnSchema("l_commitdate", "int32", "bitpack"),
            ColumnSchema("l_receiptdate", "int32", "bitpack"),
            ColumnSchema("l_shipmode", "str"),
            ColumnSchema("l_shipinstruct", "str"),
        ],
    )


def orders_schema() -> TableSchema:
    return TableSchema(
        "orders",
        [
            ColumnSchema("o_orderkey", "int32", "auto"),
            ColumnSchema("o_orderdate", "int32", "auto"),
            ColumnSchema("o_orderpriority", "str"),
        ],
    )


def part_schema() -> TableSchema:
    return TableSchema(
        "part",
        [
            ColumnSchema("p_partkey", "int32", "auto"),
            ColumnSchema("p_brand", "str"),
            ColumnSchema("p_type", "str"),
            ColumnSchema("p_container", "str"),
            ColumnSchema("p_size", "int32", "bitpack"),
        ],
    )


def gen_tables(sf: float = 0.1, seed: int = 0, sorted_data: bool = False) -> Dict[str, Dict]:
    rng = np.random.default_rng(seed)
    n_li = int(LI_PER_SF * sf)
    n_ord = int(ORDERS_PER_SF * sf)
    n_part = max(256, int(PARTS_PER_SF * sf))
    n_supp = max(64, int(SUPPS_PER_SF * sf))

    li_order = np.sort(rng.integers(0, n_ord, size=n_li)).astype(np.int64)
    shipdate = rng.integers(0, DAYS, size=n_li).astype(np.int64)
    lineitem = {
        "l_orderkey": li_order,
        "l_partkey": rng.integers(0, n_part, size=n_li),
        "l_suppkey": rng.integers(0, n_supp, size=n_li),
        "l_quantity": rng.integers(1, 51, size=n_li),
        "l_extendedprice": (rng.random(n_li).astype(np.float32) * 10000 + 900).round(2),
        "l_discount": (rng.integers(0, 11, size=n_li) / 100).astype(np.float32),
        "l_tax": (rng.integers(0, 9, size=n_li) / 100).astype(np.float32),
        "l_returnflag": [RETURNFLAGS[i] for i in rng.integers(0, 3, size=n_li)],
        "l_linestatus": [LINESTATUS[i] for i in rng.integers(0, 2, size=n_li)],
        "l_shipdate": shipdate,
        "l_commitdate": np.clip(shipdate + rng.integers(-30, 60, size=n_li), 0, DAYS),
        "l_receiptdate": np.clip(shipdate + rng.integers(1, 30, size=n_li), 0, DAYS),
        "l_shipmode": [SHIPMODES[i] for i in rng.integers(0, len(SHIPMODES), size=n_li)],
        "l_shipinstruct": [SHIPINSTRUCT[i] for i in rng.integers(0, 4, size=n_li)],
    }
    if sorted_data:  # paper footnote 2: lineitem sorted on l_shipdate
        order = np.argsort(lineitem["l_shipdate"], kind="stable")
        lineitem = {
            k: ([v[i] for i in order] if isinstance(v, list) else v[order])
            for k, v in lineitem.items()
        }

    orderdate = rng.integers(0, DAYS, size=n_ord).astype(np.int64)
    if sorted_data:  # orders sorted on o_orderdate
        orderdate = np.sort(orderdate)
    orders = {
        "o_orderkey": np.arange(n_ord, dtype=np.int64),
        "o_orderdate": orderdate,
        "o_orderpriority": [PRIORITIES[i] for i in rng.integers(0, 5, size=n_ord)],
    }

    part = {
        "p_partkey": np.arange(n_part, dtype=np.int64),
        "p_brand": [BRANDS[i] for i in rng.integers(0, len(BRANDS), size=n_part)],
        "p_type": [TYPES[i] for i in rng.integers(0, len(TYPES), size=n_part)],
        "p_container": [CONTAINERS[i] for i in rng.integers(0, len(CONTAINERS), size=n_part)],
        "p_size": rng.integers(1, 51, size=n_part),
    }
    return {"lineitem": lineitem, "orders": orders, "part": part}


def write_tables(dirpath: str, sf: float = 0.1, seed: int = 0, sorted_data: bool = False,
                 row_group_size: int = 65536) -> Dict[str, str]:
    import os

    os.makedirs(dirpath, exist_ok=True)
    data = gen_tables(sf, seed, sorted_data)
    paths = {}
    for name, schema in [("lineitem", lineitem_schema()), ("orders", orders_schema()),
                         ("part", part_schema())]:
        p = os.path.join(dirpath, f"{name}.lake")
        write_table(p, schema, data[name], row_group_size)
        paths[name] = p
    return paths
