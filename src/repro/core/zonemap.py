"""Zone-map row-group pruning (paper Fig. 3b).

Metadata-only: evaluates the pushed-down predicate against per-row-group
min/max from the lakeformat footer and returns the surviving row-group ids,
before a single data byte is read or decoded.  On sorted data this is where
the paper's large Q6/Q14/Q15 wins come from.

The evaluation is conservative three-valued logic: a row group is pruned
only if the predicate is provably false for every row in it.
"""

from __future__ import annotations

from typing import List, Optional

from repro.core.plan import And, BloomProbe, Cmp, Expr, InSet, Or


def _maybe_true(e: Expr, zonemaps: dict, rg: int) -> bool:
    """Can any row in row group `rg` satisfy e?  (conservative)."""
    if isinstance(e, Cmp):
        zm = zonemaps[e.column][rg]
        lo, hi = zm["min"], zm["max"]
        v = e.value
        if e.op == "between":
            a, b = v
            return not (hi < a or lo > b)
        if e.op in ("lt", "le"):
            return lo < v if e.op == "lt" else lo <= v
        if e.op in ("gt", "ge"):
            return hi > v if e.op == "gt" else hi >= v
        if e.op == "eq":
            return lo <= v <= hi
        if e.op == "ne":
            return not (lo == hi == v)
        raise ValueError(e.op)
    if isinstance(e, InSet):
        zm = zonemaps[e.column][rg]
        return any(zm["min"] <= v <= zm["max"] for v in e.values)
    if isinstance(e, BloomProbe):
        return True  # bloom membership is not derivable from min/max
    if isinstance(e, And):
        return all(_maybe_true(c, zonemaps, rg) for c in e.children)
    if isinstance(e, Or):
        return any(_maybe_true(c, zonemaps, rg) for c in e.children)
    raise TypeError(e)


def prune_row_groups(reader, predicate: Optional[Expr]) -> List[int]:
    """Surviving row-group ids for `predicate` over `reader`'s zone maps."""
    n = reader.n_row_groups
    if predicate is None:
        return list(range(n))
    from repro.core.plan import expr_columns

    cols = set(expr_columns(predicate))
    zonemaps = {c: reader.zonemaps(c) for c in cols}
    return [rg for rg in range(n) if _maybe_true(predicate, zonemaps, rg)]


# ---------------------------------------------------------------------------
# Selectivity estimation (metadata only) — the adaptive-offload-policy input
# ---------------------------------------------------------------------------

_BLOOM_SELECTIVITY = 0.5  # membership is not derivable from min/max
_EQ_NARROW = 0.1  # eq on a sub-unit float range: cardinality unknown


def _eq_frac(lo: float, hi: float, v: float, width: float) -> float:
    if not (lo <= v <= hi):
        return 0.0
    # integers: ~width+1 distinct values; narrow float ranges (width < 1)
    # would invert the estimate under 1/width, so use a fixed guess
    return 1.0 / (width + 1.0) if width >= 1.0 else _EQ_NARROW


def _range_frac(zm: dict, a: float, b: float) -> float:
    """P(a <= value <= b) within one row group.  Uses the footer's
    equi-width histogram when present — full bins contribute their true
    mass, the two boundary bins prorate uniformly within the bin — and
    degrades to uniform-over-[min,max] for legacy files without one."""
    lo, hi = float(zm["min"]), float(zm["max"])
    width = hi - lo
    if width <= 0:
        return 1.0 if a <= lo <= b else 0.0
    a2, b2 = max(a, lo), min(b, hi)
    if a2 > b2:
        return 0.0
    hist = zm.get("hist")
    if hist:
        total = float(sum(hist)) or 1.0
        bw = width / len(hist)
        acc = 0.0
        for i, c in enumerate(hist):
            if not c:
                continue
            ov = min(lo + (i + 1) * bw, b2) - max(lo + i * bw, a2)
            if ov > 0:
                acc += c * min(1.0, ov / bw)
        return min(1.0, acc / total)
    return (b2 - a2) / width


def _eq_frac_zm(zm: dict, v: float) -> float:
    """P(value == v): the containing histogram bin's mass spread over the
    bin's distinct values; uniform-assumption fallback without a hist."""
    lo, hi = float(zm["min"]), float(zm["max"])
    width = hi - lo
    if width <= 0:
        return 1.0 if v == lo else 0.0
    if not (lo <= v <= hi):
        return 0.0
    hist = zm.get("hist")
    if hist:
        total = float(sum(hist)) or 1.0
        bw = width / len(hist)
        i = min(int((v - lo) / bw), len(hist) - 1)
        mass = hist[i] / total
        return mass / (bw + 1.0) if bw >= 1.0 else mass
    return _eq_frac(lo, hi, v, width)


def _frac_true(e: Expr, zonemaps: dict, rg: int) -> float:
    """Estimated fraction of rows in row group `rg` satisfying e, from the
    footer's per-row-group value histograms (uniform over [min, max] for
    files without them).  Cheap and rough by design — it only has to rank
    requests for the offload policy, not be an optimizer."""
    if isinstance(e, Cmp):
        zm = zonemaps[e.column][rg]
        v = e.value
        if float(zm["max"]) - float(zm["min"]) <= 0:
            return 1.0 if _maybe_true(e, zonemaps, rg) else 0.0
        if e.op == "between":
            return _range_frac(zm, float(v[0]), float(v[1]))
        v = float(v)
        if e.op in ("lt", "le"):
            return _range_frac(zm, float("-inf"), v)
        if e.op in ("gt", "ge"):
            return _range_frac(zm, v, float("inf"))
        if e.op == "eq":
            return _eq_frac_zm(zm, v)
        if e.op == "ne":
            return 1.0 - _eq_frac_zm(zm, v)
        raise ValueError(e.op)
    if isinstance(e, InSet):
        zm = zonemaps[e.column][rg]
        lo, hi = float(zm["min"]), float(zm["max"])
        if hi - lo <= 0:
            return 1.0 if any(lo <= float(v) <= hi for v in e.values) else 0.0
        return min(1.0, sum(_eq_frac_zm(zm, float(v)) for v in e.values))
    if isinstance(e, BloomProbe):
        return _BLOOM_SELECTIVITY
    if isinstance(e, And):
        f = 1.0
        for c in e.children:
            f *= _frac_true(c, zonemaps, rg)
        return f
    if isinstance(e, Or):
        f = 1.0
        for c in e.children:
            f *= 1.0 - _frac_true(c, zonemaps, rg)
        return 1.0 - f
    raise TypeError(e)


def prune_and_estimate(reader, predicate: Optional[Expr]):
    """One zone-map walk -> (surviving row-group ids, estimated selectivity).

    The admission path needs both; computing them together halves the
    per-request metadata cost vs prune_row_groups + estimate_selectivity."""
    n_rg = reader.n_row_groups
    if predicate is None:
        return list(range(n_rg)), 1.0
    from repro.core.plan import expr_columns

    cols = set(expr_columns(predicate))
    zonemaps = {c: reader.zonemaps(c) for c in cols}
    rgs: List[int] = []
    total = 0
    surviving = 0.0
    for rg in range(n_rg):
        n = reader.row_group_meta(rg)["n"]
        total += n
        if _maybe_true(predicate, zonemaps, rg):
            rgs.append(rg)
            surviving += _frac_true(predicate, zonemaps, rg) * n
    return rgs, surviving / max(total, 1)


def estimate_selectivity(reader, predicate: Optional[Expr]) -> float:
    """Estimated fraction of the table's rows surviving `predicate`,
    row-count-weighted across row groups.  Pruned groups contribute 0."""
    return prune_and_estimate(reader, predicate)[1]
