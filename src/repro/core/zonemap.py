"""Zone-map row-group pruning (paper Fig. 3b).

Metadata-only: evaluates the pushed-down predicate against per-row-group
min/max from the lakeformat footer and returns the surviving row-group ids,
before a single data byte is read or decoded.  On sorted data this is where
the paper's large Q6/Q14/Q15 wins come from.

The evaluation is conservative three-valued logic: a row group is pruned
only if the predicate is provably false for every row in it.
"""

from __future__ import annotations

from typing import List, Optional

from repro.core.plan import And, BloomProbe, Cmp, Expr, InSet, Or


def _maybe_true(e: Expr, zonemaps: dict, rg: int) -> bool:
    """Can any row in row group `rg` satisfy e?  (conservative)."""
    if isinstance(e, Cmp):
        zm = zonemaps[e.column][rg]
        lo, hi = zm["min"], zm["max"]
        v = e.value
        if e.op == "between":
            a, b = v
            return not (hi < a or lo > b)
        if e.op in ("lt", "le"):
            return lo < v if e.op == "lt" else lo <= v
        if e.op in ("gt", "ge"):
            return hi > v if e.op == "gt" else hi >= v
        if e.op == "eq":
            return lo <= v <= hi
        if e.op == "ne":
            return not (lo == hi == v)
        raise ValueError(e.op)
    if isinstance(e, InSet):
        zm = zonemaps[e.column][rg]
        return any(zm["min"] <= v <= zm["max"] for v in e.values)
    if isinstance(e, BloomProbe):
        return True  # bloom membership is not derivable from min/max
    if isinstance(e, And):
        return all(_maybe_true(c, zonemaps, rg) for c in e.children)
    if isinstance(e, Or):
        return any(_maybe_true(c, zonemaps, rg) for c in e.children)
    raise TypeError(e)


def prune_row_groups(reader, predicate: Optional[Expr]) -> List[int]:
    """Surviving row-group ids for `predicate` over `reader`'s zone maps."""
    n = reader.n_row_groups
    if predicate is None:
        return list(range(n))
    from repro.core.plan import expr_columns

    cols = set(expr_columns(predicate))
    zonemaps = {c: reader.zonemaps(c) for c in cols}
    return [rg for rg in range(n) if _maybe_true(predicate, zonemaps, rg)]
