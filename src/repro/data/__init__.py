"""Data-lake-backed training data pipeline."""

from repro.data.corpus import corpus_schema, write_corpus, synth_corpus  # noqa: F401
from repro.data.pipeline import TokenPipeline  # noqa: F401
