"""Tokenized corpora as lake tables.

One row per token.  Columns chosen so every lakeformat encoding earns its
keep on real training data:

  token    BITPACK(ceil(log2 V))  — e.g. 18 bits for a 202k vocab: the
                                     host->device DMA shrinks 1.78x vs int32
  doc_id   DELTA                  — monotone, ~1-2 bits/token
  quality  RLE                    — per-document score replicated per token:
                                     long runs; this is the pushdown column
  lang     RLE/DICT               — per-document label

Row groups default to 65,536 tokens = 16 bitpack blocks; zone maps on
quality/doc_id drive row-group pruning for quality-threshold pushdown.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional

import numpy as np

from repro.lakeformat.encodings import bits_needed
from repro.lakeformat.schema import ColumnSchema, TableSchema
from repro.lakeformat.writer import write_table

LANGS = ["en", "de", "fr", "zh", "es", "ja", "ko", "pt"]


def corpus_schema() -> TableSchema:
    return TableSchema(
        "corpus",
        [
            ColumnSchema("token", "int32", "bitpack"),
            ColumnSchema("doc_id", "int32", "delta"),
            ColumnSchema("quality", "int32", "rle"),
            ColumnSchema("lang", "str"),
        ],
    )


def synth_corpus(n_tokens: int, vocab: int, seed: int = 0,
                 mean_doc: int = 2048) -> Dict[str, np.ndarray]:
    """Synthetic corpus with zipf-ish tokens and per-document metadata."""
    rng = np.random.default_rng(seed)
    # zipf-ish without scipy: inverse-CDF on 1/rank
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    tokens = rng.choice(vocab, size=n_tokens, p=probs).astype(np.int64)

    n_docs = max(1, n_tokens // mean_doc)
    doc_lens = rng.integers(mean_doc // 2, mean_doc * 3 // 2, size=n_docs)
    doc_ids = np.repeat(np.arange(n_docs), doc_lens)[:n_tokens]
    if doc_ids.shape[0] < n_tokens:
        doc_ids = np.pad(doc_ids, (0, n_tokens - doc_ids.shape[0]), constant_values=n_docs - 1)
    doc_quality = rng.integers(0, 101, size=n_docs + 1)
    quality = doc_quality[doc_ids]
    doc_lang = rng.integers(0, len(LANGS), size=n_docs + 1)
    lang = [LANGS[i] for i in doc_lang[doc_ids]]
    return {"token": tokens, "doc_id": doc_ids.astype(np.int64), "quality": quality.astype(np.int64), "lang": lang}


def write_corpus(dirpath: str, n_tokens: int, vocab: int, n_shards: int = 2,
                 seed: int = 0, row_group_size: int = 65536) -> List[str]:
    os.makedirs(dirpath, exist_ok=True)
    paths = []
    per = n_tokens // n_shards
    for s in range(n_shards):
        data = synth_corpus(per, vocab, seed=seed + s)
        p = os.path.join(dirpath, f"shard_{s:05d}.lake")
        write_table(p, corpus_schema(), data, row_group_size)
        paths.append(p)
    return paths
