"""TokenPipeline — the datapath-offloaded training input pipeline.

Three ingestion modes reproducing the paper's configurations on the LM
workload (benchmarks/pipeline_bench.py):

  'host'   traditional: host CPU decodes + filters every row group with
           numpy, then device_puts int32 tokens          (no SmartNIC)
  'engine' datapath: the DatapathEngine decodes + quality-filters row
           groups ON DEVICE; host work is a memcpy of encoded bytes
           (decode amortized across epochs by the BlockCache)
  'fused'  zero-host-work: raw bit-packed blocks are sliced straight out
           of the file and handed to train_step, which decodes them inside
           the jitted program (models/model.py:unpack_tokens) — quality
           pushdown happens at row-group granularity via zone maps

The pipeline is deterministic and resumable: its cursor (shard, row group,
pool offset, epoch) is part of the training checkpoint.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DatapathEngine
from repro.core.plan import Cmp, ScanPlan
from repro.core.zonemap import prune_row_groups
from repro.lakeformat.encodings import PACK_BLOCK, bits_needed, decode_column_host
from repro.lakeformat.reader import LakeReader


@dataclasses.dataclass
class PipelineState:
    shard: int = 0
    row_group: int = 0
    epoch: int = 0
    pool_off: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**{k: int(v) for k, v in d.items()})


class TokenPipeline:
    def __init__(
        self,
        paths: List[str],
        batch_size: int,
        seq_len: int,
        mode: str = "engine",
        quality_min: Optional[int] = None,
        engine: Optional[DatapathEngine] = None,
        state: Optional[PipelineState] = None,
    ):
        assert mode in ("host", "engine", "fused")
        self.paths = paths
        self.readers = [LakeReader(p) for p in paths]
        self.B, self.S = batch_size, seq_len
        self.mode = mode
        self.quality_min = quality_min
        self.engine = engine or DatapathEngine(backend="ref", offload="preloaded")
        self.state = state or PipelineState()
        self._pool: Optional[jax.Array] = None  # device-resident token pool
        self._pool_np: Optional[np.ndarray] = None
        self.stats = {"host_bytes_decoded": 0, "dma_bytes": 0, "rowgroups_pruned": 0,
                      "rowgroups_read": 0}
        if mode == "fused":
            k = self.readers[0].footer["row_groups"][0]["columns"]["token"]["k"]
            self._k = k

    # ------------------------------------------------------------------
    def _predicate(self):
        if self.quality_min is None:
            return None
        return Cmp("quality", "ge", int(self.quality_min))

    def _advance(self):
        st = self.state
        st.row_group += 1
        if st.row_group >= self.readers[st.shard].n_row_groups:
            st.row_group = 0
            st.shard += 1
            if st.shard >= len(self.readers):
                st.shard = 0
                st.epoch += 1

    def _next_rowgroup_tokens(self) -> Optional[np.ndarray]:
        """One row group's surviving tokens (None if the row group is pruned)."""
        st = self.state
        reader = self.readers[st.shard]
        pred = self._predicate()
        keep_rgs = prune_row_groups(reader, pred)
        if st.row_group not in keep_rgs:
            self.stats["rowgroups_pruned"] += 1
            self._advance()
            return None
        self.stats["rowgroups_read"] += 1

        if self.mode == "host":
            enc = reader.read_encoded(st.row_group, ["token", "quality"])
            toks = decode_column_host(enc["token"])
            self.stats["host_bytes_decoded"] += toks.nbytes
            if pred is not None:
                q = decode_column_host(enc["quality"])
                toks = toks[q >= self.quality_min]
            self.stats["dma_bytes"] += toks.nbytes
            self._advance()
            return toks

        # engine mode: decode + filter + compact on device
        plan = ScanPlan("corpus", ["token"], pred, compact=pred is not None)
        saved_scan = self.engine.scan  # scan a single row group
        res = self._scan_one(reader, st.row_group, plan)
        self.stats["dma_bytes"] += sum(
            c.encoded_bytes() for c in reader.read_encoded(st.row_group, plan.all_columns()).values()
        )
        self._advance()
        n = int(res.count)
        toks = np.asarray(jax.device_get(res.columns["token"][:n]))
        return toks

    def _scan_one(self, reader, rg, plan):
        """Engine scan restricted to one row group (pipeline granularity)."""
        sub = _SingleRG(reader, rg)
        return self.engine.scan(sub, plan)

    # ------------------------------------------------------------------
    def next_batch(self) -> Dict[str, jax.Array]:
        B, S = self.B, self.S
        if self.mode == "fused":
            return self._next_batch_fused()
        need = B * S + 1
        buf = self._pool_np if self._pool_np is not None else np.zeros(0, np.int32)
        while buf.shape[0] - self.state.pool_off < need:
            toks = self._next_rowgroup_tokens()
            if toks is None:
                continue
            buf = np.concatenate([buf[self.state.pool_off:], toks.astype(np.int32)])
            self.state.pool_off = 0
        start = self.state.pool_off
        flat = buf[start : start + need]
        self.state.pool_off = start + B * S
        self._pool_np = buf
        tokens = jnp.asarray(flat[: B * S].reshape(B, S))
        return {"tokens": tokens}

    def _next_batch_fused(self) -> Dict[str, jax.Array]:
        """Slice raw bit-packed blocks; decode happens inside train_step.

        state.pool_off doubles as the block cursor within the current row
        group so no block is skipped between batches; DMA is charged once
        per row group, on load."""
        B, S = self.B, self.S
        nb = -(-S // PACK_BLOCK)
        blocks_needed = B * nb
        out = []
        while len(out) < blocks_needed:
            st = self.state
            reader = self.readers[st.shard]
            pred = self._predicate()
            keep = prune_row_groups(reader, pred)
            if st.row_group not in keep:
                self.stats["rowgroups_pruned"] += 1
                st.pool_off = 0
                self._advance()
                continue
            enc = reader.read_encoded(st.row_group, ["token"])["token"]
            packed = enc.buffers["packed"]  # (nblocks, k, 128) raw file bytes
            if st.pool_off == 0:
                self.stats["rowgroups_read"] += 1
                self.stats["dma_bytes"] += packed.nbytes
            while st.pool_off < packed.shape[0] and len(out) < blocks_needed:
                out.append(packed[st.pool_off])
                st.pool_off += 1
            if st.pool_off >= packed.shape[0]:
                st.pool_off = 0
                self._advance()
        arr = np.stack(out).reshape(B, nb, self._k, 128)
        return {"packed": jnp.asarray(arr)}

    # ------------------------------------------------------------------
    def checkpoint_state(self) -> dict:
        return self.state.as_dict()

    def restore_state(self, d: dict):
        self.state = PipelineState.from_dict(d)
        self._pool_np = None


class _SingleRG:
    """Reader view exposing exactly one row group (keeps ScanPlan static)."""

    def __init__(self, reader: LakeReader, rg: int):
        self._r = reader
        self._rg = rg
        self.path = f"{reader.path}#{rg}"
        self.n_row_groups = 1
        self.n_rows = reader.row_group_meta(rg)["n"]
        self.string_dicts = reader.string_dicts

    def zonemaps(self, column):
        return [self._r.zonemaps(column)[self._rg]]

    def row_group_meta(self, rg):
        return self._r.row_group_meta(self._rg)

    def read_encoded(self, rg, columns=None):
        return self._r.read_encoded(self._rg, columns)

    def string_code(self, column, value):
        return self._r.string_code(column, value)
