"""datapath — the SmartNIC as a shared, scheduled, multi-tenant service.

service.py    DatapathService: bounded queue, admission control, quotas
scheduler.py  per-tick batching + shared-scan coalescing (DecodePool)
netsim.py     storage->NIC bandwidth/latency model, prefetch overlap
policy.py     adaptive raw/preloaded/prefiltered choice per request
telemetry.py  queue depth, decoded-bytes-saved, per-tenant p50/p99

See DESIGN.md §8.  The synchronous per-caller path (core/engine.py)
remains the substrate; the service schedules it.
"""

from repro.datapath.netsim import DecodeModel, LinkModel, PrefetchPipeline  # noqa: F401
from repro.datapath.policy import AdaptiveOffloadPolicy, StaticPolicy  # noqa: F401
from repro.datapath.scheduler import DecodePool, run_tick  # noqa: F401
from repro.datapath.service import (  # noqa: F401
    DatapathService,
    QueueFull,
    QuotaExceeded,
    ScanRequest,
    ServiceClient,
    TenantQuota,
    Ticket,
)
from repro.datapath.telemetry import Telemetry  # noqa: F401
