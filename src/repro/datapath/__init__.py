"""datapath — the SmartNIC as a shared, scheduled, multi-tenant service.

service.py    DatapathService: bounded queue, admission control, quotas,
              per-tenant WFQ virtual time + actual-cost reconciliation
scheduler.py  fair-share batch formation (wfq/fifo, row-group preemption,
              cross-tick coalescing holds) + shared-scan DecodePool
costmodel.py  calibrated per-encoding decode rates (GB/s table with a
              nominal fallback), decode-seconds estimates from footer
              metadata — the WFQ virtual-time currency
netsim.py     storage->NIC bandwidth/latency model, prefetch overlap
              (decode priced by the same calibrated table)
policy.py     adaptive raw/preloaded/prefiltered choice per request,
              hold-window footprint compatibility
telemetry.py  queue depth, decoded-bytes-saved, per-tenant p50/p99,
              fair-share metrics (Jain index, held-request latency),
              estimated-vs-actual decode-cost ledger

See DESIGN.md §8–§9.  The synchronous per-caller path (core/engine.py)
remains the substrate; the service schedules it — at row-group
granularity, so no scan occupies the device longer than one preemption
quantum.
"""

from repro.datapath.costmodel import (  # noqa: F401
    NOMINAL_RATES_GBPS,
    CostModel,
    RowGroupCost,
    measure_rates,
)
from repro.datapath.netsim import DecodeModel, LinkModel, PrefetchPipeline  # noqa: F401
from repro.datapath.policy import (  # noqa: F401
    AdaptiveOffloadPolicy,
    StaticPolicy,
    coalesce_compatible,
)
from repro.datapath.scheduler import DecodePool, form_batch, run_tick  # noqa: F401
from repro.datapath.service import (  # noqa: F401
    DatapathService,
    QueueFull,
    QuotaExceeded,
    ScanRequest,
    ServiceClient,
    TenantQuota,
    Ticket,
)
from repro.datapath.telemetry import Telemetry, jain_index, quantile  # noqa: F401
