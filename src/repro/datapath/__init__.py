"""datapath — the SmartNIC as a shared, scheduled, multi-tenant service.

service.py    DatapathService: bounded queue, admission control, quotas,
              per-tenant WFQ virtual time
scheduler.py  fair-share batch formation (wfq/fifo, row-group preemption,
              cross-tick coalescing holds) + shared-scan DecodePool
netsim.py     storage->NIC bandwidth/latency model, prefetch overlap
policy.py     adaptive raw/preloaded/prefiltered choice per request,
              hold-window footprint compatibility
telemetry.py  queue depth, decoded-bytes-saved, per-tenant p50/p99,
              fair-share metrics (Jain index, held-request latency)

See DESIGN.md §8–§9.  The synchronous per-caller path (core/engine.py)
remains the substrate; the service schedules it — at row-group
granularity, so no scan occupies the device longer than one preemption
quantum.
"""

from repro.datapath.netsim import DecodeModel, LinkModel, PrefetchPipeline  # noqa: F401
from repro.datapath.policy import (  # noqa: F401
    AdaptiveOffloadPolicy,
    StaticPolicy,
    coalesce_compatible,
)
from repro.datapath.scheduler import DecodePool, form_batch, run_tick  # noqa: F401
from repro.datapath.service import (  # noqa: F401
    DatapathService,
    QueueFull,
    QuotaExceeded,
    ScanRequest,
    ServiceClient,
    TenantQuota,
    Ticket,
)
from repro.datapath.telemetry import Telemetry, jain_index, quantile  # noqa: F401
