"""datapath — the SmartNIC as a shared, scheduled, multi-tenant service.

service.py    Pod (née DatapathService): bounded queue, admission control,
              quotas, per-tenant WFQ virtual time + actual-cost
              reconciliation, auto-tuned coalescing hold window
fabric.py     ScanFabric: N pods behind consistent-hash row-group
              ownership — routed sub-scans, bit-identical global merge,
              peer block-store fetch over the inter-pod link, fleet WFQ
              re-leveling, heartbeat-driven drain/replay
catalog.py    shared table registry with per-scan snapshot pins
              (monotonic version; mid-scan DDL is invisible in flight)
blockstore.py unified tiered BlockStore (encoded pages / decoded columns
              / prefiltered results): one byte ledger, cost-aware
              eviction priced by the cost model, window-scoped decode
              pins that survive hold_ticks
scheduler.py  fair-share batch formation (wfq/fifo, row-group preemption,
              cross-tick coalescing holds) + shared decode windows +
              batched dispatch (each WFQ slice = one bucketed batch
              decode, reconciled by actual kernel launches)
costmodel.py  calibrated per-encoding decode rates (GB/s table with a
              nominal fallback), decode-seconds estimates from footer
              metadata — the WFQ virtual-time currency AND the store's
              eviction pricing
netsim.py     storage->NIC bandwidth/latency model, prefetch overlap
              (decode priced by the same calibrated table; store hits
              never enter the simulated fetch)
policy.py     adaptive raw/preloaded/prefiltered choice per request
              (residency read per tier from the store), hold-window
              footprint compatibility
telemetry.py  queue depth, decoded-bytes-saved, per-tenant p50/p99/p99.9,
              fair-share metrics (Jain index, held-request latency,
              window-retained bytes), estimated-vs-actual decode-cost
              ledger, per-tier store ledger
trace.py      flight recorder: per-request span trees (admission / waits
              / slices / fetch / decode / filter / reconcile), bounded
              ring of completed traces, Chrome-trace export, and the
              paper-anchored decode/filter/rest stage attribution
faults.py     storage fault plane: seedable deterministic fault schedules
              (FaultPlan), bounded retry/backoff/timeout/hedge policy
              (RetryPolicy + FaultInjector on the engine's storage-read
              seam), per-target circuit breaker with degraded mode and
              typed Overloaded load-shed — every extra modeled second
              reconciled into WFQ virtual time

See DESIGN.md §8–§9 and §11.  The synchronous per-caller path
(core/engine.py) remains the substrate; the service schedules it — at
row-group granularity, so no scan occupies the device longer than one
preemption quantum.
"""

from repro.datapath.blockstore import (  # noqa: F401
    TIERS,
    BlockEntry,
    BlockStore,
    DecodePool,
    PeerFetcher,
    StoreView,
)
from repro.datapath.catalog import Catalog, Snapshot  # noqa: F401
from repro.datapath.costmodel import (  # noqa: F401
    NOMINAL_RATES_GBPS,
    CostModel,
    RowGroupCost,
    measure_rates,
)
from repro.datapath.netsim import (  # noqa: F401
    DecodeModel,
    LinkModel,
    PrefetchPipeline,
    SliceClock,
)
from repro.datapath.policy import (  # noqa: F401
    AdaptiveOffloadPolicy,
    StaticPolicy,
    coalesce_compatible,
)
from repro.datapath.fabric import FabricTicket, ScanFabric  # noqa: F401
from repro.datapath.faults import (  # noqa: F401
    CircuitBreaker,
    FaultInjector,
    FaultPlan,
    FetchFailed,
    FetchTimeout,
    Overloaded,
    Quarantined,
    RetryPolicy,
    StorageFault,
    TransientFetchError,
)
from repro.datapath.scheduler import form_batch, run_tick  # noqa: F401
from repro.datapath.service import (  # noqa: F401
    DatapathService,
    Pod,
    QueueFull,
    QuotaExceeded,
    ScanRequest,
    ServiceClient,
    TenantQuota,
    Ticket,
)
from repro.datapath.telemetry import Telemetry, jain_index, quantile  # noqa: F401
from repro.datapath.trace import (  # noqa: F401
    PAPER_FIG2_PCT,
    STAGES,
    FlightRecorder,
    RequestTrace,
    Tracer,
)
