"""Unified tiered block store — ONE cost-aware cache hierarchy.

The paper's "pre-loaded" and "pre-filtered" configurations both hinge on
a cache whose metadata and orchestration it flags as an open challenge.
The repro used to answer it three disjoint ways: the LRU BlockCache
(which also held whole prefiltered ScanResults under the same budget),
the tick-scoped DecodePool that died at tick end, and the policy's
plan_fetch probing.  This module is the single accounted subsystem that
replaces all three:

  tiers      'encoded'      raw encoded pages (skip the storage->NIC
                            re-fetch; priced by the link model)
             'decoded'      decoded row-group columns (skip the decode;
                            priced by the per-encoding decode rate)
             'prefiltered'  whole filtered ScanResults (skip the scan;
                            priced by the ground-truth decode work that
                            produced them)
  ledger     one byte budget across every tier — used == Σ billed bytes
             of the kept entries, never above capacity (property-tested
             in tests/test_blockstore.py).
  eviction   cost-aware: the victim is the UNPINNED entry with the
             lowest estimated re-creation seconds per byte (cheapest to
             get back), LRU sequence as the tie-break.  Under pressure
             the store automatically keeps whatever is most expensive
             per byte to recreate — e.g. encoded pages outlive decoded
             PLAIN columns, while DICT/DELTA decodes outlive pages.
  windows    a StoreView pins decoded entries for a scheduling window
             (the service's hold_ticks), so a late-arriving coalescing
             partner reuses decodes instead of re-aligning ticks.
             Pinned entries are never evicted before their window
             expires; entries pinned by a raw scan are EPHEMERAL — they
             drop at expiry unless a preloaded/prefiltered put promoted
             them — so raw stays raw beyond the window.

`DecodePool` survives as a thin compatibility wrapper: a never-expiring
window over a private single-purpose store, with the exact budget
semantics the old tick-scoped pool had (rejected puts leave the old
entry and the ledger untouched).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Dict, Hashable, List, Optional, Tuple

from repro.datapath import trace
from repro.datapath.costmodel import CostModel

TIERS = ("encoded", "decoded", "prefiltered")

# A window pin that never expires (standalone DecodePool compatibility).
NEVER = 1 << 62


def _nbytes(obj) -> int:
    if hasattr(obj, "nbytes"):
        return int(obj.nbytes)
    if isinstance(obj, dict):
        return sum(_nbytes(v) for v in obj.values())
    if isinstance(obj, (list, tuple)):
        return sum(_nbytes(v) for v in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        # e.g. a whole prefiltered ScanResult or an EncodedColumn: bill its
        # arrays, otherwise the ledger never sees them and the store grows
        # unbounded
        return sum(_nbytes(getattr(obj, f.name)) for f in dataclasses.fields(obj))
    return 64


@dataclasses.dataclass
class TierStats:
    """Cumulative per-tier counters (live entries/bytes are computed by
    BlockStore.stats() from the ledger, so they can never drift)."""

    hits: int = 0
    misses: int = 0
    hit_bytes: int = 0
    puts: int = 0
    rejected_puts: int = 0
    evictions: int = 0
    expired: int = 0  # ephemeral window entries dropped at expiry
    demotions: int = 0  # decoded victims demoted to their encoded pages
    redecode_saved_s: float = 0.0  # estimated re-creation seconds hits avoided

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}


@dataclasses.dataclass
class BlockEntry:
    key: Hashable
    value: Any
    tier: str
    nbytes: int
    encoding: Optional[str]  # decoded tier: source encoding (pricing key)
    redecode_s: float  # estimated seconds to re-create this entry
    seq: int  # LRU clock (monotone; refreshed on touch)
    pin_tick: int = -1  # tick of the most recent window pin
    pin_expires: int = -1  # last tick (inclusive) the window pin covers
    ephemeral: bool = False  # drop at pin expiry unless promoted
    owner: Optional[str] = None  # tenant whose decode pinned it
    # eviction fallback: (key, value) of the encoded page(s) this decode
    # came from — eviction demotes to the encoded tier (pay only the
    # re-decode to get back) instead of dropping to zero (pay re-fetch
    # AND re-decode)
    demote: Optional[Tuple[Hashable, Any]] = None
    # tenants observed benefiting from this entry (window hits); retention
    # charges split across them instead of billing only the decoder
    beneficiaries: set = dataclasses.field(default_factory=set)

    def pinned(self, tick: int) -> bool:
        return self.pin_expires >= tick

    def rank(self) -> Tuple[float, int]:
        """Eviction priority: cheapest re-creation seconds per byte first,
        least recently used as the tie-break."""
        return (self.redecode_s / max(self.nbytes, 1), self.seq)


class BlockStore:
    """Tiered block cache with a single byte ledger and cost-aware
    eviction.  Keys live in one flat namespace (the engine's key tuples
    already disambiguate: ("page", ...) / ("rg", ...) / ("scan", ...));
    the tier is entry metadata driving pricing and the telemetry ledger,
    not a lookup dimension."""

    def __init__(self, capacity_bytes: int = 2 << 30,
                 cost_model: Optional[CostModel] = None):
        self.capacity = capacity_bytes
        self.cost_model = cost_model or CostModel()
        self.tick = 0
        self.used = 0
        self._entries: Dict[Hashable, BlockEntry] = {}
        self._seq = itertools.count()
        self._tier_stats: Dict[str, TierStats] = {t: TierStats() for t in TIERS}
        # Lazy-invalidation eviction heap: (seconds/byte, seq, key) records
        # pushed on every insert/touch; a record is live iff the entry
        # still exists with that exact seq (any touch/resize/re-price bumps
        # seq and pushes a fresh record, orphaning the old one).  Victim
        # selection is O(log n) amortized instead of the old O(n log n)
        # sort per eviction (ROADMAP open item).
        self._heap: List[Tuple[float, int, Hashable]] = []
        # keys that MAY hold a live window pin (pruned lazily) — lets the
        # can-we-cover-the-shortfall check sum pinned bytes without a full
        # entry walk
        self._pinned_keys: set = set()
        # window-view hit accounting, kept separate from tier hits so the
        # shim's .hits still means "cache lookups" (not pool coalescing)
        self.window_hits = 0
        self.window_hit_bytes = 0
        self.window_saved_s = 0.0
        # fabric peer-fetch accounting: entries this store pulled from a
        # sibling pod's store (hits) and served to one (serves).  The
        # seconds are the inter-pod hop price — what the scheduler folds
        # into WFQ actuals, and what the bench compares against the
        # storage link to show the remote tier is the cheaper source.
        self.peer_hits = 0
        self.peer_hit_bytes = 0
        self.peer_hit_seconds = 0.0
        self.peer_serves = 0
        self.peer_serve_bytes = 0
        # a sibling probe that raised (pod died between the liveness check
        # and the fetch) — counted here, then the fetch falls back to the
        # next peer / storage instead of propagating (DESIGN.md §17)
        self.peer_errors = 0
        # Fault plane: keys whose fetched bytes failed checksum
        # verification.  A quarantined key reads as a miss everywhere
        # (local get/peek, peer fetch, residency probes — the entry is
        # dropped) until a verified re-fetch puts it back, which clears
        # the mark.  The set holds keys currently poisoned; the counter
        # is cumulative.
        self._quarantined: set = set()
        self.quarantines = 0
        # Pod-death model for the fabric: a dead store refuses probes by
        # raising — this is what a peer fetch against a crashed sibling
        # actually sees, and what PeerFetcher must absorb.
        self.dead = False

    # ------------------------------------------------------------------
    # pricing
    # ------------------------------------------------------------------
    def _price(self, tier: str, nbytes: int, encoding: Optional[str],
               decode_work: Optional[Dict[str, int]]) -> float:
        """Estimated seconds to re-create an entry if evicted.

        encoded      re-fetch over the storage->NIC link
        decoded      re-decode at the encoding's calibrated rate
        prefiltered  re-do the scan's ground-truth decode work
        Decoded/prefiltered entries are floored at the PLAIN rate for
        their own bytes: however the entry was produced, serving it again
        at least re-materializes its output."""
        cm = self.cost_model
        if tier == "encoded":
            return cm.link_model().fetch_seconds(nbytes)
        floor = cm.decode_seconds(nbytes, "plain")
        if decode_work:
            return max(floor, sum(cm.decode_seconds(b, e)
                                  for e, b in decode_work.items()))
        return max(floor, cm.decode_seconds(nbytes, encoding or "plain"))

    # ------------------------------------------------------------------
    # core ops
    # ------------------------------------------------------------------
    def peek(self, key: Hashable) -> Optional[BlockEntry]:
        """Entry lookup without touching LRU order or hit/miss counters."""
        if self.dead:
            raise ConnectionError("block store is dead (pod crashed)")
        return self._entries.get(key)

    def quarantine(self, key: Hashable) -> None:
        """Poison `key` after a checksum failure: drop any resident copy
        and make the key read as a miss until a verified re-fetch puts a
        clean value back (put() clears the mark).  A quarantined page can
        therefore NEVER be decoded — the engine is forced back to
        storage, and the fault plane retries from there."""
        e = self._entries.pop(key, None)
        if e is not None:
            self.used -= e.nbytes
            self._pinned_keys.discard(key)
            self._tier_stats[e.tier].evictions += 1
        self._quarantined.add(key)
        self.quarantines += 1
        if trace._CUR is not None:
            trace.event("quarantine", nbytes=e.nbytes if e else 0)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def touch(self, entry: BlockEntry) -> None:
        entry.seq = next(self._seq)
        self._heap_push(entry)

    def _heap_push(self, entry: BlockEntry) -> None:
        heapq.heappush(self._heap, entry.rank() + (entry.key,))
        # stale records accumulate one per touch; compact when they clearly
        # dominate so the heap stays O(live entries)
        if len(self._heap) > 64 and len(self._heap) > 4 * len(self._entries):
            self._heap = [
                e.rank() + (e.key,) for e in self._entries.values()
            ]
            heapq.heapify(self._heap)

    def get(self, key: Hashable, tier: Optional[str] = None):
        """Counting lookup: a hit is recorded under the entry's tier (plus
        the re-creation seconds it avoided); a miss under `tier` (the tier
        the caller expected to find the key in, 'decoded' by default)."""
        e = self._entries.get(key)
        if e is None:
            self._tier_stats[tier or "decoded"].misses += 1
            return None
        st = self._tier_stats[e.tier]
        st.hits += 1
        st.hit_bytes += e.nbytes
        st.redecode_saved_s += e.redecode_s
        if trace._CUR is not None:  # flight recorder: hit inside a slice
            trace.event("store_hit", tier=e.tier, nbytes=e.nbytes,
                        saved_s=e.redecode_s)
        self.touch(e)
        return e.value

    def put(
        self,
        key: Hashable,
        value: Any,
        tier: str = "decoded",
        encoding: Optional[str] = None,
        decode_work: Optional[Dict[str, int]] = None,
        pin_until: Optional[int] = None,
        ephemeral: bool = False,
        owner: Optional[str] = None,
        demote: Optional[Tuple[Hashable, Any]] = None,
    ) -> bool:
        """Insert or refresh one entry; returns False when the entry could
        not be kept (bigger than the store, or the shortfall is pinned).
        Re-inserting an existing key bills only the size delta, and a
        rejected resize leaves the old entry — the ledger never holds an
        unbilled or over-budget byte."""
        assert tier in TIERS, tier
        # a fresh put IS the verified re-fetch that absolves a poisoned key
        self._quarantined.discard(key)
        nb = _nbytes(value)
        st = self._tier_stats[tier]
        old = self._entries.get(key)
        need = nb - (old.nbytes if old is not None else 0)
        if nb > self.capacity:
            st.rejected_puts += 1
            return False  # never cache something bigger than the device
        if self.used + need > self.capacity:
            self._evict(self.used + need - self.capacity, exclude=key)
            if self.used + need > self.capacity:  # the rest is pinned
                st.rejected_puts += 1
                return False
        seq = next(self._seq)
        if old is not None:
            self.used += need
            old.value = value
            old.nbytes = nb
            old.tier = tier if not ephemeral else old.tier
            old.encoding = encoding or old.encoding
            old.redecode_s = self._price(old.tier, nb, old.encoding, decode_work)
            old.seq = seq
            old.demote = demote or old.demote
            # promotion clears the ephemeral flag; a window re-pin of a
            # persistent entry never re-taints it
            old.ephemeral = old.ephemeral and ephemeral
            if pin_until is not None:
                old.pin_tick = self.tick
                old.pin_expires = max(old.pin_expires, pin_until)
                old.owner = owner or old.owner
                self._pinned_keys.add(key)
            if owner:
                old.beneficiaries.add(owner)
            self._heap_push(old)
            return True
        entry = BlockEntry(
            key=key, value=value, tier=tier, nbytes=nb, encoding=encoding,
            redecode_s=self._price(tier, nb, encoding, decode_work), seq=seq,
            ephemeral=ephemeral, owner=owner, demote=demote,
        )
        if owner:
            entry.beneficiaries.add(owner)
        if pin_until is not None:
            entry.pin_tick = self.tick
            entry.pin_expires = pin_until
            self._pinned_keys.add(key)
        self._entries[key] = entry
        self.used += nb
        st.puts += 1
        self._heap_push(entry)
        return True

    def _pinned_bytes(self) -> int:
        """Bytes held by live window pins, pruning stale pin bookkeeping as
        it goes.  O(pinned keys), not O(entries) — pins are the handful of
        window-held decodes, entries can be thousands."""
        total = 0
        for key in [k for k in self._pinned_keys]:
            e = self._entries.get(key)
            if e is None or not e.pinned(self.tick):
                self._pinned_keys.discard(key)
            else:
                total += e.nbytes
        return total

    def _evictable_bytes(self, exclude: Optional[Hashable]) -> int:
        total = self.used - self._pinned_bytes()
        ex = self._entries.get(exclude) if exclude is not None else None
        if ex is not None and not ex.pinned(self.tick):
            total -= ex.nbytes
        return total

    def _victims_linear(self, exclude: Optional[Hashable] = None) -> List[BlockEntry]:
        """O(n log n) rank-ordered victim list — the heap's oracle.  Kept
        for the property test in tests/test_blockstore.py (heap and linear
        selection must pick the same victim) and for debugging; production
        eviction goes through `_pop_victim`."""
        return sorted(
            (e for e in self._entries.values()
             if e.key != exclude and not e.pinned(self.tick)),
            key=BlockEntry.rank,
        )

    def _pop_victim(self, exclude: Optional[Hashable] = None) -> Optional[BlockEntry]:
        """Next eviction victim off the lazy heap: skip records orphaned by
        touches/resizes/deletes (seq mismatch), defer records for entries
        that are merely unevictable right now (pinned, or the excluded
        key) so they stay discoverable, and return the first live one —
        identical choice to `_victims_linear()[0]`."""
        deferred: List[Tuple[float, int, Hashable]] = []
        victim = None
        while self._heap:
            rec = heapq.heappop(self._heap)
            e = self._entries.get(rec[2])
            if e is None or e.seq != rec[1]:
                continue  # orphaned: entry gone or re-ranked since pushed
            if rec[2] == exclude or e.pinned(self.tick):
                deferred.append(rec)
                continue
            victim = e
            break
        for rec in deferred:
            heapq.heappush(self._heap, rec)
        return victim

    def _demote(self, victim: BlockEntry) -> int:
        """Re-insert an evicted decoded column as its source encoded
        page(s) — getting it back then costs only the re-decode, not
        re-fetch AND re-decode.  Returns the bytes the demoted entry
        re-occupies (0 when demotion was skipped: no payload, source
        pages still resident, or no footprint shrink).  Ephemeral (raw
        window) victims never demote — raw leaves no persistent state."""
        if victim.tier != "decoded" or not victim.demote or victim.ephemeral:
            return 0
        dkey, dval = victim.demote
        if dkey in self._entries:
            return 0  # the encoded pages are still resident on their own
        nb = _nbytes(dval)
        if nb >= victim.nbytes or self.used + nb > self.capacity:
            return 0
        entry = BlockEntry(
            key=dkey, value=dval, tier="encoded", nbytes=nb,
            encoding=victim.encoding,
            redecode_s=self._price("encoded", nb, victim.encoding, None),
            seq=next(self._seq), owner=victim.owner,
            beneficiaries=set(victim.beneficiaries),
        )
        self._entries[dkey] = entry
        self.used += nb
        self._tier_stats["decoded"].demotions += 1
        self._tier_stats["encoded"].puts += 1
        self._heap_push(entry)
        if trace._CUR is not None:
            trace.event("demote", tier="encoded", nbytes=nb)
        return nb

    def _evict(self, need_bytes: int, exclude: Optional[Hashable] = None) -> None:
        """Free at least `need_bytes` by evicting unpinned entries in
        cost-rank order (lowest re-creation seconds per byte first, LRU
        tie-break) via the lazy-invalidation heap.  Window-pinned blocks
        are never victims — and when the evictable entries cannot cover
        the shortfall, NOTHING is evicted: the caller's put will be
        refused anyway, and a doomed put must not flush the unpinned
        working set on its way out.

        A decoded victim carrying a demote payload falls back to the
        encoded tier instead of dropping to zero; the demoted entry is
        itself unpinned, so coverage is preserved (the shortfall and the
        evictable pool grow by the same re-occupied bytes) and the loop
        still terminates (each demotion strictly shrinks the footprint)."""
        if self._evictable_bytes(exclude) < need_bytes:
            return
        while need_bytes > 0:
            victim = self._pop_victim(exclude)
            if victim is None:  # defensive: coverage said this can't happen
                return
            del self._entries[victim.key]
            self.used -= victim.nbytes
            need_bytes -= victim.nbytes
            self._tier_stats[victim.tier].evictions += 1
            if trace._CUR is not None:  # eviction forced by a traced slice
                trace.event("evict", tier=victim.tier, nbytes=victim.nbytes)
            need_bytes += self._demote(victim)

    def advance_tick(self, tick: int) -> None:
        """Move the window clock: pins whose window ended become evictable,
        and ephemeral (raw-scan) entries among them are dropped outright —
        raw mode leaves no persistent state beyond its hold window."""
        self.tick = tick
        for key in [k for k, e in self._entries.items()
                    if e.ephemeral and e.pin_expires < tick]:
            e = self._entries.pop(key)
            self.used -= e.nbytes
            self._pinned_keys.discard(key)
            self._tier_stats[e.tier].expired += 1

    def clear(self) -> None:
        self._entries.clear()
        self.used = 0
        self._heap = []
        self._pinned_keys.clear()

    # ------------------------------------------------------------------
    # metadata probes (non-mutating — admission control and the policy)
    # ------------------------------------------------------------------
    def plan_fetch(self, keys: List[Hashable],
                   tier: Optional[str] = None) -> Tuple[List[Hashable], List[Hashable]]:
        """Split keys into (resident, missing) without touching LRU order
        or counters; `tier` restricts residency to one tier."""
        def resident(k):
            e = self._entries.get(k)
            return e is not None and (tier is None or e.tier == tier)

        cached = [k for k in keys if resident(k)]
        missing = [k for k in keys if not resident(k)]
        return cached, missing

    def pinned(self, key: Hashable) -> bool:
        """Is `key` a live window-pinned decoded block right now?"""
        e = self._entries.get(key)
        return e is not None and e.tier == "decoded" and e.pinned(self.tick)

    def retention_charges(self) -> Dict[str, Tuple[int, float]]:
        """Per-tenant (pinned bytes, per-tick retention price) over window
        pins held ACROSS a tick boundary.  Each entry's price amortizes
        one full re-creation over its window, so holding a decode for its
        whole hold window costs exactly what re-decoding it would have —
        window retention is paid for in the same WFQ currency it saves.

        The price splits EQUALLY across the entry's observed beneficiaries
        (tenants whose window lookups hit it, decoder included) instead of
        billing only the tenant that happened to decode first: a coalesced
        decode that three tenants reuse costs each a third, not the
        decoder everything and the free-riders nothing."""
        out: Dict[str, Tuple[int, float]] = {}
        for e in self._entries.values():
            if not e.pinned(self.tick) or e.pin_tick >= self.tick:
                continue
            who = sorted(e.beneficiaries) or ([e.owner] if e.owner else [])
            if not who:
                continue
            share = 1.0 / len(who)
            price = e.redecode_s / max(e.pin_expires - e.pin_tick, 1)
            for t in who:
                b, s = out.get(t, (0, 0.0))
                out[t] = (b + int(e.nbytes * share), s + price * share)
        return out

    # ------------------------------------------------------------------
    # windows + reporting
    # ------------------------------------------------------------------
    def window(self, expires_tick: int, max_bytes: Optional[int] = None,
               owner: Optional[str] = None) -> "StoreView":
        return StoreView(self, expires_tick, max_bytes=max_bytes, owner=owner)

    def stats(self) -> dict:
        """Deterministic per-tier ledger (key-sorted, plain types) for
        telemetry snapshots and the blockstore bench sub-report."""
        live: Dict[str, Dict[str, int]] = {
            t: {"entries": 0, "bytes": 0, "pinned_bytes": 0} for t in TIERS
        }
        for e in self._entries.values():
            lv = live[e.tier]
            lv["entries"] += 1
            lv["bytes"] += e.nbytes
            if e.pinned(self.tick):
                lv["pinned_bytes"] += e.nbytes
        tiers = {}
        for t in TIERS:
            d = self._tier_stats[t].as_dict()
            d.update(live[t])
            tiers[t] = dict(sorted(d.items()))
        return {
            "capacity": self.capacity,
            "used": self.used,
            "tick": self.tick,
            "tiers": tiers,
            "window_hits": self.window_hits,
            "window_hit_bytes": self.window_hit_bytes,
            "window_saved_s": self.window_saved_s,
            "peer_hits": self.peer_hits,
            "peer_hit_bytes": self.peer_hit_bytes,
            "peer_hit_seconds": self.peer_hit_seconds,
            "peer_serves": self.peer_serves,
            "peer_serve_bytes": self.peer_serve_bytes,
            "peer_errors": self.peer_errors,
            "quarantines": self.quarantines,
            "quarantined_live": len(self._quarantined),
        }


class PeerFetcher:
    """Peer-to-peer block-store fetch for the scan fabric (DESIGN.md §15).

    Installed on a pod's BlockCache (`cache.peer`); consulted only when a
    COUNTING get misses the local store.  A sibling pod that already holds
    the page/decoded column serves a copy over the inter-pod link — wider
    and shallower than the storage hop, and a decoded-tier hit also skips
    the decode — and the copy is installed into the local store at the
    same tier so subsequent lookups are plain local hits.

    Scope rules keeping the fabric bit-identical and honestly priced:
      * only 'page' (encoded) and 'rg' (decoded) keys cross pods — whole
        prefiltered results stay pod-local (their keys carry the pod's
        row-group-subset scan tag, so a cross-pod hit could never match
        a different subset anyway);
      * residency PROBES (`__contains__`, `plan_fetch`) stay local-only:
        the policy and scheduler see exactly what single-node pods see,
        and peer traffic happens only when work actually runs;
      * window-pinned / ephemeral state never transfers — the serving
        side is read via `peek` (non-mutating), the local install is an
        ordinary unpinned put.

    `peers` is a zero-arg callable yielding live (pod_id, BlockStore)
    siblings — the fabric rebinds it on drain so a dead pod's store is
    never consulted."""

    PEER_KINDS = ("page", "rg")

    def __init__(self, pod_id: str, peers, link=None):
        from repro.datapath.netsim import interpod_link

        self.pod_id = pod_id
        self.peers = peers
        self.link = link or interpod_link()

    def fetch(self, key: Hashable, into: BlockStore, stats=None):
        """Probe siblings for `key`; on a hit, bill the hop, install a
        local copy, and return the value.  `stats` (a ScanStats) receives
        the transferred bytes so the scheduler can price THIS request's
        peer traffic into its WFQ reconcile."""
        kind = key[0] if isinstance(key, tuple) and key else None
        if kind not in self.PEER_KINDS:
            return None
        try:
            peers = list(self.peers())
        except Exception:
            # the membership callback itself failed — treat as no peers
            into.peer_errors += 1
            return None
        for pid, store in peers:
            if store is into:
                continue
            try:
                e = store.peek(key)
            except Exception:
                # The sibling died between the fabric's liveness check and
                # this probe.  A cache miss must degrade to the next peer
                # (and ultimately storage), never propagate out of the
                # miss path — the requesting scan did nothing wrong.
                into.peer_errors += 1
                if trace._CUR is not None:
                    trace.event("peer_error", source=pid)
                continue
            if e is None or e.tier == "prefiltered" or e.ephemeral:
                # ephemeral = a raw scan's window-pinned decode; raw mode
                # leaves no persistent state, and peering must not turn
                # another pod's transient window into a durable copy
                continue
            secs = self.link.fetch_seconds(e.nbytes)
            store.peer_serves += 1
            store.peer_serve_bytes += e.nbytes
            into.peer_hits += 1
            into.peer_hit_bytes += e.nbytes
            into.peer_hit_seconds += secs
            if stats is not None:
                stats.peer_bytes += e.nbytes
            if trace._CUR is not None:
                trace.event("peer_fetch", tier=e.tier, nbytes=e.nbytes,
                            source=pid, hop_s=secs)
            into.put(key, e.value, tier=e.tier, encoding=e.encoding)
            return e.value
        return None


class StoreView:
    """Window-scoped view into the store's decoded tier — the scheduler's
    shared decode pool.  Entries it inserts are pinned (evictable only
    after `expires_tick`) and ephemeral (dropped at expiry unless a
    preloaded/prefiltered put promotes them); entries pinned by EARLIER
    windows are visible too, which is exactly how a late-arriving
    coalescing partner reuses retained decodes.

    Budget semantics match the old tick-scoped DecodePool: `used_bytes`
    is the summed nbytes of the entries this view pinned, a re-insert
    bills only the size delta, and a rejected put (view budget or store
    capacity) changes nothing."""

    def __init__(self, store: BlockStore, expires_tick: int,
                 max_bytes: Optional[int] = None, owner: Optional[str] = None):
        self.store = store
        self.expires_tick = expires_tick
        self.max_bytes = max_bytes
        self.owner = owner  # rebindable: run_tick sets it per request
        self._mine: Dict[Hashable, int] = {}  # key -> billed nbytes
        self.used_bytes = 0
        self.hits = 0
        self.misses = 0
        self.hit_bytes = 0
        self.puts = 0
        self.rejected_puts = 0
        # cross-tick reuse: hits on entries pinned by an EARLIER tick
        self.retained_hits = 0
        self.retained_hit_bytes = 0
        self.retained_saved_s = 0.0

    # -- visibility --------------------------------------------------------
    def _visible(self, key: Hashable) -> Optional[BlockEntry]:
        e = self.store.peek(key)
        if e is None or e.tier != "decoded" or not e.pinned(self.store.tick):
            return None
        return e

    def __contains__(self, key: Hashable) -> bool:
        return self._visible(key) is not None

    def __len__(self) -> int:
        return sum(1 for k in self.store._entries if self._visible(k) is not None)

    def __iter__(self):
        return (k for k in list(self.store._entries) if self._visible(k) is not None)

    def values(self):
        return [self.store._entries[k].value for k in self]

    def __getitem__(self, key: Hashable):
        e = self._visible(key)
        if e is None:
            raise KeyError(key)
        return e.value

    def encoding_of(self, key: Hashable) -> Optional[str]:
        """Source encoding recorded for a visible entry — carried along
        when the engine promotes a pool hit into another store, so the
        promoted decode keeps its honest eviction price."""
        e = self._visible(key)
        return e.encoding if e is not None else None

    # -- counting ops ------------------------------------------------------
    def get(self, key: Hashable, default=None):
        e = self._visible(key)
        if e is None:
            self.misses += 1
            return default
        self.hits += 1
        self.hit_bytes += e.nbytes
        if self.owner:
            # observed beneficiary: retention charges split across every
            # tenant that actually reused this decode, not just its owner
            e.beneficiaries.add(self.owner)
        self.store.window_hits += 1
        self.store.window_hit_bytes += e.nbytes
        self.store.window_saved_s += e.redecode_s
        retained = -1 < e.pin_tick < self.store.tick  # pinned by an earlier tick
        if retained:
            self.retained_hits += 1
            self.retained_hit_bytes += e.nbytes
            self.retained_saved_s += e.redecode_s
        if trace._CUR is not None:  # flight recorder: window-pool hit
            trace.event("store_hit", tier="decoded", window=True,
                        retained=retained, nbytes=e.nbytes,
                        saved_s=e.redecode_s)
        self.store.touch(e)
        return e.value

    def put(self, key: Hashable, value, encoding: Optional[str] = None) -> bool:
        nb = int(value.nbytes)
        delta = nb - self._mine.get(key, 0)
        if (self.max_bytes is not None and delta > 0
                and self.used_bytes + delta > self.max_bytes):
            self.rejected_puts += 1
            return False
        kept = self.store.put(
            key, value, tier="decoded", encoding=encoding,
            pin_until=self.expires_tick, ephemeral=True, owner=self.owner,
        )
        if not kept:
            self.rejected_puts += 1
            return False
        if key not in self._mine:
            self.puts += 1
        self.used_bytes += delta
        self._mine[key] = nb
        return True

    def __setitem__(self, key: Hashable, value) -> None:
        self.put(key, value)


class DecodePool(StoreView):
    """Back-compat shim: the old tick-scoped shared decode pool, now a
    never-expiring window over a private single-purpose BlockStore.  All
    entries are pinned, so the store never evicts — an over-budget put is
    refused with the old entry (and the ledger) untouched, exactly the
    accounting the property suite in tests/test_decode_pool_props.py
    pins down."""

    def __init__(self, max_bytes: int = 1 << 30):
        super().__init__(
            BlockStore(capacity_bytes=max_bytes), NEVER, max_bytes=max_bytes
        )
