"""Catalog — the fabric's shared table registry with snapshot isolation.

Every pod in a ScanFabric resolves table names through ONE catalog, so
the fleet agrees on what "table t" means.  Mutations (register / drop)
bump a monotonic global version and copy-on-write the name->reader map;
a scan pins the version current at submission (`pin()`) and keeps
reading that immutable view for its whole lifetime — a mid-scan
re-registration is invisible to in-flight scans and visible to every
scan submitted after it.  That is snapshot isolation, not serializable
DDL: two concurrent registrations last-write-win on the name, which is
exactly the lake-catalog semantic the paper's appliance sits under.

Pins are bookkeeping only (no locks, nothing is copied at pin time):
`release()` retires the pin so `pinned_versions()` reports what any
compaction / vacuum job must still keep readable.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """An immutable view of the catalog at one version.  The `tables`
    dict is never mutated after the snapshot is taken (the catalog
    copies on write), so readers resolved through it stay valid no
    matter what the live catalog does."""

    version: int
    tables: Dict[str, object]

    def table(self, name: str):
        try:
            return self.tables[name]
        except KeyError:
            raise KeyError(
                f"table {name!r} not in catalog snapshot v{self.version} "
                f"(has: {sorted(self.tables)})"
            ) from None

    def __contains__(self, name: str) -> bool:
        return name in self.tables


class Catalog:
    def __init__(self):
        self._tables: Dict[str, object] = {}
        self._version = 0
        # version -> live pin count; pins retire via release()
        self._pins: Dict[int, int] = collections.Counter()

    @property
    def version(self) -> int:
        return self._version

    # -- mutations (copy-on-write; each bumps the global version) --------
    def register(self, name: str, reader) -> int:
        """Bind `name` to `reader` (new table or replacement — lake
        commits swap the manifest the same way).  Returns the new
        catalog version."""
        tables = dict(self._tables)
        tables[name] = reader
        self._tables = tables
        self._version += 1
        return self._version

    def drop(self, name: str) -> int:
        if name not in self._tables:
            raise KeyError(f"table {name!r} not in catalog")
        tables = dict(self._tables)
        del tables[name]
        self._tables = tables
        self._version += 1
        return self._version

    # -- reads -----------------------------------------------------------
    def resolve(self, name: str):
        """The LATEST reader for `name` — admission-time resolution.
        In-flight scans must use their pinned snapshot instead."""
        try:
            return self._tables[name]
        except KeyError:
            raise KeyError(f"table {name!r} not in catalog "
                           f"(has: {sorted(self._tables)})") from None

    def tables(self) -> List[str]:
        return sorted(self._tables)

    # -- snapshot pins ---------------------------------------------------
    def pin(self) -> Snapshot:
        """Pin the current version for one scan.  O(1): the returned
        Snapshot aliases the current copy-on-write map."""
        self._pins[self._version] += 1
        return Snapshot(self._version, self._tables)

    def release(self, snap: Optional[Snapshot]) -> None:
        """Retire one pin (idempotent for None, strict otherwise)."""
        if snap is None:
            return
        n = self._pins.get(snap.version, 0)
        if n <= 0:
            raise RuntimeError(f"catalog version {snap.version} has no live pins")
        if n == 1:
            del self._pins[snap.version]
        else:
            self._pins[snap.version] = n - 1

    def pinned_versions(self) -> List[int]:
        """Versions still readable by an in-flight scan — the floor any
        vacuum/compaction job must respect."""
        return sorted(self._pins)
