"""Calibrated, encoding-aware decode cost model — the WFQ currency mint.

The paper's SmartNIC can hide decode cost only if the appliance knows
what decode actually costs.  Charging fair-share virtual time in nominal
decoded BYTES prices an RLE row group the same as PLAIN even though the
device decodes them at very different rates; this module prices work in
estimated *device decode-seconds* instead:

  measure    `CostModel.calibrate()` microbenchmarks each decode kernel
             (PLAIN / BITPACK / DICT / DELTA / RLE — the same `kernels.ops`
             paths benchmarks/kernels_bench.py measures) into a
             per-encoding decoded-GB/s table, persistable as JSON with a
             nominal fallback when kernels are slow or unavailable.
             Rates and launch overhead are PER BACKEND — the ref-jitted
             and pallas paths differ wildly (and ref-eager historically by
             ~100x) — so the persisted JSON is keyed by backend
             (`{"backends": {"ref": {...}, "pallas": {...}}}`); `save`
             merges into an existing file and `load`/`load_or_nominal`
             pick the entry for the ACTIVE backend (kernels.ops dispatch
             resolution), never pricing one backend with another's table.
  estimate   `estimate_row_groups()` reads true dtype widths + encodings
             from footer metadata via `engine.decode_footprint` (padded
             rows, fused predicate column never materialized) and converts
             each row group to (honest decoded bytes, estimated seconds).
  unify      `decode_model()` / `pipeline()` hand the SAME table to
             netsim, so the prefetch-overlap simulation and the scheduler
             price decode identically.

The estimate is still an estimate — a tenant whose metadata (or doctored
request) under-prices its scans would buy extra share.  The service
therefore charges the estimate at dispatch and RECONCILES at slice
completion against the bytes the engine actually materialized
(service._vreconcile), the same estimate-then-correct pattern the quota
path uses for encoded bytes.  Systematic under-estimates are re-billed
within one tick; over-estimates (e.g. prefiltered cache hits that decode
nothing) are refunded.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional

import numpy as np

from repro.datapath.netsim import (
    INTERPOD_BANDWIDTH_GBPS,
    INTERPOD_LATENCY_US,
    DecodeModel,
    LinkModel,
    PrefetchPipeline,
)

# Decoded-output GB/s per encoding when no calibration is available.
# Loosely ordered by work per output byte on the jnp reference path; any
# systematic error is corrected by WFQ reconciliation, so these only need
# to be sane, not exact.
NOMINAL_RATES_GBPS: Dict[str, float] = {
    "plain": 20.0,  # device put of already-decoded bytes
    "rle": 12.0,
    "bitpack": 10.0,
    "dict": 8.0,
    "delta": 6.0,
    # pushed-down aggregate reduction (ops.grouped_agg_batch /
    # ops.fused_agg_batch), priced per PROCESSED value byte — the values
    # are reduced in-kernel and never materialized (DESIGN.md §16)
    "agg": 8.0,
}

# Fixed per-kernel-launch overhead when no calibration is available.
# Deliberately ZERO: unlike the rates (where any sane nonzero beats
# nothing), dispatch overhead is meaningless un-measured — a guessed
# value would churn every legacy charge/reconcile pair for no accuracy.
# `calibrate()` measures the real value (a 1-block decode is ~pure
# dispatch), and only then do estimates price launches — at which point
# the sequential path (one launch per row-group column) and the batched
# path (one per bucket) are both priced honestly and reconciled against
# `ScanStats.kernel_launches`.
NOMINAL_LAUNCH_OVERHEAD_S = 0.0


def active_backend() -> str:
    """The kernel backend `kernels.ops` actually dispatches to for
    backend='auto' right now — the key calibration tables are stored and
    looked up under."""
    from repro.kernels.ops import _resolve

    return _resolve("auto")[0]


# Process-default cost model: DatapathService registers its (possibly
# calibrated) model here so DEFAULT-constructed netsim DecodeModels price
# decode from the same table the scheduler charges with, instead of the
# nominal constants (netsim.DecodeModel.__post_init__ reads this).
_DEFAULT_MODEL: Optional["CostModel"] = None


def set_default_cost_model(cm: Optional["CostModel"]) -> Optional["CostModel"]:
    """Install `cm` as the process-default table; returns the previous one."""
    global _DEFAULT_MODEL
    prev, _DEFAULT_MODEL = _DEFAULT_MODEL, cm
    return prev


def default_cost_model() -> "CostModel":
    """The registered process-default model, or a nominal table for the
    active backend when none has been registered."""
    return _DEFAULT_MODEL if _DEFAULT_MODEL is not None else CostModel()


@dataclasses.dataclass
class RowGroupCost:
    """One row group's estimated decode price.

    `nbytes` is what the engine will MATERIALIZE (the tick-budget and
    reconciliation currency); `seconds` is estimated device time and
    includes non-materialized decode work (the fused predicate column is
    processed at its encoding's rate even though it produces no bytes)."""

    nbytes: int
    seconds: float


def _median_seconds(fn, repeats: int) -> float:
    import jax

    jax.block_until_ready(fn())  # warmup: compile + first dispatch
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return max(times[len(times) // 2], 1e-9)


def measure_rates(backend: str = "ref", n: int = 1 << 18, repeats: int = 3,
                  seed: int = 0, overhead_s: float = 0.0) -> Dict[str, float]:
    """Microbenchmark each decode kernel path into decoded-output GB/s.

    Exercises the exact entry points the engine's `_decode_device` uses
    (repro.kernels.ops), with value distributions matching
    benchmarks/kernels_bench.py.  Raises on any kernel failure — callers
    wanting a fallback use `CostModel.calibrate`.

    `overhead_s` (the measured per-launch dispatch cost) is subtracted
    from each timed call before deriving the rate, so the table prices
    MARGINAL per-byte decode work and estimates don't double-count the
    overhead that `launch_overhead_s` bills separately per launch
    (floored at 5% of the measured time so a noisy overhead sample can
    never produce a zero/negative rate)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.lakeformat import encodings as E

    rng = np.random.default_rng(seed)
    rates: Dict[str, float] = {}

    def _marginal(t: float) -> float:
        return max(t - overhead_s, t * 0.05)

    # PLAIN: decode == device put of the raw buffer
    buf = rng.standard_normal(n).astype(np.float32)
    t = _median_seconds(lambda: jnp.asarray(buf), repeats)
    rates["plain"] = n * 4 / _marginal(t) / 1e9

    # BITPACK @ 16 bits
    v = rng.integers(0, 1 << 16, size=n, dtype=np.uint64)
    p = jnp.asarray(E.bitpack_encode(v, 16))
    t = _median_seconds(lambda: ops.bitunpack(p, 16, n, backend=backend), repeats)
    rates["bitpack"] = n * 4 / _marginal(t) / 1e9

    # DICT (low cardinality)
    v = rng.choice(np.array([1, 5, 9, 13, 20, 44, 90], dtype=np.int64), size=n)
    b = E.dict_encode(v)
    k = int(b.pop("_k")[0])
    pk, d = jnp.asarray(b["packed"]), jnp.asarray(b["dictionary"].astype(np.int32))
    t = _median_seconds(lambda: ops.dict_decode(pk, d, k, n, backend=backend), repeats)
    rates["dict"] = n * 4 / _marginal(t) / 1e9

    # DELTA (sorted-ish ints)
    v = np.cumsum(rng.integers(0, 16, size=n)).astype(np.int64)
    b = E.delta_encode(v)
    k = int(b.pop("_k")[0])
    pk, bs = jnp.asarray(b["packed"]), jnp.asarray(b["bases"].astype(np.int32))
    t = _median_seconds(lambda: ops.delta_decode(pk, bs, k, n, backend=backend), repeats)
    rates["delta"] = n * 4 / _marginal(t) / 1e9

    # RLE (runs ~64 long; smaller n — one-hot expansion is eager on CPU)
    nr = min(n, 1 << 17)
    v = np.repeat(rng.integers(0, 100, size=max(nr // 64, 1)), 64).astype(np.int32)[:nr]
    b = E.rle_encode(v)
    rv, re_ = jnp.asarray(b["rle_values"]), jnp.asarray(b["rle_ends"])
    t = _median_seconds(lambda: ops.rle_decode(rv, re_, len(v), backend=backend), repeats)
    rates["rle"] = len(v) * 4 / _marginal(t) / 1e9

    return rates


def measure_launch_overhead(backend: str = "ref", repeats: int = 5,
                            seed: int = 0) -> float:
    """Fixed per-launch dispatch cost: the median wall time of a ONE-block
    decode, whose compute is negligible next to dispatch + jit-cache
    lookup.  This is what the sequential scan pays per (row group, column)
    and what bucketed batch launches amortize across pages."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.lakeformat import encodings as E

    rng = np.random.default_rng(seed)
    v = rng.integers(0, 1 << 8, size=E.PACK_BLOCK, dtype=np.uint64)
    p = jnp.asarray(E.bitpack_encode(v, 8))
    return _median_seconds(lambda: ops.bitunpack(p, 8, backend=backend), repeats)


class CostModel:
    """Per-encoding decode rates + link parameters, with estimation and
    persistence.  `source` records provenance: 'nominal', 'calibrated', or
    'nominal-fallback' (calibration attempted and failed)."""

    def __init__(
        self,
        rates: Optional[Dict[str, float]] = None,
        source: str = "nominal",
        backend: Optional[str] = None,
        link_bandwidth_gbps: float = 12.5,
        link_latency_us: float = 10.0,
        launch_overhead_s: float = NOMINAL_LAUNCH_OVERHEAD_S,
        interpod_bandwidth_gbps: float = INTERPOD_BANDWIDTH_GBPS,
        interpod_latency_us: float = INTERPOD_LATENCY_US,
        link_source: str = "nominal",
    ):
        self.rates = dict(NOMINAL_RATES_GBPS)
        if rates:
            self.rates.update({k: float(v) for k, v in rates.items() if v and v > 0})
        self.source = source
        # link provenance is tracked SEPARATELY from the kernel-rate source:
        # calibrate() measures decode kernels but nothing today measures the
        # storage link, so link_source stays 'nominal' until a real fabric
        # calibration exists — telemetry surfaces this as a one-time warning
        # instead of silently pricing fetches with guessed constants
        self.link_source = link_source
        self.backend = backend or active_backend()
        self.link_bandwidth_gbps = link_bandwidth_gbps
        self.link_latency_us = link_latency_us
        self.launch_overhead_s = max(0.0, float(launch_overhead_s))
        self.interpod_bandwidth_gbps = interpod_bandwidth_gbps
        self.interpod_latency_us = interpod_latency_us

    # -- pricing -----------------------------------------------------------
    def rate_gbps(self, encoding: str = "plain") -> float:
        return self.rates.get(encoding, self.rates["plain"])

    def decode_seconds(self, nbytes: int, encoding: str = "plain") -> float:
        return nbytes / (self.rate_gbps(encoding) * 1e9)

    def launch_seconds(self, n_launches: int) -> float:
        """Fixed dispatch cost of `n_launches` device kernel launches — the
        term bucketed batch decoding amortizes.  Zero until calibrated."""
        return n_launches * self.launch_overhead_s

    # -- estimation (footer metadata only) ---------------------------------
    def estimate_row_groups(
        self, engine, reader, plan, row_groups, pred=None
    ) -> List[RowGroupCost]:
        """Per-row-group (materialized bytes, estimated decode-seconds) for
        a scan, from footer metadata via `engine.decode_footprint` — padded
        rows, true dtype widths, encoding-specific rates, fused predicate
        column priced but never counted as output bytes."""
        out = []
        for fp in engine.decode_footprint(reader, plan, row_groups, pred=pred):
            nbytes = 0
            seconds = 0.0
            for col in fp["columns"].values():
                # one launch per column is the SEQUENTIAL path's dispatch
                # bill (a fused predicate column launches its fused scan);
                # the batched path launches per bucket and reconciles the
                # difference against ScanStats.kernel_launches
                seconds += (self.decode_seconds(col["nbytes"], col["encoding"])
                            + self.launch_overhead_s)
                if col["materialized"]:
                    nbytes += col["nbytes"]
            out.append(RowGroupCost(nbytes, seconds))
        return out

    # -- netsim unification ------------------------------------------------
    def decode_model(self) -> DecodeModel:
        return DecodeModel(decode_gbps=self.rate_gbps("plain"), rates=dict(self.rates),
                           launch_overhead_s=self.launch_overhead_s)

    def link_model(self) -> LinkModel:
        return LinkModel(bandwidth_gbps=self.link_bandwidth_gbps,
                         latency_us=self.link_latency_us)

    def interpod_link_model(self) -> LinkModel:
        """The pod<->pod hop a fabric peer fetch pays — wider and shallower
        than the storage link, so a remote pod's tier is ALWAYS a cheaper
        source than re-fetching from disaggregated storage."""
        return LinkModel(bandwidth_gbps=self.interpod_bandwidth_gbps,
                         latency_us=self.interpod_latency_us)

    def peer_fetch_seconds(self, nbytes: int) -> float:
        """Price one slice's peer-fetched bytes over the inter-pod hop.
        This is what the scheduler folds into a slice's ACTUAL seconds at
        reconcile time, so WFQ vtime stays honest when a pod's scan is fed
        by its neighbors' block stores (latency billed once per slice)."""
        if nbytes <= 0:
            return 0.0
        return self.interpod_link_model().fetch_seconds(nbytes)

    def pipeline(self) -> PrefetchPipeline:
        return PrefetchPipeline(link=self.link_model(), decode=self.decode_model())

    # -- calibration -------------------------------------------------------
    @classmethod
    def calibrate(cls, backend: str = "ref", n: int = 1 << 18, repeats: int = 3,
                  **kw) -> "CostModel":
        """Measure the kernel table AND the per-launch dispatch overhead;
        fall back to the nominal table (with `source='nominal-fallback'`)
        if any kernel path fails — a cost model must never take the
        service down."""
        try:
            overhead = measure_launch_overhead(backend=backend,
                                               repeats=max(repeats, 3))
            # the overhead is measured FIRST and subtracted from the rate
            # microbenchmarks, so rates price marginal per-byte work and
            # estimates (rate + one launch_overhead_s per launch) don't
            # double-count dispatch
            rates = measure_rates(backend=backend, n=n, repeats=repeats,
                                  overhead_s=overhead)
            return cls(rates=rates, source="calibrated", backend=backend,
                       launch_overhead_s=overhead, **kw)
        except Exception:  # noqa: BLE001 — calibration is best-effort
            return cls(source="nominal-fallback", backend=backend, **kw)

    # -- persistence -------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "rates_gbps": {k: self.rates[k] for k in sorted(self.rates)},
            "source": self.source,
            "link_source": self.link_source,
            "backend": self.backend,
            "link_bandwidth_gbps": self.link_bandwidth_gbps,
            "link_latency_us": self.link_latency_us,
            "launch_overhead_s": self.launch_overhead_s,
            "interpod_bandwidth_gbps": self.interpod_bandwidth_gbps,
            "interpod_latency_us": self.interpod_latency_us,
        }

    def save(self, path: str) -> str:
        """Write this model's table under its backend key, MERGING into an
        existing per-backend file (a pallas calibration must not clobber
        the ref one — the two differ by orders of magnitude).  A legacy
        flat-format file is folded in under its recorded backend."""
        data: dict = {"format": "per-backend", "backends": {}}
        try:
            with open(path) as f:
                old = json.load(f)
            if isinstance(old.get("backends"), dict):
                data["backends"].update(old["backends"])
            elif "rates_gbps" in old:
                data["backends"][old.get("backend", "ref")] = old
        except (OSError, ValueError):
            pass
        data["backends"][self.backend] = self.to_dict()
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def _from_dict(cls, d: dict) -> "CostModel":
        return cls(
            rates=d.get("rates_gbps"),
            source=d.get("source", "calibrated"),
            link_source=d.get("link_source", "nominal"),
            backend=d.get("backend", "ref"),
            link_bandwidth_gbps=d.get("link_bandwidth_gbps", 12.5),
            link_latency_us=d.get("link_latency_us", 10.0),
            launch_overhead_s=d.get("launch_overhead_s",
                                    NOMINAL_LAUNCH_OVERHEAD_S),
            interpod_bandwidth_gbps=d.get("interpod_bandwidth_gbps",
                                          INTERPOD_BANDWIDTH_GBPS),
            interpod_latency_us=d.get("interpod_latency_us",
                                      INTERPOD_LATENCY_US),
        )

    @classmethod
    def load(cls, path: str, backend: Optional[str] = None) -> "CostModel":
        """Load the table for `backend` (default: the ACTIVE backend) from
        a per-backend file; raises KeyError when that backend has no entry
        — a table calibrated on another backend does not transfer.  Legacy
        flat-format files load as-is (pre-per-backend artifacts)."""
        with open(path) as f:
            d = json.load(f)
        if isinstance(d.get("backends"), dict):
            be = backend or active_backend()
            entry = d["backends"].get(be)
            if entry is None:
                raise KeyError(f"no calibration for backend {be!r} in {path}")
            return cls._from_dict(entry)
        return cls._from_dict(d)

    @classmethod
    def load_or_nominal(cls, path: Optional[str],
                        backend: Optional[str] = None) -> "CostModel":
        """Best-effort load of the active (or given) backend's table: a
        missing file, corrupt JSON, or absent backend entry degrades to
        nominal rates rather than failing service construction."""
        if path:
            try:
                return cls.load(path, backend=backend)
            except (OSError, ValueError, KeyError):
                pass
        return cls(backend=backend)


def main(argv=None) -> int:
    """Calibration smoke for CI: measure (or fall back), print, persist.

        python -m repro.datapath.costmodel --out calibration.json --n 65536
    """
    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--backend", default="auto",
                    help="'auto' resolves to the active dispatch backend")
    ap.add_argument("--n", type=int, default=1 << 18)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="write/merge the per-backend table as JSON")
    ap.add_argument("--nominal", action="store_true",
                    help="skip measurement, emit the nominal table")
    args = ap.parse_args(argv)
    be = active_backend() if args.backend == "auto" else args.backend
    cm = (CostModel(backend=be) if args.nominal
          else CostModel.calibrate(backend=be, n=args.n,
                                   repeats=args.repeats))
    for enc in sorted(cm.rates):
        print(f"costmodel.{enc},{cm.rates[enc]:.3f} GB/s,"
              f"source={cm.source},backend={cm.backend}")
    print(f"costmodel.launch_overhead,{cm.launch_overhead_s * 1e6:.1f} us,"
          f"source={cm.source},backend={cm.backend}")
    if args.out:
        cm.save(args.out)
        print(f"costmodel.saved,{args.out},backend={cm.backend}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
