"""ScanFabric — N pods behind consistent-hash row-group ownership.

One Pod (datapath/service.py) is the single-node appliance: scheduler,
block store, netsim clock, telemetry.  The fabric is the fleet layer
(DESIGN.md §15):

  routing    a scan's pruned row groups partition by the consistent-hash
             ring (distributed/sharding.HashRing over `rg_key(path, rg)`)
             into one sub-scan per owning pod; each pod runs its slice
             through its own admission/WFQ/decode machinery unchanged
  merging    sub-results come back pre-compaction (sub-plans strip
             `compact`), are sliced back into per-row-group chunks, and
             reassemble in GLOBAL row-group order — so an N-pod scan is
             bit-identical to the single-node scan, compaction included
  peer fetch a pod that misses locally may pull encoded pages / decoded
             columns from a sibling's block store (blockstore.PeerFetcher
             installed on each pod's cache) over the inter-pod link —
             cheaper than the storage hop at any size, and billed to the
             tenant whose miss pulled it (scheduler._reconcile_slice)
  catalog    all pods resolve tables through one Catalog; every scan pins
             the version current at submission, so a mid-scan
             re-registration is invisible to in-flight work
  fairness   WFQ virtual time is per pod; the fabric re-levels it each
             tick by charging every pod the decode-seconds its queued
             tenants consumed ELSEWHERE, so a tenant cannot dodge its
             backlog by having its bytes land on another pod's scheduler
  drain      a pod failure (heartbeat silence or explicit fail_pod) pulls
             it from the ring — minimal moved arc, survivors' ownership
             untouched — and re-partitions only the uncollected sub-scans
             among survivors; collected sub-results are fabric-held and
             survive, so a scan replays from its last COMPLETED slice and
             still merges bit-identically

Everything stays deterministically single-threaded: pods tick in pod-id
order inside `ScanFabric.tick()`, which is what makes the bit-identity
sweep in tests/test_fabric.py a hard equality, not a tolerance check.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

import numpy as np

from repro.core import agg as agg_merge
from repro.core.cache import BlockCache
from repro.core.engine import DatapathEngine, ScanResult, ScanStats, group_domain
from repro.core.plan import ScanPlan, bind_expr
from repro.core.zonemap import prune_and_estimate
from repro.datapath.blockstore import PeerFetcher
from repro.datapath.catalog import Catalog, Snapshot
from repro.datapath.costmodel import CostModel
from repro.datapath.faults import StorageFault
from repro.datapath.service import Pod, TenantQuota
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    plan_pod_drain,
)
from repro.distributed.sharding import HashRing, rg_key
from repro.lakeformat.encodings import padded_rows


@dataclasses.dataclass
class _SubScan:
    """One pod's slice of a fabric scan: the pod ticket plus the row
    groups it was asked to produce (global-order subsequence)."""

    pod_id: str
    ticket: object
    rgs: Tuple[int, ...]


@dataclasses.dataclass
class FabricTicket:
    req_id: int
    tenant: str
    reader: object
    plan: ScanPlan
    blooms: Optional[Dict]
    snapshot: Optional[Snapshot]
    pruned_rgs: Tuple[int, ...] = ()
    status: str = "queued"  # queued | done | error
    subs: Dict[str, _SubScan] = dataclasses.field(default_factory=dict)
    # rg -> (cols, mask) chunks collected from COMPLETED sub-scans; these
    # survive a pod failure (replay granularity is the pod sub-scan)
    parts: Dict[int, object] = dataclasses.field(default_factory=dict)
    stats_parts: List[ScanStats] = dataclasses.field(default_factory=list)
    replays: int = 0  # sub-scans re-submitted after a pod drain
    result: Optional[ScanResult] = None
    error: Optional[BaseException] = None


class ScanFabric:
    """An N-pod scan fleet with one routing/merge/fairness brain.

    `n_pods=1` degenerates to a thin wrapper over a single Pod — the
    identity tests lean on that — and every pod shares one calibrated
    CostModel so the fleet's WFQ charges, eviction prices and netsim
    clocks read a single table."""

    def __init__(
        self,
        n_pods: int = 2,
        backend: str = "ref",
        cost_model: Optional[CostModel] = None,
        catalog: Optional[Catalog] = None,
        quotas: Optional[Dict[str, TenantQuota]] = None,
        default_quota: Optional[TenantQuota] = None,
        scheduler: str = "wfq",
        batch_decode: bool = True,
        hold_ticks=0,
        replicas: int = 64,
        # fleet-level WFQ re-leveling (see _rebalance_vtime)
        reconcile_fairness: bool = True,
        # heartbeat silence (in fabric ticks) before a pod is declared dead
        heartbeat_timeout_ticks: int = 3,
        peer_fetch: bool = True,
        **pod_kwargs,
    ):
        assert n_pods >= 1, n_pods
        self.cost_model = cost_model or CostModel()
        self.catalog = catalog or Catalog()
        self.reconcile_fairness = reconcile_fairness
        self._backend = backend
        self._peer_fetch = peer_fetch
        self._pod_cfg = dict(
            quotas=quotas, default_quota=default_quota, scheduler=scheduler,
            batch_decode=batch_decode, hold_ticks=hold_ticks, **pod_kwargs,
        )
        self.pods: Dict[str, Pod] = {}
        self._live: List[str] = []
        self._silent: set = set()  # failed pods that simply stop beating
        self._next_idx = 0
        for _ in range(n_pods):
            self._make_pod()
        self.ring = HashRing(self._live, replicas=replicas)
        self._tick = 0
        self.monitor = HeartbeatMonitor(
            list(self._live), timeout_s=float(heartbeat_timeout_ticks),
            clock=lambda: float(self._tick),
        )
        self.stragglers = StragglerDetector()
        self._ids = 0
        self.active: List[FabricTicket] = []
        self.drains: List[object] = []  # PodDrainPlans, newest last
        # pods evicted because their storage circuit breaker tripped open
        # (fault plane, DESIGN.md §17) — same drain path as heartbeat death
        self.breaker_drains = 0
        # per-(pod, tenant) occupancy watermark for the fairness re-level
        self._occ_seen: Dict[Tuple[str, str], float] = {}

    # ------------------------------------------------------------------
    # topology
    # ------------------------------------------------------------------
    def _make_pod(self) -> str:
        pid = f"pod{self._next_idx}"
        self._next_idx += 1
        cfg = dict(self._pod_cfg)
        if cfg.get("quotas"):
            cfg["quotas"] = dict(cfg["quotas"])
        pod = Pod(
            engine=DatapathEngine(backend=self._backend, cache=BlockCache()),
            cost_model=self.cost_model, pod_id=pid, **cfg,
        )
        if self._peer_fetch:
            # each pod consults its LIVE siblings' stores on a counting
            # miss; a drained pod drops out of everyone's peer list the
            # moment it leaves self._live
            pod.engine.cache.peer = PeerFetcher(
                pid, self._peers, link=self.cost_model.interpod_link_model()
            )
        self.pods[pid] = pod
        self._live.append(pid)
        return pid

    def add_pod(self) -> str:
        """Scale out by one pod.  The ring steals ONLY the arcs the new
        pod now owns (minimal movement), so scans routed after this reuse
        every survivor-owned block — and the new pod's first scans of its
        stolen arcs pull warm blocks from the OLD owners over the
        inter-pod hop instead of re-fetching storage (the PeerFetcher's
        headline win).  In-flight sub-scans keep their old assignment:
        their tags pin the exact row-group subsets they were issued
        with."""
        pid = self._make_pod()
        self.ring.add_node(pid)
        self.monitor.beat(pid)
        return pid

    def _peers(self) -> List[Tuple[str, object]]:
        # A silently-crashed pod is still in _live until its heartbeat
        # times out, but its store must NOT serve peer fetches during
        # that window — it is dead, the fabric just doesn't know yet.
        # (PeerFetcher additionally absorbs a store that dies between
        # this listing and the peek itself.)
        return [(pid, self.pods[pid].store) for pid in self._live
                if pid not in self._silent]

    @property
    def live_pods(self) -> List[str]:
        return list(self._live)

    def pod(self, pod_id: str) -> Pod:
        return self.pods[pod_id]

    def owner_of(self, path: str, rg: int) -> str:
        return self.ring.owner(rg_key(path, rg))

    # ------------------------------------------------------------------
    # submission / routing
    # ------------------------------------------------------------------
    def submit(self, tenant: str, reader, plan: ScanPlan,
               blooms: Optional[Dict] = None) -> FabricTicket:
        """Route one scan: pin the catalog, prune once, partition the
        surviving row groups by ring ownership, and submit one tagged
        sub-scan per owning pod.  `reader` may be a catalog table name
        (resolved through the pinned snapshot) or a reader object."""
        snap = self.catalog.pin()
        try:
            if isinstance(reader, str):
                reader = snap.table(reader)
            pred = bind_expr(plan.predicate, reader)
            rgs, _sel = prune_and_estimate(reader, pred)
            rgs = tuple(rgs)
        except Exception:
            self.catalog.release(snap)
            raise
        t = FabricTicket(self._ids, tenant, reader, plan, blooms, snap,
                         pruned_rgs=rgs)
        self._ids += 1
        try:
            for pid, sub_rgs in self._partition(reader.path, rgs):
                t.subs[pid] = self._submit_sub(t, pid, sub_rgs)
        except Exception:
            self.catalog.release(snap)
            raise
        if t.subs:
            self.active.append(t)
        else:  # everything pruned: nothing to run anywhere, merge empty now
            self._try_merge(t)
        return t

    def _partition(self, path: str, rgs) -> List[Tuple[str, Tuple[int, ...]]]:
        """Group row groups by owning pod, preserving global scan order
        within each pod's slice.  Pods are emitted in first-ownership
        order (deterministic, ring-derived)."""
        by_pod: Dict[str, List[int]] = {}
        order: List[str] = []
        for rg in rgs:
            pid = self.ring.owner(rg_key(path, rg))
            if pid not in by_pod:
                by_pod[pid] = []
                order.append(pid)
            by_pod[pid].append(rg)
        return [(pid, tuple(by_pod[pid])) for pid in order]

    def _submit_sub(self, t: FabricTicket, pid: str, sub_rgs) -> _SubScan:
        # compaction is GLOBAL (row i of the compacted stream can come
        # from any pod), so sub-plans run uncompacted and the merge
        # compacts once over the reassembled stream
        sub_plan = (dataclasses.replace(t.plan, compact=False)
                    if t.plan.compact else t.plan)
        ticket = self.pods[pid].submit(
            t.tenant, t.reader, sub_plan, t.blooms,
            row_groups=sub_rgs,
            # the tag folds the exact row-group subset into the
            # prefiltered-cache identity: identical sub-scans hit, but a
            # post-drain re-partition (different subset) can never be
            # served a stale slice
            scan_tag=("fab", sub_rgs),
        )
        return _SubScan(pid, ticket, tuple(sub_rgs))

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One fabric tick: heartbeats -> drain dead pods -> fleet WFQ
        re-level -> tick every live pod in pod-id order -> collect
        completed sub-scans and merge finished tickets.  Returns the
        number of fabric tickets that reached a terminal state."""
        self._tick += 1
        for pid in self._live:
            if pid not in self._silent:
                self.monitor.beat(pid)
        for pid in self.monitor.dead_hosts():
            if pid in self._live:
                self._drain_pod(pid)
        # A pod whose storage fetches tripped its circuit breaker open is
        # treated exactly like a heartbeat-silent pod: drain it and replay
        # its uncollected sub-scans bit-identically on survivors, whose
        # own breakers (separate storage paths) are presumed healthy.
        # Never drain the last pod — a one-pod fleet degrades in place.
        if len(self._live) > 1:
            for pid in list(self._live):
                if pid in self._silent or len(self._live) <= 1:
                    continue
                br = getattr(self.pods[pid], "breaker", None)
                if br is not None and br.any_open():
                    self.breaker_drains += 1
                    self._drain_pod(pid)
        if self.reconcile_fairness:
            self._rebalance_vtime()
        for pid in list(self._live):
            if pid in self._silent:
                continue  # a crashed pod does no work while the fabric
                # waits out its heartbeat timeout
            pod = self.pods[pid]
            t0 = time.perf_counter()
            pod.tick()
            self.stragglers.record(pid, self._tick, time.perf_counter() - t0)
        return self._collect()

    def _collect(self) -> int:
        done = 0
        for t in list(self.active):
            if t.status != "queued":
                continue
            for pid, sub in list(t.subs.items()):
                tk = sub.ticket
                if tk.status == "error":
                    # A storage-hop failure on a pod whose circuit breaker
                    # is OPEN is the pod's problem, not the scan's: drain
                    # it like a heartbeat-silent pod, which pops this sub
                    # (and every other uncollected sub it held) and
                    # replays them bit-identically on survivors.  With no
                    # survivors the typed error propagates.
                    br = getattr(self.pods[sub.pod_id], "breaker", None)
                    if (isinstance(tk.error, StorageFault)
                            and sub.pod_id in self._live
                            and len(self._live) > 1
                            and br is not None and br.any_open()):
                        self.breaker_drains += 1
                        self._drain_pod(sub.pod_id)
                        break  # subs changed; re-examine next tick
                    t.error = tk.error
                    t.status = "error"
                    self.catalog.release(t.snapshot)
                    t.snapshot = None
                    break
                if tk.status == "done":
                    self._absorb(t, sub, tk.result)
                    del t.subs[pid]
            if t.status == "error":
                self.active.remove(t)
                done += 1
                continue
            if self._try_merge(t):
                self.active.remove(t)
                done += 1
        return done

    def _absorb(self, t: FabricTicket, sub: _SubScan, res: ScanResult) -> None:
        """Slice one completed sub-result back into per-row-group chunks.
        Sub-results are uncompacted, so each row group occupies exactly
        `padded_rows(n)` consecutive rows of the concatenated arrays.
        Aggregate sub-results carry per-row-group ColPartials instead
        (ScanResult.agg_partials) — the merge re-folds them in GLOBAL
        row-group order, so the fabric's float sums land on the exact
        bit pattern the single-node fold produces."""
        if res.agg_partials is not None:
            for rg in sub.rgs:
                t.parts[rg] = res.agg_partials[rg]
            t.stats_parts.append(res.stats)
            return
        off = 0
        for rg in sub.rgs:
            L = padded_rows(t.reader.row_group_meta(rg)["n"])
            cols = {c: v[off:off + L] for c, v in res.columns.items()}
            t.parts[rg] = (cols, res.mask[off:off + L])
            off += L
        t.stats_parts.append(res.stats)

    def _try_merge(self, t: FabricTicket) -> bool:
        if t.subs or t.status != "queued":
            return bool(t.status != "queued")
        stats = _merge_stats(t.stats_parts, t.reader)
        if t.plan.aggregates:
            t.result = self._merge_agg(t, stats)
        elif not t.pruned_rgs:  # all pruned — same empty result the engine builds
            empty = {c: jnp.zeros((0,), t.reader.decoded_dtype(c))
                     for c in t.plan.columns}
            mask = jnp.zeros((0,), jnp.bool_)
            t.result = ScanResult(empty, mask, jnp.int32(0), stats)
        else:
            first_cols = t.parts[t.pruned_rgs[0]][0]
            cols = {
                c: jnp.concatenate([t.parts[rg][0][c] for rg in t.pruned_rgs])
                for c in first_cols
            }
            mask = jnp.concatenate([t.parts[rg][1] for rg in t.pruned_rgs])
            count = jnp.sum(mask.astype(jnp.int32))
            if t.plan.compact:
                # one global compaction over the reassembled stream — the
                # exact call ResumableScan._finish makes single-node
                engine = self.pods[self._live[0]].engine
                cols, mask, count = engine._compact(cols, mask)
            stats.rows_out = int(count)
            t.result = ScanResult(cols, mask, count, stats)
        t.status = "done"
        self.catalog.release(t.snapshot)
        t.snapshot = None
        return True

    def _merge_agg(self, t: FabricTicket, stats: ScanStats) -> ScanResult:
        """Deterministic partial-aggregate merge: every pod's per-row-group
        ColPartials re-fold in GLOBAL row-group order (t.pruned_rgs), the
        exact boundary-and-order ResumableScan._finish_agg uses — so the
        N-pod grouped sum is bit-identical to the single-node one, float
        accumulation included, regardless of which pods owned what or how
        a drain replayed a slice."""
        sources = agg_merge.agg_sources(t.plan.aggregates)
        n_groups = (group_domain(t.reader, t.plan.group_by)
                    if t.plan.group_by is not None else 1)
        if not t.pruned_rgs:
            merged = {
                src: agg_merge.identity_partial(
                    n_groups,
                    t.reader.decoded_dtype(src) if src is not None else np.int32,
                )
                for src in sources
            }
        else:
            merged = {
                src: agg_merge.merge_partials(
                    [t.parts[rg][src] for rg in t.pruned_rgs])
                for src in sources
            }
        aggs = agg_merge.finalize(t.plan.aggregates, merged, n_groups)
        count = int(next(iter(merged.values())).cnt.sum())
        stats.rows_out = count
        stats.result_bytes = sum(int(a.nbytes) for a in aggs.values())
        return ScanResult(
            {}, jnp.zeros((0,), jnp.bool_), jnp.int32(count), stats,
            aggregates=aggs, agg_partials=dict(t.parts),
        )

    def result(self, ticket: FabricTicket) -> ScanResult:
        while ticket.status == "queued":
            if not self.active:
                raise RuntimeError(f"fabric ticket {ticket.req_id} queued "
                                   "but nothing is active")
            self.tick()
        if ticket.status == "error":
            raise ticket.error
        return ticket.result

    def scan(self, reader, plan: ScanPlan, blooms: Optional[Dict] = None,
             tenant: str = "default") -> ScanResult:
        return self.result(self.submit(tenant, reader, plan, blooms))

    def drain(self) -> int:
        done = 0
        while self.active:
            done += self.tick()
        return done

    # ------------------------------------------------------------------
    # failure / drain
    # ------------------------------------------------------------------
    def fail_pod(self, pod_id: str, silent: bool = False) -> None:
        """Kill one pod.  `silent=True` models a crash the fabric only
        notices by heartbeat silence (drained after the timeout);
        otherwise the drain runs immediately."""
        assert pod_id in self._live, pod_id
        if silent:
            self._silent.add(pod_id)
            # the crashed pod's store now refuses probes by raising —
            # exactly what a sibling's peer fetch racing the crash sees
            self.pods[pod_id].store.dead = True
        else:
            self._drain_pod(pod_id)

    def inject_faults(self, pod_id: str, plan, policy=None) -> None:
        """Install a fault plan on ONE pod's storage path (the other pods
        keep clean reads) — the per-pod chaos knob the breaker-drain and
        straggler tests drive."""
        self.pods[pod_id].install_faults(plan, policy)

    def _drain_pod(self, dead: str) -> None:
        """Remove `dead` from the fleet and replay its uncollected work.

        The ring mutation moves ONLY the dead pod's arcs (HashRing's
        minimal-movement property), so survivors keep their ownership and
        their caches stay warm.  Every active ticket with an uncollected
        sub-scan on the dead pod re-partitions THAT SUB'S row groups over
        the new ring — collected parts are fabric-held and survive, which
        is what makes post-drain results still bit-identical."""
        owned: List[str] = []
        in_flight: List[object] = []
        lost: List[Tuple[FabricTicket, List[_SubScan]]] = []
        for t in self.active:
            # match by the sub's pod_id, not the dict key — a replay from
            # an EARLIER drain rides under a suffixed key
            dead_subs = [k for k, s in t.subs.items() if s.pod_id == dead]
            if dead_subs:
                subs = [t.subs.pop(k) for k in dead_subs]
                lost.append((t, subs))
                for s in subs:
                    owned.extend(rg_key(t.reader.path, rg) for rg in s.rgs)
                in_flight.append(t.req_id)
        plan = plan_pod_drain(dead, self.ring, owned, in_flight)
        self.drains.append(plan)
        self._live.remove(dead)
        self._silent.discard(dead)
        self.monitor.last_seen.pop(dead, None)
        for t, subs in lost:
            t.replays += 1
            # re-partition each lost slice over the survivors; merging
            # with an existing sub on the same pod would break the
            # pod-side in-order contract, so a replay rides as its own
            # sub-scan under a suffixed dict key
            for s in subs:
                for pid, sub_rgs in self._partition(t.reader.path, s.rgs):
                    key = pid if pid not in t.subs else f"{pid}#replay{t.replays}"
                    while key in t.subs:
                        key += "+"
                    t.subs[key] = self._submit_sub(t, pid, sub_rgs)

    # ------------------------------------------------------------------
    # fleet fairness
    # ------------------------------------------------------------------
    def _rebalance_vtime(self) -> None:
        """Re-level per-pod WFQ clocks with fleet-wide consumption.

        Each pod's virtual time only sees the decode-seconds IT charged;
        a tenant whose requests land on several pods would otherwise get
        one fresh WFQ clock per pod (N-fold share).  Every tick, each
        pod charges its QUEUED tenants the occupancy those tenants
        accrued on OTHER pods since the last tick (scheduled + reconciled
        + retention seconds — the same currency _vcharge uses), divided
        by the tenant's weight on the charging pod.  Idle tenants are
        skipped: vtime only orders tenants who are contending here."""
        deltas: Dict[str, Dict[str, float]] = {}
        for pid in self._live:
            tel = self.pods[pid].telemetry
            d: Dict[str, float] = {}
            for tenant in tel.known_tenants():
                occ = (tel.tenant_sched_seconds.get(tenant, 0.0)
                       + tel.tenant_recon_seconds.get(tenant, 0.0)
                       + tel.tenant_retained_seconds.get(tenant, 0.0))
                prev = self._occ_seen.get((pid, tenant), 0.0)
                if occ != prev:
                    d[tenant] = occ - prev
                    self._occ_seen[(pid, tenant)] = occ
            deltas[pid] = d
        for pid in self._live:
            pod = self.pods[pid]
            if pod.scheduler != "wfq":
                continue
            queued = {r.tenant for r in pod.queue if r.ticket.status == "queued"}
            for tenant in queued:
                foreign = sum(d.get(tenant, 0.0)
                              for q, d in deltas.items() if q != pid)
                if foreign > 0.0:
                    pod._vtime[tenant] = (
                        pod._vtime.get(tenant, 0.0)
                        + foreign / pod._weight(tenant)
                    )
                    pod.telemetry.inc("fleet_vtime_charges")
                    pod.telemetry.inc("fleet_vtime_seconds", foreign)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def report(self) -> dict:
        """Fleet roll-up: per-pod telemetry snapshots plus the fabric's
        own counters (peer traffic, drains, straggler timings)."""
        pods = {pid: self.pods[pid].telemetry.snapshot() for pid in self._live}
        peer = {
            pid: {
                "peer_hits": self.pods[pid].store.peer_hits,
                "peer_hit_bytes": self.pods[pid].store.peer_hit_bytes,
                "peer_hit_seconds": self.pods[pid].store.peer_hit_seconds,
                "peer_serves": self.pods[pid].store.peer_serves,
                "peer_serve_bytes": self.pods[pid].store.peer_serve_bytes,
            }
            for pid in self._live
        }
        return {
            "tick": self._tick,
            "live_pods": list(self._live),
            "drains": [
                {"dead": p.dead, "survivors": p.survivors,
                 "reassigned": len(p.reassigned), "replayed": len(p.replay)}
                for p in self.drains
            ],
            "breaker_drains": self.breaker_drains,
            "pods": pods,
            "peer": peer,
            "stragglers": self.stragglers.report(),
        }


def _merge_stats(parts: List[ScanStats], reader) -> ScanStats:
    """Sum sub-scan stats into one fleet-level ScanStats: numeric fields
    add, dict fields merge-add, bools OR.  rows_out is overwritten by the
    merge's final count; totals reflect the whole table."""
    out = ScanStats(row_groups_total=reader.n_row_groups,
                    rows_total=reader.n_rows)
    for s in parts:
        for f in dataclasses.fields(ScanStats):
            if f.name in ("row_groups_total", "rows_total"):
                continue
            v = getattr(s, f.name)
            cur = getattr(out, f.name)
            if isinstance(v, bool):
                setattr(out, f.name, cur or v)
            elif isinstance(v, dict):
                for k, n in v.items():
                    cur[k] = cur.get(k, 0) + n
            elif isinstance(v, (int, float)):
                setattr(out, f.name, cur + v)
    return out
