"""Storage fault plane: deterministic fault injection, bounded retries
with backoff, fetch timeouts, hedged reads, and a per-target circuit
breaker (DESIGN.md §17).

The SmartNIC sits between compute and remote disaggregated storage —
exactly where cloud reality bites: tail-latency spikes, transient fetch
errors, short reads, bit-rot, and straggler pods.  This module makes all
of those injectable and all of the recovery machinery observable:

* `FaultPlan` — a seedable, STATELESS fault schedule.  Every decision is
  a pure hash of (seed, kind, table, row group[, column], attempt): no
  RNG object, no replay-time state, so any chaos run reproduces exactly
  from its seed and the same scan reproduces the same faults in every
  chaos iteration of a property sweep.
* `FaultInjector` — wraps the engine's two storage-read seams
  (`DatapathEngine._storage_read`) with the retry loop: transient errors
  retry with exponential backoff, corrupt pages are checksum-detected,
  quarantined in the BlockStore and re-fetched (never decoded), modeled
  fetch times past `timeout_s` retry, and past `hedge_after_s` race a
  hedged second fetch.  Every extra modeled second lands in
  `ScanStats.fault_wait_s`, which the scheduler reconciles into WFQ
  vtime — a faulty tenant's retries bill to that tenant, not the fleet.
* `CircuitBreaker` — per storage target (table path).  Consecutive
  attempt failures trip it open: dispatch degrades to raw offload,
  admission sheds with a typed `Overloaded` once the queue nears
  collapse, and after a cooldown a half-open probe decides recovery.
  `fabric.ScanFabric` treats a pod with an open breaker like a
  heartbeat-silent pod: drain + bit-identical replay on survivors.

Like the rest of the datapath, nothing here sleeps: latency is modeled
seconds threaded through the same netsim/WFQ ledgers as fetch and
decode time.  Injected corruption only ever tampers with COPIES of the
reader's buffers (they are read-only views over the mapped file), so
the file itself — and therefore bit-identity of recovered scans — is
never at risk.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.datapath import trace
from repro.datapath.netsim import LinkModel
from repro.lakeformat.encodings import EncodedColumn
from repro.lakeformat.integrity import CorruptPageError, page_checksum, verify_page

__all__ = [
    "StorageFault",
    "TransientFetchError",
    "FetchTimeout",
    "FetchFailed",
    "Quarantined",
    "Overloaded",
    "CorruptPageError",
    "FaultPlan",
    "RetryPolicy",
    "FaultInjector",
    "CircuitBreaker",
]


# ---------------------------------------------------------------------------
# typed errors — a request NEVER fails silently: every terminal outcome is
# one of these, parked on the ticket and re-raised by Ticket/service.result()
# ---------------------------------------------------------------------------
class StorageFault(RuntimeError):
    """Base class for storage-hop failures."""


class TransientFetchError(StorageFault):
    """One fetch attempt failed; retryable."""


class FetchTimeout(StorageFault):
    """One fetch attempt exceeded the policy's modeled timeout; retryable."""


class FetchFailed(StorageFault):
    """Retries exhausted without a clean page (terminal, typed)."""


class Quarantined(StorageFault):
    """Retries exhausted and every attempt failed checksum verification —
    the page is quarantined in the BlockStore and unreadable (terminal)."""


class Overloaded(RuntimeError):
    """Admission load-shed: the target's circuit breaker is open and the
    queue is near collapse.  Typed so callers can distinguish 'come back
    later' from QueueFull/QuotaExceeded."""


# ---------------------------------------------------------------------------
# deterministic fault schedule
# ---------------------------------------------------------------------------
def _u(seed: int, *coords) -> float:
    """Uniform [0, 1) as a pure function of (seed, *coords) — blake2b of
    the repr'd coordinate tuple.  This is the whole 'no RNG at replay
    time' trick: the schedule is a mathematical function, not a stream."""
    payload = repr((seed,) + coords).encode()
    h = hashlib.blake2b(payload, digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0**64


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seedable fault schedule over (table, row group[, column], attempt).

    Rates are per-attempt probabilities.  By default a selected fault
    clears on the next attempt (transient), so bounded retries recover;
    `fail_forever=True` pins every selected coordinate permanently —
    that is how tests drive terminal FetchFailed/Quarantined outcomes
    and breaker trips.  Tables hash by basename so a plan's schedule is
    stable across tmpdir locations.
    """

    seed: int = 0
    transient_rate: float = 0.0     # attempt raises before any byte lands
    corrupt_rate: float = 0.0       # page arrives with a flipped byte
    short_read_rate: float = 0.0    # page arrives truncated
    spike_rate: float = 0.0         # attempt's fetch takes spike_s extra
    spike_s: float = 0.0            # latency spike magnitude (modeled s)
    fail_forever: bool = False      # faults never clear across attempts
    # pod_id -> extra modeled seconds added to EVERY fetch on that pod —
    # the whole-pod straggler the hedge/breaker machinery exists to absorb.
    straggler_pods: Mapping[str, float] = dataclasses.field(
        default_factory=dict)

    def _attempt(self, attempt: int) -> int:
        # fail_forever collapses the attempt axis: a selected coordinate
        # fires on every retry instead of clearing after the first.
        return 0 if self.fail_forever else int(attempt)

    @staticmethod
    def _table(table: str) -> str:
        return os.path.basename(table)

    def transient(self, table: str, rg: int, attempt: int) -> bool:
        return _u(self.seed, "transient", self._table(table), rg,
                  self._attempt(attempt)) < self.transient_rate

    def corrupt(self, table: str, rg: int, column: str, attempt: int) -> bool:
        return _u(self.seed, "corrupt", self._table(table), rg, column,
                  self._attempt(attempt)) < self.corrupt_rate

    def short_read(self, table: str, rg: int, column: str,
                   attempt: int) -> bool:
        return _u(self.seed, "short", self._table(table), rg, column,
                  self._attempt(attempt)) < self.short_read_rate

    def spike(self, table: str, rg: int, attempt: int) -> float:
        """Latency spike for this attempt (0.0 when not selected), plus
        this plan's straggler term is added separately by the injector."""
        t = self._table(table)
        a = self._attempt(attempt)
        if _u(self.seed, "spike", t, rg, a) >= self.spike_rate:
            return 0.0
        # deterministic magnitude jitter in [0.5, 1.5)·spike_s
        return self.spike_s * (0.5 + _u(self.seed, "spike_mag", t, rg, a))

    def straggle(self, pod_id: str) -> float:
        return float(self.straggler_pods.get(pod_id, 0.0))

    def any_faults(self) -> bool:
        return (self.transient_rate > 0 or self.corrupt_rate > 0
                or self.short_read_rate > 0 or self.spike_rate > 0
                or bool(self.straggler_pods))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff, per-fetch timeout, and a
    hedge threshold.  All times are modeled seconds (netsim clock)."""

    max_attempts: int = 4
    backoff_base_s: float = 200e-6
    backoff_mult: float = 2.0
    # One attempt's modeled fetch time past this aborts the attempt and
    # retries (the full timeout is billed — we waited it out).  None
    # disables.
    timeout_s: Optional[float] = None
    # One attempt's modeled fetch time past this launches a hedged second
    # fetch; the attempt completes at min(primary, hedge_after_s + clean
    # fetch).  None disables.
    hedge_after_s: Optional[float] = None

    def backoff(self, attempt: int) -> float:
        if attempt <= 0:
            return 0.0
        return self.backoff_base_s * (self.backoff_mult ** (attempt - 1))


# ---------------------------------------------------------------------------
# circuit breaker — per storage target (table path)
# ---------------------------------------------------------------------------
class CircuitBreaker:
    """closed → open on `fail_threshold` consecutive attempt failures;
    open → half-open after `cooldown_ticks` (next admission becomes the
    recovery probe); half-open → closed on probe success, → open on
    probe failure.  While open: dispatch degrades to raw offload and
    admission sheds (`Overloaded`) once the queue passes
    `shed_queue_frac` of capacity — degrade, never collapse."""

    def __init__(self, fail_threshold: int = 4, cooldown_ticks: int = 8,
                 shed_queue_frac: float = 0.75):
        self.fail_threshold = int(fail_threshold)
        self.cooldown_ticks = int(cooldown_ticks)
        self.shed_queue_frac = float(shed_queue_frac)
        self._state: Dict[str, str] = {}
        self._fails: Dict[str, int] = {}
        self._opened_at: Dict[str, int] = {}
        self.trips = 0
        self.probes = 0
        self.sheds = 0

    def state(self, target: str) -> str:
        return self._state.get(target, "closed")

    def degraded(self, target: str) -> bool:
        return self.state(target) == "open"

    def any_open(self) -> bool:
        return any(s == "open" for s in self._state.values())

    def record_failure(self, target: str, tick: int = 0) -> bool:
        """Returns True when this failure TRIPPED the breaker open."""
        f = self._fails.get(target, 0) + 1
        self._fails[target] = f
        st = self.state(target)
        if st == "half-open" or (st == "closed" and f >= self.fail_threshold):
            self._state[target] = "open"
            self._opened_at[target] = int(tick)
            self.trips += 1
            return True
        return False

    def record_success(self, target: str, tick: int = 0) -> None:
        self._fails[target] = 0
        if self.state(target) == "half-open":
            self._state[target] = "closed"

    def admit(self, target: str, tick: int, queue_frac: float = 0.0) -> str:
        """Admission verdict: 'ok' | 'degraded' | 'probe' | 'shed'."""
        if self.state(target) != "open":
            return "ok"
        if tick - self._opened_at.get(target, tick) >= self.cooldown_ticks:
            self._state[target] = "half-open"
            self.probes += 1
            return "probe"
        if queue_frac >= self.shed_queue_frac:
            self.sheds += 1
            return "shed"
        return "degraded"

    def report(self) -> dict:
        return {
            "trips": self.trips,
            "probes": self.probes,
            "sheds": self.sheds,
            "open": sorted(t for t, s in self._state.items() if s == "open"),
        }


# ---------------------------------------------------------------------------
# injected-corruption helpers — always tamper with COPIES
# ---------------------------------------------------------------------------
def _flip_byte(col: EncodedColumn) -> EncodedColumn:
    """Flip one byte of the page's first (sorted-name) non-empty buffer."""
    bufs = dict(col.buffers)
    for name in sorted(bufs):
        arr = bufs[name]
        raw = bytearray(np.ascontiguousarray(arr).tobytes())
        if not raw:
            continue
        raw[0] ^= 0xFF
        bufs[name] = np.frombuffer(bytes(raw), dtype=arr.dtype).reshape(
            arr.shape)
        break
    return dataclasses.replace(col, buffers=bufs)


def _truncate(col: EncodedColumn) -> EncodedColumn:
    """Short read: the page's first buffer arrives one element short,
    flattened — the checksum's shape fold catches it like a flipped bit."""
    bufs = dict(col.buffers)
    for name in sorted(bufs):
        arr = np.ascontiguousarray(bufs[name]).reshape(-1)
        if arr.size == 0:
            continue
        bufs[name] = arr[: arr.size - 1].copy()
        break
    return dataclasses.replace(col, buffers=bufs)


# ---------------------------------------------------------------------------
# the injector: retry / verify / quarantine / hedge loop
# ---------------------------------------------------------------------------
class FaultInjector:
    """Installed on `DatapathEngine.faults` by the service (duck-typed —
    core never imports datapath).  `read()` replaces a bare
    `reader.read_encoded` with the full fault-plane loop; with an empty
    FaultPlan it still verifies checksums and enforces the retry policy,
    so the machinery is exercised even fault-free."""

    def __init__(
        self,
        plan: Optional[FaultPlan] = None,
        policy: Optional[RetryPolicy] = None,
        link: Optional[LinkModel] = None,
        pod_id: str = "pod0",
        telemetry=None,
        breaker: Optional[CircuitBreaker] = None,
        clock: Optional[Callable[[], int]] = None,
    ):
        self.plan = plan if plan is not None else FaultPlan()
        self.policy = policy if policy is not None else RetryPolicy()
        self.link = link if link is not None else LinkModel()
        self.pod_id = pod_id
        self.telemetry = telemetry
        self.breaker = breaker
        self.clock = clock if clock is not None else (lambda: 0)
        # Global per-(table, rg) attempt ordinal.  Deterministic within a
        # run (the datapath is single-threaded by design), and it gives
        # the plan a monotone attempt axis even when the same page is
        # re-fetched after eviction later in the run.
        self._attempt_no: Dict[Tuple[str, int], int] = {}

    # -- small plumbing ----------------------------------------------------
    def _inc(self, name: str, n: int = 1) -> None:
        if self.telemetry is not None:
            self.telemetry.inc(name, n)

    def _secs(self, kind: str, s: float) -> None:
        if s and self.telemetry is not None:
            self.telemetry.observe_fault_seconds(kind, s)

    def _fail(self, target: str) -> None:
        if self.breaker is not None:
            if self.breaker.record_failure(target, self.clock()):
                self._inc("breaker_trips")

    def _ok(self, target: str) -> None:
        if self.breaker is not None:
            self.breaker.record_success(target, self.clock())

    @staticmethod
    def _quarantine(engine, reader, rg: int, name: str) -> None:
        store = getattr(engine.cache, "store", None)
        if store is not None and hasattr(store, "quarantine"):
            store.quarantine(engine.page_cache_key(reader, rg, name))

    # -- the read seam -----------------------------------------------------
    def read(self, engine, reader, rg: int, columns,
             stats) -> Dict[str, EncodedColumn]:
        """Fetch `columns` of row group `rg` through the fault plane.

        Returns verified pages or raises a TYPED terminal error
        (FetchFailed / Quarantined).  All modeled extra seconds — failed
        attempts, backoff, spikes survived, hedge exposure — accumulate
        in `stats.fault_wait_s` for WFQ reconciliation.
        """
        plan, policy = self.plan, self.policy
        path = reader.path
        last_err: Optional[Exception] = None
        tr = trace._CUR is not None
        for attempt in range(max(policy.max_attempts, 1)):
            key = (path, rg)
            a = self._attempt_no.get(key, 0)
            self._attempt_no[key] = a + 1
            backoff = policy.backoff(attempt)
            if backoff:
                stats.fault_wait_s += backoff
                self._secs("backoff", backoff)

            spike = plan.spike(path, rg, a) + plan.straggle(self.pod_id)

            # 1) transient error: the attempt dies before any byte lands.
            if plan.transient(path, rg, a):
                stats.retry_fetches += 1
                self._inc("faults_transient")
                self._secs("wasted", spike)
                stats.fault_wait_s += spike
                self._fail(path)
                if tr:
                    trace.event("fault", kind="transient", rg=rg, attempt=a)
                last_err = TransientFetchError(
                    f"{path} rg={rg} attempt={a}: transient fetch error")
                continue

            # 2) the bytes arrive; model the attempt's wall time.
            got = reader.read_encoded(rg, columns)
            nbytes = sum(c.encoded_bytes() for c in got.values())
            base_s = self.link.fetch_seconds(nbytes) if nbytes else 0.0
            t_s = base_s + spike

            if policy.timeout_s is not None and t_s > policy.timeout_s:
                # Waited the full timeout, then gave up on the attempt.
                stats.fetch_timeouts += 1
                stats.retry_fetches += 1
                self._inc("fetch_timeouts")
                stats.fault_wait_s += policy.timeout_s
                self._secs("timeout", policy.timeout_s)
                self._fail(path)
                if tr:
                    trace.event("fault", kind="timeout", rg=rg, attempt=a,
                                t_s=t_s)
                last_err = FetchTimeout(
                    f"{path} rg={rg} attempt={a}: fetch {t_s:.6f}s > "
                    f"timeout {policy.timeout_s:.6f}s")
                continue

            extra_s = spike
            if policy.hedge_after_s is not None and t_s > policy.hedge_after_s:
                # Straggler: at hedge_after_s a second fetch races the
                # first; the hedge is clean (fresh storage attempt, no
                # spike), so the slice completes at the earlier finish.
                hedge_t = policy.hedge_after_s + base_s
                eff = min(t_s, hedge_t)
                stats.hedged_fetches += 1
                self._inc("hedged_fetches")
                if eff < t_s:
                    stats.hedge_wins += 1
                    self._inc("hedge_wins")
                    self._secs("hedge_saved", t_s - eff)
                if tr:
                    trace.event("hedge", rg=rg, primary_s=t_s, hedged_s=eff)
                extra_s = eff - base_s
            stats.fault_wait_s += extra_s
            self._secs("straggle", extra_s)

            # 3) injected payload damage (on COPIES — reader buffers are
            # read-only views over the file).
            for name in list(got):
                if plan.short_read(path, rg, name, a):
                    got[name] = _truncate(got[name])
                    self._inc("faults_short_read")
                elif plan.corrupt(path, rg, name, a):
                    got[name] = _flip_byte(got[name])
                    self._inc("faults_corrupt")

            # 4) verify every page before it can reach a decode kernel.
            meta = getattr(reader, "page_checksum_meta", None)
            bad = []
            for name, col in got.items():
                expect = meta(rg, name) if meta is not None else None
                if expect is None:
                    self._inc("unverified_pages")  # legacy footer
                    continue
                if not verify_page(col, expect):
                    bad.append(name)
            if bad:
                for name in bad:
                    stats.corrupt_pages += 1
                    self._inc("corrupt_detected")
                    self._inc("quarantined_pages")
                    self._quarantine(engine, reader, rg, name)
                    if tr:
                        trace.event("page_quarantined", rg=rg, column=name,
                                    attempt=a)
                stats.retry_fetches += 1
                self._fail(path)
                last_err = CorruptPageError(
                    f"{path} rg={rg} attempt={a}: checksum mismatch on "
                    f"{sorted(bad)}", table=path, rg=rg, column=bad[0])
                continue

            self._ok(path)
            if attempt > 0:
                self._inc("fetch_retry_successes")
            return got

        # retries exhausted — terminal, TYPED, never silent.
        self._inc("fetch_retries_exhausted")
        if isinstance(last_err, CorruptPageError):
            raise Quarantined(
                f"{path} rg={rg}: page corrupt after "
                f"{policy.max_attempts} attempts (quarantined)"
            ) from last_err
        raise FetchFailed(
            f"{path} rg={rg}: fetch failed after "
            f"{policy.max_attempts} attempts"
        ) from last_err
