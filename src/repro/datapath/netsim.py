"""Storage->NIC hop model: bandwidth/latency + double-buffered prefetch.

The SmartNIC sits between disaggregated storage and the host, so every
scan pays a network fetch for its encoded bytes before it can decode.
`LinkModel` is the per-transfer cost model; `PrefetchPipeline` simulates
the double-buffered overlap the device uses — while row group i decodes,
row group i+1 is in flight — mirroring the two-slot VMEM pipelining idiom
the Pallas kernels in kernels/ use for HBM->VMEM copies.

This is a simulated clock (no sleeping): the scheduler feeds it the real
encoded/decoded byte counts per row group and records the modeled
serial vs overlapped times in telemetry, which is what lets a CPU-only
container still reproduce the paper's "fetch hides behind decode" claim.

Block-store hits never enter the pipeline: a row group served from the
unified store (decoded tier, window-pinned decodes, or encoded pages)
pulls zero bytes over the storage->NIC hop, and the scheduler feeds this
model only the row groups whose slice actually fetched — at row-group
granularity, so one resident group in a multi-group slice is not billed
for its neighbors' transfers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass
class LinkModel:
    """One storage->NIC link.  Defaults: ~100 GbE, 10us one-way latency."""

    bandwidth_gbps: float = 12.5  # gigaBYTES/s (100 Gbit/s)
    latency_us: float = 10.0

    def fetch_seconds(self, nbytes: int) -> float:
        return self.latency_us * 1e-6 + nbytes / (self.bandwidth_gbps * 1e9)


# Inter-pod hop (fabric peer block-store fetch): pods share a rack-local
# switch, so pod<->pod transfers run wider and shallower than the
# storage->NIC hop (400 GbE-class, ~2us).  Pulling a row group from a
# peer's tier is therefore strictly cheaper than re-fetching it from
# disaggregated storage at ANY size — and a peer's DECODED tier also
# skips the decode entirely.  costmodel.CostModel persists these per
# backend next to the storage-link parameters.
INTERPOD_BANDWIDTH_GBPS = 50.0
INTERPOD_LATENCY_US = 2.0


def interpod_link(bandwidth_gbps: float = INTERPOD_BANDWIDTH_GBPS,
                  latency_us: float = INTERPOD_LATENCY_US) -> LinkModel:
    """The pod<->pod hop the ScanFabric prices peer fetches with."""
    return LinkModel(bandwidth_gbps=bandwidth_gbps, latency_us=latency_us)


@dataclasses.dataclass
class DecodeModel:
    """On-device decode rate in decoded-output gigabytes/s.

    `rates` is an optional per-encoding table (plain/bitpack/dict/delta/
    rle -> GB/s) — the calibrated table from datapath/costmodel.py — so
    the prefetch simulation prices an RLE row group differently from
    PLAIN.  Encodings absent from the table (and encoding=None callers)
    fall back to the scalar `decode_gbps`.  `launch_overhead_s` is the
    calibrated fixed cost per kernel dispatch (costmodel's per-launch
    term): the sequential scan pays it once per (row group, column), the
    batched scan once per bucket — pass `launches` to bill it.

    A DEFAULT-constructed model resolves every field from the
    process-default cost model's per-backend table (costmodel.
    default_cost_model — the one DatapathService registers), NOT from a
    stale module-level constant: after calibration, the simulated
    fetch/decode overlap and what the scheduler charges come from ONE
    table.  Passing `decode_gbps` explicitly keeps the old scalar-model
    semantics (rates stays None unless given)."""

    decode_gbps: Optional[float] = None
    rates: Optional[Dict[str, float]] = None
    launch_overhead_s: Optional[float] = None

    def __post_init__(self):
        if self.decode_gbps is None:
            from repro.datapath import costmodel as _cm  # avoid import cycle

            cm = _cm.default_cost_model()
            self.decode_gbps = cm.rate_gbps("plain")
            if self.rates is None:
                self.rates = dict(cm.rates)
            if self.launch_overhead_s is None:
                self.launch_overhead_s = cm.launch_overhead_s
        elif self.launch_overhead_s is None:
            self.launch_overhead_s = 0.0

    def rate_gbps(self, encoding: Optional[str] = None) -> float:
        if encoding is not None and self.rates:
            return self.rates.get(encoding, self.decode_gbps)
        return self.decode_gbps

    def decode_seconds(self, nbytes: int, encoding: Optional[str] = None,
                       launches: int = 0) -> float:
        return (nbytes / (self.rate_gbps(encoding) * 1e9)
                + launches * self.launch_overhead_s)


class SliceClock:
    """Streaming fetch/decode pipeline clock across DISPATCH SLICES — the
    batched scan loop's simulated steady state.

    The stateless `PrefetchPipeline.simulate` models overlap only within
    one call, but the batched scheduler dispatches one slice per tick: the
    next slice's storage->NIC fetch is issued while this slice's bucketed
    batch decode still runs, ACROSS the tick boundary.  This clock carries
    that state: `feed(nbytes, decode_seconds)` starts the slice's fetch as
    soon as the link is free and its decode when both the fetch has landed
    and the device is free.  `serial_s` / `overlapped_s` / `saved_s` are
    cumulative over the whole run — saved_s is exactly the fetch time the
    pipelining hid."""

    def __init__(self, link: Optional[LinkModel] = None):
        self.link = link or LinkModel()
        self.link_free = 0.0  # when the storage->NIC link is next free
        self.device_free = 0.0  # when the decoder is next free
        self.serial_s = 0.0
        self.slices = 0

    def feed(self, nbytes: int, decode_seconds: float,
             extra_fetch_s: float = 0.0) -> Dict[str, float]:
        """Advance the clock by one slice; returns that slice's fetch
        anatomy so the flight recorder can show hidden-vs-exposed fetch
        time PER SLICE: `exposed_s` is how long the decoder actually
        stalled waiting for this slice's fetch to land (including link
        backlog), `hidden_s` the part of the transfer that overlapped
        earlier decode work.  `extra_fetch_s` is fault-plane time the
        slice's fetch additionally occupied the link with (retries,
        backoff, latency spikes, hedge exposure — ScanStats.fault_wait_s
        deltas from datapath/faults.py), so chaos runs show their tail in
        the same anatomy."""
        fetch_s = self.link.fetch_seconds(nbytes) if nbytes > 0 else 0.0
        fetch_s += max(0.0, float(extra_fetch_s))
        fetch_done = self.link_free + fetch_s
        start = max(fetch_done, self.device_free)
        exposed = max(0.0, fetch_done - self.device_free)
        self.device_free = start + decode_seconds
        self.link_free = fetch_done  # the next slice's fetch follows at once
        self.serial_s += fetch_s + decode_seconds
        self.slices += 1
        return {
            "fetch_s": fetch_s,
            "decode_s": decode_seconds,
            "exposed_s": exposed,
            "hidden_s": max(0.0, fetch_s - exposed),
            "start_s": start,
            "done_s": self.device_free,
        }

    @property
    def overlapped_s(self) -> float:
        return max(self.device_free, self.link_free)

    @property
    def saved_s(self) -> float:
        return max(0.0, self.serial_s - self.overlapped_s)


class PrefetchPipeline:
    """Two-slot fetch/decode overlap over a sequence of transfer units.

    serial     = sum(fetch_i) + sum(decode_i)
    overlapped = fetch_0 + sum_i max(fetch_{i+1}, decode_i) + decode_last

    The unit granularity is the caller's: the sequential scheduler feeds
    one unit per ROW GROUP (fetch of group i+1 hides behind its neighbor's
    decode); the batched scheduler feeds one unit per DISPATCH SLICE, so
    the next slice's whole fetch hides behind this slice's bucketed batch
    decode — fetch and decode pipeline instead of alternating.
    """

    def __init__(self, link: LinkModel = None, decode: DecodeModel = None):
        self.link = link or LinkModel()
        self.decode = decode or DecodeModel()

    def simulate(
        self,
        encoded_bytes: Sequence[int],
        decoded_bytes: Sequence[int],
        decode_seconds: Optional[Sequence[float]] = None,
    ) -> Dict[str, float]:
        """`decode_seconds` (one entry per row group) overrides the scalar
        decode-rate model — the scheduler passes per-group times computed
        by the encoding-aware cost model, so the overlap simulation and the
        WFQ charge come from one table."""
        assert len(encoded_bytes) == len(decoded_bytes)
        if decode_seconds is not None:
            assert len(decode_seconds) == len(encoded_bytes)
        if not encoded_bytes:
            return {"serial_s": 0.0, "overlapped_s": 0.0, "saved_s": 0.0, "overlap_pct": 0.0}
        fetch: List[float] = [self.link.fetch_seconds(b) for b in encoded_bytes]
        dec: List[float] = (
            [float(s) for s in decode_seconds]
            if decode_seconds is not None
            else [self.decode.decode_seconds(b) for b in decoded_bytes]
        )
        serial = sum(fetch) + sum(dec)
        overlapped = fetch[0]
        for i in range(len(fetch) - 1):
            overlapped += max(fetch[i + 1], dec[i])
        overlapped += dec[-1]
        saved = serial - overlapped
        return {
            "serial_s": serial,
            "overlapped_s": overlapped,
            "saved_s": saved,
            "overlap_pct": 100.0 * saved / serial if serial > 0 else 0.0,
        }
