"""Adaptive offload policy: raw vs preloaded vs prefiltered, per request.

The seed engine picked one engine-wide `offload=` mode at construction
time.  On a shared appliance that is wrong for every tenant at once: a
needle-in-a-haystack scan should not evict cache with decoded row groups
it will never revisit, while a scan the service has already answered
should be served straight from the prefiltered cache.  The policy decides
per request from metadata only:

  1. prefiltered  — the exact plan signature was answered recently
                    (cache still holds it, or it has recurred >= `repeat_k`
                    times so caching the result will pay off)
  2. preloaded    — the scan touches row groups whose decoded columns are
                    largely cached already, or it is broad enough
                    (selectivity >= `broad_threshold`) that decoded groups
                    are likely to be reused by coalesced neighbors
  3. raw          — highly selective one-off scans: decode+filter fresh and
                    keep the cache for workloads that reuse it
  4. pre-aggregated — aggregate-pushdown plans that recur: cache the WHOLE
                    accumulator result (a few KB) instead of seeding the
                    decoded tier with value columns pushdown never
                    materializes (DESIGN.md §16)
"""

from __future__ import annotations

import collections
from typing import Dict

from repro.core.engine import DatapathEngine
from repro.core.plan import ScanPlan
from repro.core.zonemap import prune_row_groups
from repro.lakeformat.reader import LakeReader


def coalesce_compatible(a, b) -> bool:
    """Hold-window compatibility: would scheduling `a` and `b` in the SAME
    tick let them share DecodePool entries?  True iff they read the same
    file and their (row group, column) footprints intersect — the pool is
    keyed by (path, row group, column, backend), so any intersection means
    at least one decode is shared.  Both arguments are service ScanRequests
    (duck-typed: .reader.path, .rg_set, .col_set)."""
    return (
        a.reader.path == b.reader.path
        and bool(a.rg_set & b.rg_set)
        and bool(a.col_set & b.col_set)
    )


class AdaptiveOffloadPolicy:
    def __init__(
        self,
        broad_threshold: float = 0.2,
        cached_frac_threshold: float = 0.5,
        repeat_k: int = 2,
        max_signatures: int = 4096,
    ):
        self.broad_threshold = broad_threshold
        self.cached_frac_threshold = cached_frac_threshold
        self.repeat_k = repeat_k
        self.max_signatures = max_signatures
        # LRU-bounded: parameterized workloads (moving time windows) mint a
        # fresh signature per request, and the service is long-lived
        self.seen: "collections.OrderedDict[str, int]" = collections.OrderedDict()
        self.decisions: Dict[str, int] = collections.defaultdict(int)

    def _note(self, sig: str) -> int:
        count = self.seen.get(sig, 0) + 1
        self.seen[sig] = count
        self.seen.move_to_end(sig)
        while len(self.seen) > self.max_signatures:
            self.seen.popitem(last=False)
        return count

    def choose(
        self,
        engine: DatapathEngine,
        reader: LakeReader,
        plan: ScanPlan,
        blooms=None,
        row_groups=None,
        selectivity: float = None,
        scan_tag=None,
    ) -> str:
        """`row_groups`/`selectivity` let the service reuse its admission-time
        metadata walk; without them the policy recomputes from zone maps.
        `scan_tag` is the request's prefiltered-cache disambiguator (fabric
        sub-scans tag with their row-group subset) — the whole-scan reuse
        probe must look up the SAME key the scan would hit."""
        sig = plan.signature()
        seen = self._note(sig)
        mode = self._choose(engine, reader, plan, seen, blooms, row_groups,
                            selectivity, scan_tag)
        self.decisions[mode] += 1
        return mode

    def _choose(self, engine, reader, plan, seen, blooms, row_groups,
                selectivity, scan_tag=None) -> str:
        # 1) whole-scan reuse: cached result, or a recurring signature worth
        #    caching (the key folds in bloom digests, so per-caller semijoin
        #    state can never serve another caller's probe).  Residency is
        #    read straight from the store's prefiltered tier.
        #    Aggregate plans take the fourth mode, 'pre-aggregated': same
        #    whole-result reuse, but what is cached is the (n_groups,)
        #    accumulator set — a few KB answering the entire scan — and the
        #    decoded/page tiers are NOT seeded along the way (pushdown never
        #    materializes the value column, so there is nothing worth
        #    pinning; decode behaves like 'raw').
        scan_key = engine.plan_cache_key(reader, plan, blooms, tag=scan_tag)
        cached, _ = engine.cache.plan_fetch([scan_key], tier="prefiltered")
        if cached or seen >= self.repeat_k:
            return "pre-aggregated" if plan.aggregates else "prefiltered"

        # 2) row-group reuse: are this scan's decoded columns already
        #    resident?  The probe reads the store's DECODED tier directly —
        #    window-pinned decodes from a recent coalescing hold count as
        #    resident (they are reusable right now), prefiltered results
        #    and encoded pages do not.
        if row_groups is None:
            from repro.core.plan import bind_expr

            row_groups = prune_row_groups(reader, bind_expr(plan.predicate, reader))
        rg_keys = [
            engine.rg_cache_key(reader, rg, name)
            for rg in row_groups
            for name in plan.all_columns()
        ]
        if rg_keys:
            hit, _ = engine.cache.plan_fetch(rg_keys, tier="decoded")
            if len(hit) / len(rg_keys) >= self.cached_frac_threshold:
                return "preloaded"

        # 3) broad scans seed the cache; selective one-offs stay raw
        if selectivity is None:
            selectivity = engine.estimate_selectivity(reader, plan)
        return "preloaded" if selectivity >= self.broad_threshold else "raw"


class StaticPolicy:
    """Degenerate policy pinning every request to one mode (the seed
    engine's behavior — kept for A/B comparison in benchmarks)."""

    def __init__(self, mode: str = "raw"):
        assert mode in ("raw", "preloaded", "prefiltered", "pre-aggregated")
        self.mode = mode
        self.decisions: Dict[str, int] = collections.defaultdict(int)

    def choose(self, engine, reader, plan, blooms=None, **_precomputed) -> str:
        self.decisions[self.mode] += 1
        return self.mode
