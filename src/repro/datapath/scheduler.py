"""Tick scheduler: batch queued scans and coalesce shared row groups.

Coalescing is the service's core win (the paper's "one device serves many
queries"): requests in one tick that touch the same table share a
DecodePool keyed by (path, row group, column, backend), so each pair is
decoded ONCE and every coalesced predicate is evaluated over the shared
decoded columns.  Under concurrent TPC-H-style load the queries hit the
same hot columns (l_shipdate, l_extendedprice, ...), so total decoded
bytes drop superlinearly in tenant count — benchmarks/service_bench.py
measures exactly that.

The storage->NIC fetch for the tick's union of row groups is fed through
netsim's double-buffered PrefetchPipeline, recording how much of the
fetch time hides behind on-device decode.
"""

from __future__ import annotations

from typing import Dict, List


class DecodePool(dict):
    """Tick-scoped shared decode pool with hit accounting and a byte budget.

    The engine consults it before the BlockCache and before decoding
    (engine._decode_column); `puts` therefore counts unique (row group,
    column) decodes materialized this tick — the number a set of
    perfectly-coalesced scans shares.  Once `max_bytes` of decoded output
    is pinned, further inserts are refused (later scans simply decode for
    themselves), so one oversized tick cannot bypass the BlockCache's
    capacity accounting via the pool.
    """

    def __init__(self, max_bytes: int = 1 << 30):
        super().__init__()
        self.max_bytes = max_bytes
        self.used_bytes = 0
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.rejected_puts = 0
        self.hit_bytes = 0

    def get(self, key, default=None):
        if key in self:
            self.hits += 1
            val = dict.__getitem__(self, key)
            self.hit_bytes += int(val.nbytes)
            return val
        self.misses += 1
        return default

    def __setitem__(self, key, value):
        if key not in self:
            nb = int(value.nbytes)
            if self.used_bytes + nb > self.max_bytes:
                self.rejected_puts += 1
                return
            self.puts += 1
            self.used_bytes += nb
        dict.__setitem__(self, key, value)


def run_tick(service, batch: List) -> None:
    """Execute one tick's batch: group by table, coalesce, scan, simulate
    the fetch pipeline.  Results land on each request's ticket."""
    groups: Dict[str, List] = {}
    for req in batch:
        groups.setdefault(req.reader.path, []).append(req)

    tel = service.telemetry
    for path, reqs in groups.items():
        pool = DecodePool(max_bytes=service.pool_bytes)
        if len(reqs) > 1:
            tel.inc("coalesced_groups")
            tel.inc("coalesced_requests", len(reqs))
        for req in reqs:
            try:
                mode = service.policy.choose(
                    service.engine, req.reader, req.plan, req.blooms,
                    row_groups=req.row_groups,
                    selectivity=req.est_rows / max(req.reader.n_rows, 1),
                )
                tel.inc(f"offload_{mode}")
                res = service.engine.scan(
                    req.reader, req.plan, blooms=req.blooms, offload=mode,
                    pool=pool, row_groups=req.row_groups,
                )
            except Exception as e:  # noqa: BLE001 — isolate faulty requests
                req.ticket.error = e
                tel.inc("failed")
                continue
            req.ticket.result = res
            tel.inc("decoded_bytes", res.stats.decoded_bytes)
            tel.inc("decoded_bytes_fresh", res.stats.decoded_bytes_fresh)
            tel.inc("encoded_bytes", res.stats.encoded_bytes)
            tel.inc("rows_out", res.stats.rows_out)
            if res.stats.cache_hit:
                tel.inc("prefiltered_hits")
        tel.inc("decoded_bytes_saved", pool.hit_bytes)
        if pool.rejected_puts:
            tel.inc("pool_rejected_puts", pool.rejected_puts)

        _simulate_fetch(service, reqs)


def _simulate_fetch(service, reqs: List) -> None:
    """Model the tick's storage->NIC transfer for the union of row groups
    actually read (cache-hit and failed requests fetch nothing),
    double-buffered against on-device decode.  Row groups were pruned once
    at admission (ScanRequest.row_groups) — no footer re-walk here."""
    per_rg_cols: Dict[int, set] = {}
    reader = reqs[0].reader
    for req in reqs:
        res = req.ticket.result
        if res is None or res.stats.cache_hit or res.stats.encoded_bytes == 0:
            continue  # failed / cache-served / fully resident: nothing fetched
        for rg in req.row_groups:
            per_rg_cols.setdefault(rg, set()).update(req.plan.all_columns())
    if not per_rg_cols:
        return
    enc: List[int] = []
    dec: List[int] = []
    for rg in sorted(per_rg_cols):
        meta = reader.row_group_meta(rg)
        cols = meta["columns"]
        names = [c for c in per_rg_cols[rg] if c in cols]
        enc.append(sum(cols[c]["encoded_bytes"] for c in names))
        dec.append(meta["n"] * 4 * len(names))  # int32/float32 output
    sim = service.pipeline.simulate(enc, dec)
    tel = service.telemetry
    tel.inc("sim_fetch_serial_s", sim["serial_s"])
    tel.inc("sim_fetch_overlapped_s", sim["overlapped_s"])
    tel.inc("sim_fetch_saved_s", sim["saved_s"])
