"""Tick scheduler: fair-share batch formation + shared-scan coalescing.

Two layers per tick (DESIGN.md §9):

  form_batch  decides WHAT runs — weighted fair queueing ("wfq", default)
              by per-tenant virtual time measured in estimated decode-
              SECONDS (the calibrated encoding-aware cost model's price)
              over tenant weight, dispatching at ROW-GROUP granularity so
              a giant scan is preempted between row groups and small
              scans slip through every tick; or strict arrival order
              ("fifo", the seed behavior, kept for A/B comparison in
              benchmarks/service_bench.py).  At slice completion the
              charge is reconciled against what the engine ACTUALLY
              materialized (service._vreconcile), so a tenant whose scans
              under-estimate cannot buy extra share.
  run_tick    decides HOW it runs — requests grouped by table around a
              window-scoped view into the unified BlockStore's decoded
              tier (datapath/blockstore.py) so each (path, row group,
              column, backend) pair is decoded ONCE per tick, every
              coalesced predicate is evaluated over the shared decoded
              columns, and the decodes stay pinned for `hold_ticks` more
              ticks — a late-arriving partner reuses them instead of
              re-aligning ticks.

Cross-tick coalescing window: a fresh request with no compatible partner
(policy.coalesce_compatible) in the queue may be held up to
service.hold_ticks ticks; the moment a partner dispatches it is released
into the SAME tick and shares that tick's decode window, and if no
partner ever arrives it force-dispatches at its deadline — a held
request is never late by more than hold_ticks.  A request whose
footprint is already window-pinned in the store is never held at all:
the retained decodes ARE its partner, so it dispatches immediately.

Batched dispatch (service.batch_decode, the default): each WFQ slice is
handed to the engine as ONE row-group batch
(`ResumableScan.advance_batched` -> `engine.scan_row_groups_batched`),
which buckets compatible pages by (encoding, k, dtype) and decodes each
bucket in a single kernel launch — ~4-100x fewer device dispatches than
the one-launch-per-(row group, column) sequential loop, bit-identically.
Reconciliation then re-bills each slice by the launches it REALLY made
(`ScanStats.kernel_launches` priced at the calibrated per-launch
overhead), so the batched path's dispatch savings flow back through the
same honesty loop as decode bytes.  When a tick coalesces SEVERAL
requests over one table, their slices stack into a single cross-request
bucket pass (`engine.scan_group_batched` via `_run_group_stacked`): a
page two requests both need decodes once and launches drop again by the
stacking factor, with per-request attribution and fault isolation
preserved.

The storage->NIC fetch for the row groups actually read this tick (store
hits — decoded, window-pinned, or encoded-page — fetch nothing and skip
the simulation) is fed through netsim's double-buffered PrefetchPipeline,
recording how much of the fetch time hides behind on-device decode — at
row-group granularity under sequential dispatch, at SLICE granularity
under batched dispatch (the next slice's fetch hides behind this slice's
batch decode).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core import engine as _engine_mod
from repro.core.engine import ResumableScan
from repro.datapath import trace
from repro.datapath.policy import coalesce_compatible

# Install the engine's flight-recorder hook (engine.TRACE).  The engine
# cannot import repro.datapath — that would close an import cycle through
# the package __init__ — so the scheduler, which every traced slice flows
# through, hands it the trace module once at import time.  Library users
# who never import the datapath keep TRACE = None and pay nothing.
_engine_mod.TRACE = trace


def _retained_resident(service, req) -> bool:
    """Does the store hold a live window-pinned decode for any of `req`'s
    (row group, column) blocks?  If so the hold window already paid off
    for this footprint — dispatch now and reuse, don't re-align ticks."""
    engine = service.engine
    return any(
        service.store.pinned(engine.rg_cache_key(req.reader, rg, name))
        for rg in req.row_groups
        for name in req.col_set
    )


# ---------------------------------------------------------------------------
# batch formation (WHAT runs this tick)
# ---------------------------------------------------------------------------

def form_batch(service) -> List[Tuple[object, List[int]]]:
    """Select this tick's dispatch units — ordered (request, row_groups)
    pairs — honoring the scheduling discipline, the per-tick decoded-byte
    budget (`service.tick_bytes`, None = unbounded), the distinct-request
    cap (`service.batch_per_tick`) and the cross-tick hold window.

    Mutates scheduler state: request cursors, per-tenant virtual time,
    hold counters.  Costs are the admission-time metadata estimates
    (`ScanRequest.rg_costs`), so forming a batch moves no data bytes.
    """
    tel = service.telemetry
    active = [r for r in service.queue if r.ticket.status == "queued"]
    if not active:
        return []
    budget = float("inf") if service.tick_bytes is None else float(service.tick_bytes)
    cap = max(1, service.batch_per_tick)

    # -- hold window: fresh requests with no coalescing partner wait -------
    eligible: List = []
    held: List = []
    for req in active:
        if (
            req.started
            or service.hold_ticks <= 0
            or not req.row_groups  # nothing to coalesce: holding never pays
            or req.held_ticks >= service.hold_ticks  # deadline reached
            # a prefiltered-cache-resident answer decodes nothing — waiting
            # for a decode partner cannot pay (non-mutating presence check)
            or service.engine.plan_cache_key(req.reader, req.plan, req.blooms,
                                             tag=req.scan_tag)
            in service.engine.cache
            or any(o is not req and coalesce_compatible(req, o) for o in active)
        ):
            eligible.append(req)
        elif _retained_resident(service, req):
            # the window already holds this footprint's decodes: reuse now
            eligible.append(req)
            tel.inc("retained_partner_dispatch")
        else:
            held.append(req)

    units: Dict[int, Tuple[object, List[int]]] = {}
    order: List[int] = []
    spent = 0.0

    def open_unit(req) -> bool:
        """Ensure req appears in this tick's batch; False on first open."""
        if req.req_id in units:
            return True
        units[req.req_id] = (req, [])
        order.append(req.req_id)
        req.started = True
        if req.first_tick == 0:
            req.first_tick = service._tick
        return False

    def take_rg(req) -> float:
        """Advance req's cursor one row group; charge its tenant's vtime
        in estimated decode-seconds.  Returns the row group's estimated
        decoded BYTES — the tick budget (`tick_bytes`) stays byte-
        denominated even though the fairness clock runs on device time."""
        rg = req.row_groups[req.cursor]
        cost_s = float(req.rg_costs[req.cursor])
        cost_b = float(req.rg_bytes[req.cursor])
        req.cursor += 1
        units[req.req_id][1].append(rg)
        req.charged_s += service._vcharge(req.tenant, cost_s, cost_b,
                                          table=req.reader.path)
        req.charged_raw_s += cost_s
        return cost_b

    def exhausted(req) -> bool:
        return req.cursor >= len(req.row_groups)

    # -- deadline expiry: a held request always dispatches by its deadline,
    #    budget and request cap notwithstanding
    if service.hold_ticks > 0:
        for req in eligible:
            if req.held_ticks >= service.hold_ticks and not req.started:
                open_unit(req)
                if not exhausted(req):
                    spent += take_rg(req)
                tel.inc("hold_deadline_dispatch")

    if service.scheduler == "fifo":
        # Seed behavior: strict arrival order, head-of-line — a request
        # must fully dispatch before the next one starts, so a huge scan
        # occupies tick after tick (the contrast WFQ exists to fix).
        for req in sorted(eligible, key=lambda r: r.req_id):
            if (spent >= budget and spent > 0) or (
                req.req_id not in units and len(units) >= cap
            ):
                break
            open_unit(req)
            while not exhausted(req):
                spent += take_rg(req)
                if spent >= budget:
                    break
            if not exhausted(req):
                break  # head-of-line: the unfinished request blocks
    else:  # wfq
        candidates = [r for r in eligible if not exhausted(r) or r.req_id not in units]
        # `spent == 0` guarantees one dispatch per tick even when tick_bytes
        # is zero or pathologically small — same progress rule as FIFO
        while candidates and (spent < budget or spent == 0.0):
            avail = [r for r in candidates if r.req_id in units or len(units) < cap]
            if not avail:
                break
            tenant = min(
                {r.tenant for r in avail},
                key=lambda t: (service._vtime.get(t, 0.0), t),
            )
            req = min((r for r in avail if r.tenant == tenant), key=lambda r: r.req_id)
            open_unit(req)
            if not exhausted(req):
                spent += take_rg(req)
            if exhausted(req):
                candidates.remove(req)

    # -- coalescing sweep: the hold window's payoff.  A request that waited
    #    (or whose partner waited) rides in the SAME tick as its partner so
    #    the shared row groups decode once in this tick's pool.  Only the
    #    groups ALREADY dispatched this tick ride free (their decodes are
    #    pool hits, not fresh work); any fresh group still charges the tick
    #    budget, so a big pulled-in partner cannot smuggle a whole scan past
    #    WFQ preemption — its unshared tail waits for normal scheduling.
    if service.hold_ticks > 0:
        for req in eligible:
            if req.req_id in units or req.started:
                continue
            partners = [
                u for u, _ in list(units.values())
                if u is not req and coalesce_compatible(req, u)
            ]
            if not partners or not (
                req.held_ticks > 0 or any(p.held_ticks > 0 for p in partners)
            ):
                continue
            shared = {
                rg
                for u, rgs in list(units.values())
                if u is not req and u.reader.path == req.reader.path
                for rg in rgs
            }
            while not exhausted(req):
                free = req.row_groups[req.cursor] in shared
                if not free and spent >= budget:
                    break  # fresh decode work: back to budgeted scheduling
                if req.req_id not in units:
                    open_unit(req)
                cost = take_rg(req)
                if not free:
                    spent += cost
        for req, _ in list(units.values()):
            if (
                req.held_ticks > 0
                and not req.release_counted
                and any(
                    u is not req and coalesce_compatible(req, u)
                    for u, _ in units.values()
                )
            ):
                req.release_counted = True
                tel.inc("hold_released")

    # -- whoever is still held has waited one more tick toward the deadline
    for req in held:
        req.held_ticks += 1
        if req.held_ticks == 1:
            tel.inc("held_requests")
        tel.inc("held_ticks")

    # -- flight recorder: attribute this tick's queued time by WHY the
    #    request waited.  Held requests sit in a hold_window span; eligible
    #    requests the fair scheduler passed over sit in wfq_wait.  The wait
    #    spans close the instant run_tick dispatches a slice, so waiting
    #    and executing can never overlap in the span tree.
    tracer = service.tracer
    if tracer is not None and tracer.has_live():
        for req in held:
            rt = tracer.live(req.req_id)
            if rt is not None:
                tracer.wait(rt, "hold_window", tick=service._tick)
        for req in eligible:
            if req.req_id in units:
                continue
            rt = tracer.live(req.req_id)
            if rt is not None:
                tracer.wait(rt, "wfq_wait", tick=service._tick)

    return [units[rid] for rid in order]


# ---------------------------------------------------------------------------
# tick execution (HOW the batch runs)
# ---------------------------------------------------------------------------

def run_tick(service, batch: List[Tuple[object, List[int]]]) -> None:
    """Execute one tick's dispatch units: group by table, coalesce through
    a window-scoped view into the store's decoded tier, advance each
    request's resumable scan, simulate the storage->NIC fetch.  Completed
    results land on each ticket."""
    groups: Dict[str, List[Tuple[object, List[int]]]] = {}
    for req, rgs in batch:
        groups.setdefault(req.reader.path, []).append((req, rgs))

    tel = service.telemetry
    tracer = service.tracer
    for _path, group in groups.items():
        # decodes pinned through this window survive `hold_ticks` more
        # ticks, so a late-arriving compatible partner reuses them
        pool = service.store.window(
            expires_tick=service._tick + service.hold_ticks,
            max_bytes=service.pool_bytes,
        )
        if len(group) > 1:
            tel.inc("coalesced_groups")
            tel.inc("coalesced_requests", len(group))
        # (req, fetched rgs, launch delta, fault-plane seconds delta)
        fetches: List[Tuple[object, List[int], int, float]] = []
        if service.batch_decode and len(group) > 1:
            # cross-request bucket stacking: every coalesced request's
            # pages decode through ONE bucket pass (engine.
            # scan_group_batched) instead of per-request launches that
            # meet only at the pool
            _run_group_stacked(service, group, pool, fetches)
            _finish_group(service, pool, fetches)
            continue
        for req, rgs in group:
            pool.owner = req.tenant  # retained pins bill their decoder
            # flight recorder: the slice span, plus the engine-side slice
            # context (trace.set_slice) that lets decode/fetch/filter/store
            # spans attach without a plumbed-through tracer argument
            rt = tracer.live(req.req_id) if tracer is not None else None
            if rt is not None:
                tracer.end_wait(rt)  # waiting ends the moment we dispatch
                tracer.begin(rt, "slice_dispatch", tick=service._tick,
                             rgs=len(rgs))
                trace.set_slice(tracer, rt)
            try:
                try:
                    if req.rs is None:  # first dispatch: pin the offload mode
                        # service._choose_mode wraps the adaptive policy
                        # with the circuit breaker's degraded-raw override
                        mode = service._choose_mode(req)
                        tel.inc(f"offload_{mode}")
                        req.mode = mode
                        req.rs = ResumableScan(
                            service.engine, req.reader, req.plan, blooms=req.blooms,
                            offload=mode, row_groups=req.row_groups,
                            scan_tag=req.scan_tag,
                        )
                    rs = req.rs
                    work0 = dict(rs.stats.decode_work)
                    launches0 = rs.stats.kernel_launches
                    peer0 = rs.stats.peer_bytes
                    fault0 = rs.stats.fault_wait_s
                    if rs.result is None and rgs:
                        dec0 = rs.stats.decoded_bytes
                        fetched: List[int] = []
                        if service.batch_decode:
                            # the whole WFQ slice goes to the engine as ONE
                            # batch: pages bucketed by (encoding, k, dtype),
                            # one kernel launch per bucket, and the engine
                            # reports which groups actually pulled encoded
                            # bytes (store-resident groups fetch nothing)
                            _, fetched = rs.advance_batched(rgs, pool=pool)
                            tel.inc("batch_slices")
                            tel.inc("batch_slice_rgs", len(rgs))
                        else:
                            # advance one row group at a time so the fetch
                            # simulation sees exactly the groups that pulled
                            # encoded bytes — store-resident groups (decoded,
                            # window-pinned, or page-tier) fetch nothing and
                            # are skipped at row-group granularity, not per
                            # slice
                            for rg in rgs:
                                enc0 = rs.stats.encoded_bytes
                                rs.advance([rg], pool=pool)
                                if rs.stats.encoded_bytes > enc0:
                                    fetched.append(rg)
                        tel.observe_tenant_bytes(req.tenant, rs.stats.decoded_bytes - dec0)
                        if fetched:
                            fetches.append(
                                (req, fetched,
                                 rs.stats.kernel_launches - launches0,
                                 rs.stats.fault_wait_s - fault0))
                    if rgs:
                        # retroactive honesty: the estimate was charged at
                        # dispatch; re-bill by the decode work the slice REALLY
                        # did (ScanStats.decode_work — keyed by the encodings
                        # actually read, immune to mis-estimated requests) plus
                        # the launches it REALLY dispatched (bucketed batch
                        # slices launch far fewer than the sequential estimate
                        # and are refunded the difference).  A cache/pool-
                        # resident slice did no work — fully refunded.
                        work = {
                            e: b - work0.get(e, 0)
                            for e, b in rs.stats.decode_work.items()
                            if b - work0.get(e, 0)
                        }
                        launches = rs.stats.kernel_launches - launches0
                        tel.inc("decode_launches", launches)
                        tel.inc("decode_slice_rgs", len(rgs))  # both dispatch modes
                        if rt is not None:
                            tracer.begin(rt, "reconcile")
                        actual_s = _reconcile_slice(
                            service, req, work, launches,
                            peer_bytes=rs.stats.peer_bytes - peer0,
                            fault_s=rs.stats.fault_wait_s - fault0)
                        if rt is not None:
                            tracer.end(rt, name="reconcile",
                                       launches=launches, actual_s=actual_s)
                except Exception as e:  # noqa: BLE001 — isolate faulty requests
                    req.ticket.error = e
                    tel.inc("failed")
                    continue
                if rs.result is not None:
                    res = rs.result
                    req.ticket.result = res
                    tel.inc("decoded_bytes", res.stats.decoded_bytes)
                    tel.inc("decoded_bytes_fresh", res.stats.decoded_bytes_fresh)
                    tel.inc("encoded_bytes", res.stats.encoded_bytes)
                    tel.inc("rows_out", res.stats.rows_out)
                    if res.stats.cache_hit:
                        tel.inc("prefiltered_hits")
            finally:
                if rt is not None:
                    trace.set_slice(None, None)
                    tracer.end(rt, name="slice_dispatch", mode=req.mode or "")
        _finish_group(service, pool, fetches)


def _finish_group(service, pool, fetches) -> None:
    """Per-group tick epilogue shared by both dispatch paths: pool reuse
    telemetry + the storage->NIC fetch simulation."""
    tel = service.telemetry
    tel.inc("decoded_bytes_saved", pool.hit_bytes)
    if pool.retained_hits:  # served from a PREVIOUS tick's window pins
        tel.inc("retained_hits", pool.retained_hits)
        tel.inc("retained_reuse_bytes", pool.retained_hit_bytes)
        tel.inc("retained_redecode_saved_s", pool.retained_saved_s)
    if pool.rejected_puts:
        tel.inc("pool_rejected_puts", pool.rejected_puts)

    _simulate_fetch(service, fetches)


def _run_group_stacked(service, group, pool, fetches) -> None:
    """Dispatch one table's coalesced requests as a SINGLE cross-request
    bucket pass.

    Before this path, same-tick same-table requests each launched their
    own (encoding, k, dtype) buckets and shared decodes only through pool
    hits at finalize time.  Here the whole group's pages stack into one
    set of buckets (engine.scan_group_batched): a page two requests both
    need decodes once, launches drop again by the stacking factor, and
    the engine's strict item ordering keeps results AND accounting
    bit-identical to the sequential per-request dispatch.  If the group
    pass itself fails, every request falls back to its own
    `advance_batched` (per-request fault isolation is preserved either
    way — one poisoned request never takes down its partners)."""
    tel = service.telemetry
    tracer = service.tracer
    engine = service.engine

    # -- per request: open the slice span, pin mode, create the scan ----
    live = []  # (req, rgs, rt, work0, launches0, dec0, peer0, fault0)
    items: List[dict] = []
    item_of: Dict[int, int] = {}  # req_id -> index into the group output
    for req, rgs in group:
        pool.owner = req.tenant  # retained pins bill their decoder
        rt = tracer.live(req.req_id) if tracer is not None else None
        if rt is not None:
            tracer.end_wait(rt)  # waiting ends the moment we dispatch
            tracer.begin(rt, "slice_dispatch", tick=service._tick,
                         rgs=len(rgs))
            trace.set_slice(tracer, rt)
        try:
            if req.rs is None:  # first dispatch: pin the offload mode
                mode = service._choose_mode(req)
                tel.inc(f"offload_{mode}")
                req.mode = mode
                req.rs = ResumableScan(
                    engine, req.reader, req.plan, blooms=req.blooms,
                    offload=mode, row_groups=req.row_groups,
                    scan_tag=req.scan_tag,
                )
        except Exception as e:  # noqa: BLE001 — isolate faulty requests
            req.ticket.error = e
            tel.inc("failed")
            if rt is not None:
                trace.set_slice(None, None)
                tracer.end(rt, name="slice_dispatch", mode=req.mode or "")
            continue
        finally:
            if rt is not None:
                trace.set_slice(None, None)
        rs = req.rs
        live.append((req, rgs, rt, dict(rs.stats.decode_work),
                     rs.stats.kernel_launches, rs.stats.decoded_bytes,
                     rs.stats.peer_bytes, rs.stats.fault_wait_s))
        if rs.result is None and rgs:
            item_of[req.req_id] = len(items)
            items.append({
                "reader": req.reader, "rgs": list(rgs), "plan": rs.plan,
                "pred": rs.pred, "blooms": rs.blooms, "stats": rs.stats,
                "offload": rs.offload, "owner": req.tenant,
                "trace": (tracer, rt) if rt is not None else None,
            })

    # -- ONE bucket pass across every request's slice -------------------
    results = None
    if items:
        try:
            results = engine.scan_group_batched(items, pool=pool)
            tel.inc("xreq_groups")
            tel.inc("xreq_requests", len(items))
        except Exception:  # noqa: BLE001 — fall back to per-request dispatch
            results = None
            tel.inc("xreq_fallback")

    # -- finalize per request, in dispatch order ------------------------
    for req, rgs, rt, work0, launches0, dec0, peer0, fault0 in live:
        pool.owner = req.tenant
        rs = req.rs
        if rt is not None:
            trace.set_slice(tracer, rt)
        try:
            try:
                idx = item_of.get(req.req_id)
                if idx is not None:
                    if results is not None:
                        per_rg, fetched = results[idx]
                        rs.ingest_batched(rgs, per_rg)
                    else:  # group pass failed: this request runs alone
                        _, fetched = rs.advance_batched(rgs, pool=pool)
                    tel.inc("batch_slices")
                    tel.inc("batch_slice_rgs", len(rgs))
                    tel.observe_tenant_bytes(
                        req.tenant, rs.stats.decoded_bytes - dec0)
                    if fetched:
                        fetches.append(
                            (req, fetched,
                             rs.stats.kernel_launches - launches0,
                             rs.stats.fault_wait_s - fault0))
                if rgs:
                    work = {
                        e: b - work0.get(e, 0)
                        for e, b in rs.stats.decode_work.items()
                        if b - work0.get(e, 0)
                    }
                    launches = rs.stats.kernel_launches - launches0
                    tel.inc("decode_launches", launches)
                    tel.inc("decode_slice_rgs", len(rgs))
                    if rt is not None:
                        tracer.begin(rt, "reconcile")
                    actual_s = _reconcile_slice(
                        service, req, work, launches,
                        peer_bytes=rs.stats.peer_bytes - peer0,
                        fault_s=rs.stats.fault_wait_s - fault0)
                    if rt is not None:
                        tracer.end(rt, name="reconcile",
                                   launches=launches, actual_s=actual_s)
            except Exception as e:  # noqa: BLE001 — isolate faulty requests
                req.ticket.error = e
                tel.inc("failed")
                continue
            if rs.result is not None:
                res = rs.result
                req.ticket.result = res
                tel.inc("decoded_bytes", res.stats.decoded_bytes)
                tel.inc("decoded_bytes_fresh", res.stats.decoded_bytes_fresh)
                tel.inc("encoded_bytes", res.stats.encoded_bytes)
                tel.inc("rows_out", res.stats.rows_out)
                if res.stats.cache_hit:
                    tel.inc("prefiltered_hits")
        finally:
            if rt is not None:
                trace.set_slice(None, None)
                tracer.end(rt, name="slice_dispatch", mode=req.mode or "")


def _reconcile_slice(service, req, work: Dict[str, int], launches: int = 0,
                     peer_bytes: int = 0, fault_s: float = 0.0) -> float:
    """Close the loop on one completed slice: compare the decode-seconds
    charged at dispatch against the slice's actual cost and re-bill the
    tenant's virtual time (service._vreconcile).

    Actual cost is priced from the decode work the engine REALLY did
    (`work`: fresh output bytes by the encoding of the buffers actually
    read — ground truth from the scan, independent of the request's own
    estimate) plus the kernel `launches` it really dispatched, through the
    service's cost model.  An honest solo raw sequential scan reconciles
    to exactly zero; a batched slice is refunded the launch overhead its
    buckets amortized; a 4x under-estimating request is re-billed 4x in
    the same tick it decoded (and its tenant's future dispatches are
    re-priced); a pool/cache-fed slice is refunded.

    `peer_bytes` is what this slice pulled over the inter-pod hop (fabric
    peer block-store fetches): the transfer is billed to the tenant whose
    miss triggered it at the calibrated inter-pod link rate — cheaper
    than the storage hop, but never free.

    `fault_s` is the slice's fault-plane time (ScanStats.fault_wait_s
    delta: retry backoff, failed attempts, latency spikes, hedge
    exposure — datapath/faults.py).  It is billed into the SAME actual
    so a faulty tenant's retries advance that tenant's virtual time —
    recovery work can never buy share from healthy tenants — and the
    sched + recon == actual telemetry invariant keeps holding under
    chaos."""
    charged_s, raw_s = req.charged_s, req.charged_raw_s
    req.charged_s = req.charged_raw_s = 0.0
    actual_s = sum(
        service.cost_model.decode_seconds(nbytes, encoding)
        for encoding, nbytes in work.items()
    ) + service.cost_model.launch_seconds(launches)
    if peer_bytes:
        peer_s = service.cost_model.peer_fetch_seconds(peer_bytes)
        actual_s += peer_s
        service.telemetry.observe_peer(req.tenant, peer_bytes, peer_s)
    if fault_s:
        actual_s += fault_s
        service.telemetry.observe_fault_wait(req.tenant, fault_s)
    service._vreconcile(req.tenant, charged_s, raw_s, actual_s,
                        table=req.reader.path)
    return actual_s


def _simulate_fetch(service, fetches) -> None:
    """Model the tick's storage->NIC transfer for the row groups actually
    read this tick (cache-hit / pool-fed / failed slices fetch nothing),
    double-buffered against on-device decode.

    Decode is sized exactly like the engine's (engine.decode_footprint):
    PACK_BLOCK-padded rows, true dtype widths, and a fused scan's
    predicate column is processed (it contributes decode time at its
    encoding's rate) but never materialized (it contributes no decoded
    bytes) — plus the calibrated per-launch dispatch overhead.  All times
    come from the service's cost model, so netsim and the WFQ charge read
    one table.

    Pipeline granularity follows the dispatch mode.  Sequential: one unit
    per ROW GROUP (fetch of group i+1 hides behind its neighbor's decode),
    merged across requests so a shared group is priced once.  Batched: one
    unit per DISPATCH SLICE in dispatch order — the next slice's whole
    fetch overlaps this slice's bucketed batch decode, which is the
    "pipelined fetch/decode scan loop" the batch path exists for; columns
    an earlier slice already priced this tick contribute nothing (same
    first-contributor-wins rule as the merge).

    Each row group's metadata comes from a reader that actually scanned it
    — NOT from whichever request happened to be first in the group.  Two
    reader objects may share a path while disagreeing on metadata (e.g. a
    re-opened file); pricing each request's footprint with its own reader
    keeps the simulated byte counts honest (regression-tested in
    tests/test_scheduler.py).
    """
    cm = service.cost_model
    enc: List[int] = []
    dec: List[int] = []
    dec_s: List[float] = []
    if service.batch_decode:
        # one pipeline unit per slice; dedupe (rg, column) across slices
        seen: Dict[Tuple[int, str], dict] = {}
        for req, rgs, launches, _fault_s in fetches:
            enc_b = dec_b = 0
            dec_t = 0.0
            for fp in service.engine.decode_footprint(req.reader, req.plan,
                                                      rgs, pred=req.pred):
                for name, col in fp["columns"].items():
                    prev = seen.get((fp["rg"], name))
                    if prev is None:
                        seen[(fp["rg"], name)] = dict(col)
                        enc_b += col["encoded_bytes"]
                        dec_t += cm.decode_seconds(col["nbytes"], col["encoding"])
                        if col["materialized"]:
                            dec_b += col["nbytes"]
                    elif col["materialized"] and not prev["materialized"]:
                        prev["materialized"] = True
                        dec_b += col["nbytes"]
            enc.append(enc_b)
            dec.append(dec_b)
            dec_s.append(dec_t + cm.launch_seconds(launches))
        clock = service.slice_clock
        if clock is not None:
            # cumulative cross-tick pipeline: slice i+1's fetch is in
            # flight while slice i's batch decode runs, tick boundaries
            # notwithstanding (counters are set, not incremented — the
            # clock already accumulates)
            tracer = service.tracer
            for (req, frgs, _l, fault_s), enc_b, dec_t in zip(fetches, enc,
                                                              dec_s):
                # fault-plane seconds ride the slice's fetch leg so chaos
                # tails show up in the same hidden-vs-exposed anatomy
                info = clock.feed(enc_b, dec_t, extra_fetch_s=fault_s)
                # flight recorder: per-slice hidden-vs-exposed fetch time
                # from the streaming pipeline clock
                rt = tracer.live(req.req_id) if tracer is not None else None
                if rt is not None:
                    tracer.event(rt, "sim_fetch", nbytes=enc_b, rgs=len(frgs),
                                 fetch_s=info["fetch_s"],
                                 decode_s=info["decode_s"],
                                 hidden_s=info["hidden_s"],
                                 exposed_s=info["exposed_s"])
            tel = service.telemetry
            tel.counters["sim_pipe_slices"] = float(clock.slices)
            tel.counters["sim_pipe_serial_s"] = clock.serial_s
            tel.counters["sim_pipe_overlapped_s"] = clock.overlapped_s
            tel.counters["sim_pipe_saved_s"] = clock.saved_s
    else:
        # rg -> merged column footprints.  engine.decode_footprint is the
        # ONE source of truth for what a scan materializes vs merely
        # processes (padded rows, dtype widths, per-row-group fusability —
        # auto-encoded files can flip a predicate column's encoding between
        # groups), so the transfer model cannot drift from the WFQ charge.
        # Each request's columns are priced with its OWN reader's metadata;
        # on overlap the first contributor wins (materialization is an OR).
        per_rg: Dict[int, Dict[str, dict]] = {}
        for req, rgs, _launches, _fault_s in fetches:
            for fp in service.engine.decode_footprint(req.reader, req.plan,
                                                      rgs, pred=req.pred):
                cols = per_rg.setdefault(fp["rg"], {})
                for name, col in fp["columns"].items():
                    prev = cols.get(name)
                    if prev is None:
                        cols[name] = dict(col)
                    elif col["materialized"] and not prev["materialized"]:
                        prev["materialized"] = True
        for rg in sorted(per_rg):
            cols = per_rg[rg].values()
            enc.append(sum(c["encoded_bytes"] for c in cols))
            dec.append(sum(c["nbytes"] for c in cols if c["materialized"]))
            # sequential decode launches once per column (the same bill
            # estimate_row_groups charges)
            dec_s.append(sum(cm.decode_seconds(c["nbytes"], c["encoding"])
                             for c in cols) + cm.launch_seconds(len(cols)))
    if not enc:
        return
    sim = service.pipeline.simulate(enc, dec, decode_seconds=dec_s)
    tel = service.telemetry
    tel.inc("sim_fetch_encoded_bytes", sum(enc))
    tel.inc("sim_fetch_decoded_bytes", sum(dec))
    tel.inc("sim_fetch_serial_s", sim["serial_s"])
    tel.inc("sim_fetch_overlapped_s", sim["overlapped_s"])
    tel.inc("sim_fetch_saved_s", sim["saved_s"])
    tracer = service.tracer
    if tracer is not None and not service.batch_decode:
        # sequential dispatch pipelines at row-group granularity merged
        # across requests, so per-request anatomy does not exist — attach
        # the tick-level overlap summary to each participating request
        for req, frgs, _l, _fs in fetches:
            rt = tracer.live(req.req_id)
            if rt is not None:
                tracer.event(rt, "sim_fetch", rgs=len(frgs),
                             serial_s=sim["serial_s"],
                             overlapped_s=sim["overlapped_s"],
                             saved_s=sim["saved_s"],
                             shared=len(fetches) > 1)
