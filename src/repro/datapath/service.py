"""Pod — the SmartNIC as a shared, multi-tenant appliance.

The seed engine was a synchronous per-caller library (`engine.scan()`);
the paper's vision is a device on the network datapath serving MANY
queries at once.  This module is that service layer.  Since the fabric
refactor the single-node core is the `Pod` class — one scheduler, one
block store, one netsim clock, one telemetry sink — and
`DatapathService` is a back-compat alias (a one-pod deployment IS the
old service, bit for bit).  `datapath/fabric.py` composes N pods behind
consistent-hash row-group ownership; each pod stays deterministically
single-threaded, which is what keeps fabric results bit-identical to
single-node scans.

  submit()  bounded-queue admission with per-tenant byte/row quotas,
            estimated from footer metadata only (zone maps + encoded
            sizes) — nothing is fetched or decoded to say "no"
  tick()    the scheduler forms one fair-share batch (weighted fair
            queueing over estimated decode-SECONDS from the calibrated
            encoding-aware cost model, reconciled against actual decode
            cost at slice completion, row-group preemption points,
            cross-tick coalescing holds — scheduler.py), hands each
            request's slice to the engine as ONE bucketed batch decode
            (batch_decode=True: one kernel launch per (encoding, k,
            dtype) bucket instead of one per (row group, column)) and
            runs it
            around a window-scoped view into the unified BlockStore's
            decoded tier, so each (row group, column) pair is decoded
            once per tick AND stays pinned for hold_ticks more ticks
            (late partners reuse instead of re-decoding; retained bytes
            bill the holder's virtual time)
  client()  an engine-compatible adapter (`.scan(reader, plan)`) so the
            whole query suite in core/queries.py runs through the
            service unchanged

Everything is deterministically single-threaded: "concurrency" is queue
depth per tick, which keeps service results bit-identical to direct
engine scans (tests/test_datapath.py and tests/test_scheduler.py assert
this, including for scans sliced across ticks).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Tuple, Union

from repro.core.cache import BlockCache
from repro.core.engine import DatapathEngine, ScanResult
from repro.core.plan import ScanPlan, bind_expr
from repro.core.zonemap import prune_and_estimate
from repro.datapath.blockstore import BlockStore
from repro.datapath.costmodel import CostModel
from repro.datapath.faults import (
    CircuitBreaker,
    FaultInjector,
    FaultPlan,
    Overloaded,
    RetryPolicy,
)
from repro.datapath.netsim import PrefetchPipeline, SliceClock
from repro.datapath.policy import AdaptiveOffloadPolicy
from repro.datapath.scheduler import form_batch, run_tick
from repro.datapath.telemetry import Telemetry, quantile
from repro.datapath.trace import Tracer


class QueueFull(RuntimeError):
    """Admission control: the service queue is at max depth."""


class QuotaExceeded(RuntimeError):
    """Admission control: the tenant is over its byte or row budget."""


@dataclasses.dataclass
class TenantQuota:
    """Per-quota-window budgets plus the tenant's fair-share weight.  Bytes
    are *encoded* bytes pulled over the storage->NIC hop (what the
    appliance actually meters); rows are estimated output rows; `weight`
    scales the tenant's share of each tick's decode capacity under the WFQ
    scheduler (virtual time advances by estimated decode-seconds / weight,
    reconciled against actual decode cost at slice completion)."""

    max_bytes: int = 1 << 40
    max_rows: int = 1 << 40
    weight: float = 1.0


@dataclasses.dataclass
class _TenantState:
    used_bytes: int = 0
    used_rows: int = 0

    def reset(self) -> None:
        self.used_bytes = 0
        self.used_rows = 0


@dataclasses.dataclass
class Ticket:
    req_id: int
    tenant: str
    status: str = "queued"  # queued | done | error
    result: Optional[ScanResult] = None
    error: Optional[BaseException] = None
    submitted_s: float = 0.0
    done_s: float = 0.0
    submitted_tick: int = 0  # service tick counter at admission
    done_tick: int = 0  # tick on which the request reached a terminal state


@dataclasses.dataclass
class ScanRequest:
    req_id: int
    tenant: str
    reader: object
    plan: ScanPlan
    blooms: Optional[Dict]
    ticket: Ticket
    est_bytes: int = 0
    est_rows: int = 0
    # bound predicate + surviving row groups, computed once at admission and
    # reused by the scheduler's fetch simulation (no repeat footer walks)
    pred: object = None
    row_groups: tuple = ()
    # -- scheduler state (datapath/scheduler.py) -----------------------------
    rg_costs: tuple = ()  # estimated decode-SECONDS per row group (WFQ charge)
    rg_bytes: tuple = ()  # estimated decoded bytes per row group (tick budget)
    rg_set: frozenset = frozenset()  # hold-window footprint: row groups
    col_set: frozenset = frozenset()  # hold-window footprint: columns
    cursor: int = 0  # next row-group index to dispatch
    charged_s: float = 0.0  # decode-seconds charged for not-yet-reconciled slices
    charged_raw_s: float = 0.0  # same charges before the adaptive scale
    started: bool = False  # first slice has been dispatched
    held_ticks: int = 0  # ticks spent waiting for a coalescing partner
    release_counted: bool = False  # hold_released already recorded
    first_tick: int = 0  # tick of the first dispatched slice
    mode: Optional[str] = None  # offload mode pinned at first dispatch
    rs: object = None  # ResumableScan, created at first dispatch
    # fabric: disambiguates a sub-scan's prefiltered-cache identity from the
    # whole-table scan (and from other row-group subsets after a drain
    # re-partitions ownership) — threaded into every plan_cache_key
    scan_tag: object = None


class Pod:
    """One single-node scan service: scheduler + block store + netsim
    clock + telemetry behind an admission-controlled queue.  `pod_id`
    names the pod inside a ScanFabric (peer-fetch attribution, hash-ring
    membership); a standalone pod keeps the default and never notices."""

    def __init__(
        self,
        engine: Optional[DatapathEngine] = None,
        max_queue_depth: int = 64,
        batch_per_tick: int = 8,
        quota_window_ticks: int = 16,
        quotas: Optional[Dict[str, TenantQuota]] = None,
        default_quota: Optional[TenantQuota] = None,
        policy=None,
        pipeline: Optional[PrefetchPipeline] = None,
        telemetry: Optional[Telemetry] = None,
        pool_bytes: int = 1 << 30,  # per-tick decode-window pin budget
        scheduler: str = "wfq",  # "wfq" | "fifo" (seed behavior, for A/B)
        tick_bytes: Optional[int] = None,  # per-tick decoded-byte budget
        # cross-tick coalescing window: 0 = off, N = hold up to N ticks,
        # "auto" = tuned from observed footprint-recurrence gaps
        hold_ticks: Union[int, str] = 0,
        cost_model: Optional[CostModel] = None,  # encoding-aware decode pricing
        reconcile: bool = True,  # re-bill vtime by actual decode cost
        # bucketed batch decode: each WFQ slice decodes in one kernel
        # launch per (encoding, k, dtype) bucket instead of one per
        # (row group, column) — bit-identical results, ~4-100x fewer
        # device dispatches.  False = the seed per-row-group loop (kept
        # for A/B in benchmarks/service_bench.py `batchdecode`).
        batch_decode: bool = True,
        # flight recorder (datapath/trace.py): fraction of requests that
        # carry a span tree (deterministic sampler, 0.0 = tracing off and
        # allocation-free) and how many completed traces the bounded ring
        # retains.  `tracer` injects a pre-built Tracer (e.g. with a fake
        # clock for deterministic tests) and overrides both knobs.
        trace_sample_rate: float = 1.0,
        trace_capacity: int = 64,
        tracer: Optional[Tracer] = None,
        # storage fault plane (datapath/faults.py, DESIGN.md §17): a
        # FaultPlan installs the deterministic injector on the engine's
        # storage-read seam; a RetryPolicy alone still installs it (clean
        # plan) so retries/timeouts/hedging and checksum verification run
        # against real storage faults too.  The breaker defaults on
        # whenever the injector is installed.
        fault_plan: Optional[FaultPlan] = None,
        retry_policy: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
        pod_id: str = "pod0",
    ):
        assert scheduler in ("wfq", "fifo"), scheduler
        self.pod_id = pod_id
        assert hold_ticks == "auto" or int(hold_ticks) >= 0, hold_ticks
        self.engine = engine or DatapathEngine(backend="ref", cache=BlockCache())
        self.max_queue_depth = max_queue_depth
        self.batch_per_tick = batch_per_tick
        self.quota_window_ticks = quota_window_ticks
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota or TenantQuota()
        self.policy = policy if policy is not None else AdaptiveOffloadPolicy()
        self.cost_model = cost_model or CostModel()
        # register as the process-default table so default-constructed
        # netsim models (DecodeModel()/PrefetchPipeline()) price decode
        # from the same per-backend table the scheduler charges with
        from repro.datapath import costmodel as _costmodel_mod

        _costmodel_mod.set_default_cost_model(self.cost_model)
        self.reconcile = reconcile
        self.batch_decode = batch_decode
        # scheduler and netsim share one calibrated table unless the caller
        # injects a bespoke pipeline
        self.pipeline = pipeline or self.cost_model.pipeline()
        # cross-tick fetch/decode pipeline clock for batched dispatch: one
        # slice per tick means per-tick simulation can never see the next
        # slice's fetch hiding behind this slice's batch decode — the
        # streaming clock can (telemetry sim_pipe_* counters)
        self.slice_clock = SliceClock(self.pipeline.link) if batch_decode else None
        self.pool_bytes = pool_bytes
        self.scheduler = scheduler
        self.tick_bytes = tick_bytes
        self.hold_auto = hold_ticks == "auto"
        self.hold_ticks = 0 if self.hold_auto else int(hold_ticks)
        self.telemetry = telemetry or Telemetry()
        # per-request flight recorder; None when sampling is fully off so
        # every trace touchpoint is a single attribute check
        if tracer is not None:
            self.tracer: Optional[Tracer] = tracer
        elif trace_sample_rate > 0.0:
            self.tracer = Tracer(capacity=trace_capacity,
                                 sample_rate=trace_sample_rate)
        else:
            self.tracer = None
        self.telemetry.tracer = self.tracer
        # ONE tiered store backs the engine's cache, the scheduler's decode
        # windows, and the policy's residency probes — a single byte ledger
        # priced by the service's cost model (an engine with a bespoke
        # cache still gets a private store for window coalescing)
        self.store: BlockStore = (
            getattr(self.engine.cache, "store", None) or BlockStore()
        )
        self.store.cost_model = self.cost_model
        self.telemetry.store = self.store
        self.queue: List[ScanRequest] = []
        self._tenants: Dict[str, _TenantState] = {}
        self._vtime: Dict[str, float] = {}  # WFQ virtual time, decode-s/weight
        # EWMA of actual/estimated decode cost, applied at charge time: a
        # tenant whose scans systematically under-estimate is re-priced at
        # dispatch (not only retroactively), closing the within-tick window
        # where a stale estimate could still buy extra slots.  The tenant-
        # level scale is the fallback; per-(tenant, table) scales keep one
        # lying table from re-pricing the same tenant's honest tables.
        self._est_scale: Dict[str, float] = {}
        self._est_scale_table: Dict[Tuple[str, str], float] = {}
        # footprint-recurrence log driving the "auto" hold window
        self._footprints: collections.deque = collections.deque(maxlen=64)
        self._recur_gaps: collections.deque = collections.deque(maxlen=32)
        self._ids = itertools.count()
        self._tick = 0
        # -- storage fault plane -------------------------------------------
        self.breaker = breaker
        self.retry_policy = retry_policy
        self.faults: Optional[FaultInjector] = None
        if fault_plan is not None or retry_policy is not None:
            self.install_faults(fault_plan or FaultPlan(), retry_policy)
        # cost-model provenance into telemetry (one-time nominal-link
        # warning when the per-backend JSON never calibrated the link)
        self.telemetry.note_costmodel(self.cost_model)

    EST_SCALE_ALPHA = 0.5  # EWMA weight of the newest slice's observed error
    EST_SCALE_CLAMP = 64.0  # bound on the adaptive dispatch-time scale
    HOLD_AUTO_MAX = 4  # ceiling on the auto-tuned coalescing window
    HOLD_AUTO_MIN_RECUR = 0.25  # recurrence rate below which holding is off

    # ------------------------------------------------------------------
    # storage fault plane
    # ------------------------------------------------------------------
    def install_faults(self, plan: FaultPlan,
                       policy: Optional[RetryPolicy] = None) -> None:
        """Install (or replace) the fault injector on the engine's storage
        read seam.  Idempotent per pod; the fabric's `inject_faults` routes
        here for per-pod chaos."""
        self.retry_policy = policy or self.retry_policy or RetryPolicy()
        if self.breaker is None:
            self.breaker = CircuitBreaker()
        self.faults = FaultInjector(
            plan, self.retry_policy, link=self.cost_model.link_model(),
            pod_id=self.pod_id, telemetry=self.telemetry,
            breaker=self.breaker, clock=lambda: self._tick,
        )
        self.engine.faults = self.faults

    def breaker_open(self) -> bool:
        """Any storage target's circuit breaker currently open?  The
        fabric polls this each tick: an open breaker evicts the pod from
        the fleet exactly like heartbeat silence (drain + replay)."""
        return self.breaker is not None and self.breaker.any_open()

    def _choose_mode(self, req: ScanRequest) -> str:
        """Offload mode for a request's first dispatch — the ONE place
        both scheduler paths (sequential run_tick and the stacked group
        pass) decide it.  An open breaker on the request's table degrades
        to raw offload: no caching ambitions, minimum bytes at risk,
        while recovery probes decide when to trust the target again."""
        if self.breaker is not None and self.breaker.degraded(req.reader.path):
            self.telemetry.inc("breaker_degraded_dispatches")
            return "raw"
        return self.policy.choose(
            self.engine, req.reader, req.plan, req.blooms,
            row_groups=req.row_groups,
            selectivity=req.est_rows / max(req.reader.n_rows, 1),
            scan_tag=req.scan_tag,
        )

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _quota(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def _state(self, tenant: str) -> _TenantState:
        return self._tenants.setdefault(tenant, _TenantState())

    def _weight(self, tenant: str) -> float:
        return max(self._quota(tenant).weight, 1e-9)

    def _scale_for(self, tenant: str, table: Optional[str] = None) -> float:
        """Dispatch-time estimate-error scale: the (tenant, table) EWMA when
        that table has reconciled slices, else the tenant-level blend — an
        unseen table inherits the tenant's history rather than scale 1.0."""
        if table is not None:
            s = self._est_scale_table.get((tenant, table))
            if s is not None:
                return s
        return self._est_scale.get(tenant, 1.0)

    def _vcharge(self, tenant: str, seconds: float, nbytes: float,
                 table: Optional[str] = None) -> float:
        """Advance `tenant`'s virtual time by a dispatched row group's
        estimated decode-SECONDS over its weight (the WFQ clock is device
        time, not nominal bytes — an RLE group is cheaper than PLAIN).
        The estimate is re-priced by the observed estimate-error scale of
        the (tenant, table) pair before charging; returns the seconds
        actually charged."""
        charged = seconds * self._scale_for(tenant, table)
        self._vtime[tenant] = self._vtime.get(tenant, 0.0) + charged / self._weight(tenant)
        self.telemetry.observe_sched(tenant, charged, nbytes)
        return charged

    def _vreconcile(self, tenant: str, charged_s: float, raw_s: float,
                    actual_seconds: float, table: Optional[str] = None) -> None:
        """Re-bill `tenant`'s virtual time by a completed slice's ACTUAL
        decode cost: `charged_s` was charged at dispatch, so apply only
        the difference (positive for under-estimates — a tenant whose
        scans under-price cannot buy extra share; negative refunds
        over-estimates, e.g. cache-resident slices that decoded nothing).
        Same estimate-then-correct pattern the quota path uses for encoded
        bytes.  The clamp keeps virtual time non-negative under any
        correction ordering.

        `raw_s` is the slice's pre-scale estimate; actual/raw drives the
        EWMA dispatch-time scale so a SYSTEMATIC mis-estimate stops paying
        off after its first reconciled slice, instead of re-buying a
        within-tick advantage every tick."""
        self.telemetry.observe_actual_cost(tenant, actual_seconds)
        if not self.reconcile:
            return
        correction = actual_seconds - charged_s
        if correction != 0.0:
            self._vtime[tenant] = max(
                0.0, self._vtime.get(tenant, 0.0) + correction / self._weight(tenant)
            )
            self.telemetry.observe_recon(tenant, correction)
        # Only slices that did real decode work train the scale: a cache/
        # pool-resident slice (actual == 0) is a scheduling outcome, not an
        # estimate error — folding it in would drive the scale to the floor
        # and let the tenant's next FRESH scan monopolize ticks at a
        # near-zero dispatch price.
        if raw_s > 0.0 and actual_seconds > 0.0:
            target = min(max(actual_seconds / raw_s, 1.0 / self.EST_SCALE_CLAMP),
                         self.EST_SCALE_CLAMP)
            a = self.EST_SCALE_ALPHA
            prev = self._est_scale.get(tenant, 1.0)
            self._est_scale[tenant] = (1.0 - a) * prev + a * target
            if table is not None:
                # the per-table scale trains on the same slices but never
                # blends across tables: one lying table cannot re-price a
                # tenant's honest tables (ROADMAP per-(tenant, table) item)
                prev_t = self._est_scale_table.get((tenant, table), 1.0)
                self._est_scale_table[(tenant, table)] = (1.0 - a) * prev_t + a * target

    def submit(self, tenant: str, reader, plan: ScanPlan, blooms: Optional[Dict] = None,
               row_groups=None, scan_tag=None) -> Ticket:
        """Admit one scan request or raise (QueueFull / QuotaExceeded).
        Cost estimates are metadata-only — no data bytes move on rejection.

        `row_groups` restricts the scan to a subset of the table's row
        groups (the fabric routes each pod only the groups it owns);
        pruning still runs first and the pruned order is preserved, so a
        restricted scan decodes exactly the intersection.  `scan_tag`
        disambiguates the request's prefiltered-cache identity — fabric
        sub-scans tag with their row-group subset so a cached sub-result
        can never serve a DIFFERENT subset after a drain re-partitions."""
        tr = self.tracer
        t_tr0 = tr.clock() if tr is not None else 0.0  # trace time base
        self.telemetry.inc("submitted")
        if len(self.queue) >= self.max_queue_depth:
            self.telemetry.inc("rejected_queue_full")
            raise QueueFull(
                f"queue at max depth {self.max_queue_depth}; tenant={tenant!r}"
            )
        if self.breaker is not None:
            # Graceful degradation instead of queue collapse: while the
            # table's storage target is tripped open, requests still admit
            # in degraded (raw) mode — but once the queue nears capacity
            # they shed with a typed Overloaded, and after the cooldown
            # one admission becomes the half-open recovery probe.
            path = getattr(reader, "path", str(reader))
            verdict = self.breaker.admit(
                path, self._tick,
                queue_frac=len(self.queue) / max(self.max_queue_depth, 1),
            )
            if verdict == "shed":
                self.telemetry.inc("rejected_overloaded")
                raise Overloaded(
                    f"storage target {path!r} breaker open and queue at "
                    f"{len(self.queue)}/{self.max_queue_depth}; "
                    f"tenant={tenant!r} — retry after cooldown"
                )
            if verdict == "probe":
                self.telemetry.inc("breaker_probes")
            elif verdict == "degraded":
                self.telemetry.inc("breaker_degraded_admits")

        pred = bind_expr(plan.predicate, reader)
        rgs, selectivity = prune_and_estimate(reader, pred)
        rgs = tuple(rgs)
        if row_groups is not None:
            allowed = frozenset(row_groups)
            rgs = tuple(rg for rg in rgs if rg in allowed)
        est_bytes = self.engine.estimate_scan_bytes(reader, plan, row_groups=rgs)
        if row_groups is None:
            est_rows = int(selectivity * reader.n_rows)
        else:
            # estimate against the restricted slice of the table, not the
            # whole file — a pod owning 1/N of the groups budgets ~1/N rows
            rows_in = sum(reader.row_group_meta(rg)["n"] for rg in rgs)
            est_rows = int(selectivity * rows_in)
        quota, state = self._quota(tenant), self._state(tenant)
        over_bytes = state.used_bytes + est_bytes > quota.max_bytes
        over_rows = state.used_rows + est_rows > quota.max_rows
        if (over_bytes or over_rows) and not self.queue:
            # Idle service: empty ticks would advance the window with nothing
            # to schedule, so fast-forward to the boundary and refill rather
            # than locking a quota-exhausted tenant out forever.  Quotas
            # still bind whenever there is queued work to arbitrate.
            self._tick += self.quota_window_ticks - (self._tick % self.quota_window_ticks)
            for s in self._tenants.values():
                s.reset()
            over_bytes = est_bytes > quota.max_bytes
            over_rows = est_rows > quota.max_rows
        if over_bytes:
            self.telemetry.inc("rejected_quota_bytes")
            raise QuotaExceeded(
                f"tenant {tenant!r}: {est_bytes}B would exceed byte budget "
                f"({state.used_bytes}/{quota.max_bytes} used this window)"
            )
        if over_rows:
            self.telemetry.inc("rejected_quota_rows")
            raise QuotaExceeded(
                f"tenant {tenant!r}: ~{est_rows} rows would exceed row budget "
                f"({state.used_rows}/{quota.max_rows} used this window)"
            )
        state.used_bytes += est_bytes
        state.used_rows += est_rows

        # WFQ bookkeeping: an idle service starts a fresh round; a tenant
        # joining a busy service starts at the backlog's virtual clock so it
        # cannot cash in credit hoarded while idle.
        if not self.queue:
            self._vtime.clear()
        else:
            vclock = min(self._vtime.get(r.tenant, 0.0) for r in self.queue)
            self._vtime[tenant] = max(self._vtime.get(tenant, 0.0), vclock)

        ticket = Ticket(next(self._ids), tenant, submitted_s=time.perf_counter(),
                        submitted_tick=self._tick)
        rg_costs = self.cost_model.estimate_row_groups(
            self.engine, reader, plan, rgs, pred=pred
        )
        if self.hold_auto and rgs:
            self._observe_footprint(reader.path, frozenset(rgs),
                                    frozenset(plan.all_columns()))
        self.queue.append(
            ScanRequest(ticket.req_id, tenant, reader, plan, blooms, ticket,
                        est_bytes=est_bytes, est_rows=est_rows,
                        pred=pred, row_groups=rgs,
                        rg_costs=tuple(c.seconds for c in rg_costs),
                        rg_bytes=tuple(c.nbytes for c in rg_costs),
                        rg_set=frozenset(rgs),
                        col_set=frozenset(plan.all_columns()),
                        scan_tag=scan_tag)
        )
        self.telemetry.inc("admitted")
        # flight recorder: open the request's root span at submit entry,
        # record admission as a closed child (estimate + quota work), and
        # start the queued-wait clock — run_tick closes it at dispatch
        if tr is not None:
            rt = tr.start(ticket.req_id, tenant, reader.path, t0=t_tr0,
                          submitted_tick=ticket.submitted_tick)
            if rt is not None:
                tr.add_span(rt, "admission", t_tr0, tr.clock(),
                            est_bytes=est_bytes, est_rows=est_rows,
                            row_groups=len(rgs))
                tr.wait(rt, "wfq_wait", tick=self._tick)
        return ticket

    # ------------------------------------------------------------------
    # auto-tuned coalescing window
    # ------------------------------------------------------------------
    def _observe_footprint(self, path: str, rg_set: frozenset,
                           col_set: frozenset) -> None:
        """Feed the hold-window auto-tuner one admitted footprint: the gap
        (in ticks) to the most recent overlapping footprint is a recurrence
        sample; no overlap is a one-off sample.  The window opens only when
        partners actually recur (rate >= HOLD_AUTO_MIN_RECUR) and is sized
        to cover the typical gap (p75, capped) — hold longer when a partner
        is likely, not at all for one-off footprints."""
        gap = None
        for tk, p, rgs, cols in reversed(self._footprints):
            if p == path and (rgs & rg_set) and (cols & col_set):
                gap = self._tick - tk
                break
        self._recur_gaps.append(gap)
        self._footprints.append((self._tick, path, rg_set, col_set))
        gaps = [float(g) for g in self._recur_gaps if g is not None]
        if gaps and len(gaps) / len(self._recur_gaps) >= self.HOLD_AUTO_MIN_RECUR:
            self.hold_ticks = min(self.HOLD_AUTO_MAX, int(quantile(gaps, 0.75)))
        else:
            self.hold_ticks = 0
        self.telemetry.counters["hold_ticks_auto"] = float(self.hold_ticks)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def tick(self) -> int:
        """Process one scheduler tick: form a fair-share batch of row-group
        slices (scheduler.form_batch) and execute it coalesced.  A request
        completes the tick its last row group lands; a large scan may span
        many ticks (preemption points).  Returns requests completed."""
        self._tick += 1
        # expire decode-window pins whose hold window ended (ephemeral raw
        # decodes drop; promoted entries merely become evictable)
        self.store.advance_tick(self._tick)
        # retention isn't free: decoded bytes a tenant keeps window-pinned
        # across a tick boundary bill its virtual time at a rate that sums
        # to one re-decode over the full window (blockstore.retention_charges)
        for tenant, (nbytes, charge_s) in sorted(self.store.retention_charges().items()):
            self._vtime[tenant] = (
                self._vtime.get(tenant, 0.0) + charge_s / self._weight(tenant)
            )
            self.telemetry.observe_retained(tenant, nbytes, charge_s)
        if self._tick % self.quota_window_ticks == 0:  # window boundary: refill
            for state in self._tenants.values():
                state.reset()
        self.telemetry.sample_queue_depth(len(self.queue))
        if not self.queue:
            return 0
        batch = form_batch(self)
        t0 = time.perf_counter()
        if batch:
            run_tick(self, batch)
        now = time.perf_counter()
        self.telemetry.observe_tick(now - t0)
        done: List[ScanRequest] = []
        failed = 0
        for req in self.queue:
            if req.ticket.error is None and (req.rs is None or req.rs.result is None):
                continue  # still in flight (or held) — stays queued
            done.append(req)
            req.ticket.status = "error" if req.ticket.error is not None else "done"
            req.ticket.done_s = now
            req.ticket.done_tick = self._tick
            self.telemetry.observe_latency(req.tenant, now - req.ticket.submitted_s)
            failed += req.ticket.status == "error"
            if self._tick > req.first_tick > 0:
                self.telemetry.inc("split_scans")  # preempted across ticks
            res = req.ticket.result
            if self.tracer is not None:
                # close the root span at the request's terminal tick and
                # push the trace into the flight recorder's bounded ring
                self.tracer.finish(
                    req.req_id, req.ticket.status, done_tick=self._tick,
                    mode=req.mode or "", held_ticks=req.held_ticks,
                    rows_out=res.stats.rows_out if res is not None else 0,
                )
            if res is not None:
                # reconcile the admission estimate against bytes actually
                # pulled: cache-resident and pool-coalesced scans fetch less
                # (often zero), and quotas meter the storage->NIC hop
                state = self._state(req.tenant)
                over_b = req.est_bytes - res.stats.encoded_bytes
                if over_b > 0:
                    state.used_bytes = max(0, state.used_bytes - over_b)
                over_r = req.est_rows - res.stats.rows_out
                if over_r > 0:
                    state.used_rows = max(0, state.used_rows - over_r)
        if done:
            done_ids = {r.req_id for r in done}
            self.queue = [r for r in self.queue if r.req_id not in done_ids]
        self.telemetry.inc("completed", len(done) - failed)
        return len(done)

    def drain(self) -> int:
        """Tick until the queue is empty; returns requests completed."""
        done = 0
        while self.queue:
            done += self.tick()
        return done

    def result(self, ticket: Ticket) -> ScanResult:
        while ticket.status == "queued":
            if not self.queue:
                raise RuntimeError(f"ticket {ticket.req_id} queued but queue is empty")
            self.tick()
        if ticket.status == "error":
            raise ticket.error
        return ticket.result

    def client(self, tenant: str = "default") -> "ServiceClient":
        return ServiceClient(self, tenant)


class DatapathService(Pod):
    """The historical single-node name.  A one-pod deployment is exactly
    the old service — same defaults, same scheduling, same bit-identical
    results — so existing callers and tests keep constructing this."""


class ServiceClient:
    """Engine-compatible facade: `.scan(reader, plan, blooms)` routes the
    scan through the shared service, so any code written against
    DatapathEngine (all six queries in core/queries.py) runs through the
    multi-tenant path unchanged."""

    def __init__(self, service: Pod, tenant: str):
        self.service = service
        self.tenant = tenant

    @property
    def backend(self) -> str:
        return self.service.engine.backend

    @property
    def cache(self) -> BlockCache:
        return self.service.engine.cache

    def scan(self, reader, plan: ScanPlan, blooms: Optional[Dict] = None) -> ScanResult:
        ticket = self.service.submit(self.tenant, reader, plan, blooms)
        return self.service.result(ticket)
