"""Service telemetry: queue depth, coalescing savings, per-tenant latency.

The paper's SmartNIC is a shared appliance, so the numbers an operator
needs are fleet numbers: how deep the queue runs, how many decoded bytes
shared-scan coalescing saved, and what tick latency each tenant sees at
p50/p99.  Everything here is plain Python (no jax) — it must stay cheap
enough to record on every tick.
"""

from __future__ import annotations

import collections
from typing import Dict, List


def quantile(xs: List[float], q: float) -> float:
    """Nearest-rank quantile of an unsorted list (0 <= q <= 1)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[idx]


class Telemetry:
    def __init__(self, max_samples: int = 4096):
        self.counters: Dict[str, float] = collections.defaultdict(float)
        self.queue_depth: collections.deque = collections.deque(maxlen=max_samples)
        self._tenant_latency: Dict[str, collections.deque] = {}
        self._tick_seconds: collections.deque = collections.deque(maxlen=max_samples)
        self._max_samples = max_samples

    # -- recording ---------------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] += value

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth.append(depth)

    def observe_tick(self, seconds: float) -> None:
        self._tick_seconds.append(seconds)

    def observe_latency(self, tenant: str, seconds: float) -> None:
        """One request's submit->complete wall latency for `tenant`."""
        dq = self._tenant_latency.setdefault(
            tenant, collections.deque(maxlen=self._max_samples)
        )
        dq.append(seconds)

    # -- reading -----------------------------------------------------------
    def tenant_latency(self, tenant: str) -> Dict[str, float]:
        xs = list(self._tenant_latency.get(tenant, ()))
        return {
            "n": len(xs),
            "p50_s": quantile(xs, 0.50),
            "p99_s": quantile(xs, 0.99),
        }

    def snapshot(self) -> dict:
        depths = list(self.queue_depth)
        ticks = list(self._tick_seconds)
        return {
            "counters": dict(self.counters),
            "queue_depth_max": max(depths) if depths else 0,
            "queue_depth_mean": sum(depths) / len(depths) if depths else 0.0,
            "tick_p50_s": quantile(ticks, 0.50),
            "tick_p99_s": quantile(ticks, 0.99),
            "tenants": {t: self.tenant_latency(t) for t in self._tenant_latency},
        }
