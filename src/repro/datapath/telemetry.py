"""Service telemetry: queue depth, coalescing savings, per-tenant latency,
and fair-share metrics.

The paper's SmartNIC is a shared appliance, so the numbers an operator
needs are fleet numbers: how deep the queue runs, how many decoded bytes
shared-scan coalescing saved, what tick latency each tenant sees at
p50/p99 — and, with the WFQ scheduler (DESIGN.md §9), whether decode
capacity is actually being split by weight: per-tenant decoded-byte
shares, a Jain fairness index, and how much latency the cross-tick
coalescing hold window added.  Everything here is plain Python (no jax)
— it must stay cheap enough to record on every tick.
"""

from __future__ import annotations

import collections
import math
from typing import Dict, List, Optional


def quantile(xs: List[float], q: float) -> float:
    """Nearest-rank quantile of an unsorted list.  `q` is clamped to
    [0, 1]; q=0 is the minimum, q=1 the maximum, and the half-way rank
    rounds UP (half-up, not banker's), so two-sample p50 is the larger
    sample on every platform — deterministic run-to-run."""
    if not xs:
        return 0.0
    q = min(1.0, max(0.0, q))
    s = sorted(xs)
    idx = int(math.floor(q * (len(s) - 1) + 0.5))
    return s[min(len(s) - 1, max(0, idx))]


def jain_index(shares: List[float]) -> float:
    """Jain's fairness index over non-negative allocations: 1.0 when all
    equal, 1/n when one allocation takes everything.  Empty or all-zero
    input reads as perfectly fair (nothing was allocated unevenly)."""
    if not shares:
        return 1.0
    total = float(sum(shares))
    sq = float(sum(x * x for x in shares))
    if sq <= 0.0:
        return 1.0
    return (total * total) / (len(shares) * sq)


class Telemetry:
    def __init__(self, max_samples: int = 4096):
        self.counters: Dict[str, float] = collections.defaultdict(float)
        self.queue_depth: collections.deque = collections.deque(maxlen=max_samples)
        self._tenant_latency: Dict[str, collections.deque] = {}
        self._tick_seconds: collections.deque = collections.deque(maxlen=max_samples)
        self._max_samples = max_samples
        # fair-share accounting: actually-decoded bytes vs scheduler-charged
        # (estimated) bytes, per tenant
        self.tenant_decoded_bytes: Dict[str, float] = collections.defaultdict(float)
        self.tenant_sched_bytes: Dict[str, float] = collections.defaultdict(float)
        # time-domain WFQ currency: estimated decode-seconds charged at
        # dispatch, actual decode-seconds observed at slice completion, and
        # the reconciliation corrections applied to virtual time.  With
        # reconciliation on, sched + recon == actual per tenant (property-
        # tested in tests/test_recon_props.py).
        self.tenant_sched_seconds: Dict[str, float] = collections.defaultdict(float)
        self.tenant_actual_seconds: Dict[str, float] = collections.defaultdict(float)
        self.tenant_recon_seconds: Dict[str, float] = collections.defaultdict(float)
        # window-retention ledger: decoded byte-ticks a tenant kept pinned
        # across tick boundaries, and the virtual-time it was billed for them
        self.tenant_retained_bytes: Dict[str, float] = collections.defaultdict(float)
        self.tenant_retained_seconds: Dict[str, float] = collections.defaultdict(float)
        # fabric peer-fetch ledger: bytes a tenant's slices pulled over the
        # inter-pod hop (a sibling pod's block store served a local miss)
        # and the link seconds WFQ billed for them
        self.tenant_peer_bytes: Dict[str, float] = collections.defaultdict(float)
        self.tenant_peer_seconds: Dict[str, float] = collections.defaultdict(float)
        # the unified BlockStore, registered by the service so snapshots
        # carry the per-tier hit/eviction/retained ledger
        self.store = None
        # the flight recorder's Tracer (datapath/trace.py), registered by
        # the service so snapshots carry the per-request stage attribution
        self.tracer = None
        # fault-plane ledger: modeled seconds the storage fault plane added,
        # bucketed by cause (backoff / wasted / timeout / straggle /
        # hedge_saved), plus the per-tenant total so the WFQ honesty
        # invariant (sched + recon == actual) stays checkable under faults
        self.fault_seconds: Dict[str, float] = collections.defaultdict(float)
        self.tenant_fault_seconds: Dict[str, float] = collections.defaultdict(float)
        # one-shot warnings (emitted at most once per key, surfaced in the
        # snapshot so headless bench runs still record them)
        self._warnings: Dict[str, str] = {}
        # cost-model provenance, registered by the service at construction:
        # which backend the rate tables came from and whether the link model
        # is still running on nominal (uncalibrated) constants
        self.costmodel_info: Optional[dict] = None

    # -- recording ---------------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] += value

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth.append(depth)

    def observe_tick(self, seconds: float) -> None:
        self._tick_seconds.append(seconds)

    def observe_latency(self, tenant: str, seconds: float) -> None:
        """One request's submit->complete wall latency for `tenant`."""
        dq = self._tenant_latency.setdefault(
            tenant, collections.deque(maxlen=self._max_samples)
        )
        dq.append(seconds)

    def observe_tenant_bytes(self, tenant: str, nbytes: float) -> None:
        """Decoded bytes materialized for `tenant` by one dispatched slice."""
        self.tenant_decoded_bytes[tenant] += nbytes

    def observe_sched(self, tenant: str, seconds: float, nbytes: float) -> None:
        """One dispatched row group's scheduler charge: estimated decode-
        seconds (the WFQ virtual-time currency) plus the estimated decoded
        bytes it corresponds to (the tick-budget currency)."""
        self.tenant_sched_seconds[tenant] += seconds
        self.tenant_sched_bytes[tenant] += nbytes

    def observe_actual_cost(self, tenant: str, seconds: float) -> None:
        """Actual decode cost of one completed slice (modeled from the
        bytes the engine really materialized) — recorded whether or not
        reconciliation is on, so estimate error is always reportable."""
        self.tenant_actual_seconds[tenant] += seconds

    def observe_recon(self, tenant: str, correction_s: float) -> None:
        """Virtual-time correction applied at slice completion (positive:
        the tenant under-estimated and is re-billed; negative: refund)."""
        self.tenant_recon_seconds[tenant] += correction_s
        self.inc("recon_slices")
        self.inc("recon_abs_seconds", abs(correction_s))

    def observe_retained(self, tenant: str, nbytes: float, charge_s: float) -> None:
        """One tick's window-retention bill for `tenant`: the decoded bytes
        it kept pinned across the tick boundary (a byte-tick of occupancy)
        and the virtual-time charge the scheduler applied for them."""
        self.tenant_retained_bytes[tenant] += nbytes
        self.tenant_retained_seconds[tenant] += charge_s
        self.inc("retained_byte_ticks", nbytes)
        self.inc("retained_charge_seconds", charge_s)

    def observe_peer(self, tenant: str, nbytes: float, seconds: float) -> None:
        """One slice's inter-pod peer-fetch bill: bytes a sibling pod's
        block store served into this pod for `tenant`'s scan, and the
        modeled link seconds reconciliation added to its virtual time."""
        self.tenant_peer_bytes[tenant] += nbytes
        self.tenant_peer_seconds[tenant] += seconds
        self.inc("peer_fetch_bytes", nbytes)
        self.inc("peer_fetch_seconds", seconds)

    def observe_fault_seconds(self, kind: str, seconds: float) -> None:
        """Modeled seconds the fault plane added to one fetch attempt,
        bucketed by cause.  `hedge_saved` is NEGATIVE accounting — the tail
        seconds a hedged read clawed back — and is recorded as a positive
        magnitude under its own key so the win is visible in reports."""
        self.fault_seconds[kind] += seconds
        self.inc("fault_seconds_total", seconds)

    def observe_fault_wait(self, tenant: str, seconds: float) -> None:
        """One slice's total fault-plane delay billed into `tenant`'s WFQ
        virtual time at reconciliation — retries, backoff, spikes, timeouts.
        Kept per-tenant so the honesty ledger (cost_report) can show that
        fault seconds were charged to the tenant that incurred them."""
        self.tenant_fault_seconds[tenant] += seconds
        self.inc("fault_wait_seconds", seconds)

    def warn_once(self, key: str, message: str) -> None:
        """Record a warning at most once per key.  Warnings ride the
        snapshot (benchmark JSON) rather than stderr so headless runs
        keep them."""
        if key not in self._warnings:
            self._warnings[key] = message
            self.inc("warnings")

    def note_costmodel(self, cm) -> None:
        """Register cost-model provenance.  Fires the one-time
        `nominal_link` warning when the link model is running on nominal
        (uncalibrated) constants — the silent fallback the calibration
        loader takes when its JSON lacks link entries."""
        link_source = getattr(cm, "link_source", "nominal")
        self.costmodel_info = {
            "backend": getattr(cm, "backend", "unknown"),
            "source": getattr(cm, "source", "unknown"),
            "link_source": link_source,
            "nominal_link": link_source == "nominal",
        }
        if link_source == "nominal":
            self.warn_once(
                "nominal_link",
                "LinkModel is using nominal bandwidth/latency constants "
                "(calibration provided no link entries); fetch seconds are "
                "modeled, not measured",
            )

    # -- reading -----------------------------------------------------------
    def tenant_latency(self, tenant: str) -> Dict[str, float]:
        xs = list(self._tenant_latency.get(tenant, ()))
        return {
            "n": len(xs),
            "p50_s": quantile(xs, 0.50),
            "p99_s": quantile(xs, 0.99),
            "p999_s": quantile(xs, 0.999),  # tail-of-tail (SLO work)
        }

    def known_tenants(self) -> List[str]:
        """Every tenant the scheduler has seen — decoded bytes, scheduler
        charges, actual/reconciled decode seconds, OR latency samples.
        Fairness must range over all of them: a fully-starved tenant
        decodes zero bytes and would otherwise vanish from the report,
        RAISING the Jain index exactly when it should tank — and a tenant
        observed only via observe_actual_cost/observe_recon must not
        vanish from cost_report()."""
        return sorted(
            set(self.tenant_decoded_bytes)
            | set(self.tenant_sched_bytes)
            | set(self.tenant_sched_seconds)
            | set(self.tenant_actual_seconds)
            | set(self.tenant_recon_seconds)
            | set(self.tenant_retained_bytes)
            | set(self.tenant_peer_bytes)
            | set(self.tenant_fault_seconds)
            | set(self._tenant_latency)
        )

    def cost_report(self) -> dict:
        """Estimated-vs-actual decode cost per tenant: the honesty ledger.
        `rel_err` is (estimate - actual) / actual (negative: the tenant's
        scans under-estimated); `recon_s` is the virtual-time correction
        reconciliation applied to close the gap."""
        out = {}
        for t in self.known_tenants():
            est = self.tenant_sched_seconds.get(t, 0.0)
            act = self.tenant_actual_seconds.get(t, 0.0)
            out[t] = {
                "est_s": est,
                "actual_s": act,
                "recon_s": self.tenant_recon_seconds.get(t, 0.0),
                "fault_s": self.tenant_fault_seconds.get(t, 0.0),
                "rel_err": (est - act) / act if act > 0 else 0.0,
            }
        return out

    def fault_report(self) -> dict:
        """Storage-fault-plane ledger: what went wrong, what the retry /
        hedge / breaker machinery did about it, and what it cost.  Fixed
        keys, zero when the plane is quiet, so benchmark JSON is stable
        whether or not faults were injected."""
        c = self.counters
        return {
            "transient_errors": c.get("faults_transient", 0.0),
            "fetch_timeouts": c.get("fetch_timeouts", 0.0),
            "short_reads": c.get("faults_short_read", 0.0),
            "corrupt_injected": c.get("faults_corrupt", 0.0),
            "corrupt_detected": c.get("corrupt_detected", 0.0),
            "quarantined_pages": c.get("quarantined_pages", 0.0),
            "unverified_pages": c.get("unverified_pages", 0.0),
            "retry_successes": c.get("fetch_retry_successes", 0.0),
            "retries_exhausted": c.get("fetch_retries_exhausted", 0.0),
            "hedged_fetches": c.get("hedged_fetches", 0.0),
            "hedge_wins": c.get("hedge_wins", 0.0),
            "breaker_trips": c.get("breaker_trips", 0.0),
            "breaker_probes": c.get("breaker_probes", 0.0),
            "breaker_degraded_admits": c.get("breaker_degraded_admits", 0.0),
            "breaker_degraded_dispatches": c.get(
                "breaker_degraded_dispatches", 0.0
            ),
            "rejected_overloaded": c.get("rejected_overloaded", 0.0),
            "fault_seconds": dict(sorted(self.fault_seconds.items())),
            "tenant_fault_seconds": dict(
                sorted(self.tenant_fault_seconds.items())
            ),
        }

    def batch_report(self) -> dict:
        """Batched-decode dispatch ledger: slices dispatched through the
        bucketed path, row groups they carried, and total decode-path
        kernel launches — `launches_per_rg` is the headline batching win
        (sequential pays one launch per (row group, column); batched pays
        one per bucket) and is computed over the row groups dispatched in
        EITHER mode, so a sequential service reports its true per-group
        dispatch bill rather than a fake zero.  Fixed keys, zero when
        idle."""
        slices = self.counters.get("batch_slices", 0.0)
        batch_rgs = self.counters.get("batch_slice_rgs", 0.0)
        all_rgs = self.counters.get("decode_slice_rgs", 0.0)
        launches = self.counters.get("decode_launches", 0.0)
        return {
            "batch_slices": slices,
            "batch_slice_rgs": batch_rgs,
            "decode_launches": launches,
            "launches_per_rg": launches / all_rgs if all_rgs > 0 else 0.0,
            "rgs_per_slice": batch_rgs / slices if slices > 0 else 0.0,
        }

    def fairness(self, weights: Optional[Dict[str, float]] = None) -> dict:
        """Fair-share report: each tenant's share of the decode capacity it
        OCCUPIED — decoded bytes plus window-retained byte-ticks (a byte
        kept pinned across a tick denies the pool that byte exactly like a
        byte decoded, so hoarding decodes is visible in the shares) — the
        Jain index over weight-normalized allocations (1.0 = perfectly
        weighted-fair), and what the coalescing hold window cost.  Shares
        cover every tenant known to the scheduler, so a starved tenant
        shows up as a zero share and drags the index down."""
        weights = weights or {}
        decoded = {t: self.tenant_decoded_bytes.get(t, 0.0)
                   for t in self.known_tenants()}
        retained = {t: self.tenant_retained_bytes.get(t, 0.0)
                    for t in self.known_tenants()}
        usage = {t: decoded[t] + retained[t] for t in decoded}
        total = float(sum(usage.values()))
        shares = {t: (v / total if total > 0 else 0.0) for t, v in usage.items()}
        normalized = [v / max(weights.get(t, 1.0), 1e-9) for t, v in usage.items()]
        return {
            "tenant_decoded_bytes": decoded,
            "tenant_retained_bytes": dict(sorted(retained.items())),
            "tenant_peer_bytes": dict(sorted(self.tenant_peer_bytes.items())),
            "tenant_sched_bytes": dict(sorted(self.tenant_sched_bytes.items())),
            "tenant_sched_seconds": dict(sorted(self.tenant_sched_seconds.items())),
            "tenant_share": shares,
            "jain_index": jain_index(normalized),
            "min_share": min(shares.values()) if shares else 0.0,
            "max_share": max(shares.values()) if shares else 0.0,
            "held_requests": self.counters.get("held_requests", 0.0),
            "held_ticks": self.counters.get("held_ticks", 0.0),
            # tail-of-tail latency per tenant: the fairness story is
            # incomplete if a fair byte split hides a blown p99.9
            "tenant_latency_p999_s": {
                t: quantile(list(self._tenant_latency.get(t, ())), 0.999)
                for t in self.known_tenants()
            },
        }

    def trace_report(self) -> dict:
        """The flight recorder's stage-attribution report (fixed empty
        shape when no tracer is registered, so benchmark JSON keys are
        stable whether or not tracing ran)."""
        if self.tracer is None:
            return {"enabled": False, "completed": 0, "recorded": 0,
                    "requests": []}
        return self.tracer.report()

    def snapshot(self) -> dict:
        """Deterministic summary: every dict is key-sorted and empty deques
        collapse to fixed zeros, so benchmark JSON is stable run-to-run.
        `store` is the unified block store's per-tier ledger (hits,
        evictions, retained bytes, re-decode seconds saved) when a service
        registered one, else a fixed empty dict."""
        depths = list(self.queue_depth)
        ticks = list(self._tick_seconds)
        return {
            "counters": dict(sorted(self.counters.items())),
            "queue_depth_max": max(depths) if depths else 0,
            "queue_depth_mean": sum(depths) / len(depths) if depths else 0.0,
            "tick_p50_s": quantile(ticks, 0.50),
            "tick_p99_s": quantile(ticks, 0.99),
            "tick_p999_s": quantile(ticks, 0.999),
            "tenants": {
                t: self.tenant_latency(t) for t in sorted(self._tenant_latency)
            },
            "fairness": self.fairness(),
            "cost": self.cost_report(),
            "batch": self.batch_report(),
            "faults": self.fault_report(),
            "costmodel": (
                dict(self.costmodel_info)
                if self.costmodel_info is not None
                else {"backend": "unknown", "source": "unknown",
                      "link_source": "nominal", "nominal_link": True}
            ),
            "warnings": dict(sorted(self._warnings.items())),
            "store": self.store.stats() if self.store is not None else {},
            "trace": self.trace_report(),
        }
