"""Datapath flight recorder — per-request span tracing with paper-anchored
stage attribution and exportable timelines.

The paper's headline claim is a TIME-ATTRIBUTION claim: decode is 46% of
TPC-H runtime on Parquet, filter 17% (Fig. 2).  Telemetry reports those
numbers fleet-wide; this module makes them a PER-REQUEST measurement.
Every admitted request (subject to `sample_rate`) carries a span tree —

    request                     submit() -> terminal ticket status
      admission                 metadata-only estimate + quota checks
      wfq_wait | hold_window    queued ticks, by WHY the request waited
      slice_dispatch            one per scheduler slice (run_tick)
        fetch                   storage->NIC pull of encoded pages
        decode_launch           one per device dispatch (bucket or column)
        filter                  predicate eval / stream compaction
        reconcile               actual-cost re-billing of virtual time
        store_hit / evict / sim_fetch   zero-duration instant events

— and the completed trees live in a bounded ring (`FlightRecorder`,
last-N requests, fixed memory, always on).  Exporters: Chrome/Perfetto
`trace_event` JSON (one pid per tenant, one tid per request) and a
deterministic stage-attribution report whose `decode_pct`/`filter_pct`/
`rest_pct` line up against the paper's 46/17 split (PAPER_FIG2_PCT).

Cost discipline (DESIGN.md §13): everything here is pure stdlib, and the
hot path is gated so an untraced run allocates NOTHING — the engine's
call sites check `trace._CUR is None` (one module-attribute load) before
building any kwargs.  The scheduler publishes the active request's trace
via `set_slice()` around each slice, so engine/blockstore code needs no
plumbed-through tracer argument.  Tracing must never perturb results:
bit-identity of scan output with tracing on/off is property-tested in
tests/test_trace_props.py.
"""

from __future__ import annotations

import collections
import json
import time
from typing import Any, Dict, List, Optional

# The paper's Fig. 2 TPC-H-on-Parquet breakdown — the anchor every
# stage-attribution report is printed against.
PAPER_FIG2_PCT = {"decode": 46.0, "filter": 17.0, "rest": 37.0}

# span name -> attribution stage.  Children of a mapped span are NOT
# recursed into (a store_hit inside a fetch span must not double-bill),
# so stage seconds over one trace can never exceed the root wall time.
STAGE_OF = {
    "admission": "admission",
    "hold_window": "hold_window",
    "wfq_wait": "wfq_wait",
    "fetch": "fetch",
    "decode_launch": "decode",
    "filter": "filter",
    "reconcile": "reconcile",
}
STAGES = ("admission", "hold_window", "wfq_wait", "fetch", "decode",
          "filter", "reconcile")


def _span(name: str, t0: float, attrs: dict) -> dict:
    return {"name": name, "t0": t0, "t1": None, "args": attrs, "children": []}


class RequestTrace:
    """One request's span tree while in flight.  Spans are plain dicts
    (name/t0/t1/args/children); `stack` enforces strict nesting — the
    scheduler and engine call begin/end in stack discipline, and
    `Tracer.finish` force-closes anything an error path left open."""

    __slots__ = ("req_id", "tenant", "table", "status", "root", "stack",
                 "n_spans", "dropped_spans", "drop_depth", "wait_kind",
                 "summary")

    def __init__(self, req_id: int, tenant: str, table: str, t0: float,
                 attrs: dict):
        attrs = dict(attrs)
        attrs.update(req_id=req_id, tenant=tenant, table=table)
        self.req_id = req_id
        self.tenant = tenant
        self.table = table
        self.status = "queued"
        self.root = _span("request", t0, attrs)
        self.stack: List[dict] = [self.root]
        self.n_spans = 1
        self.dropped_spans = 0  # spans refused by the max_spans cap
        self.drop_depth = 0  # open-but-dropped begins awaiting their end
        self.wait_kind: Optional[str] = None  # open wfq_wait / hold_window
        self.summary: Optional[dict] = None  # filled at finish()


class FlightRecorder:
    """Bounded ring of the last `capacity` COMPLETED request traces.
    Always on, fixed memory: an old trace falls off the back, its spans
    garbage-collected with it."""

    def __init__(self, capacity: int = 64):
        self.capacity = max(1, int(capacity))
        self._ring: collections.deque = collections.deque(maxlen=self.capacity)
        self.completed = 0  # total finishes ever, including evicted ones

    def add(self, rt: RequestTrace) -> None:
        self._ring.append(rt)
        self.completed += 1

    def traces(self) -> List[RequestTrace]:
        return list(self._ring)

    # -- stage attribution -------------------------------------------------
    def report(self) -> dict:
        """Deterministic stage-attribution report over the ring: one
        summary per recorded request (ring order), fleet stage seconds
        and time-weighted decode/filter/rest percentages, a per-tenant
        rollup, and the paper's Fig. 2 anchor for side-by-side reading.
        Every dict is key-sorted; values are plain floats/ints."""
        traces = list(self._ring)
        stage_s = {s: 0.0 for s in STAGES}
        wall = 0.0
        by_tenant: Dict[str, dict] = {}
        for rt in traces:
            sm = rt.summary or {}
            wall += sm.get("wall_s", 0.0)
            bt = by_tenant.setdefault(
                rt.tenant, {"n": 0, "wall_s": 0.0,
                            "stage_s": {s: 0.0 for s in STAGES}})
            bt["n"] += 1
            bt["wall_s"] += sm.get("wall_s", 0.0)
            for s, v in sm.get("stages_s", {}).items():
                stage_s[s] += v
                bt["stage_s"][s] += v
        for bt in by_tenant.values():
            w = bt["wall_s"]
            bt["stage_pct"] = {
                s: (100.0 * v / w if w > 0 else 0.0)
                for s, v in sorted(bt["stage_s"].items())
            }
            bt["decode_pct"] = bt["stage_pct"]["decode"]
            bt["filter_pct"] = bt["stage_pct"]["filter"]
            bt["rest_pct"] = max(
                0.0, 100.0 - bt["decode_pct"] - bt["filter_pct"])
            bt["stage_s"] = dict(sorted(bt["stage_s"].items()))
        decode_pct = 100.0 * stage_s["decode"] / wall if wall > 0 else 0.0
        filter_pct = 100.0 * stage_s["filter"] / wall if wall > 0 else 0.0
        return {
            "capacity": self.capacity,
            "completed": self.completed,
            "recorded": len(traces),
            "requests": [rt.summary for rt in traces if rt.summary],
            "wall_s": wall,
            "stage_s": dict(sorted(stage_s.items())),
            "stage_pct": {
                "decode": decode_pct,
                "filter": filter_pct,
                "rest": max(0.0, 100.0 - decode_pct - filter_pct),
            },
            "by_tenant": dict(sorted(by_tenant.items())),
            "paper_fig2_pct": dict(sorted(PAPER_FIG2_PCT.items())),
        }

    # -- Chrome/Perfetto export --------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome `trace_event` JSON (load in ui.perfetto.dev or
        chrome://tracing): one process per tenant, one thread per request,
        "X" complete events for spans, "i" instants for zero-duration
        events.  Timestamps are microseconds relative to the earliest
        recorded request, so the export is position-independent."""
        traces = list(self._ring)
        events: List[dict] = []
        if not traces:
            return {"displayTimeUnit": "ms", "traceEvents": events}
        base = min(rt.root["t0"] for rt in traces)
        tenants = sorted({rt.tenant for rt in traces})
        pid_of = {t: i + 1 for i, t in enumerate(tenants)}
        for t in tenants:
            events.append({"args": {"name": t}, "name": "process_name",
                           "ph": "M", "pid": pid_of[t], "tid": 0})
        for rt in sorted(traces, key=lambda r: r.req_id):
            pid, tid = pid_of[rt.tenant], rt.req_id
            events.append({"args": {"name": f"req-{rt.req_id}"},
                           "name": "thread_name", "ph": "M",
                           "pid": pid, "tid": tid})
            stack = [rt.root]
            while stack:
                sp = stack.pop()
                stack.extend(reversed(sp["children"]))
                if sp["t1"] is None:
                    continue
                args = {
                    k: (v if isinstance(v, (str, int, float, bool)) else str(v))
                    for k, v in sorted(sp["args"].items())
                }
                ts = (sp["t0"] - base) * 1e6
                dur = (sp["t1"] - sp["t0"]) * 1e6
                if dur <= 0.0:
                    events.append({"args": args, "name": sp["name"],
                                   "ph": "i", "pid": pid, "s": "t",
                                   "tid": tid, "ts": ts})
                else:
                    events.append({"args": args, "dur": dur,
                                   "name": sp["name"], "ph": "X",
                                   "pid": pid, "tid": tid, "ts": ts})
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def save_chrome_trace(self, path: str) -> int:
        """Write the Chrome-trace JSON to `path`; returns event count."""
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True)
        return len(doc["traceEvents"])


class Tracer:
    """Per-request span recorder.  `sample_rate` in [0, 1] picks requests
    DETERMINISTICALLY (a fractional accumulator, no RNG — rate 0.5 traces
    every second request, run-to-run stable); `max_spans` bounds one
    request's tree (overflow increments `dropped_spans`, stack discipline
    preserved); completed trees land in `recorder` (bounded ring).  The
    clock is injectable so property tests can drive a counter clock and
    assert exact nesting."""

    def __init__(self, capacity: int = 64, sample_rate: float = 1.0,
                 max_spans: int = 4096, clock=time.perf_counter):
        self.sample_rate = min(1.0, max(0.0, float(sample_rate)))
        self.max_spans = max_spans
        self.clock = clock
        self.recorder = FlightRecorder(capacity)
        self._live: Dict[int, RequestTrace] = {}
        self._acc = 0.0  # deterministic sampling accumulator
        self.sampled = 0
        self.skipped = 0

    # -- lifecycle ---------------------------------------------------------
    def start(self, req_id: int, tenant: str, table: str,
              t0: Optional[float] = None, **attrs) -> Optional[RequestTrace]:
        """Open a request's root span at admission; None when the sampler
        skips this request (all later lookups no-op on None)."""
        self._acc += self.sample_rate
        if self._acc < 1.0:
            self.skipped += 1
            return None
        self._acc -= 1.0
        rt = RequestTrace(req_id, tenant, table,
                          self.clock() if t0 is None else t0, attrs)
        self._live[req_id] = rt
        self.sampled += 1
        return rt

    def live(self, req_id: int) -> Optional[RequestTrace]:
        return self._live.get(req_id)

    def has_live(self) -> bool:
        return bool(self._live)

    def finish(self, req_id: int, status: str, **attrs) -> Optional[RequestTrace]:
        """Close the root span at the request's terminal tick, force-close
        anything an error path left open, compute the stage-attribution
        summary and push the trace into the flight recorder."""
        rt = self._live.pop(req_id, None)
        if rt is None:
            return None
        self.end_wait(rt)
        while len(rt.stack) > 1:  # error paths may leave spans open
            self.end(rt)
        now = self.clock()
        root = rt.root
        root["t1"] = max(now, root["t0"])
        root["args"].update(attrs)
        root["args"]["status"] = status
        rt.status = status
        rt.summary = self._summarize(rt)
        self.recorder.add(rt)
        return rt

    # -- span ops (all take the RequestTrace; None-safe at call sites) -----
    def begin(self, rt: RequestTrace, name: str, **attrs) -> None:
        if rt.n_spans >= self.max_spans:
            rt.dropped_spans += 1
            rt.drop_depth += 1  # the matching end() must not pop a real span
            return
        sp = _span(name, self.clock(), attrs)
        rt.stack[-1]["children"].append(sp)
        rt.stack.append(sp)
        rt.n_spans += 1

    def end(self, rt: RequestTrace, name: Optional[str] = None, **attrs) -> None:
        """Close the innermost open span.  With `name`, pop (and close at
        the same instant) any deeper spans an exception left open until
        that span is closed — keeps the tree well-formed on error paths."""
        if rt.drop_depth > 0:
            rt.drop_depth -= 1
            return
        now = self.clock()
        while len(rt.stack) > 1:
            sp = rt.stack.pop()
            sp["t1"] = max(now, sp["t0"])
            if name is None or sp["name"] == name:
                sp["args"].update(attrs)
                return
        # underflow (unmatched end): ignore rather than corrupt the root

    def event(self, rt: RequestTrace, name: str, **attrs) -> None:
        """Zero-duration instant (store_hit / evict / sim_fetch) attached
        to the innermost open span."""
        if rt.n_spans >= self.max_spans:
            rt.dropped_spans += 1
            return
        now = self.clock()
        sp = _span(name, now, attrs)
        sp["t1"] = now
        rt.stack[-1]["children"].append(sp)
        rt.n_spans += 1

    def add_span(self, rt: RequestTrace, name: str, t0: float, t1: float,
                 **attrs) -> None:
        """Attach an already-closed span (e.g. admission, timed inline)."""
        if rt.n_spans >= self.max_spans:
            rt.dropped_spans += 1
            return
        sp = _span(name, t0, attrs)
        sp["t1"] = max(t1, t0)
        rt.stack[-1]["children"].append(sp)
        rt.n_spans += 1

    # -- wait-state machine (queued time, attributed by WHY) ---------------
    def wait(self, rt: RequestTrace, kind: str, **attrs) -> None:
        """The request is waiting this tick — `kind` is "wfq_wait" or
        "hold_window".  Consecutive same-kind ticks extend the open span
        (its `ticks` arg counts them); a kind switch closes the old span
        and opens the new one."""
        if rt.wait_kind == kind:
            top = rt.stack[-1]
            if top["name"] == kind:
                top["args"]["ticks"] = top["args"].get("ticks", 0) + 1
            return
        self.end_wait(rt)
        self.begin(rt, kind, ticks=1, **attrs)
        rt.wait_kind = kind

    def end_wait(self, rt: RequestTrace) -> None:
        """Close any open wait span — the scheduler calls this right
        before dispatching a slice, so wait time and slice time can never
        overlap (the stage-sum <= wall invariant depends on it)."""
        if rt.wait_kind is not None:
            self.end(rt, name=rt.wait_kind)
            rt.wait_kind = None

    # -- attribution -------------------------------------------------------
    def _summarize(self, rt: RequestTrace) -> dict:
        stages = {s: 0.0 for s in STAGES}

        def walk(sp: dict) -> None:
            stage = STAGE_OF.get(sp["name"])
            if stage is not None and sp["t1"] is not None:
                stages[stage] += sp["t1"] - sp["t0"]
                return  # never double-bill a mapped span's children
            for c in sp["children"]:
                walk(c)

        for c in rt.root["children"]:
            walk(c)
        wall = rt.root["t1"] - rt.root["t0"]
        decode_pct = 100.0 * stages["decode"] / wall if wall > 0 else 0.0
        filter_pct = 100.0 * stages["filter"] / wall if wall > 0 else 0.0
        args = rt.root["args"]
        return {
            "req_id": rt.req_id,
            "tenant": rt.tenant,
            "table": rt.table,
            "status": rt.status,
            "submitted_tick": args.get("submitted_tick", 0),
            "done_tick": args.get("done_tick", 0),
            "mode": args.get("mode", ""),
            "held_ticks": args.get("held_ticks", 0),
            "wall_s": wall,
            "stages_s": dict(sorted(stages.items())),
            "attributed_s": sum(stages.values()),
            "decode_pct": decode_pct,
            "filter_pct": filter_pct,
            "rest_pct": max(0.0, 100.0 - decode_pct - filter_pct),
            "spans": rt.n_spans,
            "dropped_spans": rt.dropped_spans,
        }

    def report(self) -> dict:
        """The recorder's stage-attribution report plus sampler state."""
        out = {
            "enabled": True,
            "sample_rate": self.sample_rate,
            "sampled": self.sampled,
            "skipped": self.skipped,
            "live": len(self._live),
        }
        out.update(self.recorder.report())
        return dict(sorted(out.items()))


# ---------------------------------------------------------------------------
# module-level slice context — how the engine/blockstore emit spans without
# a plumbed-through tracer argument
# ---------------------------------------------------------------------------
# The scheduler sets (_CUR_TRACER, _CUR) around each dispatched slice; the
# engine's hot loops gate on `trace._CUR is None` (one attribute load, no
# allocation) before building span kwargs.  Deterministically single-
# threaded by construction (DESIGN.md §7), so one slot suffices.
_CUR: Optional[RequestTrace] = None
_CUR_TRACER: Optional[Tracer] = None


def set_slice(tracer: Optional[Tracer], rt: Optional[RequestTrace]) -> None:
    """Publish (or clear, with Nones) the request whose slice is executing."""
    global _CUR, _CUR_TRACER
    _CUR, _CUR_TRACER = rt, tracer


def begin(name: str, **attrs) -> None:
    if _CUR is not None:
        _CUR_TRACER.begin(_CUR, name, **attrs)


def end(name: Optional[str] = None, **attrs) -> None:
    if _CUR is not None:
        _CUR_TRACER.end(_CUR, name=name, **attrs)


def event(name: str, **attrs) -> None:
    if _CUR is not None:
        _CUR_TRACER.event(_CUR, name, **attrs)
