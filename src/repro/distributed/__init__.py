"""Distributed runtime: sharding rules, collectives, fault tolerance."""

from repro.distributed.fault_tolerance import (  # noqa: F401
    HeartbeatMonitor,
    PodDrainPlan,
    StragglerDetector,
    plan_pod_drain,
)
from repro.distributed.sharding import (  # noqa: F401
    HashRing,
    ShardingCtx,
    constrain,
    local_ctx,
    rg_key,
    spec_for,
)
