"""Distributed runtime: sharding rules, collectives, fault tolerance."""

from repro.distributed.sharding import (  # noqa: F401
    ShardingCtx,
    constrain,
    local_ctx,
    spec_for,
)
