"""Distributed-optimization collectives.

1. `hierarchical_psum` — topology-aware gradient reduction for the
   (pod, data, model) mesh: reduce-scatter over the fast intra-pod ICI
   axis, all-reduce only the 1/N shard over the slow cross-pod DCN axis,
   then all-gather intra-pod.  Cross-pod bytes drop by the data-axis size
   (16x here) versus a flat all-reduce.

2. `compressed_psum` — int8-quantized cross-pod all-reduce with error
   feedback: q = round((g+err)/scale); the residual feeds back into the
   next step, so quantization error accumulates to zero over time instead
   of biasing the trajectory.  Cross-pod bytes drop 4x (f32->i8).

Both are expressed with shard_map + jax.lax collectives (the JAX-native
mapping of the NCCL patterns, per the hardware-adaptation brief) and are
unit-tested for exactness/convergence on an 8-device host mesh.  GSPMD
inserts plain all-reduces on its own; these are the *explicit-DP* variants
a production launcher swaps in for the cross-pod hop (used by
make_compressed_dp_fn below).
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import axis_size, shard_map_nocheck


# ---------------------------------------------------------------------------
# hierarchical psum (inside shard_map over ('pod','data'))
# ---------------------------------------------------------------------------


def hierarchical_psum(x: jax.Array, intra_axis: str, inter_axis: str) -> jax.Array:
    """Sum over both axes; cross-`inter_axis` traffic is 1/size(intra)."""
    n = axis_size(intra_axis)
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    shard = jax.lax.psum_scatter(xp, intra_axis, scatter_dimension=0, tiled=True)
    shard = jax.lax.psum(shard, inter_axis)  # only 1/n of bytes cross pods
    full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
    return full[: x.shape[0]] if pad else full


# ---------------------------------------------------------------------------
# int8 compressed psum with error feedback
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (q int8, scale f32 scalar, new_err)."""
    comb = x.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(comb)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(comb / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, comb - deq


def compressed_psum(x: jax.Array, err: jax.Array, axis: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8 all-gather + local dequant-sum over `axis` (error feedback).

    Bytes on the wire: n*size(int8) vs ring-all-reduce 2*size(f32) — a 8x
    reduction at n=2 pods.  Returns (summed f32, new local error)."""
    q, scale, new_err = quantize_int8(x, err)
    qs = jax.lax.all_gather(q, axis)  # (n, ...)
    ss = jax.lax.all_gather(scale, axis)  # (n,)
    total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0))
    return total, new_err


# ---------------------------------------------------------------------------
# explicit-DP wrapper: per-pod grads -> compressed cross-pod reduction
# ---------------------------------------------------------------------------


def make_compressed_dp_fn(grad_fn: Callable, mesh: Mesh, pod_axis: str = "pod"):
    """Wrap a per-shard gradient function with int8 cross-pod reduction.

    grad_fn(batch_shard) -> grads pytree (local).  Returns fn(batch, err)
    -> (summed grads, new err) under shard_map over the pod axis."""

    def inner(batch, err):
        g = grad_fn(batch)
        flat_g, tdef = jax.tree.flatten(g)
        flat_e = jax.tree.leaves(err)
        out, errs = [], []
        for gl, el in zip(flat_g, flat_e):
            s, ne = compressed_psum(gl, el, pod_axis)
            out.append(s)
            errs.append(ne)
        return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, errs)

    # replication checking off (jax names the flag check_vma or check_rep
    # depending on version — the compat shim picks the right one): the
    # error-feedback state is intentionally per-shard, not replicated
    return shard_map_nocheck(
        inner, mesh, (P(pod_axis), P()), (P(), P()),
    )
