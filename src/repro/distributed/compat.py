"""jax version-compat shims for the distributed runtime.

The distributed code targets the stable mesh/shard_map surface newer jax
exposes at the top level (`jax.shard_map`, `jax.set_mesh`, mesh axis
types), but the pinned jax here still spells those
`jax.experimental.shard_map` (with `check_rep` instead of `check_vma`)
and enters a mesh through the `Mesh` context manager.  Everything that
needs one of the moved APIs goes through this module so the version
split lives in exactly one place.
"""

from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import Mesh

try:  # jax >= 0.6 moved shard_map to the top level
    shard_map: Callable = jax.shard_map
    _NOCHECK = {"check_vma": False}
except AttributeError:  # pragma: no cover - version-dependent branch
    from jax.experimental.shard_map import shard_map  # type: ignore

    _NOCHECK = {"check_rep": False}


def shard_map_nocheck(f, mesh, in_specs, out_specs):
    """`shard_map` with replication checking off — the flag newer jax
    names `check_vma` and older jax `check_rep`."""
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **_NOCHECK)


def axis_size(name) -> int:
    """Size of a named mesh axis from inside shard_map — `jax.lax.
    axis_size` where it exists, else `psum(1, name)`, which older jax
    constant-folds to the same static size."""
    size = getattr(jax.lax, "axis_size", None)
    if size is not None:
        return size(name)
    return jax.lax.psum(1, name)


def make_mesh(axis_shapes, axis_names) -> Mesh:
    """`jax.make_mesh` minus the `axis_types` kwarg newer callers pass:
    explicitly-Auto axes are the default everywhere, and older jax has no
    `jax.sharding.AxisType` to spell them with."""
    return jax.make_mesh(axis_shapes, axis_names)


def use_mesh(mesh: Mesh):
    """Context manager making `mesh` current: `jax.set_mesh` on newer
    jax, the `Mesh` context manager on older."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh
