"""Fault tolerance at 1000+-node posture: heartbeats, stragglers, elasticity.

No real cluster exists in this container, so the *policies* are built and
tested against simulated telemetry, and the *mechanisms* they trigger
(checkpoint restore, elastic re-mesh) are real and tested:

  HeartbeatMonitor  — declares hosts dead after `timeout_s` silence;
                      produces a RestartPlan (same-size restart if spares
                      exist, else shrink to the largest feasible mesh)
  StragglerDetector — robust per-step timing stats (median + MAD); flags
                      hosts slower than `factor` x median; policy choices:
                      'observe' | 'skip_batch' (drop the straggler's
                      microbatch that step) | 'evict' (treat as failed)
  plan_elastic_mesh — largest (data, model) mesh fitting the survivors,
                      keeping the model axis (TP needs full shards — you
                      shrink DP, never TP)

CheckpointManager.restore_latest + distributed.sharding re-spec the arrays
onto whatever mesh the plan selects (tests/test_fault_tolerance.py runs a
kill -> shrink -> resume cycle on host devices).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class RestartPlan:
    dead_hosts: List[str]
    surviving_hosts: List[str]
    action: str  # 'none' | 'restart_same' | 'shrink'
    new_mesh: Optional[Tuple[int, int]] = None  # (data, model)


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], timeout_s: float = 60.0,
                 spares: int = 0, clock=time.monotonic):
        self.timeout = timeout_s
        self.spares = spares
        self.clock = clock
        self.last_seen: Dict[str, float] = {h: clock() for h in hosts}

    def beat(self, host: str, at: Optional[float] = None):
        self.last_seen[host] = self.clock() if at is None else at

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]

    def plan(self, mesh_shape: Tuple[int, int]) -> RestartPlan:
        dead = self.dead_hosts()
        alive = [h for h in self.last_seen if h not in dead]
        if not dead:
            return RestartPlan([], alive, "none")
        if len(dead) <= self.spares:
            return RestartPlan(dead, alive, "restart_same", mesh_shape)
        new_mesh = plan_elastic_mesh(len(alive), mesh_shape)
        return RestartPlan(dead, alive, "shrink", new_mesh)


def plan_elastic_mesh(n_hosts_alive: int, old_mesh: Tuple[int, int],
                      chips_per_host: int = 4) -> Tuple[int, int]:
    """Largest (data, model) mesh on surviving chips; model axis preserved
    (TP shards are not divisible), data axis shrinks to the largest
    power-of-two that fits."""
    data, model = old_mesh
    chips = n_hosts_alive * chips_per_host
    max_data = max(1, chips // model)
    new_data = 1
    while new_data * 2 <= max_data:
        new_data *= 2
    return (new_data, model)


@dataclasses.dataclass
class PodDrainPlan:
    """What the scan fabric must do when a pod dies (DESIGN.md §15).

    `reassigned` maps each row-group key the dead pod owned to its new
    owner on the post-removal ring; `replay` lists the in-flight scan ids
    that had uncollected work on the dead pod and must re-submit their
    remaining row groups to the survivors.  Collected sub-results are
    fabric-held and survive — replay granularity is the pod sub-scan, so
    a scan resumes from its last *completed* slice, never from scratch."""

    dead: str
    survivors: List[str]
    reassigned: Dict[str, str]  # row-group key -> new owner pod
    replay: List[object]        # in-flight scan ids to re-submit


def plan_pod_drain(dead: str, ring, owned_keys: List[str],
                   in_flight: List[object]) -> PodDrainPlan:
    """Drain a dead pod: remove it from the ring (minimal moved arc —
    only ITS keys re-home), then map every key it owned to the survivor
    that now owns it.  `ring` is mutated (the fabric's live ring).
    Raises if the dead pod was the last one: there is nowhere to drain."""
    ring.remove_node(dead)
    if not ring.nodes:
        raise RuntimeError(f"pod {dead!r} was the last node; cannot drain")
    reassigned = {k: ring.owner(k) for k in owned_keys}
    assert all(o != dead for o in reassigned.values())
    return PodDrainPlan(
        dead=dead,
        survivors=list(ring.nodes),
        reassigned=reassigned,
        replay=list(in_flight),
    )


class StragglerDetector:
    def __init__(self, factor: float = 2.0, min_samples: int = 5,
                 policy: str = "observe"):
        self.factor = factor
        self.min_samples = min_samples
        self.policy = policy
        self.times: Dict[str, List[float]] = {}

    def record(self, host: str, step: int, seconds: float):
        self.times.setdefault(host, []).append(seconds)

    def stragglers(self) -> List[str]:
        if not self.times:
            return []
        recent = {h: ts[-self.min_samples:] for h, ts in self.times.items()
                  if len(ts) >= self.min_samples}
        if not recent:
            return []
        med = statistics.median(v for ts in recent.values() for v in ts)
        return [h for h, ts in recent.items()
                if statistics.median(ts) > self.factor * med]

    def action_for(self, host: str) -> str:
        if host not in self.stragglers():
            return "none"
        return {"observe": "log", "skip_batch": "skip_batch", "evict": "evict"}[self.policy]

    def report(self) -> dict:
        out = {}
        for h, ts in self.times.items():
            out[h] = {
                "n": len(ts),
                "median_s": statistics.median(ts),
                "p_max_s": max(ts),
            }
        out["stragglers"] = self.stragglers()
        return out
