"""Sharding rules: logical tensor dims -> mesh PartitionSpecs, plus the
datapath fabric's consistent-hash ring (`HashRing`) mapping row groups to
pod owners.

Every tensor in the framework is described by *logical* dims ('batch',
'seq', 'd', 'ff', 'heads', 'vocab', 'experts', ...).  `spec_for` maps them
onto the production mesh (pod, data, model):

  batch    -> (pod, data)   pure DP across pods + DP within a pod
  vocab/ff/heads/experts -> model   (TP / EP)
  d/hd_out -> data          (FSDP: parameters sharded over the data axis,
                             all-gathered by GSPMD at use — ZeRO-3)
  seq      -> model ONLY when requested ('seq_tp': sequence-parallel
              attention / flash-decode KV sharding)

JAX requires annotated dims to divide the axis size, so every rule is
guarded: a non-divisible dim silently degrades to replicated (the
divisibility-driven choice between head-parallel and sequence-parallel
attention is made by the model layer, see models/layers.py).

`constrain` applies jax.lax.with_sharding_constraint when a mesh is
active, and is a no-op in single-device smoke tests.
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical dim -> mesh axis role
_TP_DIMS = frozenset({"vocab", "ff", "heads", "kv", "experts", "moe_ff", "inner", "seq_tp", "state_tp"})
_FSDP_DIMS = frozenset({"d", "fsdp"})
_DP_DIMS = frozenset({"batch"})


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)  # ('pod','data') on the multi-pod mesh
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    # Activation-sharding strategy (§Perf lever; params always stay sharded):
    #  'tp'      Megatron: activations TP-sharded on ff/heads, per-layer
    #            all-reduces of (B_local, S, D)   [baseline]
    #  'fsdp'    ZeRO-3: batch sharded over (dp x model), weights gathered
    #            per layer, NO activation all-reduces
    #  'fsdp_ep' as 'fsdp' but batch stays on dp only (MoE: the model axis
    #            carries expert parallelism via shard_map)
    strategy: str = "tp"

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def dp(self) -> int:
        return self.axis_size(self.dp_axes)

    @property
    def tp(self) -> int:
        return self.axis_size(self.tp_axis)


def local_ctx() -> ShardingCtx:
    """No-mesh context for single-device smoke tests."""
    return ShardingCtx(mesh=None)


def _axis_for(dim: Optional[str], ctx: ShardingCtx, activation: bool = False):
    """Mesh axis (or candidate tuple list for batch) for a logical dim.

    Params (activation=False) always keep storage sharding regardless of
    strategy; activation constraints are strategy-dependent."""
    if dim is None:
        return None
    if dim in _DP_DIMS:
        if activation and ctx.strategy in ("fsdp", "fsdp_ep"):
            # widest-first candidates; spec_for picks the first divisible.
            # (fsdp_ep: the MoE shard_map re-shards its own inputs to
            # dp-only at its boundary, dense sublayers stay wide)
            return [tuple(ctx.dp_axes) + (ctx.tp_axis,), tuple(ctx.dp_axes),
                    (ctx.dp_axes[-1],)]
        return [tuple(ctx.dp_axes), (ctx.dp_axes[-1],)]
    if dim in _TP_DIMS:
        if activation and ctx.strategy in ("fsdp", "fsdp_ep") and dim != "seq_tp":
            return None  # ZeRO: no TP activation sharding (caches keep seq_tp)
        return ctx.tp_axis
    if dim in _FSDP_DIMS:
        return ctx.fsdp_axis
    return None


def spec_for(dims: Sequence[Optional[str]], ctx: ShardingCtx,
             shape: Optional[Sequence[int]] = None, activation: bool = False) -> P:
    """PartitionSpec for logical dims, dropping non-divisible annotations."""
    if not ctx.enabled:
        return P()
    entries = []
    for i, dim in enumerate(dims):
        ax = _axis_for(dim, ctx, activation)
        if isinstance(ax, list):  # candidate tuples, widest first
            chosen = None
            for cand in ax:
                if shape is None or shape[i] % ctx.axis_size(cand) == 0:
                    chosen = cand if len(cand) > 1 else cand[0]
                    break
            ax = chosen
        elif ax is not None and shape is not None:
            if shape[i] % ctx.axis_size(ax) != 0:
                ax = None  # degrade to replicated
        entries.append(ax)
    return P(*entries)


def sharding_for(dims, ctx: ShardingCtx, shape=None, activation: bool = False
                 ) -> Optional[NamedSharding]:
    if not ctx.enabled:
        return None
    return NamedSharding(ctx.mesh, spec_for(dims, ctx, shape, activation))


def constrain(x: jax.Array, dims: Sequence[Optional[str]], ctx: ShardingCtx) -> jax.Array:
    """with_sharding_constraint on logical dims (no-op without a mesh).
    Activation path: strategy-aware."""
    if not ctx.enabled:
        return x
    spec = spec_for(dims, ctx, x.shape, activation=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def rg_key(path: str, rg: int) -> str:
    """Canonical ring key for a row group: ownership is per (table file,
    row group), so one table's groups spread across the whole fleet."""
    return f"{path}#rg{rg}"


class HashRing:
    """Consistent-hash ring mapping keys -> node ids (fabric pods).

    Each node is hashed onto the ring at `replicas` virtual points
    (sha1 of "node#i" — NEVER Python `hash()`, which is salted per
    process and would re-shuffle ownership on every restart).  A key
    is owned by the first virtual point clockwise from its hash.

    Properties the fabric relies on (tests/test_sharding_ring.py):
      * deterministic: same nodes -> same ownership, any process
      * minimal movement: removing a node re-homes ONLY the arcs that
        node owned; adding one steals only the arcs it now owns —
        every other key keeps its owner (the drain/replay path re-hashes
        a dead pod's row groups without touching survivors' caches)
      * balanced: virtual points smooth per-node load to ~1/N
    """

    def __init__(self, nodes: Iterable[str] = (), replicas: int = 64):
        assert replicas >= 1
        self.replicas = replicas
        self._points: List[int] = []       # sorted virtual-point hashes
        self._owner_at: Dict[int, str] = {}  # point hash -> node id
        self.nodes: List[str] = []
        for n in nodes:
            self.add_node(n)

    @staticmethod
    def _hash(s: str) -> int:
        return int.from_bytes(hashlib.sha1(s.encode()).digest()[:8], "big")

    def _vpoints(self, node: str) -> List[int]:
        return [self._hash(f"{node}#{i}") for i in range(self.replicas)]

    def add_node(self, node: str):
        if node in self.nodes:
            return
        self.nodes.append(node)
        for h in self._vpoints(node):
            # sha1 collisions across 8 bytes are not a practical concern;
            # last-add wins keeps the structure consistent regardless
            if h not in self._owner_at:
                bisect.insort(self._points, h)
            self._owner_at[h] = node

    def remove_node(self, node: str):
        if node not in self.nodes:
            return
        self.nodes.remove(node)
        for h in self._vpoints(node):
            if self._owner_at.get(h) == node:
                del self._owner_at[h]
                i = bisect.bisect_left(self._points, h)
                if i < len(self._points) and self._points[i] == h:
                    del self._points[i]

    def owner(self, key: str) -> str:
        if not self._points:
            raise ValueError("HashRing has no nodes")
        h = self._hash(key)
        i = bisect.bisect_right(self._points, h)
        if i == len(self._points):
            i = 0  # wrap: first point clockwise
        return self._owner_at[self._points[i]]

    def owners(self, keys: Iterable[str]) -> Dict[str, str]:
        return {k: self.owner(k) for k in keys}


def tree_shardings(param_dims, ctx: ShardingCtx, param_shapes):
    """Map a pytree of logical-dims tuples + matching shapes -> NamedShardings."""
    return jax.tree.map(
        lambda dims, shp: sharding_for(dims, ctx, shp.shape if hasattr(shp, "shape") else shp),
        param_dims,
        param_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
