"""Sharding rules: logical tensor dims -> mesh PartitionSpecs.

Every tensor in the framework is described by *logical* dims ('batch',
'seq', 'd', 'ff', 'heads', 'vocab', 'experts', ...).  `spec_for` maps them
onto the production mesh (pod, data, model):

  batch    -> (pod, data)   pure DP across pods + DP within a pod
  vocab/ff/heads/experts -> model   (TP / EP)
  d/hd_out -> data          (FSDP: parameters sharded over the data axis,
                             all-gathered by GSPMD at use — ZeRO-3)
  seq      -> model ONLY when requested ('seq_tp': sequence-parallel
              attention / flash-decode KV sharding)

JAX requires annotated dims to divide the axis size, so every rule is
guarded: a non-divisible dim silently degrades to replicated (the
divisibility-driven choice between head-parallel and sequence-parallel
attention is made by the model layer, see models/layers.py).

`constrain` applies jax.lax.with_sharding_constraint when a mesh is
active, and is a no-op in single-device smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical dim -> mesh axis role
_TP_DIMS = frozenset({"vocab", "ff", "heads", "kv", "experts", "moe_ff", "inner", "seq_tp", "state_tp"})
_FSDP_DIMS = frozenset({"d", "fsdp"})
_DP_DIMS = frozenset({"batch"})


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)  # ('pod','data') on the multi-pod mesh
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    # Activation-sharding strategy (§Perf lever; params always stay sharded):
    #  'tp'      Megatron: activations TP-sharded on ff/heads, per-layer
    #            all-reduces of (B_local, S, D)   [baseline]
    #  'fsdp'    ZeRO-3: batch sharded over (dp x model), weights gathered
    #            per layer, NO activation all-reduces
    #  'fsdp_ep' as 'fsdp' but batch stays on dp only (MoE: the model axis
    #            carries expert parallelism via shard_map)
    strategy: str = "tp"

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def dp(self) -> int:
        return self.axis_size(self.dp_axes)

    @property
    def tp(self) -> int:
        return self.axis_size(self.tp_axis)


def local_ctx() -> ShardingCtx:
    """No-mesh context for single-device smoke tests."""
    return ShardingCtx(mesh=None)


def _axis_for(dim: Optional[str], ctx: ShardingCtx, activation: bool = False):
    """Mesh axis (or candidate tuple list for batch) for a logical dim.

    Params (activation=False) always keep storage sharding regardless of
    strategy; activation constraints are strategy-dependent."""
    if dim is None:
        return None
    if dim in _DP_DIMS:
        if activation and ctx.strategy in ("fsdp", "fsdp_ep"):
            # widest-first candidates; spec_for picks the first divisible.
            # (fsdp_ep: the MoE shard_map re-shards its own inputs to
            # dp-only at its boundary, dense sublayers stay wide)
            return [tuple(ctx.dp_axes) + (ctx.tp_axis,), tuple(ctx.dp_axes),
                    (ctx.dp_axes[-1],)]
        return [tuple(ctx.dp_axes), (ctx.dp_axes[-1],)]
    if dim in _TP_DIMS:
        if activation and ctx.strategy in ("fsdp", "fsdp_ep") and dim != "seq_tp":
            return None  # ZeRO: no TP activation sharding (caches keep seq_tp)
        return ctx.tp_axis
    if dim in _FSDP_DIMS:
        return ctx.fsdp_axis
    return None


def spec_for(dims: Sequence[Optional[str]], ctx: ShardingCtx,
             shape: Optional[Sequence[int]] = None, activation: bool = False) -> P:
    """PartitionSpec for logical dims, dropping non-divisible annotations."""
    if not ctx.enabled:
        return P()
    entries = []
    for i, dim in enumerate(dims):
        ax = _axis_for(dim, ctx, activation)
        if isinstance(ax, list):  # candidate tuples, widest first
            chosen = None
            for cand in ax:
                if shape is None or shape[i] % ctx.axis_size(cand) == 0:
                    chosen = cand if len(cand) > 1 else cand[0]
                    break
            ax = chosen
        elif ax is not None and shape is not None:
            if shape[i] % ctx.axis_size(ax) != 0:
                ax = None  # degrade to replicated
        entries.append(ax)
    return P(*entries)


def sharding_for(dims, ctx: ShardingCtx, shape=None, activation: bool = False
                 ) -> Optional[NamedSharding]:
    if not ctx.enabled:
        return None
    return NamedSharding(ctx.mesh, spec_for(dims, ctx, shape, activation))


def constrain(x: jax.Array, dims: Sequence[Optional[str]], ctx: ShardingCtx) -> jax.Array:
    """with_sharding_constraint on logical dims (no-op without a mesh).
    Activation path: strategy-aware."""
    if not ctx.enabled:
        return x
    spec = spec_for(dims, ctx, x.shape, activation=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def tree_shardings(param_dims, ctx: ShardingCtx, param_shapes):
    """Map a pytree of logical-dims tuples + matching shapes -> NamedShardings."""
    return jax.tree.map(
        lambda dims, shp: sharding_for(dims, ctx, shp.shape if hasattr(shp, "shape") else shp),
        param_dims,
        param_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
