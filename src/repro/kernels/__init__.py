"""Pallas TPU kernels for the datapath engine + consumer hot spots.

Each kernel <name>.py carries a pl.pallas_call with explicit BlockSpec VMEM
tiling; ref.py holds the pure-jnp oracles; ops.py is the public dispatching
API.  See kernels/EXAMPLE.md and DESIGN.md §4.
"""

from repro.kernels import ops, ref  # noqa: F401
