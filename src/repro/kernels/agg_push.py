"""Pallas TPU kernels: fused operator pushdown — decode→aggregate.

The paper's SmartNIC wins by operating on data in the datapath; these
kernels extend that from filter+compact to aggregation (DESIGN.md §16).
Two entry points, both batched over pages stacked along the block axis
so many row groups share ONE launch per (encoding, k, dtype) bucket:

  grouped_agg_pallas   decoded value blocks + pre-decode int group ids
                       (a DICT/string column's codes) + survivor mask ->
                       per-block partial accumulators (count / hi-lo
                       split sums / min / max), each (nblocks, n_groups)
  fused_agg_pallas     BITPACK pages -> in-kernel unpack ladder -> masked
                       ungrouped aggregate; the value column NEVER exists
                       outside VMEM — the result DMA is (nblocks, 1)
                       accumulators instead of (nblocks, 4096) values

Both mirror `kernels/ref.py` `grouped_agg` op-for-op (the kernel bodies
call the same block math), so parity is exact and every reduction stays
within a block: grid steps, bucket splits, row-group slices and pod
shards all produce bit-identical partial rows, and the host-side int64 /
float64 merge (core/agg.py) is order-independent by exactness.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitunpack import _ladder
from repro.kernels.ref import grouped_agg
from repro.lakeformat.encodings import LANES, PACK_BLOCK

# (group, 4096, n_groups) one-hot intermediates bound VMEM: group=2 at
# the MAX_GROUPS ceiling stays ~4 MB per intermediate
DEFAULT_GROUP = 2
MAX_GROUPS = 128  # pushdown eligibility ceiling (engine falls back above)


def _out_shapes(nblocks: int, n_groups: int, vdtype):
    """ShapeDtypeStructs for the 5 accumulator planes (cnt/s0/s1/mn/mx)."""
    sum_dt = jnp.float32 if jnp.issubdtype(vdtype, jnp.floating) else jnp.int32
    dts = (jnp.int32, sum_dt, jnp.int32, vdtype, vdtype)
    return [jax.ShapeDtypeStruct((nblocks, n_groups), dt) for dt in dts]


def _grouped_kernel(n_groups, vals_ref, gids_ref, mask_ref,
                    cnt_ref, s0_ref, s1_ref, mn_ref, mx_ref):
    cnt, s0, s1, mn, mx = grouped_agg(
        vals_ref[...], gids_ref[...], mask_ref[...], n_groups
    )
    cnt_ref[...], s0_ref[...], s1_ref[...] = cnt, s0, s1
    mn_ref[...], mx_ref[...] = mn, mx


@functools.partial(jax.jit, static_argnames=("n_groups", "group", "interpret"))
def grouped_agg_pallas(
    values: jax.Array,
    gids: jax.Array,
    mask: jax.Array,
    n_groups: int,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
):
    """values (nblocks, 4096) int32|float32; gids/mask (nblocks, 4096)
    int32 -> 5 x (nblocks, n_groups): cnt, s0, s1, mn, mx (ref.grouped_agg
    layout).  Padded blocks carry mask == 0, so their rows are exact merge
    identities and the caller can simply drop them."""
    nblocks = values.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        gids = jnp.pad(gids, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))  # zeros -> identity rows
    steps = values.shape[0] // group
    out_shape = _out_shapes(values.shape[0], n_groups, values.dtype)
    spec = pl.BlockSpec((group, PACK_BLOCK), lambda i: (i, 0))
    gspec = pl.BlockSpec((group, n_groups), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_grouped_kernel, n_groups),
        grid=(steps,),
        in_specs=[spec, spec, spec],
        out_specs=[gspec] * 5,
        out_shape=out_shape,
        interpret=interpret,
    )(values, gids.astype(jnp.int32), mask.astype(jnp.int32))
    return tuple(o[:nblocks] for o in outs)


def _fused_kernel(k, packed_ref, mask_ref,
                  cnt_ref, s0_ref, s1_ref, mn_ref, mx_ref):
    vals = _ladder(packed_ref[...], k)  # (G, 32, 128) int32, in VMEM only
    vals = vals.reshape(vals.shape[0], PACK_BLOCK)
    gids = jnp.zeros(vals.shape, jnp.int32)
    cnt, s0, s1, mn, mx = grouped_agg(vals, gids, mask_ref[...], 1)
    cnt_ref[...], s0_ref[...], s1_ref[...] = cnt, s0, s1
    mn_ref[...], mx_ref[...] = mn, mx


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def fused_agg_pallas(
    packed: jax.Array,
    k: int,
    mask: jax.Array,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
):
    """packed (nblocks, k, 128) uint32 BITPACK pages + mask (nblocks,
    4096) int32 -> 5 x (nblocks, 1) accumulators, decode fused in-kernel
    (the flagship never-materialize path)."""
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    steps = packed.shape[0] // group
    out_shape = _out_shapes(packed.shape[0], 1, jnp.int32)
    gspec = pl.BlockSpec((group, 1), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_fused_kernel, k),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((group, PACK_BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[gspec] * 5,
        out_shape=out_shape,
        interpret=interpret,
    )(packed, mask.astype(jnp.int32))
    return tuple(o[:nblocks] for o in outs)
