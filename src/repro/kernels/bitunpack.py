"""Pallas TPU kernel: lane-transposed k-bit unpack.

This is the shared "decode core" of the datapath engine (DESIGN.md §4) —
the TPU stand-in for the SmartNIC's line-rate Parquet decoder.  The layout
(lakeformat/encodings.py) was designed so this kernel is gather-free:

  packed block (k, 128) uint32  ->  values block (32, 128) int32

with per-row *static* shifts, i.e. 32 unrolled VPU shift/or/and ops per
block of 4096 values.  Arithmetic intensity: reads 4*k bytes, writes 4*32
bytes per lane per block -> the kernel is purely HBM-bandwidth-bound, which
is exactly the property the paper wants from a datapath decoder (decode at
"line rate" = HBM rate, upstream of the consumer).

Grid: one step per group of GROUP blocks; BlockSpec stages
(GROUP, k, 128) packed words into VMEM and (GROUP, 32, 128) values out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.lakeformat.encodings import LANES, SUBLANES

DEFAULT_GROUP = 8


def _ladder(p: jax.Array, k: int) -> jax.Array:
    """(G, k, 128) uint32 -> (G, 32, 128) int32; statically unrolled."""
    if k == 32:
        return p.astype(jnp.int32)
    mask = jnp.uint32((1 << k) - 1)
    rows = []
    for s in range(SUBLANES):
        w0, sh = divmod(s * k, 32)
        val = jax.lax.shift_right_logical(p[:, w0, :], jnp.uint32(sh))
        if sh + k > 32:
            val = val | jax.lax.shift_left(p[:, w0 + 1, :], jnp.uint32(32 - sh))
        rows.append(val & mask)
    return jnp.stack(rows, axis=1).astype(jnp.int32)


def _kernel(k: int, packed_ref, out_ref):
    out_ref[...] = _ladder(packed_ref[...], k)


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def bitunpack_pallas(
    packed: jax.Array, k: int, *, group: int = DEFAULT_GROUP, interpret: bool = True
) -> jax.Array:
    """(nblocks, k, 128) uint32 -> (nblocks, 32, 128) int32."""
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
    steps = packed.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_kernel, k),
        grid=(steps,),
        in_specs=[pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((group, SUBLANES, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((packed.shape[0], SUBLANES, LANES), jnp.int32),
        interpret=interpret,
    )(packed)
    return out[:nblocks]
