"""Pallas TPU kernel: bloom-filter probe (pushed-down semijoin).

The paper's on-NIC engine applies "bloom filters for probe-side filtering
in joins" to the decoded stream.  Here the bloom filter (byte-per-bit,
n_bits <= 2^17 -> <= 128 KiB) is VMEM-resident across all grid steps; keys
are hashed with a murmur-style double hash (identical constants to
ref.bloom_hashes) and tested with a clipped vector gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024
DEFAULT_GROUP = 4


def _mix(h):
    h = h ^ jax.lax.shift_right_logical(h, jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ jax.lax.shift_right_logical(h, jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    return h ^ jax.lax.shift_right_logical(h, jnp.uint32(16))


def _kernel(n_hashes: int, n_bits: int, keys_ref, bits_ref, out_ref):
    ku = keys_ref[...].astype(jnp.uint32)  # (G, B)
    bits = bits_ref[...]  # (n_bits,)
    h1 = _mix(ku * jnp.uint32(0xCC9E2D51))
    h2 = _mix(ku * jnp.uint32(0x1B873593)) | jnp.uint32(1)
    mod = jnp.uint32(n_bits - 1)
    ok = jnp.ones(ku.shape, jnp.int32)
    for i in range(n_hashes):
        idx = ((h1 + jnp.uint32(i) * h2) & mod).astype(jnp.int32)
        ok = ok & (jnp.take(bits, idx, axis=0, mode="clip") > 0).astype(jnp.int32)
    out_ref[...] = ok


@functools.partial(jax.jit, static_argnames=("n_hashes", "group", "interpret"))
def bloom_probe_pallas(
    keys: jax.Array,
    bits: jax.Array,
    *,
    n_hashes: int = 4,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
) -> jax.Array:
    """keys (nblk, 1024) int32, bits (n_bits,) uint8 -> membership (nblk, 1024) int32."""
    nblk = keys.shape[0]
    n_bits = bits.shape[0]
    assert n_bits & (n_bits - 1) == 0, "n_bits must be a power of two"
    group = min(group, nblk)
    pad = (-nblk) % group
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    steps = keys.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_kernel, n_hashes, n_bits),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((n_bits,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((group, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((keys.shape[0], BLOCK), jnp.int32),
        interpret=interpret,
    )(keys, bits)
    return out[:nblk]
