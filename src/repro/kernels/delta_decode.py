"""Pallas TPU kernel: fused bitunpack + un-zigzag + blocked prefix sum.

DELTA(k) decode for sorted-ish integer columns (doc offsets, dates, keys).
Value order within a 4096 block is v = s*128 + l, so the prefix sum
decomposes into (a) a log2(128)-step shift/add scan along lanes and (b) a
32-row carry ladder — both static VPU work, fused with the unpack so
deltas never leave VMEM.

Critical path: the row carries are derived from plain row SUMS (a log-depth
tree reduction), not from the last lane of the materialized lane scan, so
the carry ladder and the single full-width lane scan are independent
dataflow — the old form ran `_lane_prefix_sum` twice with the second
waiting on the first's materialization.  int32 addition is associative
mod 2^32, so any association is bit-identical to the reference cumsum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitunpack import _ladder
from repro.lakeformat.encodings import LANES, PACK_BLOCK, SUBLANES

DEFAULT_GROUP = 4


def _lane_prefix_sum(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last (lane) axis via log-shift adds."""
    n = x.shape[-1]
    sh = 1
    while sh < n:
        shifted = jnp.pad(x[..., :-sh], [(0, 0)] * (x.ndim - 1) + [(sh, 0)])
        x = x + shifted
        sh *= 2
    return x


def _kernel(k: int, packed_ref, bases_ref, out_ref):
    z = _ladder(packed_ref[...], k)  # (G,32,128) zigzag int32
    zu = z.astype(jnp.uint32)
    d = jax.lax.shift_right_logical(zu, jnp.uint32(1)).astype(jnp.int32) ^ -(
        zu & jnp.uint32(1)
    ).astype(jnp.int32)
    # row totals via tree reduction — does NOT wait on the lane scan
    row_tot = jnp.sum(d, axis=2)  # (G,32)
    row_carry = _lane_prefix_sum(row_tot) - row_tot  # exclusive over rows
    lane_cs = _lane_prefix_sum(d)  # the single full-width lane scan
    out = lane_cs + row_carry[:, :, None] + bases_ref[...][:, :1, None]
    out_ref[...] = out.reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def delta_decode_pallas(
    packed: jax.Array,
    bases: jax.Array,
    k: int,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
) -> jax.Array:
    """(nblocks,k,128) zigzag deltas + (nblocks,) int32 bases -> (nblocks,4096) int32."""
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
        bases = jnp.pad(bases, (0, pad))
    bases2d = bases.astype(jnp.int32)[:, None]  # (nb,1) — 2D for TPU layout
    steps = packed.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_kernel, k),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((group, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((group, PACK_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((packed.shape[0], PACK_BLOCK), jnp.int32),
        interpret=interpret,
    )(packed, bases2d)
    return out[:nblocks]
