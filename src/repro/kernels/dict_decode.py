"""Pallas TPU kernel: fused bitunpack + dictionary lookup.

Decodes DICT(k) columns in one VMEM pass: the packed codes are unpacked
with the shared shift ladder (bitunpack.py) and immediately looked up in a
VMEM-resident dictionary, so codes never round-trip to HBM — the fusion the
paper's SmartNIC gets for free by being a pipeline.

Lookup strategies, chosen statically:
  - small code widths (k <= SELECT_MAX_K): gather-free arithmetic select —
    a flat mux over the 2^k possible codes (`jnp.where(code == i, d[i], …)`
    chained).  Pure lane compares + selects on the VPU, no gather and no
    one-hot matmul; each code matches exactly one arm, so the result is
    bit-identical to `jnp.take(..., mode="clip")` for ints AND floats.
    Low-cardinality dictionaries are the common case the paper's workloads
    lean on (countries, flags, status enums), so this is the hot path.
  - larger dicts, float values (<= ONE_HOT_MAX entries): one-hot matmul on
    the MXU (gather-free, always lowers on TPU).
  - otherwise: vector gather (jnp.take) against the VMEM dictionary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitunpack import _ladder
from repro.lakeformat.encodings import LANES, SUBLANES

ONE_HOT_MAX = 256
SELECT_MAX_K = 4  # <= 16 dictionary entries: flat select mux beats a gather
DEFAULT_GROUP = 4


def _select_shared(codes: jax.Array, d: jax.Array, n: int) -> jax.Array:
    """Flat mux of one shared dictionary: out[...] = d[codes[...]] for
    codes < n, exact for any dtype (selection, never arithmetic)."""
    out = jnp.full(codes.shape, d[0], dtype=d.dtype)
    for i in range(1, n):
        out = jnp.where(codes == i, d[i], out)
    return out


def _select_per_block(codes: jax.Array, d: jax.Array, n: int) -> jax.Array:
    """Flat mux with a per-block dictionary row: codes (G,32,128) int32,
    d (G, Dpad); out[g, ...] = d[g, codes[g, ...]] for codes < n."""
    out = jnp.broadcast_to(d[:, 0][:, None, None], codes.shape).astype(d.dtype)
    for i in range(1, n):
        out = jnp.where(codes == i, d[:, i][:, None, None], out)
    return out


def _kernel(k: int, mode: str, n_true: int, packed_ref, dict_ref, out_ref):
    # clip against the TRUE dictionary length, not the lane-padded one:
    # ref.dict_decode clips out-of-dict codes to the last real entry, and
    # reading a pad slot instead would break bit-identity
    codes = jnp.clip(_ladder(packed_ref[...], k), 0, n_true - 1)
    d = dict_ref[...]  # (Dpad,)
    if mode == "select":
        # clipped codes < min(2^k, n_true), so that many mux arms cover
        # every reachable code
        out_ref[...] = _select_shared(
            codes, d, min(1 << k, n_true)
        ).astype(out_ref.dtype)
    elif mode == "one_hot":
        G = codes.shape[0]
        flat = codes.reshape(G * SUBLANES, LANES)  # (rows, 128)
        oh = (flat[:, :, None] == jnp.arange(d.shape[0], dtype=jnp.int32)[None, None, :])
        vals = jnp.einsum(
            "rlD,D->rl", oh.astype(jnp.float32), d.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        out_ref[...] = vals.reshape(codes.shape).astype(out_ref.dtype)
    else:
        out_ref[...] = jnp.take(d, codes, axis=0, mode="clip").astype(out_ref.dtype)


def _batch_kernel(k: int, select: bool, packed_ref, dict_ref, size_ref, out_ref):
    """Per-BLOCK dictionaries: each block of codes looks up its own
    dictionary row (pre-gathered to (G, Dpad) by the ops wrapper), clipped
    to its own dictionary's true length — exactly `jnp.take(dict_p, codes,
    mode="clip")` per source page, so batched == sequential bit-for-bit."""
    codes = _ladder(packed_ref[...], k)  # (G, 32, 128) int32
    d = dict_ref[...]  # (G, Dpad)
    lim = (size_ref[...] - 1).astype(jnp.int32)  # (G, 1)
    c = jnp.clip(codes, 0, lim[:, :, None])  # (G, 32, 128)
    if select:
        out_ref[...] = _select_per_block(c, d, 1 << k).astype(out_ref.dtype)
    else:
        flat = jnp.take_along_axis(d, c.reshape(c.shape[0], -1), axis=1)
        out_ref[...] = flat.reshape(codes.shape).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def dict_decode_batch_pallas(
    packed: jax.Array,
    dicts: jax.Array,
    sizes: jax.Array,
    k: int,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
) -> jax.Array:
    """Batched multi-page dict decode in ONE kernel launch.

    packed (nblocks, k, 128) uint32 codes stacked from many pages;
    dicts (nblocks, Dpad) per-block dictionary rows (page dictionaries
    padded to a common width and gathered per block by the caller);
    sizes (nblocks, 1) int32 true dictionary lengths.
    -> (nblocks, 32, 128) values of dicts.dtype.
    """
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
        dicts = jnp.pad(dicts, ((0, pad), (0, 0)))
        sizes = jnp.pad(sizes, ((0, pad), (0, 0)), constant_values=1)
    dpad = (-dicts.shape[1]) % LANES
    if dpad:
        dicts = jnp.pad(dicts, ((0, 0), (0, dpad)))
    # clipped codes are < sizes <= 2^k <= Dpad for k <= SELECT_MAX_K, so the
    # mux arms cover every reachable code
    select = k <= SELECT_MAX_K and (1 << k) <= dicts.shape[1]
    steps = packed.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_batch_kernel, k, select),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((group, dicts.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((group, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((group, SUBLANES, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (packed.shape[0], SUBLANES, LANES), dicts.dtype
        ),
        interpret=interpret,
    )(packed, dicts, sizes)
    return out[:nblocks]


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def dict_decode_pallas(
    packed: jax.Array,
    dictionary: jax.Array,
    k: int,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
) -> jax.Array:
    """(nblocks,k,128) uint32 codes + (D,) dict -> (nblocks,32,128) values."""
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
    n_true = dictionary.shape[0]
    dpad = (-dictionary.shape[0]) % LANES
    if dpad:
        dictionary = jnp.pad(dictionary, (0, dpad))
    if k <= SELECT_MAX_K:
        mode = "select"  # exact for ints and floats alike
    elif dictionary.shape[0] <= ONE_HOT_MAX and jnp.issubdtype(
        dictionary.dtype, jnp.floating
    ):
        # One-hot path is exact only for f32-representable values
        mode = "one_hot"
    else:
        mode = "gather"
    steps = packed.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_kernel, k, mode, n_true),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((dictionary.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((group, SUBLANES, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (packed.shape[0], SUBLANES, LANES), dictionary.dtype
        ),
        interpret=interpret,
    )(packed, dictionary)
    return out[:nblocks]
