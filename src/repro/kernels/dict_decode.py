"""Pallas TPU kernel: fused bitunpack + dictionary gather.

Decodes DICT(k) columns in one VMEM pass: the packed codes are unpacked
with the shared shift ladder (bitunpack.py) and immediately looked up in a
VMEM-resident dictionary, so codes never round-trip to HBM — the fusion the
paper's SmartNIC gets for free by being a pipeline.

Two lookup strategies, chosen statically by dictionary size:
  - small dicts (<= ONE_HOT_MAX entries): one-hot matmul on the MXU
    (gather-free, always lowers on TPU),
  - larger dicts: vector gather (jnp.take) against the VMEM dictionary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitunpack import _ladder
from repro.lakeformat.encodings import LANES, SUBLANES

ONE_HOT_MAX = 256
DEFAULT_GROUP = 4


def _kernel(k: int, one_hot: bool, packed_ref, dict_ref, out_ref):
    codes = _ladder(packed_ref[...], k)  # (G, 32, 128) int32
    d = dict_ref[...]  # (Dpad,)
    if one_hot:
        G = codes.shape[0]
        flat = codes.reshape(G * SUBLANES, LANES)  # (rows, 128)
        oh = (flat[:, :, None] == jnp.arange(d.shape[0], dtype=jnp.int32)[None, None, :])
        vals = jnp.einsum(
            "rlD,D->rl", oh.astype(jnp.float32), d.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        out_ref[...] = vals.reshape(codes.shape).astype(out_ref.dtype)
    else:
        out_ref[...] = jnp.take(d, codes, axis=0, mode="clip").astype(out_ref.dtype)


def _batch_kernel(k: int, packed_ref, dict_ref, size_ref, out_ref):
    """Per-BLOCK dictionaries: each block of codes gathers from its own
    dictionary row (pre-gathered to (G, Dpad) by the ops wrapper), clipped
    to its own dictionary's true length — exactly `jnp.take(dict_p, codes,
    mode="clip")` per source page, so batched == sequential bit-for-bit."""
    codes = _ladder(packed_ref[...], k)  # (G, 32, 128) int32
    d = dict_ref[...]  # (G, Dpad)
    lim = (size_ref[...] - 1).astype(jnp.int32)  # (G, 1)
    c = jnp.clip(codes, 0, lim[:, :, None])  # (G, 32, 128)
    flat = jnp.take_along_axis(d, c.reshape(c.shape[0], -1), axis=1)
    out_ref[...] = flat.reshape(codes.shape).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def dict_decode_batch_pallas(
    packed: jax.Array,
    dicts: jax.Array,
    sizes: jax.Array,
    k: int,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
) -> jax.Array:
    """Batched multi-page dict decode in ONE kernel launch.

    packed (nblocks, k, 128) uint32 codes stacked from many pages;
    dicts (nblocks, Dpad) per-block dictionary rows (page dictionaries
    padded to a common width and gathered per block by the caller);
    sizes (nblocks, 1) int32 true dictionary lengths.
    -> (nblocks, 32, 128) values of dicts.dtype.
    """
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
        dicts = jnp.pad(dicts, ((0, pad), (0, 0)))
        sizes = jnp.pad(sizes, ((0, pad), (0, 0)), constant_values=1)
    dpad = (-dicts.shape[1]) % LANES
    if dpad:
        dicts = jnp.pad(dicts, ((0, 0), (0, dpad)))
    steps = packed.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_batch_kernel, k),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((group, dicts.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((group, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((group, SUBLANES, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (packed.shape[0], SUBLANES, LANES), dicts.dtype
        ),
        interpret=interpret,
    )(packed, dicts, sizes)
    return out[:nblocks]


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def dict_decode_pallas(
    packed: jax.Array,
    dictionary: jax.Array,
    k: int,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
) -> jax.Array:
    """(nblocks,k,128) uint32 codes + (D,) dict -> (nblocks,32,128) values."""
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
    dpad = (-dictionary.shape[0]) % LANES
    if dpad:
        dictionary = jnp.pad(dictionary, (0, dpad))
    # One-hot path is exact only for f32-representable values; ints use gather.
    one_hot = dictionary.shape[0] <= ONE_HOT_MAX and jnp.issubdtype(
        dictionary.dtype, jnp.floating
    )
    steps = packed.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_kernel, k, one_hot),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((dictionary.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((group, SUBLANES, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (packed.shape[0], SUBLANES, LANES), dictionary.dtype
        ),
        interpret=interpret,
    )(packed, dictionary)
    return out[:nblocks]
