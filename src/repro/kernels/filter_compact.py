"""Pallas TPU kernel: stream compaction (the pushed-down filter's output stage).

A streaming filter on an FPGA emits a variable-length stream; TPUs need
static shapes.  The TPU-idiomatic equivalent: per 1024-value block, build
the permutation one-hot P[p, j] = (prefix(mask)[j]-1 == p) & mask[j] and
contract it with the values on the MXU, packing survivors to the front.
Per-block survivor counts come along for free; the engine stitches blocks
with an exclusive scan over counts (core/engine.py).

Exactness: float columns are exact in f32; int columns are compacted via
the f32 MXU only when |v| < 2^24, else the ops wrapper splits into two
16-bit halves and recombines (two matmuls, still exact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _prefix_sum_last(x: jax.Array) -> jax.Array:
    n = x.shape[-1]
    sh = 1
    while sh < n:
        x = x + jnp.pad(x[..., :-sh], [(0, 0)] * (x.ndim - 1) + [(sh, 0)])
        sh *= 2
    return x


def _kernel(vals_ref, mask_ref, out_ref, cnt_ref):
    vals = vals_ref[...]  # (1, B)
    m = mask_ref[...].astype(jnp.int32)  # (1, B)
    pos = _prefix_sum_last(m) - 1  # (1, B)
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, BLOCK, 1), 1)  # (1, p, 1)
    onehot = (pos[:, None, :] == slots) & (m[:, None, :] > 0)  # (1, p, j)
    out = jax.lax.dot_general(
        onehot.astype(jnp.float32),
        vals[:, :, None].astype(jnp.float32),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[..., 0]
    out_ref[...] = out.astype(out_ref.dtype)
    cnt_ref[...] = jnp.sum(m, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def filter_compact_pallas(
    values: jax.Array, mask: jax.Array, *, interpret: bool = True
):
    """values (nblk, 1024), mask (nblk, 1024) int32/bool ->
    (compacted (nblk, 1024), counts (nblk,))."""
    nblk = values.shape[0]
    out, cnt = pl.pallas_call(
        _kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, BLOCK), values.dtype),
            jax.ShapeDtypeStruct((nblk, 1), jnp.int32),
        ],
        interpret=interpret,
    )(values, mask.astype(jnp.int32))
    return out, cnt[:, 0]
