"""Pallas TPU kernel: blocked causal/GQA flash attention (consumer side).

The datapath engine feeds models; their dominant compute hot-spot is
attention.  This kernel implements the standard online-softmax blocked
attention with:
  - GQA: grid is (batch, q_heads, q_blocks); K/V BlockSpecs map q-head ->
    kv-head via h // (H // Hkv), so kv blocks are fetched once per group,
  - causal block skipping: the fori_loop upper bound is trimmed to the
    last kv block visible to this q block,
  - optional sliding window (lower bound trimmed symmetrically).

K/V rows for one (batch, kv-head) are staged whole into VMEM, which bounds
supported context to ~8k at d=128 in f32; longer contexts use the jnp
blocked path (models/layers.py) — see DESIGN.md §Perf for the trade.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(bq: int, bk: int, scale: float, causal: bool, window: Optional[int],
            q_ref, k_ref, v_ref, o_ref):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
    Sk = k_ref.shape[2]
    nkb = Sk // bk
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    if causal:
        hi = jnp.minimum(nkb, ((qi + 1) * bq + bk - 1) // bk)
    else:
        hi = nkb
    if window is not None:
        lo = jnp.maximum(0, (qi * bq - window) // bk)
    else:
        lo = 0

    def body(i, carry):
        m, l, acc = carry
        kb = k_ref[0, 0, pl.ds(i * bk, bk), :].astype(jnp.float32)  # (bk, D)
        vb = v_ref[0, 0, pl.ds(i * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        k_pos = i * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    D = q_ref.shape[-1]
    init = (
        jnp.full((bq, 1), NEG_INF, jnp.float32),
        jnp.zeros((bq, 1), jnp.float32),
        jnp.zeros((bq, D), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(lo, hi, body, init)
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret", "scale")
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """q (B,H,Sq,D), k/v (B,Hkv,Sk,D) -> (B,H,Sq,D).  Sq % bq == Sk % bk == 0."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    assert Sq == Sk or not causal, "causal kernel assumes aligned q/k (training)"
    rep = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    grid = (B, H, Sq // bq)
    out = pl.pallas_call(
        functools.partial(_kernel, bq, bk, scale, causal, window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
