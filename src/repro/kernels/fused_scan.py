"""Pallas TPU kernel: the flagship fused datapath scan.

decode (BITPACK or DICT) -> range predicate -> mask + per-block counts,
one VMEM pass, no decoded-but-unfiltered bytes ever written to HBM.  This
is the direct analogue of the paper's SmartNIC pipeline: the consumer only
ever sees the survivor mask (and the engine materializes survivors on
demand with filter_compact).

Runtime predicate constants (lo, hi) arrive as a (1, 2) int32 operand so
one compiled kernel serves every query.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitunpack import _ladder
from repro.lakeformat.encodings import LANES, PACK_BLOCK, SUBLANES

DEFAULT_GROUP = 4


def _kernel(k: int, has_dict: bool, *refs):
    if has_dict:
        packed_ref, dict_ref, lohi_ref, mask_ref, cnt_ref = refs
    else:
        packed_ref, lohi_ref, mask_ref, cnt_ref = refs
    codes = _ladder(packed_ref[...], k)  # (G,32,128) int32
    if has_dict:
        vals = jnp.take(dict_ref[...], codes, axis=0, mode="clip")
    else:
        vals = codes
    G = vals.shape[0]
    vals = vals.reshape(G, PACK_BLOCK)
    lo = lohi_ref[0, 0]
    hi = lohi_ref[0, 1]
    m = (vals >= lo.astype(vals.dtype)) & (vals <= hi.astype(vals.dtype))
    mask_ref[...] = m.astype(jnp.int32)
    cnt_ref[...] = jnp.sum(m.astype(jnp.int32), axis=-1, keepdims=True)


def _batch_kernel(k: int, packed_ref, lohi_ref, mask_ref):
    """Per-BLOCK predicate bounds: block b tests lohi_ref[b] — the batched
    form of the scalar kernel, so pages from many row groups (each with its
    own code-rewritten range, e.g. per-group DICT bounds) share one launch."""
    codes = _ladder(packed_ref[...], k)  # (G, 32, 128) int32
    G = codes.shape[0]
    vals = codes.reshape(G, PACK_BLOCK)
    lo = lohi_ref[:, 0:1]  # (G, 1)
    hi = lohi_ref[:, 1:2]
    m = (vals >= lo) & (vals <= hi)
    mask_ref[...] = m.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def fused_scan_batch_pallas(
    packed: jax.Array,
    k: int,
    lohi: jax.Array,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
) -> jax.Array:
    """Batched fused decode+filter over stacked pages in ONE launch.

    packed (nblocks, k, 128) uint32; lohi (nblocks, 2) int32 per-block
    bounds -> mask (nblocks, 4096) int32 (nonzero = survivor).
    """
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
        # empty range (1, 0): padded blocks match nothing
        lohi = jnp.concatenate(
            [lohi, jnp.tile(jnp.array([[1, 0]], jnp.int32), (pad, 1))], axis=0
        )
    steps = packed.shape[0] // group
    mask = pl.pallas_call(
        functools.partial(_batch_kernel, k),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((group, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((group, PACK_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((packed.shape[0], PACK_BLOCK), jnp.int32),
        interpret=interpret,
    )(packed, lohi)
    return mask[:nblocks]


@functools.partial(jax.jit, static_argnames=("k", "group", "interpret"))
def fused_scan_pallas(
    packed: jax.Array,
    k: int,
    lo: jax.Array,
    hi: jax.Array,
    dictionary: Optional[jax.Array] = None,
    *,
    group: int = DEFAULT_GROUP,
    interpret: bool = True,
):
    """Returns (mask (nblocks, 4096) int32, counts (nblocks,) int32)."""
    nblocks = packed.shape[0]
    group = min(group, nblocks)
    pad = (-nblocks) % group
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0), (0, 0)))
    steps = packed.shape[0] // group
    lohi = jnp.stack([lo, hi]).astype(jnp.int32)[None, :]  # (1, 2)
    in_specs = [pl.BlockSpec((group, k, LANES), lambda i: (i, 0, 0))]
    args = [packed]
    if dictionary is not None:
        dpad = (-dictionary.shape[0]) % LANES
        if dpad:
            dictionary = jnp.pad(dictionary, (0, dpad))
        in_specs.append(pl.BlockSpec((dictionary.shape[0],), lambda i: (0,)))
        args.append(dictionary.astype(jnp.int32))
    in_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
    args.append(lohi)
    mask, cnt = pl.pallas_call(
        functools.partial(_kernel, k, dictionary is not None),
        grid=(steps,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((group, PACK_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((group, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((packed.shape[0], PACK_BLOCK), jnp.int32),
            jax.ShapeDtypeStruct((packed.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    return mask[:nblocks], cnt[:nblocks, 0]
