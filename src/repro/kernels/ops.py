"""Public jit'd kernel API: dispatches Pallas kernel vs pure-jnp reference.

backend:
  'ref'    — pure jnp (default on CPU; also the dry-run path, since Pallas
             TPU lowering is unavailable on the CPU dry-run backend)
  'pallas' — pl.pallas_call (interpret=True automatically off-TPU)
  'auto'   — 'pallas' on TPU, 'ref' elsewhere

Every function here is shape/dtype-stable across backends; tests assert
exact agreement.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitunpack import bitunpack_pallas
from repro.kernels.bloom_probe import bloom_probe_pallas
from repro.kernels.delta_decode import delta_decode_pallas
from repro.kernels.dict_decode import dict_decode_pallas
from repro.kernels.filter_compact import filter_compact_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_scan import fused_scan_pallas
from repro.kernels.rle_decode import rle_decode_pallas


def _resolve(backend: str) -> Tuple[str, bool]:
    """-> (backend, interpret)"""
    on_tpu = jax.default_backend() == "tpu"
    if backend == "auto":
        backend = "pallas" if on_tpu else "ref"
    return backend, not on_tpu


def bitunpack(packed, k: int, n: Optional[int] = None, *, backend: str = "auto"):
    """(nblocks,k,128) uint32 -> flat (n,) int32 (or (nb,32,128) if n is None)."""
    backend, interp = _resolve(backend)
    out = (
        bitunpack_pallas(packed, k, interpret=interp)
        if backend == "pallas"
        else ref.bitunpack(packed, k)
    )
    return out if n is None else out.reshape(-1)[:n]


def dict_decode(packed, dictionary, k: int, n: Optional[int] = None, *, backend="auto"):
    backend, interp = _resolve(backend)
    out = (
        dict_decode_pallas(packed, dictionary, k, interpret=interp)
        if backend == "pallas"
        else ref.dict_decode(packed, dictionary, k)
    )
    return out if n is None else out.reshape(-1)[:n]


def rle_decode(values, ends, n: Optional[int] = None, *, backend="auto"):
    backend, interp = _resolve(backend)
    out = (
        rle_decode_pallas(values, ends, interpret=interp)
        if backend == "pallas"
        else ref.rle_decode(values, ends)
    )
    return out if n is None else out.reshape(-1)[:n]


def delta_decode(packed, bases, k: int, n: Optional[int] = None, *, backend="auto"):
    backend, interp = _resolve(backend)
    out = (
        delta_decode_pallas(packed, bases, k, interpret=interp)
        if backend == "pallas"
        else ref.delta_decode(packed, bases, k)
    )
    return out if n is None else out.reshape(-1)[:n]


def filter_compact(values, mask, *, backend="auto"):
    """values (nblk,1024), mask (nblk,1024) -> (compacted, counts).

    Ints with |v| >= 2^24 are split into two 16-bit halves so the f32 MXU
    contraction stays exact.
    """
    backend, interp = _resolve(backend)
    fn = (
        (lambda v, m: filter_compact_pallas(v, m, interpret=interp))
        if backend == "pallas"
        else ref.filter_compact
    )
    if jnp.issubdtype(values.dtype, jnp.integer):
        v = values.astype(jnp.int32)
        hi16 = jax.lax.shift_right_arithmetic(v, 16)
        lo16 = v & 0xFFFF
        chi, cnt = fn(hi16, mask)
        clo, _ = fn(lo16, mask)
        out = jax.lax.shift_left(chi.astype(jnp.int32), 16) | clo.astype(jnp.int32)
        return out.astype(values.dtype), cnt
    return fn(values, mask)


def bloom_build(keys, n_bits: int, n_hashes: int = 4):
    return ref.bloom_build(keys, n_bits, n_hashes)


def bloom_probe(keys, bits, n_hashes: int = 4, *, backend="auto"):
    """keys (nblk,1024) -> membership (nblk,1024) bool."""
    backend, interp = _resolve(backend)
    if backend == "pallas":
        return bloom_probe_pallas(keys, bits, n_hashes=n_hashes, interpret=interp) > 0
    return ref.bloom_probe(keys, bits, n_hashes)


def fused_scan(packed, k: int, lo, hi, dictionary=None, *, backend="auto"):
    backend, interp = _resolve(backend)
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    if backend == "pallas":
        mask, cnt = fused_scan_pallas(packed, k, lo, hi, dictionary, interpret=interp)
        return mask > 0, cnt
    return ref.fused_scan(packed, k, lo, hi, dictionary)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None, backend="auto",
                    bq: int = 256, bk: int = 256):
    backend, interp = _resolve(backend)
    if backend == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale, bq=bq, bk=bk,
            interpret=interp,
        )
    return ref.mha(q, k, v, causal=causal, window=window, scale=scale)
