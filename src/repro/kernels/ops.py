"""Public jit'd kernel API: dispatches Pallas kernel vs pure-jnp reference.

backend:
  'ref'    — pure jnp (default on CPU; also the dry-run path, since Pallas
             TPU lowering is unavailable on the CPU dry-run backend)
  'pallas' — pl.pallas_call (interpret=True automatically off-TPU)
  'auto'   — 'pallas' on TPU, 'ref' elsewhere

Every function here is shape/dtype-stable across backends; tests assert
exact agreement.

Batched entry points (`*_batch`): every encoding's block layout is
page-count-agnostic — BITPACK/DICT/DELTA pages are (nblocks, k, 128) and
RLE pages are (nblk, 128) — so compatible pages from MANY row groups
stack along the leading block axis and decode in ONE device dispatch.
Inputs are stacked host (numpy) buffers; the leading axis is padded to a
two-size-ladder bucket (see `bucket_blocks`) BEFORE the jitted call, so
the whole scan reuses a handful of compiled traces instead of re-tracing
per row-group count.

Single-call entry points on the 'ref' backend route through jitted
wrappers too: eager jnp issues one XLA executable per primitive, which
made a single RLE block decode ~100x slower than the same math compiled —
the dispatch-overhead wall the per-backend cost-model tables measure.
The module-level dispatch counter underneath `dispatch_count()` is the
benchmarks' device-dispatch metric: each public entry here counts the
launches it issues (a batch call counts ONE however many pages it
carries).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.agg_push import MAX_GROUPS, fused_agg_pallas, grouped_agg_pallas
from repro.kernels.bitunpack import bitunpack_pallas
from repro.kernels.bloom_probe import bloom_probe_pallas
from repro.kernels.delta_decode import delta_decode_pallas
from repro.kernels.dict_decode import dict_decode_batch_pallas, dict_decode_pallas
from repro.kernels.filter_compact import filter_compact_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_scan import fused_scan_batch_pallas, fused_scan_pallas
from repro.kernels.rle_decode import rle_decode_pallas


def _resolve(backend: str) -> Tuple[str, bool]:
    """-> (backend, interpret)"""
    on_tpu = jax.default_backend() == "tpu"
    if backend == "auto":
        backend = "pallas" if on_tpu else "ref"
    return backend, not on_tpu


# ---------------------------------------------------------------------------
# device-dispatch accounting (the batching benchmark's currency)
# ---------------------------------------------------------------------------

_DISPATCHES = 0


def _count(n: int = 1) -> None:
    global _DISPATCHES
    _DISPATCHES += n


def dispatch_count() -> int:
    """Device dispatches issued through this module since the last reset.
    One public decode/filter call counts one dispatch per kernel launch it
    issues (filter_compact's two-half int path counts two); a `*_batch`
    call counts ONE regardless of how many pages it carries."""
    return _DISPATCHES


def reset_dispatch_count() -> int:
    """Zero the dispatch counter; returns the value it had."""
    global _DISPATCHES
    n, _DISPATCHES = _DISPATCHES, 0
    return n


BUCKET_MODE = "ladder"  # 'ladder' (default) or 'pow2' (legacy, kept for A/B)


def set_bucket_mode(mode: str) -> str:
    """Switch the batch-padding bucket scheme; returns the previous mode."""
    global BUCKET_MODE
    assert mode in ("ladder", "pow2"), mode
    prev, BUCKET_MODE = BUCKET_MODE, mode
    return prev


def bucket_blocks(n: int, mode: Optional[str] = None) -> int:
    """Pad a stacked block count to its bucket, so batch launches hit a
    small, reused set of jit traces (shape-stable jit).

    'ladder' (default): two rungs per octave — {2^m, 3*2^(m-1)}, i.e.
    1, 2, 3, 4, 6, 8, 12, 16, 24, 32, ...  Worst-case pad waste drops
    from pow2's ~100% (n = 2^m + 1 pads to 2^(m+1)) to a bounded ~50%
    (~17% typical), at the cost of at most 2 compiled traces per octave
    instead of 1.  Each batch call is still exactly ONE launch, so the
    ladder never issues more dispatches than pow2 for the same workload
    (tests/test_batch_decode.py pins the invariant).
    'pow2': the legacy single-rung octave."""
    assert n > 0, n
    mode = mode or BUCKET_MODE
    p = 1 << (n - 1).bit_length()  # next power of two >= n
    if mode == "pow2" or p < 4:
        return p
    mid = 3 * (p // 4)  # the mid-octave rung 3*2^(m-2)
    return mid if n <= mid else p


def device_put(buf) -> jax.Array:
    """Counted host->device transfer: PLAIN 'decode' is a device put, and
    the dispatch metric must see it on both the sequential path (one put
    per page) and the batched path (one put per stacked bucket)."""
    _count()
    return jnp.asarray(buf)


# Jitted single-call reference paths.  The ref backend used to run these
# EAGERLY — one XLA executable per jnp primitive, so a single-page decode
# paid dozens of dispatches and the calibrated RLE/DELTA/DICT rates sat
# three orders of magnitude under PLAIN (BENCH_service.json point 5).
# Compiling each (shape, k) once and replaying it is the same trick the
# batch paths already used; the jit cache is keyed on page shape, which a
# real workload draws from a handful of values.

_ref_dict_decode = functools.partial(jax.jit, static_argnums=(2,))(ref.dict_decode)
_ref_bloom_probe = functools.partial(jax.jit, static_argnums=(2,))(ref.bloom_probe)
_ref_fused_scan = functools.partial(jax.jit, static_argnums=(1,))(ref.fused_scan)
_ref_filter_compact = jax.jit(ref.filter_compact)


@jax.jit
def _ref_filter_compact_int(values, mask):
    """Whole two-half int compaction fused into one executable."""
    v = values.astype(jnp.int32)
    hi16 = jax.lax.shift_right_arithmetic(v, 16)
    lo16 = v & 0xFFFF
    chi, cnt = ref.filter_compact(hi16, mask)
    clo, _ = ref.filter_compact(lo16, mask)
    out = jax.lax.shift_left(chi.astype(jnp.int32), 16) | clo.astype(jnp.int32)
    return out, cnt


def bitunpack(packed, k: int, n: Optional[int] = None, *, backend: str = "auto"):
    """(nblocks,k,128) uint32 -> flat (n,) int32 (or (nb,32,128) if n is None)."""
    backend, interp = _resolve(backend)
    _count()
    out = (
        bitunpack_pallas(packed, k, interpret=interp)
        if backend == "pallas"
        else _ref_bitunpack_batch(packed, k)
    )
    return out if n is None else out.reshape(-1)[:n]


def dict_decode(packed, dictionary, k: int, n: Optional[int] = None, *, backend="auto"):
    backend, interp = _resolve(backend)
    _count()
    out = (
        dict_decode_pallas(packed, dictionary, k, interpret=interp)
        if backend == "pallas"
        else _ref_dict_decode(packed, dictionary, k)
    )
    return out if n is None else out.reshape(-1)[:n]


def rle_decode(values, ends, n: Optional[int] = None, *, backend="auto"):
    backend, interp = _resolve(backend)
    _count()
    out = (
        rle_decode_pallas(values, ends, interpret=interp)
        if backend == "pallas"
        else _ref_rle_decode_batch(values, ends)
    )
    return out if n is None else out.reshape(-1)[:n]


def delta_decode(packed, bases, k: int, n: Optional[int] = None, *, backend="auto"):
    backend, interp = _resolve(backend)
    _count()
    out = (
        delta_decode_pallas(packed, bases, k, interpret=interp)
        if backend == "pallas"
        else _ref_delta_decode_batch(packed, bases, k)
    )
    return out if n is None else out.reshape(-1)[:n]


def filter_compact(values, mask, *, backend="auto"):
    """values (nblk,1024), mask (nblk,1024) -> (compacted, counts).

    Ints with |v| >= 2^24 are split into two 16-bit halves so the f32 MXU
    contraction stays exact.
    """
    backend, interp = _resolve(backend)
    if jnp.issubdtype(values.dtype, jnp.integer):
        # _count(2) on both backends: the pallas path launches two kernels,
        # and the ref path prices the same two logical compactions even
        # though jit fuses them into one executable
        _count(2)
        if backend != "pallas":
            out, cnt = _ref_filter_compact_int(values, mask)
            return out.astype(values.dtype), cnt
        v = values.astype(jnp.int32)
        hi16 = jax.lax.shift_right_arithmetic(v, 16)
        lo16 = v & 0xFFFF
        chi, cnt = filter_compact_pallas(hi16, mask, interpret=interp)
        clo, _ = filter_compact_pallas(lo16, mask, interpret=interp)
        out = jax.lax.shift_left(chi.astype(jnp.int32), 16) | clo.astype(jnp.int32)
        return out.astype(values.dtype), cnt
    _count()
    if backend == "pallas":
        return filter_compact_pallas(values, mask, interpret=interp)
    return _ref_filter_compact(values, mask)


def bloom_build(keys, n_bits: int, n_hashes: int = 4):
    return ref.bloom_build(keys, n_bits, n_hashes)


def bloom_probe(keys, bits, n_hashes: int = 4, *, backend="auto"):
    """keys (nblk,1024) -> membership (nblk,1024) bool."""
    backend, interp = _resolve(backend)
    _count()
    if backend == "pallas":
        return bloom_probe_pallas(keys, bits, n_hashes=n_hashes, interpret=interp) > 0
    return _ref_bloom_probe(keys, bits, n_hashes)


def fused_scan(packed, k: int, lo, hi, dictionary=None, *, backend="auto"):
    backend, interp = _resolve(backend)
    _count()
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    if backend == "pallas":
        mask, cnt = fused_scan_pallas(packed, k, lo, hi, dictionary, interpret=interp)
        return mask > 0, cnt
    return _ref_fused_scan(packed, k, lo, hi, dictionary)


# ---------------------------------------------------------------------------
# batched multi-page decode: one launch per (encoding, k, dtype) bucket
# ---------------------------------------------------------------------------
#
# The jitted reference implementations below are what makes the ref backend
# a single dispatch per bucket too: eager jnp would issue one executable
# per primitive, but jax.jit with a static k and a bucket-padded leading
# axis compiles each (k, bucket_blocks) shape once and replays it.


@functools.partial(jax.jit, static_argnames=("k",))
def _ref_bitunpack_batch(packed, k: int):
    return ref.bitunpack(packed, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _ref_dict_decode_batch(packed, dicts, sizes, k: int):
    codes = ref.bitunpack(packed, k)  # (nb, 32, 128) int32, >= 0
    lim = (sizes - 1).astype(jnp.int32)  # (nb, 1)
    c = jnp.clip(codes, 0, lim[:, :, None])  # per-block mode="clip"
    flat = jnp.take_along_axis(dicts, c.reshape(c.shape[0], -1), axis=1)
    return flat.reshape(codes.shape)


@functools.partial(jax.jit, static_argnames=("k",))
def _ref_delta_decode_batch(packed, bases, k: int):
    return ref.delta_decode(packed, bases, k)


@jax.jit
def _ref_rle_decode_batch(values, ends):
    return ref.rle_decode(values, ends)


@functools.partial(jax.jit, static_argnames=("k",))
def _ref_fused_scan_batch(packed, lohi, k: int):
    from repro.lakeformat.encodings import PACK_BLOCK

    vals = ref.bitunpack(packed, k).reshape(packed.shape[0], PACK_BLOCK)
    return (vals >= lohi[:, 0:1]) & (vals <= lohi[:, 1:2])


@functools.partial(jax.jit, static_argnames=("n_groups",))
def _ref_grouped_agg_batch(values, gids, mask, n_groups: int):
    return ref.grouped_agg(values, gids, mask, n_groups)


@functools.partial(jax.jit, static_argnames=("k",))
def _ref_fused_agg_batch(packed, mask, k: int):
    return ref.fused_agg_scan(packed, k, mask)


def _pad_blocks(arr: np.ndarray, target: int, fill=0) -> np.ndarray:
    """Host-side leading-axis pad to the bucket size.  Padding happens
    BEFORE the jitted call on purpose: padding inside the trace would key
    the jit cache on the raw block count and defeat bucketing."""
    nb = arr.shape[0]
    if nb == target:
        return arr
    pad = np.full((target - nb,) + arr.shape[1:], fill, dtype=arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def bitunpack_batch(packed: np.ndarray, k: int, *, backend: str = "auto"):
    """Stacked (nblocks,k,128) uint32 pages -> (nblocks,32,128) int32 in
    ONE dispatch.  `packed` is a host (numpy) stack; the leading axis is
    bucket-padded host-side so jit traces are reused."""
    backend, interp = _resolve(backend)
    nb = packed.shape[0]
    padded = _pad_blocks(packed, bucket_blocks(nb))
    _count()
    out = (
        bitunpack_pallas(padded, k, interpret=interp)
        if backend == "pallas"
        else _ref_bitunpack_batch(padded, k)
    )
    return out[:nb]


def dict_decode_batch(
    packed: np.ndarray,
    dicts: np.ndarray,
    sizes: np.ndarray,
    page: np.ndarray,
    k: int,
    *,
    backend: str = "auto",
):
    """Multi-page dict decode in ONE dispatch.

    packed (nblocks,k,128) uint32 stacked codes; dicts (P, Dmax) page
    dictionaries padded to a common width; sizes (P,) true lengths;
    page (nblocks,) block -> source-page index.  Returns
    (nblocks,32,128) values of dicts.dtype, bit-identical per page to
    `dict_decode(packed_p, dicts[p, :sizes[p]], k)`.
    """
    backend, interp = _resolve(backend)
    nb = packed.shape[0]
    target = bucket_blocks(nb)
    padded = _pad_blocks(packed, target)
    page = _pad_blocks(np.asarray(page, np.int32), target)
    d_blocks = np.ascontiguousarray(dicts[page])  # (nb_pad, Dmax)
    s_blocks = np.asarray(sizes, np.int32)[page][:, None]  # (nb_pad, 1)
    np.maximum(s_blocks, 1, out=s_blocks)
    _count()
    out = (
        dict_decode_batch_pallas(padded, d_blocks, s_blocks, k, interpret=interp)
        if backend == "pallas"
        else _ref_dict_decode_batch(padded, d_blocks, s_blocks, k)
    )
    return out[:nb]


def delta_decode_batch(packed: np.ndarray, bases: np.ndarray, k: int, *, backend="auto"):
    """Stacked (nblocks,k,128) zigzag deltas + (nblocks,) bases ->
    (nblocks,4096) int32 in ONE dispatch (blocks are self-contained)."""
    backend, interp = _resolve(backend)
    nb = packed.shape[0]
    target = bucket_blocks(nb)
    padded = _pad_blocks(packed, target)
    bases = _pad_blocks(np.asarray(bases, np.int32), target)
    _count()
    out = (
        delta_decode_pallas(padded, bases, k, interpret=interp)
        if backend == "pallas"
        else _ref_delta_decode_batch(padded, bases, k)
    )
    return out[:nb]


def rle_decode_batch(values: np.ndarray, ends: np.ndarray, *, backend="auto"):
    """Stacked (nblk,128) run values + ends -> (nblk,1024) in ONE dispatch
    (the writer clips runs at block boundaries, so blocks are independent)."""
    backend, interp = _resolve(backend)
    nb = values.shape[0]
    target = bucket_blocks(nb)
    values = _pad_blocks(values, target)
    ends = _pad_blocks(ends, target)
    _count()
    out = (
        rle_decode_pallas(values, ends, interpret=interp)
        if backend == "pallas"
        else _ref_rle_decode_batch(values, ends)
    )
    return out[:nb]


def fused_scan_batch(packed: np.ndarray, k: int, lo: np.ndarray, hi: np.ndarray,
                     *, backend="auto"):
    """Batched fused decode+filter: stacked (nblocks,k,128) pages with
    PER-BLOCK int bounds lo/hi (nblocks,) -> survivor mask
    (nblocks,4096) bool in ONE dispatch.  Per-block bounds are what let
    DICT pages ride along: each row group's range is rewritten onto its
    own codes, so bounds differ across the stack."""
    backend, interp = _resolve(backend)
    nb = packed.shape[0]
    target = bucket_blocks(nb)
    padded = _pad_blocks(packed, target)
    lohi = np.stack([np.asarray(lo, np.int32), np.asarray(hi, np.int32)], axis=1)
    lohi = _pad_blocks(lohi, target)
    lohi[nb:, 0], lohi[nb:, 1] = 1, 0  # padded blocks match nothing
    _count()
    if backend == "pallas":
        return fused_scan_batch_pallas(padded, k, jnp.asarray(lohi),
                                       interpret=interp)[:nb] > 0
    return _ref_fused_scan_batch(padded, lohi, k)[:nb]


def _pad_blocks_dev(arr, target: int):
    """Leading-axis zero-pad that works for host numpy AND device arrays
    (decoded value blocks never round-trip to host just to be padded)."""
    nb = arr.shape[0]
    if nb == target:
        return arr
    if isinstance(arr, np.ndarray):
        return _pad_blocks(arr, target)
    return jnp.pad(arr, [(0, target - nb)] + [(0, 0)] * (arr.ndim - 1))


def grouped_agg_batch(values, gids, mask, n_groups: int, *, backend="auto"):
    """Batched grouped aggregate over stacked decoded blocks in ONE
    dispatch: values/gids/mask (nblocks, 4096) -> 5 x (nblocks, n_groups)
    partial accumulators (ref.grouped_agg layout).  Padded blocks carry
    mask == 0 so their rows are exact merge identities."""
    assert 1 <= n_groups <= MAX_GROUPS, n_groups
    backend, interp = _resolve(backend)
    nb = values.shape[0]
    target = bucket_blocks(nb)
    values = _pad_blocks_dev(values, target)
    gids = _pad_blocks_dev(gids, target)
    mask = _pad_blocks_dev(mask, target)
    _count()
    outs = (
        grouped_agg_pallas(values, gids, mask, n_groups, interpret=interp)
        if backend == "pallas"
        else _ref_grouped_agg_batch(values, gids, mask, n_groups)
    )
    return tuple(o[:nb] for o in outs)


def fused_agg_batch(packed: np.ndarray, k: int, mask, *, backend="auto"):
    """Fully-fused BITPACK decode -> masked ungrouped aggregate in ONE
    dispatch: stacked (nblocks, k, 128) pages + (nblocks, 4096) survivor
    mask -> 5 x (nblocks, 1) accumulators.  The decoded value column
    never leaves the kernel (the pushdown headline path)."""
    backend, interp = _resolve(backend)
    nb = packed.shape[0]
    target = bucket_blocks(nb)
    packed = _pad_blocks(packed, target)
    mask = _pad_blocks_dev(mask, target)
    _count()
    outs = (
        fused_agg_pallas(packed, k, mask, interpret=interp)
        if backend == "pallas"
        else _ref_fused_agg_batch(packed, mask, k)
    )
    return tuple(o[:nb] for o in outs)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None, backend="auto",
                    bq: int = 256, bk: int = 256):
    backend, interp = _resolve(backend)
    if backend == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale, bq=bq, bk=bk,
            interpret=interp,
        )
    return ref.mha(q, k, v, causal=causal, window=window, scale=scale)
