"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: each Pallas kernel in this package is
asserted allclose against the function of the same name here, across shape
and dtype sweeps (tests/test_kernels.py).  They are also the production
decode path on non-TPU backends and inside the 512-device dry-run, where
Pallas TPU lowering is unavailable (DESIGN.md §2).

All decoders consume the block layouts defined in lakeformat/encodings.py.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.lakeformat.encodings import LANES, PACK_BLOCK, RLE_OUT_BLOCK, RLE_WINDOW, SUBLANES


# ---------------------------------------------------------------------------
# bitunpack
# ---------------------------------------------------------------------------


def bitunpack(packed: jax.Array, k: int) -> jax.Array:
    """(nblocks, k, 128) uint32 -> (nblocks, 32, 128) int32 values.

    Statically-unrolled 32-row shift/mask ladder; no gathers.
    """
    assert packed.ndim == 3 and packed.shape[1] == k and packed.shape[2] == LANES
    p = packed.astype(jnp.uint32)
    if k == 32:
        return p.astype(jnp.int32).reshape(packed.shape[0], SUBLANES, LANES)
    mask = jnp.uint32((1 << k) - 1)
    rows = []
    for s in range(SUBLANES):
        w0, sh = divmod(s * k, 32)
        val = jax.lax.shift_right_logical(p[:, w0, :], jnp.uint32(sh))
        if sh + k > 32:
            val = val | jax.lax.shift_left(p[:, w0 + 1, :], jnp.uint32(32 - sh))
        rows.append(val & mask)
    return jnp.stack(rows, axis=1).astype(jnp.int32)


def bitunpack_flat(packed: jax.Array, k: int, n: int) -> jax.Array:
    return bitunpack(packed, k).reshape(-1)[:n]


# ---------------------------------------------------------------------------
# dict decode
# ---------------------------------------------------------------------------


def dict_decode(packed: jax.Array, dictionary: jax.Array, k: int) -> jax.Array:
    """(nblocks,k,128) codes + (D,) dict -> (nblocks,32,128) values."""
    codes = bitunpack(packed, k)
    return jnp.take(dictionary, codes, axis=0, mode="clip")


# ---------------------------------------------------------------------------
# rle decode
# ---------------------------------------------------------------------------


def rle_decode(values: jax.Array, ends: jax.Array) -> jax.Array:
    """(nblk,128) run values + (nblk,128) exclusive ends -> (nblk,1024).

    Rank lookup: position j belongs to the first run whose exclusive end
    exceeds j, i.e. rank(j) = |{r : ends[r] <= j}|.  The writer pads the
    run window with end=1024 repeats of the final value, so clipping the
    rank into the window re-reads that value for any padded tail.  A
    gather of the single owning run is exact for every dtype (no
    accumulation at all), unlike the old dense (nblk,1024,128) one-hot
    contraction it replaces — and it never materializes the cube.
    """
    e = ends.astype(jnp.int32)
    j = jnp.arange(RLE_OUT_BLOCK, dtype=jnp.int32)
    rank = jax.vmap(lambda eb: jnp.searchsorted(eb, j, side="right"))(e)
    idx = jnp.minimum(rank, RLE_WINDOW - 1)
    return jnp.take_along_axis(values, idx, axis=1)


# ---------------------------------------------------------------------------
# delta decode
# ---------------------------------------------------------------------------


def _unzigzag_i32(z: jax.Array) -> jax.Array:
    zu = z.astype(jnp.uint32)
    return (
        jax.lax.shift_right_logical(zu, jnp.uint32(1)).astype(jnp.int32)
        ^ -(zu & jnp.uint32(1)).astype(jnp.int32)
    )


def delta_decode(packed: jax.Array, bases: jax.Array, k: int) -> jax.Array:
    """(nblocks,k,128) zigzag deltas + (nblocks,) bases -> (nblocks,4096) int32.

    Value order is v = s*128 + l, so prefix sum = lane cumsum + row carries.
    """
    z = bitunpack(packed, k)  # (nb,32,128) int32 (zigzag, < 2^31)
    d = _unzigzag_i32(z)
    lane_cs = jnp.cumsum(d, axis=2)  # within-row prefix
    row_tot = lane_cs[:, :, -1]  # (nb,32)
    row_carry = jnp.cumsum(row_tot, axis=1) - row_tot  # exclusive
    out = lane_cs + row_carry[:, :, None] + bases.astype(jnp.int32)[:, None, None]
    return out.reshape(packed.shape[0], PACK_BLOCK)


# ---------------------------------------------------------------------------
# predicate eval + stream compaction
# ---------------------------------------------------------------------------


def filter_compact(values: jax.Array, mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block stream compaction.

    values: (nblk, B) any dtype; mask: (nblk, B) bool.
    Returns (compacted (nblk,B) with survivors packed to the front, counts (nblk,)).

    TPU-idiomatic form: permutation one-hot built from the mask prefix sum,
    contracted on the MXU.  Exact for f32 and for ints < 2^24 (the engine
    guarantees that for compacted int columns; larger ints are compacted in
    two f32 halves by the ops wrapper).
    """
    nblk, B = values.shape
    m = mask.astype(jnp.int32)
    pos = jnp.cumsum(m, axis=1) - 1  # target slot per survivor
    slots = jnp.arange(B, dtype=jnp.int32)[None, :, None]  # (1,B,1) target p
    onehot = ((pos[:, None, :] == slots) & mask[:, None, :])  # (nblk, p, j)
    if jnp.issubdtype(values.dtype, jnp.floating):
        out = jnp.einsum("bpj,bj->bp", onehot.astype(jnp.float32), values.astype(jnp.float32))
        out = out.astype(values.dtype)
    else:
        out = jnp.einsum(
            "bpj,bj->bp", onehot.astype(jnp.float32), values.astype(jnp.float32)
        ).astype(values.dtype)
    return out, jnp.sum(m, axis=1)


# ---------------------------------------------------------------------------
# bloom probe
# ---------------------------------------------------------------------------

_BLOOM_C1 = jnp.uint32(0xCC9E2D51)
_BLOOM_C2 = jnp.uint32(0x1B873593)


def _mix(h: jax.Array) -> jax.Array:
    h = h ^ jax.lax.shift_right_logical(h, jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ jax.lax.shift_right_logical(h, jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    return h ^ jax.lax.shift_right_logical(h, jnp.uint32(16))


def bloom_hashes(keys: jax.Array, n_hashes: int, n_bits: int):
    """Double hashing: idx_i = (h1 + i*h2) mod n_bits.  n_bits power of two."""
    ku = keys.astype(jnp.uint32)
    h1 = _mix(ku * _BLOOM_C1)
    h2 = _mix(ku * _BLOOM_C2) | jnp.uint32(1)
    mod = jnp.uint32(n_bits - 1)
    return [(h1 + jnp.uint32(i) * h2) & mod for i in range(n_hashes)]


def bloom_build(keys: jax.Array, n_bits: int, n_hashes: int = 4) -> jax.Array:
    """Build a bloom filter as (n_bits,) uint8 (byte-per-bit for gather-free probing)."""
    bits = jnp.zeros((n_bits,), jnp.uint8)
    for idx in bloom_hashes(keys, n_hashes, n_bits):
        bits = bits.at[idx].set(jnp.uint8(1))
    return bits


def bloom_probe(keys: jax.Array, bits: jax.Array, n_hashes: int = 4) -> jax.Array:
    """Membership mask (no false negatives)."""
    n_bits = bits.shape[0]
    out = jnp.ones(keys.shape, jnp.bool_)
    for idx in bloom_hashes(keys, n_hashes, n_bits):
        out = out & (jnp.take(bits, idx.astype(jnp.int32), mode="clip") > 0)
    return out


# ---------------------------------------------------------------------------
# fused scan: decode (bitpack|dict) -> range predicate -> mask + counts
# ---------------------------------------------------------------------------


def fused_scan(
    packed: jax.Array,
    k: int,
    lo: jax.Array,
    hi: jax.Array,
    dictionary: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Decode one filter column and evaluate lo <= v <= hi in one pass.

    Returns (mask (nblocks, 4096) bool, per-block survivor counts (nblocks,)).
    """
    vals = bitunpack(packed, k) if dictionary is None else dict_decode(packed, dictionary, k)
    vals = vals.reshape(packed.shape[0], PACK_BLOCK)
    mask = (vals >= lo.astype(vals.dtype)) & (vals <= hi.astype(vals.dtype))
    return mask, jnp.sum(mask.astype(jnp.int32), axis=1)


# ---------------------------------------------------------------------------
# grouped aggregate pushdown: per-block partial accumulators
# ---------------------------------------------------------------------------

# int32 sums are computed exactly as a 16-bit hi/lo split per block:
# v == (v >> 16) * 2^16 + (v & 0xFFFF) in two's complement, and both
# partial sums fit int32 for any 4096-row block (4096 * 0xFFFF < 2^28),
# so the host-side int64 recombination is EXACT — which is what makes the
# merge associative and the fabric's partial-aggregate reduction
# bit-identical under any bucket/row-group/pod split.
AGG_INT_SHIFT = 16
AGG_INT_MASK = 0xFFFF

# identity fills for (block, group) cells with no masked member.  Plain
# Python scalars on purpose: jnp constants would be captured by the
# pallas kernel bodies that call grouped_agg, which pallas_call rejects.
AGG_INT_MIN_IDENT = 2**31 - 1
AGG_INT_MAX_IDENT = -(2**31)
AGG_FLT_MIN_IDENT = float("inf")
AGG_FLT_MAX_IDENT = float("-inf")


def grouped_agg(
    values: jax.Array, gids: jax.Array, mask: jax.Array, n_groups: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """(nblk, B) values + (nblk, B) int32 group ids + (nblk, B) mask ->
    per-block partial accumulators, each (nblk, n_groups):

      cnt  int32    masked member count
      s0   float32  block sums          (float values)
           int32    sum of (v >> 16)    (int values, arithmetic shift)
      s1   int32    sum of (v & 0xFFFF) (int values; zeros for float)
      mn   value dtype, min (identity fill where the cell is empty)
      mx   value dtype, max (identity fill where the cell is empty)

    All reductions are WITHIN a block (axis 1), so computing any subset of
    blocks yields bit-identical rows — the pallas kernel's grid steps and
    this oracle agree exactly, and cross-block merging happens host-side
    in int64/float64 (core/agg.py)."""
    oh = (
        gids.astype(jnp.int32)[:, :, None]
        == jnp.arange(n_groups, dtype=jnp.int32)[None, None, :]
    ) & (mask.astype(jnp.int32) != 0)[:, :, None]
    cnt = jnp.sum(oh.astype(jnp.int32), axis=1)
    v = values[:, :, None]
    if jnp.issubdtype(values.dtype, jnp.floating):
        s0 = jnp.sum(jnp.where(oh, v.astype(jnp.float32), 0.0), axis=1)
        s1 = jnp.zeros_like(cnt)
        mn = jnp.min(jnp.where(oh, v, AGG_FLT_MIN_IDENT), axis=1)
        mx = jnp.max(jnp.where(oh, v, AGG_FLT_MAX_IDENT), axis=1)
    else:
        vi = values.astype(jnp.int32)[:, :, None]
        s0 = jnp.sum(jnp.where(oh, vi >> AGG_INT_SHIFT, 0), axis=1)
        s1 = jnp.sum(jnp.where(oh, vi & AGG_INT_MASK, 0), axis=1)
        mn = jnp.min(jnp.where(oh, vi, AGG_INT_MIN_IDENT), axis=1)
        mx = jnp.max(jnp.where(oh, vi, AGG_INT_MAX_IDENT), axis=1)
    return cnt, s0, s1, mn.astype(values.dtype), mx.astype(values.dtype)


def fused_agg_scan(
    packed: jax.Array, k: int, mask: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fully-fused BITPACK decode -> masked ungrouped aggregate: the value
    column never exists outside the kernel.  Returns the same 5-tuple as
    `grouped_agg` with n_groups == 1 (shapes (nblk, 1))."""
    vals = bitunpack(packed, k).reshape(packed.shape[0], PACK_BLOCK)
    gids = jnp.zeros(vals.shape, jnp.int32)
    return grouped_agg(vals, gids, mask, 1)


# ---------------------------------------------------------------------------
# attention (oracle for flash_attention kernel)
# ---------------------------------------------------------------------------


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Plain softmax attention.  q: (B,H,Sq,D), k/v: (B,Hkv,Sk,D); GQA by head repeat."""
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    Sk = k.shape[2]
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)  # align ends (decode-friendly)
    ki = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), jnp.bool_)
    if causal:
        m = m & (ki <= qi)
    if window is not None:
        m = m & (ki > qi - window)
    logits = jnp.where(m[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
