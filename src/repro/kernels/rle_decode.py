"""Pallas TPU kernel: block-aligned RLE expansion by rank lookup.

The writer (lakeformat) clips runs at 1024-value block boundaries and pads
each block's run window to exactly RLE_WINDOW = 128 entries (repeating the
final value with end = 1024), so the kernel is fully static.  `ends` IS
the cumulative sum of run lengths, so the run owning output position j is
its rank:  rank(j) = |{r : ends[r] <= j}|.  The kernel counts that rank
with a lane comparison per 128-wide output tile — a (G,128,128) compare
summed over the run axis — then reads the owning run's value.  Working set
per tile is 8x smaller than the old dense (G,1024,128) run-membership
one-hot, and there is no MXU/VPU accumulation at all: reading the single
owning run is exact for every dtype, so the float/int split disappears.

This keeps the *bounded decoder working set* property — the TPU analogue
of the paper's "decoders should share resources" co-design (DESIGN.md §4):
no data-dependent loop, deterministic VMEM footprint per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.lakeformat.encodings import RLE_OUT_BLOCK, RLE_WINDOW

DEFAULT_GROUP = 4


def _kernel(vals_ref, ends_ref, out_ref):
    vals = vals_ref[...]  # (G, 128)
    ends = ends_ref[...].astype(jnp.int32)[:, None, :]  # (G, 1, 128)
    tiles = []
    for t in range(RLE_OUT_BLOCK // RLE_WINDOW):  # 8 static 128-wide tiles
        j = jax.lax.broadcasted_iota(jnp.int32, (1, RLE_WINDOW, 1), 1)
        j = j + t * RLE_WINDOW
        # rank(j) = how many runs end at or before j; clip into the window
        # so the padded tail re-reads the final (repeated) run value
        rank = jnp.sum((ends <= j).astype(jnp.int32), axis=-1)  # (G, 128)
        idx = jnp.minimum(rank, RLE_WINDOW - 1)
        tiles.append(jnp.take_along_axis(vals, idx, axis=1))
    out_ref[...] = jnp.concatenate(tiles, axis=1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "interpret"))
def rle_decode_pallas(
    values: jax.Array, ends: jax.Array, *, group: int = DEFAULT_GROUP, interpret: bool = True
) -> jax.Array:
    """(nblk,128) run values + (nblk,128) ends -> (nblk,1024) decoded."""
    nblk = values.shape[0]
    group = min(group, nblk)
    pad = (-nblk) % group
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        ends = jnp.pad(ends, ((0, pad), (0, 0)), constant_values=RLE_OUT_BLOCK)
    steps = values.shape[0] // group
    out = pl.pallas_call(
        _kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, RLE_WINDOW), lambda i: (i, 0)),
            pl.BlockSpec((group, RLE_WINDOW), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((group, RLE_OUT_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((values.shape[0], RLE_OUT_BLOCK), values.dtype),
        interpret=interpret,
    )(values, ends)
    return out[:nblk]
