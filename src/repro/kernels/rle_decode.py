"""Pallas TPU kernel: block-aligned RLE expansion.

The writer (lakeformat) clips runs at 1024-value block boundaries and pads
each block's run window to exactly RLE_WINDOW = 128 entries, so the kernel
is fully static: expansion of one block is a (1024 x 128) run-membership
one-hot contracted with the 128 run values.  Integer columns accumulate in
int32 on the VPU (exact); float columns contract on the MXU.

This trades storage (fixed window) for a *bounded decoder working set* —
the TPU analogue of the paper's "decoders should share resources" co-design
(DESIGN.md §4): no data-dependent loop, no gather, deterministic VMEM
footprint per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.lakeformat.encodings import RLE_OUT_BLOCK, RLE_WINDOW

DEFAULT_GROUP = 4


def _kernel(is_float: bool, vals_ref, ends_ref, out_ref):
    vals = vals_ref[...]  # (G, 128)
    ends = ends_ref[...].astype(jnp.int32)  # (G, 128)
    G = vals.shape[0]
    j = jax.lax.broadcasted_iota(jnp.int32, (1, RLE_OUT_BLOCK, 1), 1)
    e = ends[:, None, :]
    starts = jnp.concatenate([jnp.zeros((G, 1, 1), jnp.int32), e[..., :-1]], axis=-1)
    member = (j >= starts) & (j < e)  # (G, 1024, 128)
    if is_float:
        out = jax.lax.dot_general(
            member.astype(jnp.float32),
            vals[:, :, None].astype(jnp.float32),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )[..., 0]
        out_ref[...] = out.astype(out_ref.dtype)
    else:
        out = jnp.sum(member.astype(jnp.int32) * vals[:, None, :].astype(jnp.int32), axis=-1)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "interpret"))
def rle_decode_pallas(
    values: jax.Array, ends: jax.Array, *, group: int = DEFAULT_GROUP, interpret: bool = True
) -> jax.Array:
    """(nblk,128) run values + (nblk,128) ends -> (nblk,1024) decoded."""
    nblk = values.shape[0]
    group = min(group, nblk)
    pad = (-nblk) % group
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        ends = jnp.pad(ends, ((0, pad), (0, 0)), constant_values=RLE_OUT_BLOCK)
    is_float = jnp.issubdtype(values.dtype, jnp.floating)
    steps = values.shape[0] // group
    out = pl.pallas_call(
        functools.partial(_kernel, bool(is_float)),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((group, RLE_WINDOW), lambda i: (i, 0)),
            pl.BlockSpec((group, RLE_WINDOW), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((group, RLE_OUT_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((values.shape[0], RLE_OUT_BLOCK), values.dtype),
        interpret=interpret,
    )(values, ends)
    return out[:nblk]
