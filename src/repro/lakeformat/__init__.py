"""lakeformat: the columnar, TPU-decodable storage substrate ("Parquet" analog).

Encodings are co-designed with the Pallas decoders (see DESIGN.md §4):
  - BITPACK(k): lane-transposed k-bit packing; decode is pure shift/mask VPU ops
  - DICT(k):    dictionary + bitpacked codes
  - RLE:        block-aligned runs with a fixed per-block run window
  - DELTA(k):   zigzag deltas, bitpacked, blocked prefix-sum decode
  - PLAIN:      raw values

Files carry per-row-group zone maps (min/max/count) for pruning.
"""

from repro.lakeformat.encodings import (  # noqa: F401
    PACK_BLOCK,
    LANES,
    SUBLANES,
    RLE_OUT_BLOCK,
    RLE_WINDOW,
    Encoding,
    EncodedColumn,
    encode_column,
    decode_column_host,
    bitpack_encode,
    bitpack_decode_np,
    bits_needed,
)
from repro.lakeformat.schema import ColumnSchema, TableSchema  # noqa: F401
from repro.lakeformat.writer import LakeWriter, write_table  # noqa: F401
from repro.lakeformat.reader import LakeReader  # noqa: F401
