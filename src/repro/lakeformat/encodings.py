"""Host-side (numpy) encoders + host decoders for lakeformat encodings.

The bit layout is co-designed with the TPU decoder (kernels/bitunpack.py):

BITPACK(k), 1 <= k <= 32
------------------------
Values are grouped into blocks of PACK_BLOCK = 4096, viewed as a (32, 128)
matrix in *row-major value order* (value v sits at row s = v // 128,
lane l = v % 128).  Each lane packs its 32 values vertically into exactly
k uint32 words: row s occupies bits [s*k, (s+1)*k) of the lane's 32*k-bit
budget.  Packed block shape: (k, 128) uint32.

The decoder therefore needs, per row s (statically unrolled, 32 rows):
    w0, sh = divmod(s*k, 32)
    val    = packed[w0] >> sh            # vector over 128 lanes
    if sh + k > 32: val |= packed[w0+1] << (32 - sh)
    out[s] = val & ((1 << k) - 1)
-- no gathers, no transposes, per-row-constant shifts: pure VPU work.
This is the FastLanes-style "unified transposed layout" adapted to the
8x128 TPU vector register shape.

RLE
---
Outputs are blocked at RLE_OUT_BLOCK = 1024.  The writer clips runs at
block boundaries so each block is self-contained, and requires
<= RLE_WINDOW = 128 runs per block (else the caller falls back to
BITPACK/DICT).  Per block we store `values[128]` and exclusive
cumulative `ends[128]` (within-block, padded by repeating the final
end=1024).  Decode of one block is a (1024 x 128) one-hot times
(128,) values contraction -- MXU-friendly.

DELTA(k)
--------
Per PACK_BLOCK block: int32 base + zigzag-encoded deltas bitpacked at k
bits.  Decode = bitunpack -> unzigzag -> prefix sum + base.

DICT(k)
-------
`dictionary` (plain values) + BITPACK(k) codes, k = bits(len(dict)).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Optional

import numpy as np

PACK_BLOCK = 4096  # values per bitpack block
LANES = 128
SUBLANES = 32  # PACK_BLOCK == SUBLANES * LANES
RLE_OUT_BLOCK = 1024
RLE_WINDOW = 128

_U32 = np.uint32
_MASK32 = np.uint64(0xFFFFFFFF)


class Encoding(enum.Enum):
    PLAIN = "plain"
    BITPACK = "bitpack"
    DICT = "dict"
    RLE = "rle"
    DELTA = "delta"


@dataclasses.dataclass
class EncodedColumn:
    """One column of one row group, encoded."""

    encoding: Encoding
    n: int  # logical value count
    dtype: str  # logical dtype: 'int32' | 'float32'
    k: int = 0  # bit width for BITPACK/DICT/DELTA
    # Buffers (all numpy, layout per encoding):
    #  BITPACK: packed (nblocks, k, 128) uint32
    #  DICT:    packed codes + dictionary (ndict,) of logical dtype
    #  RLE:     rle_values (nblk, 128) int32/float32, rle_ends (nblk, 128) int32
    #  DELTA:   packed zigzag deltas + bases (nblocks,) int32
    #  PLAIN:   plain (n,) logical dtype
    buffers: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def encoded_bytes(self) -> int:
        return sum(int(b.nbytes) for b in self.buffers.values())

    def plain_bytes(self) -> int:
        return self.n * 4


def padded_rows(n: int) -> int:
    """Rows the engine actually materializes for an n-row row group: decode
    output is padded to the PACK_BLOCK boundary (kernel block shape), so
    honest decoded-byte accounting must size L, not n."""
    return -(-n // PACK_BLOCK) * PACK_BLOCK


def bits_needed(max_value: int) -> int:
    """Bits to represent values in [0, max_value]."""
    if max_value <= 0:
        return 1
    return max(1, int(max_value).bit_length())


# ---------------------------------------------------------------------------
# BITPACK
# ---------------------------------------------------------------------------


def _pad_to_blocks(values: np.ndarray) -> np.ndarray:
    n = values.shape[0]
    nblocks = max(1, math.ceil(n / PACK_BLOCK))
    out = np.zeros(nblocks * PACK_BLOCK, dtype=np.uint64)
    out[:n] = values.astype(np.uint64)
    return out.reshape(nblocks, SUBLANES, LANES)


def bitpack_encode(values: np.ndarray, k: int) -> np.ndarray:
    """Pack non-negative ints < 2**k.  Returns (nblocks, k, 128) uint32."""
    assert 1 <= k <= 32, k
    v = _pad_to_blocks(values)
    if np.any(v >= (np.uint64(1) << np.uint64(k))):
        raise ValueError(f"value does not fit in {k} bits")
    nblocks = v.shape[0]
    packed = np.zeros((nblocks, k, LANES), dtype=np.uint64)
    for s in range(SUBLANES):
        off = s * k
        w0, sh = divmod(off, 32)
        acc = v[:, s, :] << np.uint64(sh)
        packed[:, w0, :] |= acc & _MASK32
        if sh + k > 32:
            packed[:, w0 + 1, :] |= acc >> np.uint64(32)
    return packed.astype(_U32)


def bitpack_decode_np(packed: np.ndarray, k: int, n: int) -> np.ndarray:
    """Host decoder (oracle for the jnp/Pallas decoders).  Returns uint32 (n,)."""
    assert packed.ndim == 3 and packed.shape[1] == k and packed.shape[2] == LANES
    p = packed.astype(np.uint64)
    nblocks = p.shape[0]
    mask = (np.uint64(1) << np.uint64(k)) - np.uint64(1)
    rows = np.empty((nblocks, SUBLANES, LANES), dtype=np.uint64)
    for s in range(SUBLANES):
        off = s * k
        w0, sh = divmod(off, 32)
        val = p[:, w0, :] >> np.uint64(sh)
        if sh + k > 32:
            val |= p[:, w0 + 1, :] << np.uint64(32 - sh)
        rows[:, s, :] = val & mask
    return rows.reshape(-1)[:n].astype(_U32)


# ---------------------------------------------------------------------------
# RLE
# ---------------------------------------------------------------------------


def _compute_runs(values: np.ndarray):
    """Return (run_values, run_lengths)."""
    n = values.shape[0]
    if n == 0:
        return values[:0], np.zeros(0, dtype=np.int64)
    change = np.nonzero(np.diff(values))[0] + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])
    return values[starts], ends - starts


def rle_encode(values: np.ndarray) -> Optional[Dict[str, np.ndarray]]:
    """Block-aligned RLE.  Returns None if any block exceeds RLE_WINDOW runs."""
    n = values.shape[0]
    nblk = max(1, math.ceil(n / RLE_OUT_BLOCK))
    padded = np.zeros(nblk * RLE_OUT_BLOCK, dtype=values.dtype)
    padded[:n] = values
    if n:
        padded[n:] = values[-1]
    blocks = padded.reshape(nblk, RLE_OUT_BLOCK)
    out_vals = np.zeros((nblk, RLE_WINDOW), dtype=values.dtype)
    out_ends = np.zeros((nblk, RLE_WINDOW), dtype=np.int32)
    for b in range(nblk):
        rv, rl = _compute_runs(blocks[b])
        if rv.shape[0] > RLE_WINDOW:
            return None
        ends = np.cumsum(rl)
        r = rv.shape[0]
        out_vals[b, :r] = rv
        out_ends[b, :r] = ends
        out_vals[b, r:] = rv[-1] if r else 0
        out_ends[b, r:] = RLE_OUT_BLOCK
    return {"rle_values": out_vals, "rle_ends": out_ends}


def rle_decode_np(bufs: Dict[str, np.ndarray], n: int) -> np.ndarray:
    vals, ends = bufs["rle_values"], bufs["rle_ends"]
    nblk = vals.shape[0]
    j = np.arange(RLE_OUT_BLOCK)
    out = np.empty((nblk, RLE_OUT_BLOCK), dtype=vals.dtype)
    for b in range(nblk):
        idx = np.searchsorted(ends[b], j, side="right")
        out[b] = vals[b][np.minimum(idx, RLE_WINDOW - 1)]
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# DELTA
# ---------------------------------------------------------------------------


def _zigzag(d: np.ndarray) -> np.ndarray:
    d = d.astype(np.int64)
    return ((d << 1) ^ (d >> 63)).astype(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    z = z.astype(np.uint64)
    return ((z >> np.uint64(1)).astype(np.int64)) ^ -(z & np.uint64(1)).astype(np.int64)


def delta_encode(values: np.ndarray) -> Optional[Dict[str, np.ndarray]]:
    """Per-block base + zigzag deltas.  Returns None if deltas need > 30 bits."""
    v = values.astype(np.int64)
    n = v.shape[0]
    nblocks = max(1, math.ceil(n / PACK_BLOCK))
    padded = np.zeros(nblocks * PACK_BLOCK, dtype=np.int64)
    padded[:n] = v
    if n:
        padded[n:] = v[-1]
    blocks = padded.reshape(nblocks, PACK_BLOCK)
    bases = blocks[:, 0].astype(np.int64)
    deltas = np.diff(blocks, axis=1, prepend=blocks[:, :1])  # delta[0] == 0
    zz = _zigzag(deltas.reshape(-1))
    kmax = bits_needed(int(zz.max())) if zz.size else 1
    if kmax > 30:
        return None
    packed = bitpack_encode(zz, kmax)
    return {"packed": packed, "bases": bases.astype(np.int64), "_k": np.array([kmax])}


def delta_decode_np(bufs: Dict[str, np.ndarray], k: int, n: int) -> np.ndarray:
    packed, bases = bufs["packed"], bufs["bases"]
    nblocks = packed.shape[0]
    zz = bitpack_decode_np(packed, k, nblocks * PACK_BLOCK)
    deltas = _unzigzag(zz).reshape(nblocks, PACK_BLOCK)
    out = np.cumsum(deltas, axis=1) + bases[:, None]
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# DICT
# ---------------------------------------------------------------------------


def dict_encode(values: np.ndarray, max_dict: int = 1 << 16) -> Optional[Dict[str, np.ndarray]]:
    dictionary, codes = np.unique(values, return_inverse=True)
    if dictionary.shape[0] > max_dict:
        return None
    k = bits_needed(dictionary.shape[0] - 1)
    packed = bitpack_encode(codes.astype(np.uint64), k)
    return {"packed": packed, "dictionary": dictionary, "_k": np.array([k])}


def dict_decode_np(bufs: Dict[str, np.ndarray], k: int, n: int) -> np.ndarray:
    codes = bitpack_decode_np(bufs["packed"], k, n)
    return bufs["dictionary"][codes]


# ---------------------------------------------------------------------------
# Column-level entry points
# ---------------------------------------------------------------------------


def _as_storage_ints(values: np.ndarray) -> np.ndarray:
    """Bit-cast float32 to uint32 so float columns can ride integer encodings."""
    if values.dtype == np.float32:
        return values.view(np.uint32).astype(np.uint64)
    return values.astype(np.int64)


def encode_column(
    values: np.ndarray,
    encoding: Encoding | str = "auto",
    dtype: Optional[str] = None,
) -> EncodedColumn:
    """Encode one column.  'auto' picks, in order: RLE (if runs are long),
    DICT (if low cardinality), DELTA (if sorted-ish ints), BITPACK
    (non-negative ints), PLAIN."""
    n = int(values.shape[0])
    dtype = dtype or ("float32" if values.dtype.kind == "f" else "int32")
    if isinstance(encoding, str) and encoding != "auto":
        encoding = Encoding(encoding)

    def make(enc, k=0, **bufs):
        return EncodedColumn(encoding=enc, n=n, dtype=dtype, k=k, buffers=bufs)

    if encoding == Encoding.PLAIN:
        return make(Encoding.PLAIN, plain=values.astype(dtype))

    if encoding in (Encoding.RLE, "auto") or encoding == "auto":
        pass  # fallthrough logic below

    ints = _as_storage_ints(values)

    if encoding == Encoding.RLE or encoding == "auto":
        # RLE only pays off (and fits the window) with long runs.
        rv, _ = _compute_runs(values)
        if rv.shape[0] * 8 <= n or encoding == Encoding.RLE:
            bufs = rle_encode(values.astype(dtype))
            if bufs is not None:
                return make(Encoding.RLE, **bufs)
            if encoding == Encoding.RLE:
                raise ValueError("RLE window exceeded; use auto")

    if encoding == Encoding.DICT or encoding == "auto":
        card = np.unique(values).shape[0] if n else 0
        if encoding == Encoding.DICT or (card and card <= max(16, n // 4) and card <= (1 << 16)):
            bufs = dict_encode(values)
            if bufs is not None:
                k = int(bufs.pop("_k")[0])
                return make(Encoding.DICT, k=k, **bufs)
            if encoding == Encoding.DICT:
                raise ValueError("dictionary too large")

    if encoding == Encoding.DELTA or encoding == "auto":
        if dtype == "int32":
            is_sortedish = n > 1 and np.mean(np.diff(ints) >= 0) > 0.9
            if encoding == Encoding.DELTA or is_sortedish:
                bufs = delta_encode(ints)
                if bufs is not None:
                    k = int(bufs.pop("_k")[0])
                    return make(Encoding.DELTA, k=k, **bufs)
                if encoding == Encoding.DELTA:
                    raise ValueError("delta overflow")

    if encoding == Encoding.BITPACK or encoding == "auto":
        if dtype == "int32" and n and ints.min() >= 0:
            k = bits_needed(int(ints.max()))
            if k < 32 or encoding == Encoding.BITPACK:
                return make(Encoding.BITPACK, k=k, packed=bitpack_encode(ints, k))
        elif encoding == Encoding.BITPACK:
            raise ValueError("bitpack requires non-negative ints")

    return make(Encoding.PLAIN, plain=values.astype(dtype))


def decode_column_host(col: EncodedColumn) -> np.ndarray:
    """Full host decode (the 'CPU does everything' baseline)."""
    e, n = col.encoding, col.n
    if e == Encoding.PLAIN:
        return col.buffers["plain"][:n]
    if e == Encoding.BITPACK:
        out = bitpack_decode_np(col.buffers["packed"], col.k, n)
        return out.view(np.float32) if col.dtype == "float32" else out.astype(np.int32)
    if e == Encoding.DICT:
        out = dict_decode_np(col.buffers, col.k, n)
        return out.astype(col.dtype) if col.dtype != "float32" else out.astype(np.float32)
    if e == Encoding.RLE:
        return rle_decode_np(col.buffers, n).astype(col.dtype)
    if e == Encoding.DELTA:
        return delta_decode_np(col.buffers, col.k, n).astype(np.int32)
    raise ValueError(e)
