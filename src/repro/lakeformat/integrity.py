"""Page integrity: per-page checksums for lakeformat files.

The storage->NIC hop is a network hop, and networks corrupt bytes.  The
writer stamps a CRC32 of every encoded page (one column of one row
group) into the footer; the engine verifies it on every storage fetch
(core/engine._storage_read) before the page can reach a decode kernel.
Legacy files whose footers predate the field fall back to UNVERIFIED —
they still read, but bit-rot on them is invisible (telemetry counts the
unverified pages so the operator can see the exposure).

The checksum covers everything a decode kernel consumes: the encoding
tag, row count, dtype, bit width, and every buffer's name, dtype, shape
and raw bytes — so a truncated (short-read) buffer fails exactly like a
flipped bit.  CRC32 (zlib) runs at GB/s on commodity CPUs, which keeps
verification noise against even the calibrated decode rates.

This module lives in lakeformat (not datapath) on purpose: core/engine
may not import repro.datapath (package-init import cycle), but it must
be able to verify pages and raise the typed error.  The fault plane
(datapath/faults.py) re-exports `CorruptPageError` for service callers.
"""

from __future__ import annotations

import zlib
from typing import Optional

import numpy as np

from repro.lakeformat.encodings import EncodedColumn


class CorruptPageError(RuntimeError):
    """A fetched page failed checksum verification.  Raised by the engine
    BEFORE the page can reach a decode kernel; the fault plane quarantines
    the page key in the BlockStore and re-fetches."""

    def __init__(self, msg: str, table: str = "", rg: int = -1,
                 column: str = ""):
        super().__init__(msg)
        self.table = table
        self.rg = rg
        self.column = column


def page_checksum(col: EncodedColumn) -> int:
    """CRC32 over one encoded page's metadata + buffer bytes.

    Buffers are folded in sorted-name order so the checksum is a pure
    function of the page's content, independent of dict insertion order.
    """
    crc = zlib.crc32(
        f"{col.encoding.value}|{col.n}|{col.dtype}|{col.k}".encode()
    )
    for name in sorted(col.buffers):
        buf = np.ascontiguousarray(col.buffers[name])
        head = f"|{name}|{buf.dtype}|{buf.shape}".encode()
        crc = zlib.crc32(buf.tobytes(), zlib.crc32(head, crc))
    return crc & 0xFFFFFFFF


def verify_page(col: EncodedColumn, expected: Optional[int]) -> bool:
    """True iff the page matches `expected`.  `expected is None` (legacy
    footer without the field) verifies trivially — the caller decides
    whether to count the page as unverified."""
    if expected is None:
        return True
    return page_checksum(col) == int(expected)
