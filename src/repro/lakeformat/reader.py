"""lakeformat binary reader.

The reader never decodes: it hands back `EncodedColumn`s (raw buffers +
metadata).  Decoding is the job of the datapath engine (core/engine.py) —
on-device by default, mirroring the SmartNIC position in the paper.
Zone maps are available without touching data bytes.
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.lakeformat.encodings import EncodedColumn, Encoding
from repro.lakeformat.writer import MAGIC


class LakeReader:
    def __init__(self, path: str):
        self.path = path
        with open(path, "rb") as f:
            data = f.read()
        if data[: len(MAGIC)] != MAGIC or data[-len(MAGIC):] != MAGIC:
            raise ValueError(f"{path}: not a lakeformat file")
        (footer_len,) = struct.unpack("<Q", data[-len(MAGIC) - 8 : -len(MAGIC)])
        footer_start = len(data) - len(MAGIC) - 8 - footer_len
        self.footer = json.loads(data[footer_start : footer_start + footer_len])
        self._data = data
        self.n_rows: int = self.footer["n_rows"]
        self.n_row_groups: int = len(self.footer["row_groups"])
        self.column_names: List[str] = [c["name"] for c in self.footer["schema"]["columns"]]
        self.string_dicts: Dict[str, List[str]] = self.footer.get("string_dicts", {})

    # -- metadata ----------------------------------------------------------
    def zonemaps(self, column: str) -> List[dict]:
        return [rg["columns"][column]["zonemap"] for rg in self.footer["row_groups"]]

    def row_group_meta(self, rg: int) -> dict:
        return self.footer["row_groups"][rg]

    def page_checksum_meta(self, rg: int, column: str) -> Optional[int]:
        """Footer checksum for one page, or None on legacy files written
        before the field existed (those pages read back unverified)."""
        cmeta = self.footer["row_groups"][rg]["columns"].get(column)
        if cmeta is None:
            return None
        ck = cmeta.get("checksum")
        return None if ck is None else int(ck)

    def decoded_dtype(self, column: str) -> np.dtype:
        """Dtype of the DECODED device column: float32 columns decode to
        float32, everything else (ints, string codes) to int32.  Lets the
        engine build schema-correct empty results without decoding."""
        for c in self.footer["schema"]["columns"]:
            if c["name"] == column:
                return np.dtype("float32" if c["dtype"] == "float32" else "int32")
        raise KeyError(column)

    def string_code(self, column: str, value: str) -> int:
        """Host-side constant folding: a string predicate constant -> code."""
        try:
            return self.string_dicts[column].index(value)
        except ValueError:
            return -1  # matches nothing

    def encoded_bytes(self, columns: Optional[Sequence[str]] = None) -> int:
        total = 0
        for rg in self.footer["row_groups"]:
            for name, c in rg["columns"].items():
                if columns is None or name in columns:
                    total += c["encoded_bytes"]
        return total

    # -- data --------------------------------------------------------------
    def _buffer(self, meta: dict) -> np.ndarray:
        off, nbytes = meta["offset"], meta["nbytes"]
        dt = np.dtype(meta["dtype"])
        arr = np.frombuffer(self._data, dtype=dt, count=nbytes // dt.itemsize, offset=off)
        return arr.reshape(meta["shape"])

    def read_encoded(self, rg: int, columns: Optional[Sequence[str]] = None) -> Dict[str, EncodedColumn]:
        """Raw encoded column buffers for one row group (zero decode work)."""
        rgmeta = self.footer["row_groups"][rg]
        out = {}
        for name, cmeta in rgmeta["columns"].items():
            if columns is not None and name not in columns:
                continue
            bufs = {bname: self._buffer(bmeta) for bname, bmeta in cmeta["buffers"].items()}
            out[name] = EncodedColumn(
                encoding=Encoding(cmeta["encoding"]),
                n=cmeta["n"],
                dtype=cmeta["dtype"],
                k=cmeta["k"],
                buffers=bufs,
            )
        return out
