"""Table/column schemas for lakeformat.

Strings are dictionary-mapped to int32 codes at the schema layer (the
per-file string dictionary lives in the footer); on-device predicates on
string columns become integer code comparisons, as in real columnar engines.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class ColumnSchema:
    name: str
    dtype: str  # 'int32' | 'float32' | 'str'
    encoding: str = "auto"  # encoding hint: auto|plain|bitpack|dict|rle|delta

    @property
    def storage_dtype(self) -> str:
        return "int32" if self.dtype == "str" else self.dtype


@dataclasses.dataclass
class TableSchema:
    name: str
    columns: List[ColumnSchema]

    def __post_init__(self):
        self._by_name = {c.name: c for c in self.columns}

    def column(self, name: str) -> ColumnSchema:
        return self._by_name[name]

    def names(self) -> List[str]:
        return [c.name for c in self.columns]


def strings_to_codes(values, existing: Optional[Dict[str, int]] = None):
    """Map an array/list of strings to int32 codes + the dictionary (list)."""
    mapping: Dict[str, int] = dict(existing or {})
    codes = np.empty(len(values), dtype=np.int32)
    for i, v in enumerate(values):
        code = mapping.get(v)
        if code is None:
            code = len(mapping)
            mapping[v] = code
        codes[i] = code
    dictionary = [None] * len(mapping)
    for s, c in mapping.items():
        dictionary[c] = s
    return codes, dictionary
