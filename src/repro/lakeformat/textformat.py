"""CSV / JSON-lines writers and host parsers — the paper's slow baselines.

The paper (Fig. 3a) measures TPC-H directly on CSV and JSON at 14-16x lower
throughput than Parquet.  These parsers are deliberately the straightforward
host implementations (split/str->number conversion per field), because the
point being reproduced is that text parsing is serial, branchy CPU work
with no TPU analogue (DESIGN.md §2): the accelerator never sees text.
"""

from __future__ import annotations

import json
from typing import Dict, List, Sequence

import numpy as np

from repro.lakeformat.schema import TableSchema, strings_to_codes


def write_csv(path: str, schema: TableSchema, columns: Dict[str, Sequence]) -> str:
    names = schema.names()
    n = len(columns[names[0]])
    with open(path, "w") as f:
        f.write(",".join(names) + "\n")
        for i in range(n):
            row = []
            for cs in schema.columns:
                v = columns[cs.name][i]
                if cs.dtype == "float32":
                    row.append(f"{float(v):.6f}")
                elif cs.dtype == "str":
                    row.append(str(v))
                else:
                    row.append(str(int(v)))
            f.write(",".join(row) + "\n")
    return path


def write_jsonl(path: str, schema: TableSchema, columns: Dict[str, Sequence]) -> str:
    names = schema.names()
    n = len(columns[names[0]])
    with open(path, "w") as f:
        for i in range(n):
            rec = {}
            for cs in schema.columns:
                v = columns[cs.name][i]
                if cs.dtype == "float32":
                    rec[cs.name] = float(v)
                elif cs.dtype == "str":
                    rec[cs.name] = str(v)
                else:
                    rec[cs.name] = int(v)
            f.write(json.dumps(rec) + "\n")
    return path


def parse_csv(path: str, schema: TableSchema) -> Dict[str, np.ndarray]:
    """Straightforward per-field CSV parse (quote-free dialect)."""
    cols: Dict[str, list] = {c.name: [] for c in schema.columns}
    with open(path) as f:
        header = f.readline().rstrip("\n").split(",")
        idx = {name: header.index(name) for name in cols}
        for line in f:
            parts = line.rstrip("\n").split(",")
            for cs in schema.columns:
                raw = parts[idx[cs.name]]
                if cs.dtype == "float32":
                    cols[cs.name].append(float(raw))
                elif cs.dtype == "str":
                    cols[cs.name].append(raw)
                else:
                    cols[cs.name].append(int(raw))
    return _finalize(schema, cols)


def parse_jsonl(path: str, schema: TableSchema) -> Dict[str, np.ndarray]:
    cols: Dict[str, list] = {c.name: [] for c in schema.columns}
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            for cs in schema.columns:
                cols[cs.name].append(rec[cs.name])
    return _finalize(schema, cols)


def _finalize(schema: TableSchema, cols: Dict[str, list]) -> Dict[str, np.ndarray]:
    out = {}
    for cs in schema.columns:
        if cs.dtype == "str":
            codes, _ = strings_to_codes(cols[cs.name])
            out[cs.name] = codes
        elif cs.dtype == "float32":
            out[cs.name] = np.asarray(cols[cs.name], dtype=np.float32)
        else:
            out[cs.name] = np.asarray(cols[cs.name], dtype=np.int32)
    return out
