"""lakeformat binary writer.

File layout (little-endian):

    [ magic b'LAKE1\\0\\0\\0' ]
    [ buffer blob 0 ][ pad to 64B ] [ buffer blob 1 ] ...
    [ footer: JSON utf-8 ]
    [ footer_len: uint64 ][ magic ]

The JSON footer holds the schema, per-row-group encodings, buffer offsets
and dtypes, zone maps (min/max/count per column per row group), and string
dictionaries.  Buffers are raw C-order bytes.  All metadata needed for
pruning lives in the footer so pruning never touches data bytes.
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.lakeformat.encodings import EncodedColumn, Encoding, encode_column
from repro.lakeformat.integrity import page_checksum
from repro.lakeformat.schema import ColumnSchema, TableSchema, strings_to_codes

MAGIC = b"LAKE1\0\0\0"
ALIGN = 64
DEFAULT_ROW_GROUP = 65536


# Equi-width value histogram bins per row-group zone map.  16 keeps the
# footer entry tiny (16 ints) while letting the offload policy see value
# skew inside a row group instead of assuming uniform-over-[min,max] —
# a clustered column's narrow range predicate estimates near-0 or near-1
# per group rather than a flat width ratio (core/zonemap._frac_true).
ZONE_HIST_BINS = 16


def _zone_map(values: np.ndarray):
    if values.size == 0:
        return {"min": 0, "max": 0, "count": 0}
    is_f = values.dtype.kind == "f"
    lo, hi = values.min(), values.max()
    zm = {
        "min": float(lo) if is_f else int(lo),
        "max": float(hi) if is_f else int(hi),
        "count": int(values.shape[0]),
    }
    if hi > lo:
        counts, _ = np.histogram(values, bins=ZONE_HIST_BINS,
                                 range=(float(lo), float(hi)))
        zm["hist"] = [int(c) for c in counts]
    return zm


class LakeWriter:
    def __init__(self, path: str, schema: TableSchema, row_group_size: int = DEFAULT_ROW_GROUP):
        self.path = path
        self.schema = schema
        self.row_group_size = row_group_size
        self._f = open(path, "wb")
        self._f.write(MAGIC)
        self._offset = len(MAGIC)
        self._row_groups: List[dict] = []
        self._string_dicts: Dict[str, List[str]] = {}
        self._string_maps: Dict[str, Dict[str, int]] = {}
        self._n_rows = 0

    # -- buffers ----------------------------------------------------------
    def _write_buffer(self, arr: np.ndarray) -> dict:
        pad = (-self._offset) % ALIGN
        if pad:
            self._f.write(b"\0" * pad)
            self._offset += pad
        raw = np.ascontiguousarray(arr).tobytes()
        off = self._offset
        self._f.write(raw)
        self._offset += len(raw)
        return {"offset": off, "nbytes": len(raw), "dtype": str(arr.dtype), "shape": list(arr.shape)}

    # -- row groups -------------------------------------------------------
    def write_row_group(self, columns: Dict[str, np.ndarray]):
        """columns: name -> 1-D numpy array (or list of str for str columns)."""
        n = None
        meta_cols = {}
        for cs in self.schema.columns:
            vals = columns[cs.name]
            if cs.dtype == "str":
                codes, dictionary = strings_to_codes(vals, self._string_maps.get(cs.name))
                self._string_maps[cs.name] = {s: i for i, s in enumerate(dictionary)}
                self._string_dicts[cs.name] = dictionary
                vals = codes
            vals = np.asarray(vals)
            if n is None:
                n = vals.shape[0]
            assert vals.shape[0] == n, f"ragged row group at {cs.name}"
            enc = encode_column(vals, cs.encoding, dtype=cs.storage_dtype)
            bufmeta = {name: self._write_buffer(buf) for name, buf in enc.buffers.items()}
            meta_cols[cs.name] = {
                "encoding": enc.encoding.value,
                "n": enc.n,
                "dtype": enc.dtype,
                "k": enc.k,
                "buffers": bufmeta,
                "zonemap": _zone_map(vals),
                "encoded_bytes": enc.encoded_bytes(),
                # Per-page CRC32 over the encoded buffers; verified by the
                # engine on every storage fetch.  Footers that predate this
                # field read back as unverified (reader returns None).
                "checksum": page_checksum(enc),
            }
        self._row_groups.append({"n": n, "columns": meta_cols})
        self._n_rows += int(n or 0)

    # -- finish -----------------------------------------------------------
    def close(self):
        footer = {
            "schema": {
                "name": self.schema.name,
                "columns": [
                    {"name": c.name, "dtype": c.dtype, "encoding": c.encoding}
                    for c in self.schema.columns
                ],
            },
            "row_groups": self._row_groups,
            "string_dicts": self._string_dicts,
            "n_rows": self._n_rows,
            "row_group_size": self.row_group_size,
        }
        blob = json.dumps(footer).encode("utf-8")
        self._f.write(blob)
        self._f.write(struct.pack("<Q", len(blob)))
        self._f.write(MAGIC)
        self._f.close()


def write_table(
    path: str,
    schema: TableSchema,
    columns: Dict[str, Sequence],
    row_group_size: int = DEFAULT_ROW_GROUP,
) -> str:
    """Write a whole table dict at once, splitting into row groups."""
    w = LakeWriter(path, schema, row_group_size)
    first = columns[schema.columns[0].name]
    n = len(first)
    for start in range(0, max(n, 1), row_group_size):
        stop = min(start + row_group_size, n)
        if stop <= start:
            break
        rg = {}
        for cs in schema.columns:
            col = columns[cs.name]
            rg[cs.name] = col[start:stop] if not isinstance(col, np.ndarray) else col[start:stop]
        w.write_row_group(rg)
    w.close()
    return path
