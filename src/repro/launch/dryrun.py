import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with 512 placeholder host devices.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both --json out.json

Per cell this prints/records:
  - compiled.memory_analysis()  (per-device bytes: args/outputs/temps/peak)
  - compiled.cost_analysis()    (HLO FLOPs + bytes for §Roofline)
  - collective bytes parsed from the post-SPMD optimized HLO
A failure to lower/compile (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the framework — the suite must be green.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ALIASES, get_config, list_archs  # noqa: E402
from repro.distributed.compat import use_mesh  # noqa: E402
from repro.distributed.sharding import ShardingCtx  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import production_ctx  # noqa: E402
from repro.models.model import decode_step, forward_train, prefill  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402

# while-loop-aware HLO accounting (see benchmarks/hlo_analysis.py)
import os as _os  # noqa: E402
import sys as _sys  # noqa: E402

_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), "..", "..", "..", "benchmarks"))
from hlo_analysis import analyze_hlo  # noqa: E402


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int | None = None, strategy: str = "tp",
               remat_policy: str | None = None):
    cfg = get_config(arch)
    import dataclasses
    if microbatches:
        cfg = dataclasses.replace(cfg, microbatches=microbatches)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    ok, why = S.cell_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": why}

    if strategy == "auto":
        strategy = "fsdp_ep" if cfg.moe_experts else "fsdp"
    ctx = production_ctx(multi_pod=multi_pod, strategy=strategy)
    info = S.SHAPES[shape_name]
    pspecs = S.param_specs(cfg, ctx)
    t0 = time.time()

    with use_mesh(ctx.mesh):
        if info["kind"] == "train":
            from repro.train.loop import make_train_step
            from repro.train.optimizer import init_opt_state

            batch = S.batch_specs(cfg, shape_name, ctx)
            optcfg = OptConfig(
                name="adafactor" if cfg.n_params() > 5e10 else "adamw",
                moments_dtype="bfloat16",
            )
            opt_specs = jax.eval_shape(lambda p: init_opt_state(p, optcfg), pspecs)
            # moments inherit param shardings; scalars replicated
            def _opt_sharded(leaf, path_is_scalar=False):
                return leaf
            step = make_train_step(cfg, optcfg, ctx)
            lowered = jax.jit(step).lower(pspecs, opt_specs, batch)
        elif info["kind"] == "prefill":
            batch = S.batch_specs(cfg, shape_name, ctx)
            lowered = jax.jit(
                lambda p, b: prefill(p, b, cfg, ctx, cache_len=info["seq"])
            ).lower(pspecs, batch)
        else:  # decode
            B = info["batch"]
            cache = S.cache_specs_from_eval(cfg, shape_name, ctx)
            tok = S._sds((B, 1), jnp.int32, ("batch", None), ctx)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                lambda p, t, c, q: decode_step(p, t, c, q, cfg, ctx)
            ).lower(pspecs, tok, cache, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = analyze_hlo(compiled.as_text())
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw XLA numbers (per device; while bodies counted once):
        "xla_flops_raw": float(cost.get("flops", -1)) if cost else -1.0,
        "xla_bytes_raw": float(cost.get("bytes accessed", -1)) if cost else -1.0,
        # trip-aware per-device accounting (benchmarks/hlo_analysis.py):
        "flops": hlo.flops,
        "dot_bytes": hlo.dot_bytes,
        "collective_bytes": hlo.collective_bytes,
        "collectives": hlo.collective_by_kind,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--json", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp", "fsdp_ep", "auto"])
    ap.add_argument("--remat-policy", default=None, choices=[None, "full", "dots"])
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(S.SHAPES) if args.all or not args.shape else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    results = []
    failed = 0
    for mp in pods:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = lower_cell(arch, shape, mp, args.microbatches, args.strategy,
                                     args.remat_policy)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failed += 1
                results.append(rec)
                if rec["status"] == "ok":
                    mem = rec["memory"]
                    peak = mem.get("peak_bytes") or 0
                    print(f"[dryrun] OK  {tag}: compile {rec['compile_s']}s, "
                          f"flops {rec['flops']:.3e}, coll {rec['collective_bytes']:.3e}B, "
                          f"peak/device {peak/2**30:.2f} GiB", flush=True)
                elif rec["status"] == "skipped":
                    print(f"[dryrun] SKIP {tag}: {rec['reason']}", flush=True)
                else:
                    print(f"[dryrun] FAIL {tag}: {rec['error']}", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    print(f"[dryrun] {sum(r['status']=='ok' for r in results)} ok, "
          f"{sum(r['status']=='skipped' for r in results)} skipped, {failed} failed")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
