"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is pure
data parallelism over DCN, the inner axes ride ICI.
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import ShardingCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "run under launch/dryrun.py (it sets XLA_FLAGS first)"
        )
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def production_ctx(*, multi_pod: bool = False, strategy: str = "tp") -> ShardingCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    return ShardingCtx(mesh=mesh, dp_axes=dp_axes, strategy=strategy)


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
