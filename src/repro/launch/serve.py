"""Serving launcher: restore a checkpoint (or init) and serve a synthetic
request stream through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \
        --requests 16
"""

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.models.model import init_params, param_dims
    from repro.serve.engine import Request, ServeEngine
    from repro.train.checkpoint import CheckpointManager

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        m = CheckpointManager(args.ckpt_dir)
        restored, manifest = m.restore_latest({"params": params})
        if restored is not None:
            params = restored["params"]
            print(f"[serve] restored step {manifest['meta'].get('step')}")

    eng = ServeEngine(params, cfg, n_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab, (8 + i % 24,)),
                           max_new_tokens=args.max_new))
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens, {dt:.1f}s "
          f"({toks/dt:.1f} tok/s), {eng.steps} ticks")


if __name__ == "__main__":
    main()
