"""input_specs: ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation ever happens here — weak-type-correct, shardable
specs only.  The four assigned shapes:

    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> prefill_step
    decode_32k   seq 32,768  global_batch 128   -> serve_step (1 token, KV=32k)
    long_500k    seq 524,288 global_batch 1     -> serve_step (sub-quadratic only)

Train/prefill token inputs are BIT-PACKED (the datapath feature is on in
production), at k = ceil(log2 vocab) bits in 4096-token blocks.
Frontend stubs ([audio]/[vlm]) are precomputed embedding specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingCtx, sharding_for, spec_for
from repro.models.config import ModelConfig
from repro.models.model import packed_token_shape, param_shapes, token_bits

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def cell_supported(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k decode requires sub-quadratic attention (DESIGN.md §6)"
    return True, ""


def _sds(shape, dtype, dims, ctx: ShardingCtx, activation: bool = True):
    # inputs/caches are data (activation path: strategy-aware batch widening)
    sh = sharding_for(dims, ctx, shape, activation=activation) if ctx.enabled else None
    if sh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def param_specs(cfg: ModelConfig, ctx: ShardingCtx):
    shapes, dims = param_shapes(cfg)
    dt = jnp.dtype(cfg.dtype)

    def build(shp, dm, name):
        dtype = jnp.float32 if name in ("A_log", "dt_bias") else dt
        return _sds(tuple(shp), dtype, dm, ctx, activation=False)  # storage sharding

    out: Dict[str, Any] = {}
    for name, shp in shapes.items():
        if name == "segments":
            out["segments"] = [
                {k: build(s, dims["segments"][i][k], k) for k, s in seg.items()}
                for i, seg in enumerate(shapes["segments"])
            ]
        else:
            out[name] = build(shp, dims[name], name)
    return out


def batch_specs(cfg: ModelConfig, shape_name: str, ctx: ShardingCtx,
                packed: bool = True) -> Dict[str, Any]:
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {}
    if info["kind"] in ("train", "prefill"):
        if packed and cfg.decode_bitpack and S % 4096 == 0:
            shp = packed_token_shape(cfg, B, S)
            batch["packed"] = _sds(shp, jnp.uint32, ("batch", None, None, None), ctx)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32, ("batch", None), ctx)
        if cfg.family == "vlm":
            batch["embeds"] = _sds((B, cfg.vision_tokens, cfg.d_model), dt,
                                   ("batch", None, None), ctx)
        if cfg.is_encdec:
            batch["enc_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt,
                                       ("batch", None, None), ctx)
    return batch


def cache_sharding_dims(shape: Tuple[int, ...], ctx: ShardingCtx):
    """Heuristic logical dims for cache leaves (L, B, ...): batch on dp,
    largest remaining tp-divisible axis on model."""
    dims: list = [None] * len(shape)
    if len(shape) >= 2:
        dims[1] = "batch"
    tp = ctx.tp if ctx.enabled else 1
    if tp > 1 and len(shape) > 2:
        best, best_size = None, 0
        for i in range(2, len(shape)):
            if shape[i] % tp == 0 and shape[i] > best_size:
                best, best_size = i, shape[i]
        if best is not None:
            dims[best] = "seq_tp"
    return tuple(dims)


def cache_specs_from_eval(cfg: ModelConfig, shape_name: str, ctx: ShardingCtx):
    """Shape-infer the decode cache via eval_shape of prefill (no compile),
    then attach shardings per cache_sharding_dims."""
    from repro.models.model import prefill

    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    dt = jnp.dtype(cfg.dtype)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.d_model), dt)
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
    pspecs = param_specs(cfg, ctx)
    _, cache_shape = jax.eval_shape(
        lambda p, b: prefill(p, b, cfg, ctx, cache_len=S), pspecs, batch
    )

    def attach(leaf):
        dims = cache_sharding_dims(leaf.shape, ctx)
        return _sds(leaf.shape, leaf.dtype, dims, ctx)

    return jax.tree.map(attach, cache_shape)
