"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --corpus /data/corpus --steps 1000 [--mesh single|multi|none]

On real hardware the mesh flags select the production (16,16) or
(2,16,16) topology; `--mesh none` runs single-device (CPU smoke).
`--smoke` swaps in the reduced config.
"""

import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--corpus", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--mode", default="fused", choices=["fused", "engine", "host"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if args.mesh == "multi":
        os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import TokenPipeline
    from repro.distributed.sharding import local_ctx
    from repro.launch.mesh import production_ctx
    from repro.train.loop import train
    from repro.train.optimizer import OptConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.microbatches > 1:
        cfg = dataclasses.replace(cfg, microbatches=args.microbatches)
    ctx = local_ctx() if args.mesh == "none" else production_ctx(multi_pod=args.mesh == "multi")

    paths = [os.path.join(args.corpus, f) for f in sorted(os.listdir(args.corpus))
             if f.endswith(".lake")]
    pipe = TokenPipeline(paths, args.batch, args.seq, mode=args.mode)
    optcfg = OptConfig(
        name="adafactor" if cfg.n_params() > 5e10 else "adamw",
        lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
        total_steps=args.steps,
    )
    out = train(cfg, optcfg, pipe, steps=args.steps, ctx=ctx,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"[launch.train] done: {len(out['losses'])} steps, "
          f"final loss {out['losses'][-1]:.4f}, stragglers: {out['stragglers']}")


if __name__ == "__main__":
    main()
