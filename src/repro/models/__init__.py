"""Model zoo: the ten assigned architectures as composable JAX modules."""

from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    build_model,
    init_params,
    loss_fn,
    param_dims,
)
