"""ModelConfig — one dataclass describing every assigned architecture.

Families: dense | moe | ssm | hybrid | audio (enc-dec) | vlm.
The exact per-arch instantiations live in src/repro/configs/<id>.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

VOCAB_PAD = 2048  # embedding tables padded so 'vocab' always TP-shards


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 0

    # attention details
    act: str = "swiglu"  # swiglu | geglu
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None  # sliding-window size (hybrid SWA layers)
    global_layers: Tuple[int, ...] = ()  # full-attention layer ids (hybrid)
    attn_scale: Optional[float] = None
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    norm_plus_one: bool = False  # gemma RMSNorm (1 + w)

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0  # number of shared (always-on) experts
    moe_d_ff: int = 0
    moe_period: int = 1  # every Nth layer is MoE...
    moe_first_dense: int = 0  # ...after this many leading dense layers
    moe_capacity: float = 1.25
    moe_aux_weight: float = 0.01

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend frames

    # vlm
    vision_tokens: int = 0  # stub patch embeddings prepended to the stream

    # numerics / execution
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs: ~8ND -> 6ND)
    attn_block: int = 1024  # jnp blocked-attention kv chunk
    attn_impl: str = "blocked"  # blocked | dense | pallas
    microbatches: int = 1  # grad-accumulation steps inside train_step
    decode_bitpack: bool = True  # datapath: train tokens arrive bit-packed

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, VOCAB_PAD)

    @property
    def d_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim if self.ssm_heads else self.ssm_expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k?  SSM and hybrid (SWA+SSM) can."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def moe_layer_ids(self) -> Tuple[int, ...]:
        if self.moe_experts == 0:
            return ()
        return tuple(
            i
            for i in range(self.n_layers)
            if i >= self.moe_first_dense and (i - self.moe_first_dense) % self.moe_period == self.moe_period - 1
        )

    def n_params(self) -> int:
        """Analytic parameter count (unpadded vocab)."""
        d, f = self.d_model, self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per = self._ssm_params()
            return emb + self.n_layers * per
        attn = d * (self.n_heads + 2 * self.n_kv) * self.head_dim + self.n_heads * self.head_dim * d
        dense_ffn = 3 * d * f
        moe_ids = set(self.moe_layer_ids())
        total = emb
        for i in range(self.n_layers):
            total += attn + 2 * d  # attn + norms
            if self.family == "hybrid":
                total += self._ssm_params()
            if i in moe_ids:
                total += d * self.moe_experts * 3 * self.moe_d_ff
                total += self.moe_shared * 3 * d * self.moe_d_ff
                total += d * self.moe_experts  # router
            else:
                total += dense_ffn
        if self.is_encdec:
            enc = self.encoder_layers * (attn + dense_ffn + 2 * d)
            xattn = self.n_layers * (attn + d)
            total += enc + xattn
        return total

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: top-k + shared only)."""
        if self.moe_experts == 0:
            return self.n_params()
        d = self.d_model
        total = self.n_params()
        inactive = (self.moe_experts - self.moe_top_k) * 3 * d * self.moe_d_ff
        return total - len(self.moe_layer_ids()) * inactive

    def _ssm_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        # in_proj (z, x, B, C, dt) + conv + out_proj + A/D/dt_bias + norms
        in_p = d * (2 * di + 2 * n * (h and 1 or 1) * 1 + h)
        in_p = d * (2 * di + 2 * self.ssm_state * self._ssm_groups() + h)
        return in_p + self.conv_width * (di + 2 * self.ssm_state * self._ssm_groups()) + di * d + 3 * h + 2 * d

    def _ssm_groups(self) -> int:
        return 1  # single B/C group (Mamba2 default ngroups=1)
