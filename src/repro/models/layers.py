"""Shared model layers: norms, rotary, GQA attention (TP- or SP-parallel),
GLU MLPs, embeddings.

Attention parallelism is divisibility-driven (see distributed/sharding.py):
  - head-parallel (Megatron TP) when n_heads and n_kv divide the model axis,
  - sequence-parallel otherwise (q sharded on Sq, K/V replicated): exact
    same math, no head-count constraint — this is how 40H/25H/56H archs run
    on a 16-way model axis.
Decode attention shards the KV cache on Skv (flash-decode); GSPMD inserts
the small softmax-statistics all-reduces.

The blocked q-chunk implementation keeps HLO compact (lax.scan) and caps
the live score tensor at (B, H, chunk, Skv) — required for 32k/500k cells.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingCtx, constrain

# ---------------------------------------------------------------------------
# norms / rotary
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6, plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (xf * scale).astype(dt)


def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _attn_parallelism(n_heads: int, n_kv: int, ctx: ShardingCtx) -> str:
    tp = ctx.tp
    if tp == 1 or ctx.strategy in ("fsdp", "fsdp_ep"):
        return "none"  # ZeRO: attention fully local per batch shard
    return "head" if (n_heads % tp == 0 and n_kv % tp == 0) else "seq"


def attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,
    ctx: ShardingCtx,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    chunk: int = 1024,
    q_offset: int = 0,
    kv_valid_len: Optional[jax.Array] = None,  # decode: current cache fill
) -> jax.Array:
    """Grouped-query attention, q-chunked.  Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else hd ** -0.5
    par = _attn_parallelism(H, KV, ctx)

    if par == "head":
        q = constrain(q, ("batch", None, "heads", None), ctx)
        k = constrain(k, ("batch", None, "kv", None), ctx)
        v = constrain(v, ("batch", None, "kv", None), ctx)
    elif Sq == 1 and ctx.tp > 1:
        # decode under any strategy: shard the KV cache (flash-decode)
        k = constrain(k, ("batch", "seq_tp", None, None), ctx)
        v = constrain(v, ("batch", "seq_tp", None, None), ctx)
    elif par == "seq" and Sq > 1:
        q = constrain(q, ("batch", "seq_tp", None, None), ctx)

    qg = q.reshape(B, Sq, KV, rep, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,rep,Sq,hd)
    kg = k.transpose(0, 2, 1, 3)  # (B,KV,Skv,hd)
    vg = v.transpose(0, 2, 1, 3)

    k_pos = jnp.arange(Skv, dtype=jnp.int32)[None, :]

    def attend(qc: jax.Array, qc_start) -> jax.Array:
        # qc: (B,KV,rep,C,hd)
        C = qc.shape[3]
        s = jnp.einsum(
            "bkrcd,bksd->bkrcs", qc.astype(jnp.float32), kg.astype(jnp.float32)
        ) * scale
        q_pos = (qc_start + jnp.arange(C, dtype=jnp.int32) + q_offset)[:, None]
        m = jnp.ones((C, Skv), jnp.bool_)
        if causal:
            m = m & (k_pos <= q_pos)
        if window is not None:
            m = m & (k_pos > q_pos - window)
        if kv_valid_len is not None:
            m = m & (k_pos < kv_valid_len)
        s = jnp.where(m[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkrcs,bksd->bkrcd", p, vg.astype(jnp.float32))

    if Sq > chunk and Sq % chunk:
        # non-multiple sequence (whisper 1500 frames, llava 4672 stream):
        # largest divisor of Sq that fits the chunk budget
        c = chunk
        while c > 1 and Sq % c:
            c -= 1
        chunk = c if c > 64 else Sq
    if Sq <= chunk:
        out = attend(qg, 0)
    else:
        nq = Sq // chunk
        qs = qg.reshape(B, KV, rep, nq, chunk, hd).transpose(3, 0, 1, 2, 4, 5)

        def body(_, args):
            i, qc = args
            return None, attend(qc, i * chunk)

        _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qs))
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, rep, Sq, hd)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
    if par == "seq" and Sq > 1:
        out = constrain(out, ("batch", "seq_tp", None, None), ctx)
    return out


# ---------------------------------------------------------------------------
# MLP / embeddings
# ---------------------------------------------------------------------------


def glu_mlp(x: jax.Array, wg: jax.Array, wu: jax.Array, wo: jax.Array, act: str,
            ctx: ShardingCtx) -> jax.Array:
    h_g = x @ wg
    h_u = x @ wu
    h_g = constrain(h_g, ("batch", None, "ff"), ctx)
    h_u = constrain(h_u, ("batch", None, "ff"), ctx)
    a = jax.nn.silu(h_g) if act == "swiglu" else jax.nn.gelu(h_g, approximate=True)
    out = (a * h_u) @ wo
    return constrain(out, ("batch", None, None), ctx)


def embed_lookup(embed: jax.Array, tokens: jax.Array, ctx: ShardingCtx,
                 scale: bool = False) -> jax.Array:
    out = jnp.take(embed, tokens, axis=0, mode="clip").astype(embed.dtype)
    if scale:
        out = out * math.sqrt(embed.shape[1])
    return constrain(out, ("batch", None, None), ctx)


def lm_head_logits(h: jax.Array, w: jax.Array, ctx: ShardingCtx) -> jax.Array:
    """h (B,S,D) @ w (D,Vp) -> logits (B,S,Vp) sharded on vocab."""
    logits = h @ w
    return constrain(logits, ("batch", None, "vocab"), ctx)


def softmax_xent(logits: jax.Array, labels: jax.Array, vocab_real: int,
                 label_mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE; padded vocab rows masked out of the partition."""
    Vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if Vp > vocab_real:
        pad_bias = jnp.where(jnp.arange(Vp) >= vocab_real, -1e30, 0.0)
        lf = lf + pad_bias
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if label_mask is not None:
        nll = nll * label_mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(label_mask), 1.0)
    return jnp.mean(nll)
