"""Model API: parameter init, logical sharding dims, train/serve entry points.

The datapath integration (the paper's contribution as a first-class
feature): `forward_train` accepts tokens either decoded ('tokens') or
bit-packed ('packed', (B, nb, k, 128) uint32 at k = ceil(log2 vocab) bits).
Packed batches are decoded *inside the jitted step* by the same kernels the
analytical engine uses — host->device DMA carries ~k/32 of the plain bytes
and decode overlaps model compute under the XLA scheduler (DESIGN.md §5).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingCtx, constrain, local_ctx
from repro.kernels import ops
from repro.lakeformat.encodings import bits_needed
from repro.models.config import ModelConfig
from repro.models.layers import embed_lookup, lm_head_logits, rmsnorm, softmax_xent
from repro.models.transformer import (
    Segment,
    build_segments,
    run_segments_decode,
    run_segments_prefill,
    run_segments_train,
)

# ---------------------------------------------------------------------------
# parameter shapes / dims / init
# ---------------------------------------------------------------------------


def _attn_shapes(cfg: ModelConfig, prefix: str = "") -> Dict[str, Tuple]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    s = {
        prefix + "ln1": ((D,), (None,)),
        prefix + "wq": ((D, H * hd), ("d", "heads")),
        prefix + "wk": ((D, KV * hd), ("d", "heads")),
        prefix + "wv": ((D, KV * hd), ("d", "heads")),
        prefix + "wo": ((H * hd, D), ("heads", "d")),
    }
    if cfg.qk_norm:
        s[prefix + "qn"] = ((hd,), (None,))
        s[prefix + "kn"] = ((hd,), (None,))
    return s


def _mlp_shapes(cfg: ModelConfig, prefix: str = "") -> Dict[str, Tuple]:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.act == "gelu":
        return {
            prefix + "ln2": ((D,), (None,)),
            prefix + "w1": ((D, F), ("d", "ff")),
            prefix + "w2": ((F, D), ("ff", "d")),
        }
    return {
        prefix + "ln2": ((D,), (None,)),
        prefix + "wg": ((D, F), ("d", "ff")),
        prefix + "wu": ((D, F), ("d", "ff")),
        prefix + "wo2": ((F, D), ("ff", "d")),
    }


def _moe_shapes(cfg: ModelConfig, prefix: str = "") -> Dict[str, Tuple]:
    D, E, F = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    s = {
        prefix + "ln2": ((D,), (None,)),
        prefix + "router": ((D, E), ("d", None)),
        # expert weights stored F-sharded over data (matches the 2D-EP
        # compute layout exactly -> zero per-layer weight resharding)
        prefix + "e_wg": ((E, D, F), ("experts", None, "fsdp")),
        prefix + "e_wu": ((E, D, F), ("experts", None, "fsdp")),
        prefix + "e_wo": ((E, F, D), ("experts", "fsdp", None)),
    }
    if cfg.moe_shared:
        Fs = cfg.moe_shared * F
        s[prefix + "shared_wg"] = ((D, Fs), ("d", "ff"))
        s[prefix + "shared_wu"] = ((D, Fs), ("d", "ff"))
        s[prefix + "shared_wo"] = ((Fs, D), ("ff", "d"))
    return s


def _ssm_shapes(cfg: ModelConfig, prefix: str = "") -> Dict[str, Tuple]:
    D, di, N, H, W = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    cc = di + 2 * N
    s = {
        prefix + "in_proj": ((D, 2 * di + 2 * N + H), ("d", "inner")),
        prefix + "conv_w": ((W, cc), (None, None)),
        prefix + "conv_b": ((cc,), (None,)),
        prefix + "A_log": ((H,), (None,)),
        prefix + "D_skip": ((H,), (None,)),
        prefix + "dt_bias": ((H,), (None,)),
        prefix + "norm_y": ((di,), (None,)),
        prefix + "out_proj": ((di, D), ("inner", "d")),
    }
    if prefix == "":
        s["ln1"] = ((D,), (None,))
    return s


def _layer_shapes(kind: str, cfg: ModelConfig) -> Dict[str, Tuple]:
    D = cfg.d_model
    if kind == "dense":
        return {**_attn_shapes(cfg), **_mlp_shapes(cfg)}
    if kind == "moe":
        return {**_attn_shapes(cfg), **_moe_shapes(cfg)}
    if kind == "moe_pair":
        a = {**_attn_shapes(cfg, "a_"), **_mlp_shapes(cfg, "a_")}
        b = {**_attn_shapes(cfg, "b_"), **_moe_shapes(cfg, "b_")}
        return {**a, **b}
    if kind == "ssm":
        s = _ssm_shapes(cfg)
        if cfg.d_ff:
            s.update(_mlp_shapes(cfg))
        return s
    if kind == "hybrid":
        s = {**_attn_shapes(cfg), **_ssm_shapes(cfg, "s_"), **_mlp_shapes(cfg)}
        s.update({
            "na": ((D,), (None,)),
            "ns": ((D,), (None,)),
            "beta_a": ((D,), (None,)),
            "beta_s": ((D,), (None,)),
        })
        return s
    if kind == "enc":
        return {**_attn_shapes(cfg), **_mlp_shapes(cfg)}
    if kind == "decx":
        return {**_attn_shapes(cfg), **_attn_shapes(cfg, "x_"), **_mlp_shapes(cfg)}
    raise ValueError(kind)


def _top_shapes(cfg: ModelConfig) -> Dict[str, Tuple]:
    D, Vp = cfg.d_model, cfg.vocab_padded
    s = {
        "embed": ((Vp, D), ("vocab", "d")),
        "final_ln": ((D,), (None,)),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ((D, Vp), ("d", "vocab"))
    if cfg.is_encdec:
        s["enc_final_ln"] = ((D,), (None,))
    if cfg.family == "vlm":
        s["vis_proj"] = ((D, D), ("d", None))
    return s


def model_segments(cfg: ModelConfig) -> List[Segment]:
    segs = build_segments(cfg)
    if cfg.is_encdec:
        segs = [Segment("enc", cfg.encoder_layers)] + [
            Segment("decx", s.count, s.window) for s in segs if s.kind == "dense"
        ]
    return segs


def param_shapes(cfg: ModelConfig):
    """(shapes pytree, dims pytree) — dims feed distributed.sharding.spec_for."""
    segs = model_segments(cfg)
    shapes: Dict[str, Any] = {}
    dims: Dict[str, Any] = {}
    for name, (shp, dm) in _top_shapes(cfg).items():
        shapes[name] = shp
        dims[name] = dm
    seg_shapes, seg_dims = [], []
    for seg in segs:
        ls = _layer_shapes(seg.kind, cfg)
        seg_shapes.append({k: (seg.count, *s) for k, (s, _) in ls.items()})
        seg_dims.append({k: (None, *d) for k, (_, d) in ls.items()})
    shapes["segments"] = seg_shapes
    dims["segments"] = seg_dims
    return shapes, dims


def param_dims(cfg: ModelConfig):
    return param_shapes(cfg)[1]


_NORM_KEYS = ("ln1", "ln2", "final_ln", "enc_final_ln", "norm_y", "na", "ns",
              "qn", "kn", "D_skip", "beta_a", "beta_s", "conv_b")


def _init_leaf(key, name: str, shape, cfg: ModelConfig):
    base = name.split("_", 1)[-1] if name[:2] in ("a_", "b_", "s_", "x_") else name
    dt = jnp.dtype(cfg.dtype)
    if base in _NORM_KEYS or name in _NORM_KEYS:
        if name.endswith(("ln1", "ln2", "final_ln")) and cfg.norm_plus_one:
            return jnp.zeros(shape, dt)
        return jnp.ones(shape, dt)
    if base == "A_log" or name == "A_log":
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)
    if base == "dt_bias" or name == "dt_bias":
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 0.1)
        return jnp.log(jnp.expm1(u)).astype(jnp.float32)
    std = 0.02
    if base in ("wo", "wo2", "w2", "out_proj") or base == "shared_wo":
        std = 0.02 / math.sqrt(2 * cfg.n_layers)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)


def init_params(cfg: ModelConfig, key: jax.Array):
    shapes, _ = param_shapes(cfg)
    flat: Dict[str, Any] = {}
    keys = jax.random.split(key, 4096)
    ki = iter(range(4096))

    def mk(name, shp):
        return _init_leaf(keys[next(ki)], name, shp, cfg)

    out: Dict[str, Any] = {}
    for name, shp in shapes.items():
        if name == "segments":
            out["segments"] = [
                {k: mk(k, s) for k, s in seg.items()} for seg in shapes["segments"]
            ]
        else:
            out[name] = mk(name, shp)
    return out


# ---------------------------------------------------------------------------
# datapath token decode (stage 0 of the jitted step)
# ---------------------------------------------------------------------------


def token_bits(cfg: ModelConfig) -> int:
    return bits_needed(cfg.vocab - 1)


def packed_token_shape(cfg: ModelConfig, B: int, S: int) -> Tuple[int, int, int, int]:
    nb = -(-S // 4096)
    return (B, nb, token_bits(cfg), 128)


def unpack_tokens(packed: jax.Array, S: int, cfg: ModelConfig,
                  backend: str = "auto") -> jax.Array:
    B, nb, k, _ = packed.shape
    flat = ops.bitunpack(packed.reshape(B * nb, k, 128), k, backend=backend)
    return flat.reshape(B, nb * 4096)[:, :S]


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _tokens_from_batch(params, batch, cfg, ctx):
    if "packed" in batch:
        S = batch["packed"].shape[1] * 4096  # shapes are block-aligned by design
        tokens = unpack_tokens(batch["packed"], S, cfg, backend="ref" if ctx.enabled else "auto")
        tokens = constrain(tokens, ("batch", None), ctx)
        return tokens
    return batch["tokens"]


def forward_train(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
                  ctx: Optional[ShardingCtx] = None):
    """Returns (loss, metrics).  batch: tokens|packed [+ embeds / enc_embeds]."""
    ctx = ctx or local_ctx()
    segs = model_segments(cfg)
    tokens = _tokens_from_batch(params, batch, cfg, ctx)
    B, S = tokens.shape
    h = embed_lookup(params["embed"], tokens, ctx, scale=cfg.embed_scale)

    enc_out = None
    seg_params = params["segments"]
    if cfg.is_encdec:
        enc_h = batch["enc_embeds"].astype(h.dtype)
        enc_pos = jnp.broadcast_to(jnp.arange(enc_h.shape[1], dtype=jnp.int32), enc_h.shape[:2])
        enc_h, _ = run_segments_train(seg_params[:1], segs[:1], enc_h, cfg, ctx, enc_pos)
        enc_out = rmsnorm(enc_h, params["enc_final_ln"], cfg.norm_eps, cfg.norm_plus_one)
        segs, seg_params = segs[1:], seg_params[1:]

    n_vis = 0
    if cfg.family == "vlm" and "embeds" in batch:
        vis = batch["embeds"].astype(h.dtype) @ params["vis_proj"]
        vis = constrain(vis, ("batch", None, None), ctx)
        h = jnp.concatenate([vis, h], axis=1)
        n_vis = vis.shape[1]

    positions = jnp.broadcast_to(jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2])
    h, aux = run_segments_train(seg_params, segs, h, cfg, ctx, positions, enc_kv=enc_out)
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps, cfg.norm_plus_one)

    head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if n_vis > 0:
        pred_h = h[:, n_vis - 1 : n_vis + S - 1]
        labels = tokens
    else:
        pred_h = h[:, :-1]
        labels = tokens[:, 1:]
    logits = lm_head_logits(pred_h, head_w, ctx)
    loss = softmax_xent(logits, labels, cfg.vocab)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": jnp.int32(B * S)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            ctx: Optional[ShardingCtx] = None, cache_len: Optional[int] = None):
    """Process a prompt, build caches.  Returns (last-token logits, caches)."""
    ctx = ctx or local_ctx()
    segs = model_segments(cfg)
    tokens = _tokens_from_batch(params, batch, cfg, ctx)
    B, S = tokens.shape
    cache_len = cache_len or S
    h = embed_lookup(params["embed"], tokens, ctx, scale=cfg.embed_scale)

    enc_out = None
    seg_params = params["segments"]
    caches: List[Any] = []
    if cfg.is_encdec:
        enc_h = batch["enc_embeds"].astype(h.dtype)
        enc_pos = jnp.broadcast_to(jnp.arange(enc_h.shape[1], dtype=jnp.int32), enc_h.shape[:2])
        enc_h, _ = run_segments_train(seg_params[:1], segs[:1], enc_h, cfg, ctx, enc_pos)
        enc_out = rmsnorm(enc_h, params["enc_final_ln"], cfg.norm_eps, cfg.norm_plus_one)
        caches.append({})  # encoder segment carries no decode cache
        segs_d, seg_params_d = segs[1:], seg_params[1:]
    else:
        segs_d, seg_params_d = segs, seg_params

    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h, dec_caches = run_segments_prefill(seg_params_d, segs_d, h, cfg, ctx,
                                         positions, cache_len, enc_kv=enc_out)
    caches.extend(dec_caches)
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps, cfg.norm_plus_one)
    head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = lm_head_logits(h[:, -1:], head_w, ctx)[:, 0]
    return logits, caches


def decode_step(params, token: jax.Array, caches, pos, cfg: ModelConfig,
                ctx: Optional[ShardingCtx] = None):
    """One token in, one distribution out.  token (B,1) int32; pos scalar int32."""
    ctx = ctx or local_ctx()
    segs = model_segments(cfg)
    seg_params = params["segments"]
    h = embed_lookup(params["embed"], token, ctx, scale=cfg.embed_scale)
    if cfg.is_encdec:
        segs_d, seg_params_d = segs[1:], seg_params[1:]
        dec_caches = caches[1:]
    else:
        segs_d, seg_params_d, dec_caches = segs, seg_params, caches
    h, new_caches = run_segments_decode(seg_params_d, segs_d, h, cfg, ctx, pos, dec_caches)
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps, cfg.norm_plus_one)
    head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = lm_head_logits(h, head_w, ctx)[:, 0]
    if cfg.is_encdec:
        new_caches = [caches[0]] + new_caches
    return logits, new_caches


def build_model(cfg: ModelConfig):
    """Convenience bundle."""
    return {
        "init": lambda key: init_params(cfg, key),
        "train": lambda p, b, ctx=None: forward_train(p, b, cfg, ctx),
        "prefill": lambda p, b, ctx=None, cache_len=None: prefill(p, b, cfg, ctx, cache_len),
        "decode": lambda p, t, c, pos, ctx=None: decode_step(p, t, c, pos, cfg, ctx),
        "segments": model_segments(cfg),
        "config": cfg,
    }


def loss_fn(params, batch, cfg, ctx=None):
    return forward_train(params, batch, cfg, ctx)
