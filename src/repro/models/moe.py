"""Mixture-of-Experts FFN with expert parallelism (EP) over the model axis.

Design (DESIGN.md §7): activations are replicated across the model axis at
the MoE boundary (they already are, post attention all-reduce), experts are
sharded E/tp per model shard.  Each shard:

  1. sorts its (token, expert, gate) triples by expert id (one argsort),
  2. for each LOCAL expert: dynamic-slices a capacity-C segment out of the
     sorted order, gathers tokens, runs the expert GLU, scatter-adds back,
  3. psum over the model axis combines contributions (each token's experts
     live on some shards; others contribute zero).

Communication per MoE layer = ONE all-reduce of (B_local, S, D) — identical
to a dense Megatron TP FFN — instead of two all-to-alls; the trade is
capacity-C padding compute, bounded by moe_capacity.  Tokens over capacity
are dropped (standard).  Gathers/scatters are row-wise and local.

The same `_routed_local` body runs un-sharded in single-device smoke tests.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ShardingCtx, constrain
from repro.models.config import ModelConfig

from repro.distributed.compat import shard_map


def _capacity(n_tokens: int, k: int, n_experts: int, factor: float) -> int:
    c = int(factor * n_tokens * k / n_experts)
    return max(8, -(-c // 8) * 8)


def _routed_local(
    x, ids, gates, wg, wu, wo, *, e_local: int, k: int, n_experts: int,
    capacity: float, act: str, tp_axis: Optional[str]
):
    """Per-shard routed-expert compute.  x (Bl,S,D); wg/wu/wo (El,D,F)/(El,F,D)."""
    Bl, S, D = x.shape
    N = Bl * S
    e0 = (jax.lax.axis_index(tp_axis) if tp_axis else 0) * e_local
    xf = x.reshape(N, D)
    flat_ids = ids.reshape(-1)  # (N*k,)
    flat_gates = gates.reshape(-1)
    tok = jnp.arange(N * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_ids)
    s_ids = flat_ids[order]
    s_tok = tok[order]
    s_gate = flat_gates[order]
    C = min(_capacity(N, k, n_experts, capacity), N * k)
    out = jnp.zeros((N, D), jnp.float32)
    for j in range(e_local):
        e = e0 + j
        start = jnp.searchsorted(s_ids, e).astype(jnp.int32)
        seg_ids = jax.lax.dynamic_slice_in_dim(s_ids, start, C)
        seg_tok = jax.lax.dynamic_slice_in_dim(s_tok, start, C)
        seg_gate = jax.lax.dynamic_slice_in_dim(s_gate, start, C)
        valid = (seg_ids == e).astype(x.dtype)
        xs = jnp.take(xf, seg_tok, axis=0) * valid[:, None]
        hg = xs @ wg[j]
        hu = xs @ wu[j]
        a = jax.nn.silu(hg) if act == "swiglu" else jax.nn.gelu(hg, approximate=True)
        ys = (a * hu) @ wo[j]
        w = (seg_gate * valid.astype(jnp.float32))[:, None]
        out = out.at[seg_tok].add(ys.astype(jnp.float32) * w)
    if tp_axis:
        out = jax.lax.psum(out, tp_axis)
    return out.reshape(Bl, S, D).astype(x.dtype)


# ---------------------------------------------------------------------------
# 2D expert parallelism: tokens a2a'd along the model axis to their expert's
# owner column, broadcast along the data axis (expert F dims are data-sharded
# so every row computes a 1/dp slice), partial outputs psum'd over data, then
# a2a'd back.  Comm per layer per device ~ 3 x (C x D) buffers instead of the
# full expert-weight all-gather — the production path for 400B-scale MoE.
# ---------------------------------------------------------------------------


def _row_index(row_axes, row_sizes):
    if isinstance(row_axes, str):
        return jax.lax.axis_index(row_axes)
    idx = jax.lax.axis_index(row_axes[0])
    for a, n in zip(row_axes[1:], row_sizes[1:]):
        idx = idx * n + jax.lax.axis_index(a)
    return idx


def _routed_2d(
    x, ids, gates, wg, wu, wo, *, e_local: int, k: int, n_experts: int,
    capacity: float, act: str, tp_axis: str, tp: int, row_axes, row_sizes,
    resident: bool = False
):
    """Per-shard body under shard_map over (row_axes..., tp_axis).

    x (Nl_b, S, D) wide-batch block.
    resident=False: wg/wu (El, D, F/dp), wo (El, F/dp, D) — F row-sharded,
      tokens broadcast along rows, partials reduce-scattered (400B scale).
    resident=True: full-F expert weights live on the owner column
      (small MoE, e.g. deepseek 16B) — no row broadcast, no reduction:
      tokens only a2a along the model axis."""
    Bl, S, D = x.shape
    N = Bl * S
    dp = 1
    for n in row_sizes:
        dp *= n
    xf = x.reshape(N, D)

    # --- 1) bucket tokens by destination column (expert owner) ------------
    flat_ids = ids.reshape(-1)  # (N*k,) global expert ids
    owner = flat_ids // e_local  # destination column
    flat_gates = gates.reshape(-1)
    tok = jnp.arange(N * k, dtype=jnp.int32) // k
    order = jnp.argsort(owner)
    s_owner = owner[order]
    s_tok = tok[order]
    s_gate = flat_gates[order]
    s_eid = (flat_ids % e_local)[order]  # expert index within the column
    C = max(8, -(-int(capacity * N * k / tp) // 8) * 8)
    C = min(C, N * k)

    send_x = jnp.zeros((tp, C, D), x.dtype)
    send_eid = jnp.zeros((tp, C), jnp.int32)
    send_gate = jnp.zeros((tp, C), jnp.float32)
    send_valid = jnp.zeros((tp, C), jnp.bool_)
    send_tok = jnp.zeros((tp, C), jnp.int32)  # stays local (return scatter)
    for j in range(tp):  # static, tp = 16
        start = jnp.searchsorted(s_owner, j).astype(jnp.int32)
        seg_own = jax.lax.dynamic_slice_in_dim(s_owner, start, C)
        seg_tok = jax.lax.dynamic_slice_in_dim(s_tok, start, C)
        seg_gid = jax.lax.dynamic_slice_in_dim(s_eid, start, C)
        seg_gate = jax.lax.dynamic_slice_in_dim(s_gate, start, C)
        valid = seg_own == j
        send_x = send_x.at[j].set(jnp.take(xf, seg_tok, axis=0)
                                  * valid[:, None].astype(x.dtype))
        send_eid = send_eid.at[j].set(jnp.where(valid, seg_gid, e_local))
        send_gate = send_gate.at[j].set(seg_gate * valid)
        send_valid = send_valid.at[j].set(valid)
        send_tok = send_tok.at[j].set(seg_tok)

    # --- 2) a2a along model: tokens reach their owner column --------------
    rx = jax.lax.all_to_all(send_x, tp_axis, split_axis=0, concat_axis=0, tiled=True)
    re = jax.lax.all_to_all(send_eid, tp_axis, split_axis=0, concat_axis=0, tiled=True)

    if resident:
        gx = rx.reshape(-1, D)  # (tp*C, D): this row's tokens only
        ge = re.reshape(-1)
    else:
        # --- 3) broadcast along data rows (F is row-sharded) --------------
        gx = jax.lax.all_gather(rx, row_axes, axis=0, tiled=True)  # (dp*tp, C, D)
        ge = jax.lax.all_gather(re, row_axes, axis=0, tiled=True)
        gx = gx.reshape(-1, D)  # (dp*tp*C, D)
        ge = ge.reshape(-1)

    # --- 4) local expert compute on the F/dp slice -------------------------
    order2 = jnp.argsort(ge)
    t_ids = ge[order2]
    t_pos = order2.astype(jnp.int32)
    Tall = gx.shape[0]
    C2 = min(Tall, max(8, -(-int(capacity * Tall / max(e_local, 1)) // 8) * 8))
    out_partial = jnp.zeros((Tall, D), jnp.float32)
    for j2 in range(e_local):
        start = jnp.searchsorted(t_ids, j2).astype(jnp.int32)
        seg_ids = jax.lax.dynamic_slice_in_dim(t_ids, start, C2)
        seg_pos = jax.lax.dynamic_slice_in_dim(t_pos, start, C2)
        valid = (seg_ids == j2).astype(x.dtype)
        xs = jnp.take(gx, seg_pos, axis=0) * valid[:, None]
        hg = xs @ wg[j2]
        hu = xs @ wu[j2]
        a = jax.nn.silu(hg) if act == "swiglu" else jax.nn.gelu(hg, approximate=True)
        ys = (a * hu) @ wo[j2]  # (C2, D) partial over the F slice
        out_partial = out_partial.at[seg_pos].add(
            ys.astype(jnp.float32) * valid.astype(jnp.float32)[:, None])

    # --- 5) combine F slices; reduce-scatter hands each row its own chunk
    #        directly (1/dp the bytes of psum + slice) --------------------
    if resident:
        mine = out_partial  # (tp*C, D): already complete (full F)
    else:
        mine = jax.lax.psum_scatter(
            out_partial, row_axes, scatter_dimension=0, tiled=True
        )  # (tp*C, D)

    # --- 6) a2a back + gated scatter into source tokens --------------------
    back = jax.lax.all_to_all(mine.reshape(tp, C, D), tp_axis,
                              split_axis=0, concat_axis=0, tiled=True)
    out = jnp.zeros((N, D), jnp.float32)
    for j in range(tp):
        w = (send_gate[j] * send_valid[j].astype(jnp.float32))[:, None]
        out = out.at[send_tok[j]].add(back[j].astype(jnp.float32) * w)
    return out.reshape(Bl, S, D).astype(x.dtype)


def moe_ffn(
    x: jax.Array,
    params: dict,
    cfg: ModelConfig,
    ctx: ShardingCtx,
) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar).

    params: router (D,E), e_wg/e_wu (E,D,F), e_wo (E,F,D),
            optional shared_wg/shared_wu (D, n_shared*F), shared_wo.
    """
    E, k = cfg.moe_experts, cfg.moe_top_k
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    ids = ids.astype(jnp.int32)

    # Switch-style load-balance loss (computed globally; cheap).
    one_hot = jax.nn.one_hot(ids[..., 0], E, dtype=jnp.float32)
    f = jnp.mean(one_hot, axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * p) * cfg.moe_aux_weight

    tp = ctx.tp
    B = x.shape[0]
    wide = tuple(ctx.dp_axes) + (ctx.tp_axis,)
    use_2d = (
        ctx.enabled and tp > 1 and E % tp == 0
        and ctx.strategy == "fsdp_ep"
        and B % ctx.axis_size(wide) == 0
        and cfg.moe_d_ff % ctx.axis_size(ctx.fsdp_axis) == 0
    )
    if use_2d:
        row_axes = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
        row_sizes = tuple(ctx.axis_size(a) for a in ctx.dp_axes)
        # small expert blocks (<= 512 MB per owner column) live resident on
        # their owner: tokens a2a only, zero row-axis collectives
        e_bytes = (E // tp) * 3 * cfg.d_model * cfg.moe_d_ff * 2
        resident = e_bytes <= (512 << 20)
        fn = functools.partial(
            _routed_2d,
            e_local=E // tp, k=k, n_experts=E, capacity=cfg.moe_capacity,
            act=cfg.act, tp_axis=ctx.tp_axis, tp=tp,
            row_axes=row_axes, row_sizes=row_sizes, resident=resident,
        )
        w_spec = (
            P(ctx.tp_axis, None, None) if resident
            else P(ctx.tp_axis, None, ctx.fsdp_axis)
        )
        wo_spec = (
            P(ctx.tp_axis, None, None) if resident
            else P(ctx.tp_axis, ctx.fsdp_axis, None)
        )
        routed = shard_map(
            fn,
            mesh=ctx.mesh,
            in_specs=(
                P(wide, None, None),
                P(wide, None, None),
                P(wide, None, None),
                w_spec,   # wg (E, D, F[/dp])
                w_spec,   # wu
                wo_spec,  # wo (E, F[/dp], D)
            ),
            out_specs=P(wide, None, None),
        )(x, ids, gates, params["e_wg"], params["e_wu"], params["e_wo"])
    elif ctx.enabled and tp > 1 and E % tp == 0:
        dp_spec = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
        fn = functools.partial(
            _routed_local,
            e_local=E // tp,
            k=k,
            n_experts=E,
            capacity=cfg.moe_capacity,
            act=cfg.act,
            tp_axis=ctx.tp_axis,
        )
        routed = shard_map(
            fn,
            mesh=ctx.mesh,
            in_specs=(
                P(dp_spec, None, None),
                P(dp_spec, None, None),
                P(dp_spec, None, None),
                P(ctx.tp_axis, None, None),
                P(ctx.tp_axis, None, None),
                P(ctx.tp_axis, None, None),
            ),
            out_specs=P(dp_spec, None, None),
        )(x, ids, gates, params["e_wg"], params["e_wu"], params["e_wo"])
    else:
        routed = _routed_local(
            x, ids, gates, params["e_wg"], params["e_wu"], params["e_wo"],
            e_local=E, k=k, n_experts=E, capacity=cfg.moe_capacity,
            act=cfg.act, tp_axis=None,
        )

    if cfg.moe_shared:
        from repro.models.layers import glu_mlp

        routed = routed + glu_mlp(
            x, params["shared_wg"], params["shared_wu"], params["shared_wo"],
            cfg.act, ctx,
        )
    return constrain(routed, ("batch", None, None), ctx), aux
