"""Mamba2 (SSD — state-space duality) in chunked-parallel JAX form.

Train/prefill use the chunkwise-parallel SSD decomposition (arXiv:2405.21060):
within a chunk of Q tokens the quadratic masked-decay form runs on the MXU;
states are carried across chunks with a lax.scan.  Decode is the O(1)
recurrent update.  All state math in f32; io in model dtype.

Layer params:
  in_proj (D, 2*di + 2*N + H)   -> [z, x, B, C, dt]
  conv_w (W, di + 2*N), conv_b  -> causal depthwise conv on (x, B, C)
  A_log (H,), D_skip (H,), dt_bias (H,)
  norm_y (di,)                  -> gated RMSNorm before out_proj
  out_proj (di, D)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingCtx, constrain
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xin, Bc, Cc, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, xin, Bc, Cc, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """x (B,S,C), w (W,C) depthwise causal; state (B,W-1,C) carries history.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    S = x.shape[1]
    for i in range(W):  # static tiny loop (W=4)
        y = y + xp[:, i : i + S, :] * w[i][None, None, :]
    new_state = xp[:, -(W - 1):, :] if W > 1 else state
    return y + b[None, None, :], new_state


def ssd_scan(
    xh: jax.Array,  # (B,S,H,P) conv'd inputs, head-split
    Bc: jax.Array,  # (B,S,N)
    Cc: jax.Array,  # (B,S,N)
    dt: jax.Array,  # (B,S,H) post-softplus
    A: jax.Array,  # (H,) negative
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B,H,P,N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B_, S, H, Pd = xh.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xf = (xh.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    la = (A.astype(jnp.float32)[None, None, :] * dt.astype(jnp.float32))  # log decay (B,S,H)

    # chunked views: (nc, B, Q, ...)
    def chunked(t):
        return t.reshape(B_, nc, Q, *t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))

    xc = chunked(xf)  # (nc,B,Q,H,P)
    bc = chunked(Bc.astype(jnp.float32))  # (nc,B,Q,N)
    cc = chunked(Cc.astype(jnp.float32))
    lac = chunked(la)  # (nc,B,Q,H)

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B_, H, Pd, N), jnp.float32)
    )

    def body(state, inp):
        xq, bq, cq, laq = inp  # (B,Q,...)
        clog = jnp.cumsum(laq, axis=1)  # (B,Q,H) inclusive
        # intra-chunk: M[b,h,i,j] = (C_i . B_j) * exp(clog_i - clog_j), j <= i
        cb = jnp.einsum("bin,bjn->bij", cq, bq)  # (B,Q,Q)
        dec = jnp.exp(clog[:, :, None, :] - clog[:, None, :, :])  # (B,i,j,H)
        tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
        m = cb[:, :, :, None] * dec * tri[None, :, :, None]  # (B,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xq)
        # inter-chunk from carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, state, jnp.exp(clog))
        # new state
        tail = jnp.exp(clog[:, -1:, :] - clog)  # decay from j to chunk end
        s_new = jnp.einsum("bjn,bjhp,bjh->bhpn", bq, xq, tail)
        state = state * jnp.exp(clog[:, -1, :])[:, :, None, None] + s_new
        return state, y_intra + y_inter

    state, ys = jax.lax.scan(body, s0, (xc, bc, cc, lac))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S, H, Pd)
    return y, state


def ssm_forward(
    h: jax.Array,  # (B,S,D) pre-normed input
    p: dict,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    conv_state: Optional[jax.Array] = None,
    ssm_state: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """Full-sequence SSM branch (train / prefill)."""
    B, S, D = h.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_head_dim
    proj = h @ p["in_proj"]
    proj = constrain(proj, ("batch", None, "inner"), ctx)
    z, xin, Bc, Cc, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, S, H, Pd)
    # ragged tail: pad to a chunk multiple with dt=0 steps (decay=exp(0)=1,
    # update=dt*x=0 -> exactly zero-effect on state and outputs)
    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        dtp = jnp.pad(dtp, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_scan(xh, Bc, Cc, dtp, A, Q, ssm_state)
    if pad:
        y = y[:, :S]
        xh = xh[:, :S]
    y = y + xh.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(h.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_y"], cfg.norm_eps)
    out = y @ p["out_proj"]
    out = constrain(out, ("batch", None, None), ctx)
    if return_state:
        return out, (new_conv, state.astype(jnp.float32))
    return out


def ssm_decode_step(
    h: jax.Array,  # (B,1,D)
    p: dict,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    conv_state: jax.Array,  # (B,W-1,di+2N)
    ssm_state: jax.Array,  # (B,H,P,N) f32
):
    """O(1) recurrent step.  Returns (out (B,1,D), (conv_state, ssm_state))."""
    B, _, D = h.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_head_dim
    proj = h @ p["in_proj"]
    z, xin, Bc, Cc, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)  # (B,1,C)
    xp = jnp.concatenate([conv_state, conv_in], axis=1)  # (B,W,C)
    w = p["conv_w"]
    y = jnp.einsum("bwc,wc->bc", xp.astype(jnp.float32), w.astype(jnp.float32))
    y = jax.nn.silu(y + p["conv_b"].astype(jnp.float32))[:, None, :].astype(h.dtype)
    new_conv = xp[:, 1:, :]
    xin, Bc, Cc = jnp.split(y, [di, di + N], axis=-1)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,1,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(A[None, :] * dtp[:, 0])  # (B,H)
    xh = xin.reshape(B, H, Pd).astype(jnp.float32) * dtp[:, 0, :, None]
    upd = jnp.einsum("bn,bhp->bhpn", Bc[:, 0].astype(jnp.float32), xh)
    state = ssm_state * a[:, :, None, None] + upd
    yh = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), state)
    yh = yh + xin.reshape(B, H, Pd).astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, :, None]
    yf = yh.reshape(B, 1, di).astype(h.dtype)
    yf = rmsnorm(yf * jax.nn.silu(z), p["norm_y"], cfg.norm_eps)
    out = yf @ p["out_proj"]
    return out, (new_conv, state)
