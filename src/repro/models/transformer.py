"""Architecture assembly: segments of scanned layers.

A model is a list of SEGMENTS, each (kind, count) with parameters stacked
on a leading layer axis and executed with lax.scan — compile time stays
O(#segments), not O(#layers), which is what lets the 88-layer
mistral-large dry-run compile on one CPU core.

Layer kinds:
  dense     attn + GLU-MLP                      (qwen3/gemma/mistral/granite/llava)
  moe       attn + routed-expert FFN            (deepseek-moe tail)
  moe_pair  dense layer then MoE layer          (llama4 interleaved "early-fusion" stack)
  ssm       Mamba2 SSD block                    (mamba2)
  hybrid    parallel attn + SSM heads, then MLP (hymba; window/global per segment)
  enc       bidirectional attn + MLP            (whisper encoder)
  decx      causal self-attn + cross-attn + MLP (whisper decoder)

Caches are per-segment pytrees stacked on the layer axis; sliding-window
segments keep ring buffers of size `window` (so hymba long_500k holds 1024
keys per SWA layer, not 524288).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingCtx, constrain
from repro.models.config import ModelConfig
from repro.models.layers import attention, glu_mlp, rmsnorm, rotary
from repro.models.moe import moe_ffn
from repro.models.ssm import ssm_decode_step, ssm_forward


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    count: int
    window: Optional[int] = None  # hybrid SWA segments


def build_segments(cfg: ModelConfig) -> List[Segment]:
    if cfg.family == "ssm":
        return [Segment("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        segs: List[Segment] = []
        ids = sorted(set(cfg.global_layers))
        prev = 0
        for g in ids:
            if g > prev:
                segs.append(Segment("hybrid", g - prev, window=cfg.window))
            segs.append(Segment("hybrid", 1, window=None))
            prev = g + 1
        if prev < cfg.n_layers:
            segs.append(Segment("hybrid", cfg.n_layers - prev, window=cfg.window))
        return segs
    if cfg.moe_experts:
        if cfg.moe_period == 2:
            segs = []
            if cfg.moe_first_dense:
                segs.append(Segment("dense", cfg.moe_first_dense))
            segs.append(Segment("moe_pair", (cfg.n_layers - cfg.moe_first_dense) // 2))
            return segs
        segs = []
        if cfg.moe_first_dense:
            segs.append(Segment("dense", cfg.moe_first_dense))
        segs.append(Segment("moe", cfg.n_layers - cfg.moe_first_dense))
        return segs
    return [Segment("dense", cfg.n_layers)]


# ---------------------------------------------------------------------------
# attention sub-block
# ---------------------------------------------------------------------------


def _proj_qkv(x, p, cfg: ModelConfig, positions, ctx, prefix=""):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ p[prefix + "wq"]).reshape(B, S, H, hd)
    k = (x @ p[prefix + "wk"]).reshape(B, S, KV, hd)
    v = (x @ p[prefix + "wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p[prefix + "qn"], cfg.norm_eps)
        k = rmsnorm(k, p[prefix + "kn"], cfg.norm_eps)
    if positions is not None:
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(h, p, cfg, ctx, positions, *, causal=True, window=None, prefix="",
               src=None):
    """Self-attention, or cross-attention when `src` (B,Se,D) is given."""
    x = rmsnorm(h, p[prefix + "ln1"], cfg.norm_eps, cfg.norm_plus_one)
    if src is None:
        q, k, v = _proj_qkv(x, p, cfg, positions, ctx, prefix)
    else:
        B, S = x.shape[:2]
        H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
        q = (x @ p[prefix + "wq"]).reshape(B, S, H, hd)
        k = (src @ p[prefix + "wk"]).reshape(B, src.shape[1], KV, hd)
        v = (src @ p[prefix + "wv"]).reshape(B, src.shape[1], KV, hd)
    o = attention(q, k, v, ctx, causal=causal, window=window,
                  scale=cfg.attn_scale, chunk=cfg.attn_block)
    B, S = h.shape[:2]
    out = o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p[prefix + "wo"]
    return h + constrain(out, ("batch", None, None), ctx), (k, v)


def attn_decode(h, p, cfg, ctx, pos, kcache, vcache, *, window=None, prefix="",
                ring: bool = False):
    """h (B,1,D); kcache/vcache (B,Smax,KV,hd).  pos: scalar current index."""
    B = h.shape[0]
    x = rmsnorm(h, p[prefix + "ln1"], cfg.norm_eps, cfg.norm_plus_one)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _proj_qkv(x, p, cfg, positions, ctx, prefix)
    Smax = kcache.shape[1]
    write_at = (pos % Smax) if ring else pos
    kcache = jax.lax.dynamic_update_slice(kcache, k.astype(kcache.dtype), (0, write_at, 0, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, v.astype(vcache.dtype), (0, write_at, 0, 0))
    valid = jnp.minimum(pos + 1, Smax) if ring else (pos + 1)
    o = attention(q, kcache, vcache, ctx, causal=False, window=None,
                  scale=cfg.attn_scale, kv_valid_len=valid)
    out = o.reshape(B, 1, cfg.n_heads * cfg.head_dim) @ p[prefix + "wo"]
    return h + out, kcache, vcache


def mlp_block(h, p, cfg, ctx, prefix=""):
    x = rmsnorm(h, p[prefix + "ln2"], cfg.norm_eps, cfg.norm_plus_one)
    if cfg.act == "gelu":  # non-gated (whisper)
        y = jax.nn.gelu(x @ p[prefix + "w1"], approximate=True) @ p[prefix + "w2"]
        y = constrain(y, ("batch", None, None), ctx)
    else:
        y = glu_mlp(x, p[prefix + "wg"], p[prefix + "wu"], p[prefix + "wo2"], cfg.act, ctx)
    return h + y


def moe_block(h, p, cfg, ctx):
    x = rmsnorm(h, p["ln2"], cfg.norm_eps, cfg.norm_plus_one)
    y, aux = moe_ffn(x, p, cfg, ctx)
    return h + y, aux


# ---------------------------------------------------------------------------
# per-kind layer application (train / prefill / decode)
# ---------------------------------------------------------------------------


def layer_train(kind: str, h, lp, cfg, ctx, positions, window=None, enc_kv=None,
                want_cache: bool = False, cache_len: Optional[int] = None):
    """Returns (h, aux, cache_entry)."""
    aux = jnp.float32(0.0)
    cache: Dict[str, Any] = {}
    if kind == "dense":
        h, (k, v) = attn_train(h, lp, cfg, ctx, positions)
        if want_cache:
            cache = {"k": _to_cache(k, cache_len), "v": _to_cache(v, cache_len)}
        h = mlp_block(h, lp, cfg, ctx)
    elif kind == "moe":
        h, (k, v) = attn_train(h, lp, cfg, ctx, positions)
        if want_cache:
            cache = {"k": _to_cache(k, cache_len), "v": _to_cache(v, cache_len)}
        h, aux = moe_block(h, lp, cfg, ctx)
    elif kind == "moe_pair":
        h, (k1, v1) = attn_train(h, lp, cfg, ctx, positions, prefix="a_")
        h = mlp_block(h, lp, cfg, ctx, prefix="a_")
        h, (k2, v2) = attn_train(h, lp, cfg, ctx, positions, prefix="b_")
        h, aux = moe_block(h, _sub(lp, "b_"), cfg, ctx)
        if want_cache:
            cache = {"k": _to_cache(k1, cache_len), "v": _to_cache(v1, cache_len),
                     "k2": _to_cache(k2, cache_len), "v2": _to_cache(v2, cache_len)}
    elif kind == "ssm":
        x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        if want_cache:
            y, (cs, ss) = ssm_forward(x, lp, cfg, ctx, return_state=True)
            cache = {"conv": cs, "state": ss}
        else:
            y = ssm_forward(x, lp, cfg, ctx)
        h = h + y
        h = mlp_block(h, lp, cfg, ctx) if cfg.d_ff else h
    elif kind == "hybrid":
        x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(x, lp, cfg, positions, ctx)
        o = attention(q, k, v, ctx, causal=True, window=window,
                      scale=cfg.attn_scale, chunk=cfg.attn_block)
        B, S = h.shape[:2]
        attn_out = o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ lp["wo"]
        if want_cache:
            y, (cs, ss) = ssm_forward(x, _sub(lp, "s_"), cfg, ctx, return_state=True)
            clen = window if window is not None else cache_len
            cache = {"k": _to_cache(k, clen, ring=window is not None),
                     "v": _to_cache(v, clen, ring=window is not None),
                     "conv": cs, "state": ss}
        else:
            y = ssm_forward(x, _sub(lp, "s_"), cfg, ctx)
        mix = 0.5 * (
            rmsnorm(attn_out, lp["na"], cfg.norm_eps) * lp["beta_a"]
            + rmsnorm(y, lp["ns"], cfg.norm_eps) * lp["beta_s"]
        )
        h = h + constrain(mix.astype(h.dtype), ("batch", None, None), ctx)
        h = mlp_block(h, lp, cfg, ctx)
    elif kind == "enc":
        h, _ = attn_train(h, lp, cfg, ctx, positions, causal=False)
        h = mlp_block(h, lp, cfg, ctx)
    elif kind == "decx":
        h, (k, v) = attn_train(h, lp, cfg, ctx, positions)
        if want_cache:
            cache = {"k": _to_cache(k, cache_len), "v": _to_cache(v, cache_len)}
        h, (ck, cv) = attn_train(h, lp, cfg, ctx, None, causal=False, prefix="x_",
                                 src=enc_kv)
        if want_cache:
            cache["ck"], cache["cv"] = ck, cv
        h = mlp_block(h, lp, cfg, ctx)
    else:
        raise ValueError(kind)
    return h, aux, cache


def _to_cache(k: jax.Array, cache_len: Optional[int], ring: bool = False) -> jax.Array:
    """Pad/trim a (B,S,KV,hd) tensor to the cache length.

    Ring caches place token t at slot t % W, so a trimmed window is rolled
    into ring phase before handoff to decode."""
    S = k.shape[1]
    if cache_len is None or S == cache_len and not (ring and S > cache_len):
        return k
    if S < cache_len:
        return jnp.pad(k, ((0, 0), (0, cache_len - S), (0, 0), (0, 0)))
    trimmed = k[:, S - cache_len :]
    if ring:
        trimmed = jnp.roll(trimmed, S % cache_len, axis=1)
    return trimmed


def _sub(lp: dict, prefix: str) -> dict:
    return {k[len(prefix):]: v for k, v in lp.items() if k.startswith(prefix)}


def layer_decode(kind: str, h, lp, cfg, ctx, pos, cache, window=None):
    """One-token step.  Returns (h, new_cache)."""
    if kind in ("dense", "moe"):
        h, kc, vc = attn_decode(h, lp, cfg, ctx, pos, cache["k"], cache["v"])
        if kind == "dense":
            h = mlp_block(h, lp, cfg, ctx)
            return h, {"k": kc, "v": vc}
        h, _ = moe_block(h, lp, cfg, ctx)
        return h, {"k": kc, "v": vc}
    if kind == "moe_pair":
        h, kc1, vc1 = attn_decode(h, lp, cfg, ctx, pos, cache["k"], cache["v"], prefix="a_")
        h = mlp_block(h, lp, cfg, ctx, prefix="a_")
        h, kc2, vc2 = attn_decode(h, lp, cfg, ctx, pos, cache["k2"], cache["v2"], prefix="b_")
        h, _ = moe_block(h, _sub(lp, "b_"), cfg, ctx)
        return h, {"k": kc1, "v": vc1, "k2": kc2, "v2": vc2}
    if kind == "ssm":
        x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        y, (cs, ss) = ssm_decode_step(x, lp, cfg, ctx, cache["conv"], cache["state"])
        h = h + y
        h = mlp_block(h, lp, cfg, ctx) if cfg.d_ff else h
        return h, {"conv": cs, "state": ss}
    if kind == "hybrid":
        x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        B = h.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        q, k, v = _proj_qkv(x, lp, cfg, positions, ctx)
        Smax = cache["k"].shape[1]
        ring = window is not None
        write_at = (pos % Smax) if ring else pos
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, write_at, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, write_at, 0, 0))
        valid = jnp.minimum(pos + 1, Smax) if ring else (pos + 1)
        o = attention(q, kc, vc, ctx, causal=False, scale=cfg.attn_scale, kv_valid_len=valid)
        attn_out = o.reshape(B, 1, cfg.n_heads * cfg.head_dim) @ lp["wo"]
        y, (cs, ss) = ssm_decode_step(x, _sub(lp, "s_"), cfg, ctx, cache["conv"], cache["state"])
        mix = 0.5 * (
            rmsnorm(attn_out, lp["na"], cfg.norm_eps) * lp["beta_a"]
            + rmsnorm(y, lp["ns"], cfg.norm_eps) * lp["beta_s"]
        )
        h = h + mix.astype(h.dtype)
        h = mlp_block(h, lp, cfg, ctx)
        return h, {"k": kc, "v": vc, "conv": cs, "state": ss}
    if kind == "decx":
        h, kc, vc = attn_decode(h, lp, cfg, ctx, pos, cache["k"], cache["v"])
        x = rmsnorm(h, lp["x_ln1"], cfg.norm_eps)
        B = h.shape[0]
        q = (x @ lp["x_wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        o = attention(q, cache["ck"], cache["cv"], ctx, causal=False, scale=cfg.attn_scale)
        h = h + o.reshape(B, 1, cfg.n_heads * cfg.head_dim) @ lp["x_wo"]
        h = mlp_block(h, lp, cfg, ctx)
        return h, {"k": kc, "v": vc, "ck": cache["ck"], "cv": cache["cv"]}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# segment execution
# ---------------------------------------------------------------------------


def run_segments_train(params_segs, segs, h, cfg, ctx, positions, enc_kv=None):
    aux_total = jnp.float32(0.0)

    for seg, sp in zip(segs, params_segs):
        def body(carry, lp, _kind=seg.kind, _win=seg.window):
            hh, aux = carry
            hh, a, _ = layer_train(_kind, hh, lp, cfg, ctx, positions,
                                   window=_win, enc_kv=enc_kv)
            return (hh, aux + a), None

        if cfg.remat:
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat_policy == "dots" else None
            )
            fn = jax.checkpoint(body, policy=policy)
        else:
            fn = body
        (h, aux_total), _ = jax.lax.scan(fn, (h, aux_total), sp)
    return h, aux_total


def run_segments_prefill(params_segs, segs, h, cfg, ctx, positions, cache_len,
                         enc_kv=None):
    caches = []
    for seg, sp in zip(segs, params_segs):
        def body(hh, lp, _kind=seg.kind, _win=seg.window):
            hh, _, cache = layer_train(_kind, hh, lp, cfg, ctx, positions,
                                       window=_win, enc_kv=enc_kv,
                                       want_cache=True, cache_len=cache_len)
            return hh, cache

        h, seg_cache = jax.lax.scan(body, h, sp)
        caches.append(seg_cache)
    return h, caches


def run_segments_decode(params_segs, segs, h, cfg, ctx, pos, caches):
    new_caches = []
    for seg, sp, sc in zip(segs, params_segs, caches):
        def body(hh, inp, _kind=seg.kind, _win=seg.window):
            lp, cache_l = inp
            hh, new_cache = layer_decode(_kind, hh, lp, cfg, ctx, pos, cache_l,
                                         window=_win)
            return hh, new_cache

        h, seg_cache = jax.lax.scan(body, h, (sp, sc))
        new_caches.append(seg_cache)
    return h, new_caches
