"""Serving runtime: slot-based continuous batching over prefill/decode."""

from repro.serve.engine import ServeEngine, Request  # noqa: F401
