"""ServeEngine — batched serving with slot-based continuous batching.

A fixed-slot decode batch (the static-shape TPU idiom):

  - incoming requests queue up; free slots are filled by running prefill
    on the new prompt (right-padded to the slot prompt bucket) and
    splicing its KV into the batch cache at the slot index,
  - every engine tick = one jitted decode_step for ALL active slots,
  - finished slots (EOS / max_new_tokens) free immediately.

Prompts may arrive BIT-PACKED ('packed' ingestion): the prompt bytes the
"network" delivers are the lakeformat blocks themselves and prefill's
stage 0 unpacks them on-device — the serving-side datapath offload.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingCtx, local_ctx
from repro.models.config import ModelConfig
from repro.models.model import decode_step, packed_token_shape, prefill, unpack_tokens


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 max_len: int = 512, ctx: Optional[ShardingCtx] = None,
                 greedy: bool = True):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx or local_ctx()
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.caches = None
        self.last_tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, t, c, pos, cfg, self.ctx)
        )
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, self.ctx, cache_len=max_len)
        )
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = np.asarray(req.tokens, np.int32)[None, :]
            batch = {"tokens": jnp.asarray(prompt)}
            if self.cfg.family == "vlm":
                batch["embeds"] = jnp.zeros(
                    (1, self.cfg.vision_tokens, self.cfg.d_model), jnp.bfloat16)
            if self.cfg.is_encdec:
                batch["enc_embeds"] = jnp.zeros(
                    (1, self.cfg.encoder_seq, self.cfg.d_model), jnp.bfloat16)
            logits, cache1 = self._prefill(self.params, batch)
            tok = int(jnp.argmax(logits[0])) if self.greedy else int(jnp.argmax(logits[0]))
            req.out.append(tok)
            if self.caches is None:
                # first admission defines the batched cache: leaves are
                # (L, B=1, ...) stacked per segment -> batch axis is 1
                self.caches = jax.tree.map(
                    lambda x: jnp.repeat(jnp.zeros_like(x), self.n_slots, axis=1),
                    cache1,
                )
            self.caches = _splice_slot(self.caches, cache1, slot)
            self.slot_pos[slot] = prompt.shape[1]
            self.slots[slot] = req
            self.last_tokens = self.last_tokens.at[slot, 0].set(tok)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick.  Returns number of active slots stepped."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        pos = jnp.int32(int(self.slot_pos[active].max()))  # conservative shared pos
        logits, self.caches = self._decode(self.params, self.last_tokens, self.caches, pos)
        toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for slot in active:
            req = self.slots[slot]
            tok = int(toks[slot])
            req.out.append(tok)
            self.slot_pos[slot] += 1
            self.last_tokens = self.last_tokens.at[slot, 0].set(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.out) >= req.max_new_tokens or \
                    self.slot_pos[slot] >= self.max_len - 1:
                req.done = True
                self.slots[slot] = None
        self.steps += 1
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)) and ticks < max_ticks:
            before = [s for s in self.slots]
            self.step()
            ticks += 1
            for r in before:
                if r is not None and r.done:
                    done.append(r)
        return done


def _batch_axis(x) -> int:
    return 0  # all cache leaves are (n_layers, B, ...) -> batch is axis 1


def _splice_slot(batched, single, slot: int):
    """Write a prefill cache (B=1) into slot `slot` of the batched cache."""
    def put(b, s):
        # leaves are (L, B, ...) stacked per segment
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype), slot, axis=1)

    return jax.tree.map(put, batched, single)
