"""Training substrate: optimizers, checkpointing, the training loop."""

from repro.train.optimizer import OptConfig, init_opt_state, apply_updates  # noqa: F401
from repro.train.checkpoint import CheckpointManager  # noqa: F401
