"""Fault-tolerant checkpointing: atomic, integrity-checked, mesh-elastic.

Layout per step:
    <dir>/step_<N>.tmp-<pid>/   (staging)
    <dir>/step_<N>/
        manifest.json   {step, keys, shapes, dtypes, checksums, meta}
        arrays.npz      flattened pytree leaves (path-keyed)

Save is write-to-staging + fsync + atomic rename — a crash mid-save never
corrupts the latest checkpoint.  `restore_latest` verifies checksums and
falls back to the previous step on corruption (tested).  Retention keeps
the newest K.

Elastic re-mesh: leaves are stored UNSHARDED (host-gathered), and
`restore(..., ctx, dims)` device_puts them with the shardings of whatever
mesh is current — so a 512-chip checkpoint restarts on 256 chips (or any
divisor), which is the elastic-scaling story (tested 8 -> 4 fake devices).
At real 1000+-node scale the same manifest format fronts per-shard files;
the single-file variant is what this container can exercise.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.distributed.sharding import ShardingCtx, sharding_for

# npz cannot represent ml_dtypes (bfloat16, fp8): store as same-width uints
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_storable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][0])
    return arr


def _flatten(tree, prefix="") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            out.extend(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for i, v in enumerate(tree):
            out.extend(_flatten(v, f"{prefix}{i}/"))
        return out
    return [(prefix.rstrip("/"), tree)]


def _unflatten(template, flat: Dict[str, Any], prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten(template[k], flat, f"{prefix}{k}/") for k in template}
    if isinstance(template, list):
        return [_unflatten(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)]
    if isinstance(template, tuple):
        return tuple(_unflatten(v, flat, f"{prefix}{i}/") for i, v in enumerate(template))
    return flat[prefix.rstrip("/")]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, meta: Optional[dict] = None) -> str:
        flat = _flatten(tree)
        arrays = {}
        checksums = {}
        dtypes = {}
        for key, leaf in flat:
            arr = np.asarray(jax.device_get(leaf))
            arr, dtype_name = _to_storable(arr)
            arrays[key] = arr
            dtypes[key] = dtype_name
            checksums[key] = hashlib.sha1(arr.tobytes()).hexdigest()[:12]
        final = os.path.join(self.dir, f"step_{step:08d}")
        staging = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=self.dir)
        try:
            npz_path = os.path.join(staging, "arrays.npz")
            np.savez(npz_path, **{k.replace("/", "|"): v for k, v in arrays.items()})
            manifest = {
                "step": step,
                "checksums": checksums,
                "dtypes": dtypes,
                "meta": meta or {},
                "keys": [k for k, _ in flat],
            }
            with open(os.path.join(staging, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(staging, final)  # atomic publish
        except Exception:
            shutil.rmtree(staging, ignore_errors=True)
            raise
        self._retain()
        return final

    def _retain(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    # ------------------------------------------------------------------
    def _load_step(self, step: int, template: Any) -> Tuple[Any, dict]:
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {}
        for key in manifest["keys"]:
            arr = data[key.replace("/", "|")]
            got = hashlib.sha1(arr.tobytes()).hexdigest()[:12]
            if got != manifest["checksums"][key]:
                raise IOError(f"checksum mismatch at {key} in step {step}")
            flat[key] = _from_storable(arr, manifest.get("dtypes", {}).get(key, str(arr.dtype)))
        return _unflatten(template, flat), manifest

    def restore_latest(self, template: Any, ctx: Optional[ShardingCtx] = None,
                       dims: Optional[Any] = None) -> Tuple[Optional[Any], Optional[dict]]:
        """Try newest -> oldest; verify integrity; reshard onto `ctx`."""
        for step in reversed(self.list_steps()):
            try:
                tree, manifest = self._load_step(step, template)
            except Exception:
                continue  # corrupted: fall back to previous checkpoint
            if ctx is not None and ctx.enabled and dims is not None:
                tree = reshard(tree, dims, ctx)
            else:
                tree = jax.tree.map(jax.numpy.asarray, tree)
            return tree, manifest
        return None, None


def reshard(tree: Any, dims: Any, ctx: ShardingCtx) -> Any:
    """device_put every leaf with the sharding of the CURRENT mesh — the
    elastic-scaling entry point (old mesh shape is irrelevant)."""
    def put(leaf, dm):
        sh = sharding_for(dm, ctx, np.shape(leaf))
        return jax.device_put(leaf, sh) if sh is not None else jax.numpy.asarray(leaf)

    return jax.tree.map(
        put, tree, dims,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )
