"""Training loop: datapath batches -> microbatched grad accumulation ->
sharded optimizer -> checkpoint/resume, with straggler instrumentation.

The jitted step's first op on a 'fused'-mode batch is the bit-unpack of the
token blocks (models/model.py) — the paper's decode offload as stage 0 of
the training program.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.fault_tolerance import StragglerDetector
from repro.distributed.sharding import ShardingCtx, local_ctx, sharding_for, spec_for
from repro.models.config import ModelConfig
from repro.models.model import forward_train, init_params, param_dims
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig, apply_updates, init_opt_state


def _tree_zeros_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def make_train_step(cfg: ModelConfig, optcfg: OptConfig,
                    ctx: Optional[ShardingCtx] = None) -> Callable:
    ctx = ctx or local_ctx()
    m = cfg.microbatches

    def _shard_grads(grads):
        """Constrain grads to the param storage sharding so XLA lowers the
        cross-device reduction as reduce-scatter (1/n bytes) instead of a
        full all-gather — §Perf iteration 5."""
        if not ctx.enabled:
            return grads
        from repro.distributed.sharding import sharding_for
        dims = param_dims(cfg)
        return jax.tree.map(
            lambda dm, g: jax.lax.with_sharding_constraint(
                g, sharding_for(dm, ctx, g.shape)),
            dims, grads,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        )

    def train_step(params, opt_state, batch):
        def loss_for(p, mb):
            return forward_train(p, mb, cfg, ctx)

        if m == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_for, has_aux=True)(params, batch)
            grads = _shard_grads(grads)
        else:
            mb_batch = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch
            )

            def body(carry, mb):
                gacc, lacc = carry
                (l, met), g = jax.value_and_grad(loss_for, has_aux=True)(params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            (grads, loss), _ = jax.lax.scan(
                body, (_tree_zeros_f32(params), jnp.float32(0.0)), mb_batch
            )
            grads = _shard_grads(jax.tree.map(lambda g: g / m, grads))
            loss = loss / m
            metrics = {}
        params, opt_state, stats = apply_updates(params, grads, opt_state, optcfg)
        out = {"loss": loss, **stats}
        return params, opt_state, out

    return train_step


def train(
    cfg: ModelConfig,
    optcfg: OptConfig,
    pipeline,
    steps: int,
    ctx: Optional[ShardingCtx] = None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    seed: int = 0,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Runs `steps` steps; resumes from the latest checkpoint if present."""
    ctx = ctx or local_ctx()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params, optcfg)
    start_step = 0

    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if manager is not None:
        restored, manifest = manager.restore_latest(
            {"params": params, "opt": opt_state},
            ctx if ctx.enabled else None,
            {"params": param_dims(cfg), "opt": None} if ctx.enabled else None,
        )
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            params = jax.tree.map(lambda x: jnp.asarray(x), params)
            opt_state = jax.tree.map(lambda x: jnp.asarray(x), opt_state)
            start_step = manifest["meta"].get("step", 0)
            if "pipeline" in manifest["meta"]:
                pipeline.restore_state(manifest["meta"]["pipeline"])
            log_fn(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, optcfg, ctx), donate_argnums=(0, 1))
    straggler = StragglerDetector()
    history = []
    t_total = time.time()
    for step in range(start_step, steps):
        batch = pipeline.next_batch()
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        straggler.record("host0", step, dt)
        history.append(float(metrics["loss"]))
        if step % log_every == 0:
            log_fn(
                f"[train] step {step} loss {float(metrics['loss']):.4f} "
                f"lr {float(metrics.get('lr', 0)):.2e} {dt*1000:.0f}ms"
            )
        if manager is not None and (step + 1) % ckpt_every == 0:
            manager.save(
                step + 1,
                {"params": params, "opt": opt_state},
                meta={"step": step + 1, "pipeline": pipeline.checkpoint_state()},
            )
    return {
        "params": params,
        "opt_state": opt_state,
        "losses": history,
        "wall_s": time.time() - t_total,
        "stragglers": straggler.report(),
    }
