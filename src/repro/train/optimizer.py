"""Optimizers from scratch (no optax): AdamW and Adafactor, with warmup +
cosine schedules, global-norm clipping, and weight-decay masks.

Moments inherit the parameter sharding automatically (same pytree
structure + GSPMD propagation), so optimizer state is ZeRO-sharded for
free.  Adafactor's factored second moment is the 400B-scale option
(llama4): ~1 byte/param of optimizer state instead of 8.

moments_dtype='bfloat16' halves Adam state at <0.1% update error —
measured against the f32 reference in tests/test_optimizer.py.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"  # bfloat16 halves Adam state
    # adafactor
    factored_min_size: int = 128
    decay_adafactor: float = 0.8


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _decay_mask(params) -> Any:
    """Weight decay on >=2D params only (skip norms/scales/biases)."""
    return jax.tree.map(lambda p: p.ndim >= 2, params)


def _factored(shape, min_size: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_size and shape[-2] >= min_size


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moments_dtype)
    if cfg.name == "adamw":
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.name == "adafactor":
        def vrow(p):
            if _factored(p.shape, cfg.factored_min_size):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vcol(p):
            if _factored(p.shape, cfg.factored_min_size):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return {
            "vr": jax.tree.map(vrow, params),
            "vc": jax.tree.map(vcol, params),
            "step": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.name)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(params, grads, state, cfg: OptConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) if cfg.clip_norm else 1.0
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    mask = _decay_mask(params)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        mdt = jnp.dtype(cfg.moments_dtype)

        def upd(p, g, m, v, do_wd):
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if do_wd:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return newp, m32.astype(mdt), v32.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"], mask)
        newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": newm, "v": newv, "step": step}, {"lr": lr, "grad_norm": gnorm}

    if cfg.name == "adafactor":
        decay = 1.0 - (step.astype(jnp.float32) + 1) ** -cfg.decay_adafactor

        def upd(p, g, vr, vc, do_wd):
            g2 = g * g + 1e-30
            if _factored(p.shape, cfg.factored_min_size):
                vr32 = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
                vc32 = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr32, axis=-1, keepdims=True), 1e-30)
                vhat = (vr32[..., None] * vc32[..., None, :]) / denom[..., None]
            else:
                vr32 = decay * vr + (1 - decay) * g2
                vc32 = vc
                vhat = vr32
            delta = g / jnp.maximum(jnp.sqrt(vhat), 1e-12)
            # update clipping (RMS <= 1), Adafactor-style
            rms = jnp.sqrt(jnp.mean(delta ** 2) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms)
            if do_wd:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return newp, vr32, vc32

        out = jax.tree.map(upd, params, grads, state["vr"], state["vc"], mask)
        newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        newvr = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        newvc = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"vr": newvr, "vc": newvc, "step": step}, {"lr": lr, "grad_norm": gnorm}

    raise ValueError(cfg.name)
