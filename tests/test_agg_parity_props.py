"""Aggregate-pushdown kernels vs kernels/ref.py oracles: randomized
parity sweeps (seeded always; hypothesis-driven when available) across
int/float values, bitpack widths k, group counts, and ragged block
counts on the two-size ladder's bucket boundaries.

Bit-identity is the contract.  Every reduction in grouped_agg /
fused_agg_scan is WITHIN a block, so the batched ops must match the
oracle row-for-row regardless of how many pad blocks the ladder adds —
pad blocks carry mask == 0 and so emit exact merge identities.  The
int-sum overflow test pins the 16-bit hi/lo split: per-block int32
sums of values at the int32 extremes must recombine EXACTLY in int64,
which is the property the whole order-independent fabric merge rests on.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import agg
from repro.kernels import ops, ref
from repro.lakeformat import encodings as E
from repro.lakeformat.encodings import PACK_BLOCK

BACKENDS = ("ref", "pallas")

# block counts straddling the ladder bucket boundaries {1,2,3,4,6,8,...}
LADDER_NS = (1, 2, 3, 4, 5, 6, 7, 8, 9, 12, 13, 16, 17, 24, 25, 32, 33)


# ---------------------------------------------------------------------------
# generators (pure, seeded — shared by the fixed sweep and hypothesis)
# ---------------------------------------------------------------------------

def _rand_agg_inputs(rng, nb: int, n_groups: int, float_vals: bool):
    if float_vals:
        vals = rng.standard_normal((nb, PACK_BLOCK)).astype(np.float32) * 1e3
    else:
        vals = rng.integers(-(1 << 20), 1 << 20,
                            (nb, PACK_BLOCK)).astype(np.int32)
    gids = rng.integers(0, n_groups, (nb, PACK_BLOCK)).astype(np.int32)
    mask = rng.random((nb, PACK_BLOCK)) < 0.6
    return vals, gids, mask


def _check_grouped(vals, gids, mask, n_groups: int):
    want = tuple(np.asarray(p) for p in ref.grouped_agg(
        jnp.asarray(vals), jnp.asarray(gids), jnp.asarray(mask), n_groups))
    for be in BACKENDS:
        got = ops.grouped_agg_batch(vals, gids, mask, n_groups, backend=be)
        for i, (g, w) in enumerate(zip(got, want)):
            g = np.asarray(g)
            assert g.shape == w.shape, (be, i)
            assert np.array_equal(g, w), (be, i, n_groups)


def _rand_fused_inputs(rng, nb: int, k: int):
    v = rng.integers(0, np.uint64(1) << np.uint64(k), size=nb * PACK_BLOCK,
                     dtype=np.uint64)
    packed = E.bitpack_encode(v, k)
    mask = rng.random((nb, PACK_BLOCK)) < 0.6
    return packed, mask


def _check_fused(packed, mask, k: int):
    want = tuple(np.asarray(p) for p in ref.fused_agg_scan(
        jnp.asarray(packed), k, jnp.asarray(mask)))
    for be in BACKENDS:
        got = ops.fused_agg_batch(packed, k, mask, backend=be)
        for i, (g, w) in enumerate(zip(got, want)):
            assert np.array_equal(np.asarray(g), w), (be, i, k)


# ---------------------------------------------------------------------------
# fixed seeded sweeps (always run — hypothesis is optional in this image)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("float_vals", [False, True], ids=["int32", "float32"])
def test_grouped_agg_parity_across_ladder_boundaries(float_vals):
    rng = np.random.default_rng(10 if float_vals else 11)
    for i, nb in enumerate(LADDER_NS):
        n_groups = (1, 2, 3, 7, 16, ops.MAX_GROUPS)[i % 6]
        _check_grouped(*_rand_agg_inputs(rng, nb, n_groups, float_vals),
                       n_groups)


def test_fused_agg_parity_across_k_and_ladder_boundaries():
    # fused path is BITPACK-only by design; k sweeps the writer's range
    rng = np.random.default_rng(12)
    for i, k in enumerate(range(1, 31)):
        nb = LADDER_NS[i % len(LADDER_NS)]
        packed, mask = _rand_fused_inputs(rng, nb, k)
        _check_fused(packed, mask, k)


def test_bloom_probe_batch_parity():
    # the op's contract is (nblk, RLE_OUT_BLOCK) key tiles — the engine's
    # batched semijoin reshapes decoded columns to exactly this
    from repro.lakeformat.encodings import RLE_OUT_BLOCK

    rng = np.random.default_rng(13)
    for nb in (1, 3, 8, 17):
        keys = rng.integers(0, 1 << 30, (nb, RLE_OUT_BLOCK)).astype(np.int32)
        bits = ops.bloom_build(
            np.unique(keys.reshape(-1)[::5]).astype(np.int64), 1 << 15)
        want = np.asarray(ref.bloom_probe(jnp.asarray(keys), bits))
        for be in BACKENDS:
            got = np.asarray(ops.bloom_probe(keys, bits, backend=be))
            assert np.array_equal(got, want), (be, nb)
        # no false negatives: every inserted key must probe true
        member = np.isin(keys, np.unique(keys.reshape(-1)[::5]))
        assert bool(np.all(want[member]))


def test_int_sum_hi_lo_split_exact_at_extremes():
    """Values pinned at int32 extremes across many full blocks: the
    per-block (v >> 16, v & 0xFFFF) planes each fit int32, and the int64
    recombination must equal the exact numpy int64 sum — no overflow, no
    rounding, under every backend."""
    rng = np.random.default_rng(14)
    nb = 8
    extremes = np.array(
        [np.iinfo(np.int32).max, np.iinfo(np.int32).min, -1, 0, 1],
        np.int32)
    vals = extremes[rng.integers(0, len(extremes), (nb, PACK_BLOCK))]
    gids = rng.integers(0, 4, (nb, PACK_BLOCK)).astype(np.int32)
    mask = rng.random((nb, PACK_BLOCK)) < 0.9
    exact = np.zeros(4, np.int64)
    for g in range(4):
        sel = mask & (gids == g)
        exact[g] = vals.astype(np.int64)[sel].sum()
    for be in BACKENDS:
        planes = ops.grouped_agg_batch(vals, gids, mask, 4, backend=be)
        part = agg.fold_blocks(planes, is_float=False)
        assert part.s.dtype == np.int64
        assert np.array_equal(part.s, exact), be
        assert np.array_equal(
            part.cnt, np.array([(mask & (gids == g)).sum() for g in range(4)],
                               np.int64))


def test_int_merge_is_order_independent():
    """Exact int64 sums make merge_partials associative AND commutative —
    the property the fabric relies on only for ints (floats instead pin a
    canonical order).  Shuffled merge orders must agree bit-for-bit."""
    rng = np.random.default_rng(15)
    parts = []
    for _ in range(6):
        vals, gids, mask = _rand_agg_inputs(rng, 4, 8, float_vals=False)
        planes = ops.grouped_agg_batch(vals, gids, mask, 8, backend="ref")
        parts.append(agg.fold_blocks(planes, is_float=False))
    base = agg.merge_partials(parts)
    for _ in range(4):
        order = rng.permutation(len(parts))
        m = agg.merge_partials([parts[i] for i in order])
        assert np.array_equal(m.cnt, base.cnt)
        assert np.array_equal(m.s, base.s)
        assert np.array_equal(m.mn, base.mn)
        assert np.array_equal(m.mx, base.mx)


def test_float_sum_canonical_order_is_deterministic():
    """Float merges are NOT reassociated — they left-fold in the given
    order, and the same partition + order must reproduce the bit pattern
    exactly (while a different order is allowed to differ)."""
    rng = np.random.default_rng(16)
    parts = []
    for _ in range(5):
        vals, gids, mask = _rand_agg_inputs(rng, 3, 4, float_vals=True)
        planes = ops.grouped_agg_batch(vals, gids, mask, 4, backend="ref")
        parts.append(agg.fold_blocks(planes, is_float=True))
    a = agg.merge_partials(parts)
    b = agg.merge_partials(parts)
    assert np.array_equal(a.s, b.s)
    assert a.s.dtype == np.float64


def test_identity_partial_is_merge_noop():
    rng = np.random.default_rng(17)
    for float_vals in (False, True):
        vals, gids, mask = _rand_agg_inputs(rng, 4, 8, float_vals)
        planes = ops.grouped_agg_batch(vals, gids, mask, 8, backend="ref")
        p = agg.fold_blocks(planes, float_vals)
        ident = agg.identity_partial(8, vals.dtype)
        for m in (agg.merge_partials([ident, p]),
                  agg.merge_partials([p, ident])):
            assert np.array_equal(m.cnt, p.cnt)
            assert np.array_equal(m.s, p.s)
            assert np.array_equal(m.mn, p.mn)
            assert np.array_equal(m.mx, p.mx)


def test_agg_batch_counts_one_dispatch():
    """Satellite 6 regression: aggregate launches bill the SAME dispatch
    counter as decode launches — one per batch call, regardless of
    blocks, groups, or pad."""
    rng = np.random.default_rng(18)
    vals, gids, mask = _rand_agg_inputs(rng, 13, 8, False)
    packed, fmask = _rand_fused_inputs(rng, 13, 9)
    ops.reset_dispatch_count()
    ops.grouped_agg_batch(vals, gids, mask, 8, backend="ref")
    assert ops.dispatch_count() == 1
    ops.fused_agg_batch(packed, 9, fmask, backend="ref")
    assert ops.dispatch_count() == 2
    keys = rng.integers(0, 1 << 20, (13, PACK_BLOCK)).astype(np.int32)
    bits = ops.bloom_build(keys.reshape(-1)[:64].astype(np.int64), 1 << 15)
    ops.bloom_probe(keys, bits, backend="ref")
    assert ops.dispatch_count() == 3


# ---------------------------------------------------------------------------
# hypothesis sweep (optional dependency — skipped when absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=25)
    @given(seed=st_.integers(0, 2**32 - 1),
           nb=st_.sampled_from(LADDER_NS),
           n_groups=st_.sampled_from((1, 2, 5, 16, ops.MAX_GROUPS)),
           float_vals=st_.booleans())
    def test_grouped_agg_parity_hypothesis(seed, nb, n_groups, float_vals):
        rng = np.random.default_rng(seed)
        _check_grouped(*_rand_agg_inputs(rng, nb, n_groups, float_vals),
                       n_groups)

    @settings(deadline=None, max_examples=25)
    @given(seed=st_.integers(0, 2**32 - 1),
           nb=st_.sampled_from(LADDER_NS),
           k=st_.integers(1, 30))
    def test_fused_agg_parity_hypothesis(seed, nb, k):
        rng = np.random.default_rng(seed)
        packed, mask = _rand_fused_inputs(rng, nb, k)
        _check_fused(packed, mask, k)
