"""Batched multi-row-group decode ≡ sequential per-row-group decode.

The bucketed batch path (`kernels.ops.*_batch`, `engine.
scan_row_groups_batched`, `service batch_decode=True`) must be
bit-identical to the sequential path — same columns, masks, counts AND
the same ScanStats accounting (decoded bytes, fresh bytes, decode_work
by encoding, pool/page hits) — across encoding mixes, ragged last
groups, fused and non-fused predicates, and pool/cache residency
combinations.  Only `kernel_launches` / `batch_pad_blocks` may differ:
fewer launches is the whole point, and reconciliation prices the
difference.

Fixed cases always run; the hypothesis sweep (skipped without
`hypothesis`, same policy as tests/test_encodings.py) drives random
plans, predicates, offload modes, slice splits, and residency
prepopulation over a synthetic table whose columns hit every encoding
with a ragged (non-PACK_BLOCK-aligned) group shape.
"""

import dataclasses
import random

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan, tpch
from repro.core.engine import ScanStats
from repro.datapath import CostModel, DatapathService, StaticPolicy
from repro.kernels import ops
from repro.lakeformat.reader import LakeReader
from repro.lakeformat.schema import ColumnSchema, TableSchema
from repro.lakeformat.writer import write_table

RG_ROWS = 6000  # deliberately NOT a PACK_BLOCK multiple: every group ragged


@pytest.fixture(scope="module")
def mixed(tmp_path_factory):
    """Synthetic table covering every encoding, 4 ragged row groups:
    delta (sorted ints), rle int + rle float (long runs), plain floats,
    dict ints whose DICTIONARY differs per region (per-block fused
    bounds), bitpack keys."""
    rng = np.random.default_rng(7)
    n = 3 * RG_ROWS + 1700
    base = np.arange(n, dtype=np.int64) // 3
    cols = {
        "ts": (base + rng.integers(0, 2, n)).astype(np.int32),  # delta
        "flag": np.repeat(
            rng.integers(0, 5, size=n // 64 + 1), 64)[:n].astype(np.int32),  # rle int
        "level": np.repeat(
            rng.standard_normal(n // 128 + 1).astype(np.float32), 128)[:n],  # rle f32
        "price": rng.standard_normal(n).astype(np.float32),  # plain
        # per-region value sets => per-row-group dictionaries differ
        "cat": (rng.integers(0, 40, n) + 100 * (np.arange(n) // RG_ROWS)).astype(np.int32),
        "key": rng.integers(0, 1 << 13, n).astype(np.int32),  # bitpack
    }
    schema = TableSchema("mixed", [
        ColumnSchema("ts", "int32", "delta"),
        ColumnSchema("flag", "int32", "rle"),
        ColumnSchema("level", "float32", "rle"),
        ColumnSchema("price", "float32", "plain"),
        ColumnSchema("cat", "int32", "dict"),
        ColumnSchema("key", "int32", "bitpack"),
    ])
    path = str(tmp_path_factory.mktemp("batchdec") / "mixed.lake")
    write_table(path, schema, cols, row_group_size=RG_ROWS)
    return LakeReader(path)


@pytest.fixture(scope="module")
def lineitem(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_batch")
    paths = tpch.write_tables(str(d), sf=0.05, seed=0, sorted_data=True,
                              row_group_size=8192)
    return LakeReader(paths["lineitem"])


STAT_FIELDS = [
    f.name for f in dataclasses.fields(ScanStats)
    if f.name not in ("kernel_launches", "batch_pad_blocks")
]


def _stats_dict(stats):
    return {name: getattr(stats, name) for name in STAT_FIELDS}


def _assert_result_identical(got, want):
    assert int(got.count) == int(want.count)
    assert got.mask.dtype == want.mask.dtype
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    assert set(got.columns) == set(want.columns)
    for name in want.columns:
        assert got.columns[name].dtype == want.columns[name].dtype, name
        assert np.array_equal(
            np.asarray(got.columns[name]), np.asarray(want.columns[name])
        ), name


def _run_pair(reader, plan, offload="raw", backend="ref", pools=None,
              caches=None, split_at=None):
    """Run the same scan sequentially and batched on independent engines
    (optionally with identical pre-populated pools/caches and a slice
    split) and assert full equivalence.  Returns the two results."""
    results = []
    for idx, batched in enumerate((False, True)):
        cache = caches[idx] if caches else BlockCache(1 << 30)
        eng = DatapathEngine(backend=backend, offload=offload, cache=cache)
        pool = pools[idx] if pools else None
        rs = eng.resumable_scan(reader, plan)
        if rs.result is None:
            pending = list(rs.pending)
            cut = len(pending) if split_at is None else max(1, min(split_at, len(pending)))
            for part in (pending[:cut], pending[cut:]):
                if not part or rs.result is not None:
                    continue
                if batched:
                    rs.advance_batched(part, pool=pool)
                else:
                    for rg in part:
                        rs.advance([rg], pool=pool)
        results.append(rs)
    seq, bat = results
    _assert_result_identical(bat.result, seq.result)
    assert _stats_dict(bat.stats) == _stats_dict(seq.stats)
    return seq, bat


# ---------------------------------------------------------------------------
# fixed cases
# ---------------------------------------------------------------------------

MIXED_PLANS = [
    ScanPlan("mixed", ["ts", "flag", "level", "price", "cat", "key"]),  # all encodings
    ScanPlan("mixed", ["price", "level"], Cmp("key", "le", 1000)),  # fused bitpack
    ScanPlan("mixed", ["price", "ts"], Cmp("cat", "between", (100, 140))),  # fused dict,
    # per-row-group dictionaries => per-block bounds in one launch
    ScanPlan("mixed", ["flag", "cat"], Cmp("ts", "between", (1000, 3000))),  # pruning
]


@pytest.mark.parametrize("idx", range(len(MIXED_PLANS)))
@pytest.mark.parametrize("offload", ["raw", "preloaded", "prefiltered"])
def test_batched_identical_mixed(mixed, idx, offload):
    seq, bat = _run_pair(mixed, MIXED_PLANS[idx], offload=offload)
    # batching must actually batch when >1 group decodes fresh
    if seq.stats.row_groups_scanned > 1 and seq.stats.decoded_bytes_fresh:
        assert bat.stats.kernel_launches < seq.stats.kernel_launches


@pytest.mark.parametrize("plan", [
    ScanPlan("lineitem", ["l_extendedprice", "l_discount", "l_tax", "l_quantity"]),
    ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_quantity", "le", 10)),
    # fused over an int-DICT string column: bounds rewritten onto per-group codes
    ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_returnflag", "eq", "R")),
    ScanPlan("lineitem", ["l_orderkey", "l_shipmode"],
             Cmp("l_shipdate", "between", (300, 900)), compact=True),
])
def test_batched_identical_lineitem(lineitem, plan):
    _run_pair(lineitem, plan)


def test_batched_identical_pallas_backend(mixed):
    for plan in MIXED_PLANS[:3]:
        _run_pair(mixed, plan, backend="pallas")


def test_batched_identical_with_split_slices(mixed):
    """A scan advanced in two slices — each slice batched — folds in
    identically to the sequential slice-by-slice advance."""
    for cut in (1, 2, 3):
        _run_pair(mixed, MIXED_PLANS[0], split_at=cut)


def test_batched_identical_with_pool_residency(mixed):
    """Pool residency combinations: some (rg, column) decodes already in
    the shared tick pool — batched hits/puts/stats must match exactly,
    including the fully-resident shortcut."""
    plan = MIXED_PLANS[0]
    # build a donor pool with every decoded column, then prepopulate both
    # paths with identical subsets of varying density
    donor = {}
    eng = DatapathEngine(backend="ref", offload="raw", cache=BlockCache(1 << 30))
    eng.scan(mixed, plan, pool=donor)
    keys = sorted(donor, key=repr)
    for density in (0.0, 0.3, 0.7, 1.0):
        rnd = random.Random(int(density * 10))
        subset = {k: donor[k] for k in keys if rnd.random() < density}
        seq, bat = _run_pair(mixed, plan,
                             pools=(dict(subset), dict(subset)))
        if density == 1.0:
            assert seq.stats.decoded_bytes_fresh == 0
            assert bat.stats.pool_hits == seq.stats.pool_hits > 0


def test_batched_identical_with_cache_residency(mixed):
    """Preloaded-mode cache residency: decoded-tier entries for a subset
    of (rg, column) pairs, identical on both sides."""
    plan = ScanPlan("mixed", ["ts", "flag", "price"])
    donor = DatapathEngine(backend="ref", offload="preloaded",
                           cache=BlockCache(1 << 30))
    donor.scan(mixed, plan)  # fills decoded + encoded tiers
    for density in (0.4, 1.0):
        caches = []
        for _ in range(2):
            cache = BlockCache(1 << 30)
            rnd = random.Random(int(density * 10))
            for rg in range(mixed.n_row_groups):
                for name in plan.columns:
                    key = donor.rg_cache_key(mixed, rg, name)
                    if rnd.random() < density:
                        e = donor.cache.store.peek(key)
                        cache.put(key, e.value, encoding=e.encoding)
            caches.append(cache)
        seq, bat = _run_pair(mixed, plan, offload="preloaded", caches=caches)
        if density == 1.0:
            assert bat.stats.encoded_bytes == seq.stats.encoded_bytes == 0


# ---------------------------------------------------------------------------
# service end-to-end: batch_decode=True ≡ batch_decode=False
# ---------------------------------------------------------------------------

def _drain_service(reader, batch_decode, plans, hold_ticks=0, tick_bytes=None):
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        policy=StaticPolicy("raw"), batch_decode=batch_decode,
        hold_ticks=hold_ticks, tick_bytes=tick_bytes,
    )
    tickets = [svc.submit(f"t{i}", reader, p) for i, p in enumerate(plans)]
    svc.drain()
    return svc, tickets


def test_service_batched_equals_sequential(mixed):
    plans = [
        ScanPlan("mixed", ["ts", "price", "cat"]),
        ScanPlan("mixed", ["price", "level"], Cmp("key", "le", 2000)),
        ScanPlan("mixed", ["ts", "price"], Cmp("ts", "between", (0, 4000))),
    ]
    # tick budget sized so slices span multiple row groups: beneficiary-
    # split retention billing interleaves tenants more finely than the old
    # bill-the-decoder scheme, and at RG_ROWS*16 every slice degenerated
    # to a single row group — leaving the batched path nothing to amortize
    svc_a, tk_a = _drain_service(mixed, False, plans, hold_ticks=2,
                                 tick_bytes=RG_ROWS * 32)
    svc_b, tk_b = _drain_service(mixed, True, plans, hold_ticks=2,
                                 tick_bytes=RG_ROWS * 32)
    for a, b in zip(tk_a, tk_b):
        assert a.status == b.status == "done"
        _assert_result_identical(b.result, a.result)
        assert _stats_dict(b.result.stats) == _stats_dict(a.result.stats)
    ca, cb = svc_a.telemetry.counters, svc_b.telemetry.counters
    for key in ("decoded_bytes", "decoded_bytes_fresh", "encoded_bytes",
                "rows_out", "decoded_bytes_saved", "sim_fetch_encoded_bytes",
                "sim_fetch_decoded_bytes"):
        assert ca.get(key, 0) == cb.get(key, 0), key
    assert cb.get("batch_slices", 0) > 0
    assert cb["decode_launches"] < ca["decode_launches"]


def test_batched_launch_overhead_is_refunded(mixed):
    """With a calibrated per-launch overhead, the sequential path's honest
    estimate reconciles to ~zero while the batched path is REFUNDED the
    launch overhead its buckets amortized — and the charge ledger stays
    exact (sched + recon == actual) in both modes."""
    plan = ScanPlan("mixed", ["ts", "flag", "level", "price"])
    for batched in (False, True):
        cm = CostModel(launch_overhead_s=1e-4)
        svc = DatapathService(
            engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
            policy=StaticPolicy("raw"), batch_decode=batched, cost_model=cm,
        )
        svc.submit("t", mixed, plan)
        svc.drain()
        tel = svc.telemetry
        est = tel.tenant_sched_seconds["t"]
        recon = tel.tenant_recon_seconds.get("t", 0.0)
        actual = tel.tenant_actual_seconds["t"]
        assert est + recon == pytest.approx(actual, rel=1e-9)
        if batched:
            # 4 row groups x 4 columns sequential launches estimated; far
            # fewer buckets actually launched -> a strictly negative recon
            assert recon < -1e-4
        else:
            assert recon == pytest.approx(0.0, abs=1e-12)


def test_slice_clock_streams_overlap():
    """The cross-tick SliceClock hides each slice's fetch behind the
    previous slice's decode: fetch-bound stream -> everything but the
    trailing decode overlaps."""
    from repro.datapath.netsim import LinkModel, SliceClock

    clk = SliceClock(LinkModel(bandwidth_gbps=1.0, latency_us=0.0))
    for _ in range(3):
        clk.feed(1_000_000_000, 0.5)  # 1s fetch, 0.5s decode
    assert clk.slices == 3
    assert clk.serial_s == pytest.approx(4.5)
    assert clk.overlapped_s == pytest.approx(3.5)  # decodes hidden, last one trails
    assert clk.saved_s == pytest.approx(1.0)


def test_batched_slices_pipeline_across_ticks(mixed):
    """One slice per tick: the stateless per-tick simulation sees no
    overlap, but the streaming clock must — the next slice's fetch is in
    flight while this slice's batch decode runs."""
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        policy=StaticPolicy("raw"), batch_decode=True,
        tick_bytes=RG_ROWS * 8,  # ~one row group's decoded bytes per tick
    )
    svc.submit("t", mixed, ScanPlan("mixed", ["ts", "price", "cat"]))
    svc.drain()
    c = svc.telemetry.counters
    assert c["sim_pipe_slices"] >= 3
    assert c["sim_pipe_overlapped_s"] < c["sim_pipe_serial_s"]
    assert c["sim_pipe_saved_s"] > 0.0


# ---------------------------------------------------------------------------
# hypothesis sweep
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    COLS = ["ts", "flag", "level", "price", "cat", "key"]
    PREDS = [
        None,
        Cmp("key", "le", 1000),  # fused bitpack when key not projected
        Cmp("cat", "between", (100, 240)),  # fused dict when cat not projected
        Cmp("ts", "between", (500, 9000)),  # prunable
        Cmp("flag", "eq", 2),
    ]

    @settings(deadline=None, max_examples=40)
    @given(
        cols=st.sets(st.sampled_from(COLS), min_size=1, max_size=4),
        pred_idx=st.integers(0, len(PREDS) - 1),
        offload=st.sampled_from(["raw", "preloaded", "prefiltered"]),
        split=st.integers(0, 4),
        pool_density=st.sampled_from([None, 0.3, 1.0]),
        compact=st.booleans(),
    )
    def test_batched_equivalence_sweep(mixed, cols, pred_idx, offload, split,
                                       pool_density, compact):
        plan = ScanPlan("mixed", sorted(cols), PREDS[pred_idx], compact=compact)
        pools = None
        if pool_density is not None:
            donor = {}
            eng = DatapathEngine(backend="ref", offload="raw",
                                 cache=BlockCache(1 << 30))
            eng.scan(mixed, plan, pool=donor)
            rnd = random.Random(split)
            subset = {k: v for k, v in sorted(donor.items(), key=lambda kv: repr(kv[0]))
                      if rnd.random() < pool_density}
            pools = (dict(subset), dict(subset))
        _run_pair(mixed, plan, offload=offload, pools=pools,
                  split_at=split or None)


# ---------------------------------------------------------------------------
# batch kernel entry points: parity + bucketing
# ---------------------------------------------------------------------------

def test_bucket_blocks_ladder_and_pow2():
    # default mode: the two-rung ladder {2^m, 3*2^(m-1)}
    assert [ops.bucket_blocks(n) for n in (1, 2, 3, 4, 5, 6, 7, 8, 9, 13, 17, 64, 100)] == \
        [1, 2, 3, 4, 6, 6, 8, 8, 12, 16, 24, 64, 128]
    # legacy pow2 mode, kept for A/B benching
    assert [ops.bucket_blocks(n, mode="pow2") for n in (1, 2, 3, 5, 8, 9, 64, 100)] == \
        [1, 2, 4, 8, 8, 16, 64, 128]
    for n in range(1, 2048):
        lad = ops.bucket_blocks(n, mode="ladder")
        p2 = ops.bucket_blocks(n, mode="pow2")
        assert n <= lad <= p2  # ladder pads no more than pow2, ever
        assert lad - n <= n  # bounded waste: never more than 2x the payload


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_batch_ops_match_sequential(backend):
    """Each *_batch entry point must equal per-page sequential calls bit
    for bit — including ragged pages, per-page dictionaries, and per-block
    fused bounds — while issuing ONE counted dispatch."""
    import jax.numpy as jnp

    from repro.lakeformat import encodings as E

    rng = np.random.default_rng(3)
    # bitpack: ragged pages
    pages = [rng.integers(0, 1 << 9, size=n).astype(np.uint64)
             for n in (4096, 9000, 100)]
    packs = [E.bitpack_encode(v, 9) for v in pages]
    before = ops.dispatch_count()
    out = ops.bitunpack_batch(np.concatenate(packs, axis=0), 9, backend=backend)
    assert ops.dispatch_count() == before + 1
    s = 0
    for p, v in zip(packs, pages):
        nb = p.shape[0]
        seq = ops.bitunpack(jnp.asarray(p), 9, backend=backend)
        assert np.array_equal(np.asarray(out[s:s + nb]), np.asarray(seq))
        s += nb

    # dict: per-page dictionaries of different sizes (int + float sweep)
    for dtype, values in (
        (np.float32, np.array([1.5, 2.5, 9.0, -3.0], np.float32)),
        (np.int32, np.array([3, 17, 99, 2048, 70000], np.int64)),
    ):
        vals = [rng.choice(values[: 3 + (i % 2)], size=n).astype(dtype)
                for i, n in enumerate((5000, 4096))]
        encs = [E.dict_encode(v) for v in vals]
        ks = [int(b.pop("_k")[0]) for b in encs]
        if ks[0] != ks[1]:
            continue  # only same-k pages share a bucket
        dmax = max(b["dictionary"].shape[0] for b in encs)
        dt = np.int32 if np.dtype(dtype).kind in "iu" else dtype
        dicts = np.zeros((2, dmax), dt)
        sizes = np.zeros(2, np.int32)
        for i, b in enumerate(encs):
            d = b["dictionary"].astype(dt)
            dicts[i, : len(d)] = d
            sizes[i] = len(d)
        page = np.concatenate(
            [np.full(b["packed"].shape[0], i, np.int32) for i, b in enumerate(encs)])
        out = ops.dict_decode_batch(
            np.concatenate([b["packed"] for b in encs], axis=0),
            dicts, sizes, page, ks[0], backend=backend)
        s = 0
        for b, v in zip(encs, vals):
            nb = b["packed"].shape[0]
            seq = ops.dict_decode(jnp.asarray(b["packed"]),
                                  jnp.asarray(b["dictionary"].astype(dt)),
                                  ks[0], backend=backend)
            assert np.array_equal(np.asarray(out[s:s + nb]), np.asarray(seq))
            s += nb

    # fused: per-block bounds
    packs = [E.bitpack_encode(rng.integers(0, 1 << 8, size=n).astype(np.uint64), 8)
             for n in (8192, 5000)]
    blocks = [p.shape[0] for p in packs]
    bounds = [(10, 100), (50, 60)]
    lo = np.concatenate([np.full(b, lh[0], np.int32)
                         for b, lh in zip(blocks, bounds)])
    hi = np.concatenate([np.full(b, lh[1], np.int32)
                         for b, lh in zip(blocks, bounds)])
    m = ops.fused_scan_batch(np.concatenate(packs, axis=0), 8, lo, hi,
                             backend=backend)
    s = 0
    for p, (l, h) in zip(packs, bounds):
        nb = p.shape[0]
        seq_mask, _ = ops.fused_scan(jnp.asarray(p), 8, l, h, backend=backend)
        assert np.array_equal(np.asarray(m[s:s + nb]), np.asarray(seq_mask))
        s += nb
