"""Unified tiered BlockStore: ledger/pinning/eviction invariants (unit +
hypothesis property sweep), cost-ranked eviction, the encoded-page tier,
cross-tick retained-decode reuse through the service (bit-identical to
single-shot scans), window-retention WFQ charges, per-(tenant, table)
estimate scales, and the auto-tuned hold window."""

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan, tpch
from repro.datapath import BlockStore, CostModel, DatapathService, StaticPolicy
from repro.lakeformat.reader import LakeReader

RG_ROWS = 8192


@pytest.fixture(scope="module")
def tables(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_store")
    return tpch.write_tables(str(d), sf=0.05, seed=0, sorted_data=True,
                             row_group_size=RG_ROWS)


@pytest.fixture(scope="module")
def lineitem(tables):
    return LakeReader(tables["lineitem"])


@pytest.fixture(scope="module")
def part(tables):
    return LakeReader(tables["part"])


def _service(**kw):
    kw.setdefault("engine", DatapathEngine(backend="ref", cache=BlockCache(1 << 30)))
    kw.setdefault("policy", StaticPolicy("raw"))
    return DatapathService(**kw)


def _assert_identical(got, want):
    assert int(got.count) == int(want.count)
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    assert set(got.columns) == set(want.columns)
    for name in want.columns:
        assert np.array_equal(
            np.asarray(got.columns[name]), np.asarray(want.columns[name])
        ), name


def _arr(nbytes: int) -> np.ndarray:
    return np.zeros(nbytes, np.uint8)


# ---------------------------------------------------------------------------
# ledger + eviction units
# ---------------------------------------------------------------------------

def test_ledger_tracks_entries_and_rejects_oversized():
    st = BlockStore(capacity_bytes=1000)
    assert st.put("a", _arr(400))
    assert st.put("b", _arr(400))
    assert st.used == 800
    assert not st.put("huge", _arr(2000))  # bigger than the device
    assert st.used == 800
    assert st.put("a", _arr(100))  # resize bills only the delta
    assert st.used == 500


def test_eviction_prefers_cheapest_redecode_per_byte():
    """Victim selection is cost-aware, not LRU: the PLAIN column (cheapest
    re-decode seconds per byte) is evicted before DELTA/DICT even though it
    is the most recently used entry."""
    st = BlockStore(capacity_bytes=300)
    assert st.put("delta", _arr(100), encoding="delta")
    assert st.put("dict", _arr(100), encoding="dict")
    assert st.put("plain", _arr(100), encoding="plain")
    st.get("plain")  # freshen its LRU position
    assert st.put("delta2", _arr(100), encoding="delta")
    assert "plain" not in st and "delta" in st and "dict" in st
    assert st.put("delta3", _arr(100), encoding="delta")
    assert "dict" not in st  # next-cheapest ratio after plain
    assert st.used <= 300


def test_lru_breaks_ties_within_equal_cost():
    st = BlockStore(capacity_bytes=300)
    for k in ("a", "b", "c"):
        assert st.put(k, _arr(100), encoding="plain")
    st.get("a")  # a is now the most recent of three equal-cost entries
    assert st.put("d", _arr(100), encoding="plain")
    assert "b" not in st and "a" in st and "c" in st


def test_window_pins_survive_pressure_and_expiry_drops_ephemeral():
    st = BlockStore(capacity_bytes=300)
    view = st.window(expires_tick=2, max_bytes=None, owner="t0")
    view.put("p1", _arr(100), encoding="plain")
    view.put("p2", _arr(100), encoding="plain")
    assert st.put("cold", _arr(100), encoding="delta")
    # pinned blocks are never victims: the shortfall is pinned, so the put
    # is refused outright (the expensive DELTA entry is evictable but too
    # small to make room alone)
    assert not st.put("newcomer", _arr(250), encoding="delta")
    assert "p1" in st and "p2" in st
    assert st.used <= 300
    # promotion (a cache-path put) clears the ephemeral flag
    assert st.put("p2", st.peek("p2").value, tier="decoded", encoding="plain")
    st.advance_tick(3)  # window over: raw decodes drop, promoted stays
    assert "p1" not in st and "p2" in st
    assert not st.pinned("p2")  # evictable again, but resident


def test_refused_put_does_not_flush_the_unpinned_working_set():
    """Regression: a put whose shortfall is pinned must be refused WITHOUT
    evicting the unpinned entries first — a doomed insert used to destroy
    the working set while caching nothing."""
    st = BlockStore(capacity_bytes=300)
    view = st.window(expires_tick=5)
    view.put("pin1", _arr(100), encoding="plain")
    view.put("pin2", _arr(100), encoding="plain")
    assert st.put("dict", _arr(50), encoding="dict")
    assert not st.put("big", _arr(120), encoding="plain")  # 70 short, pinned
    assert "dict" in st  # the evictable entry survived the refusal
    assert st.used == 250


def test_promoted_pool_hit_keeps_its_encoding_price():
    """Regression: promoting a pool hit into a separate cache store used to
    drop the source encoding, re-pricing expensive decodes at the PLAIN
    floor and inverting the eviction ranking."""
    from repro.datapath import DecodePool

    pool = DecodePool()
    pool.put("k", _arr(100), encoding="delta")
    cache = BlockCache(1 << 20)
    hit = pool.get("k")
    assert cache.promote("k", hit, encoding=pool.encoding_of("k"))
    assert cache.store.peek("k").encoding == "delta"
    assert cache.store.peek("k").redecode_s == pytest.approx(
        CostModel().decode_seconds(100, "delta"))


def test_tier_pricing_encoded_vs_prefiltered():
    cm = CostModel()
    st = BlockStore(capacity_bytes=1 << 20, cost_model=cm)
    st.put("page", _arr(1000), tier="encoded")
    assert st.peek("page").redecode_s == pytest.approx(
        cm.link_model().fetch_seconds(1000))
    work = {"delta": 4000, "rle": 2000}
    st.put("scan", _arr(1000), tier="prefiltered", decode_work=work)
    assert st.peek("scan").redecode_s == pytest.approx(
        sum(cm.decode_seconds(b, e) for e, b in work.items()))


def test_evicted_decode_demotes_to_its_encoded_page():
    """Regression: evicting a decoded column used to drop it to zero, so
    the next access paid re-fetch AND re-decode.  A decoded entry carrying
    a demote payload now falls back to the encoded tier (re-decode only),
    with the ledger billing the smaller encoded footprint."""
    st = BlockStore(capacity_bytes=1000)
    page = _arr(100)
    assert st.put("dec", _arr(400), encoding="dict", demote=("pg", page))
    assert st.put("filler", _arr(500), encoding="delta")
    assert "pg" not in st
    # pressure: DICT is the cheapest redecode/byte -> "dec" is the victim
    assert st.put("new", _arr(400), encoding="delta")
    assert "dec" not in st
    e = st.peek("pg")
    assert e is not None and e.tier == "encoded" and e.nbytes == 100
    assert e.value is page
    assert e.redecode_s == pytest.approx(
        st.cost_model.link_model().fetch_seconds(100))
    assert st.used == 1000  # 500 + 400 + the demoted 100, all billed
    assert st.stats()["tiers"]["decoded"]["demotions"] == 1
    # the source pages being resident already means nothing to preserve:
    # evicting a later decode with the same payload demotes nothing
    assert st.put("dec2", _arr(300), encoding="dict", demote=("pg", page))
    assert st.put("new2", _arr(200), encoding="delta")
    assert "dec2" not in st and st.peek("pg").nbytes == 100
    assert st.stats()["tiers"]["decoded"]["demotions"] == 1


def test_demotion_never_starves_the_triggering_put():
    """The demoted entry re-occupies bytes, but it is itself unpinned, so
    the eviction loop's coverage is preserved: the put that triggered the
    pressure still lands (the demoted fallback is sacrificed if needed)."""
    st = BlockStore(capacity_bytes=1000)
    assert st.put("dec", _arr(900), encoding="dict", demote=("pg", _arr(800)))
    assert st.put("new", _arr(900), encoding="delta")
    assert "new" in st and st.used <= 1000


def test_retention_charges_split_across_observed_beneficiaries():
    """Regression: the tenant that happened to decode first used to be
    billed the WHOLE window-retention price while free-riding coalescing
    partners paid nothing.  Charges now split equally across the observed
    beneficiaries, conserving the total."""
    st = BlockStore(capacity_bytes=1 << 20)
    view_a = st.window(expires_tick=4, owner="a")
    view_a.put("k", _arr(1000), encoding="delta")
    st.advance_tick(1)
    full = st.retention_charges()
    assert set(full) == {"a"}  # nobody else observed yet: 'a' pays all
    nb_full, price_full = full["a"]
    assert nb_full == 1000 and price_full > 0.0
    # partner 'b' reuses the decode through its own window view
    view_b = st.window(expires_tick=4, owner="b")
    assert view_b.get("k") is not None
    split = st.retention_charges()
    assert set(split) == {"a", "b"}
    assert split["a"][1] == pytest.approx(price_full / 2)
    assert split["b"][1] == pytest.approx(price_full / 2)
    assert split["a"][0] == split["b"][0] == 500
    assert split["a"][1] + split["b"][1] == pytest.approx(price_full)


# ---------------------------------------------------------------------------
# hypothesis property sweep
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    OPS = st_.lists(
        st_.tuples(
            st_.integers(0, 7),  # key
            st_.integers(0, 96),  # nbytes
            st_.sampled_from(["plain", "bitpack", "dict", "delta", "rle"]),
            st_.booleans(),  # window-pin this put?
            st_.booleans(),  # advance the tick after this op?
        ),
        min_size=1, max_size=60,
    )

    @settings(deadline=None, max_examples=150)
    @given(ops=OPS, capacity=st_.integers(1, 400), hold=st_.integers(0, 3))
    def test_ledger_capacity_and_pin_invariants(ops, capacity, hold):
        """After every operation: used == Σ nbytes of the kept entries,
        used never exceeds capacity, and an accepted window pin is never
        evicted before its window expires."""
        store = BlockStore(capacity_bytes=capacity)
        pins = {}  # key -> expiry tick of the latest accepted pin
        for key, nb, enc, pin, bump in ops:
            if pin:
                view = store.window(expires_tick=store.tick + hold)
                kept = view.put(key, _arr(nb), encoding=enc)
            else:
                kept = store.put(key, _arr(nb), encoding=enc)
            if kept and pin:
                pins[key] = max(pins.get(key, -1), store.tick + hold)
            assert store.used == sum(e.nbytes for e in store._entries.values())
            assert store.used <= capacity
            for k, exp in pins.items():
                if exp >= store.tick:
                    assert k in store, (k, exp, store.tick)
            if bump:
                store.advance_tick(store.tick + 1)
                assert store.used == sum(e.nbytes for e in store._entries.values())

    VICTIM_OPS = st_.lists(
        st_.tuples(
            st_.integers(0, 3),  # 0=put 1=get 2=pinned put 3=tick advance
            st_.integers(0, 9),  # key
            st_.integers(1, 64),  # nbytes (>= 1 so one eviction frees bytes)
            st_.sampled_from(["plain", "bitpack", "dict", "delta", "rle"]),
        ),
        min_size=1, max_size=80,
    )

    @settings(deadline=None, max_examples=150)
    @given(ops=VICTIM_OPS)
    def test_heap_victim_matches_linear_selection(ops):
        """The lazy-invalidation eviction heap must pick exactly the victim
        the old O(n) linear scan picked — lowest re-creation seconds per
        byte, LRU tie-break, pins skipped — across op sequences that churn
        the heap with stale records: re-puts (re-price + resize), gets
        (re-rank), window pins, and tick advances (pin expiry + ephemeral
        drops).  Drains the store victim by victim at the end, checking
        every single selection against the oracle."""
        store = BlockStore(capacity_bytes=1 << 20)
        for op, key, nb, enc in ops:
            if op == 0:
                store.put(key, _arr(nb), encoding=enc)
            elif op == 1:
                store.get(key)
            elif op == 2:
                store.window(expires_tick=store.tick + 2).put(
                    key, _arr(nb), encoding=enc)
            else:
                store.advance_tick(store.tick + 1)
        while True:
            oracle = store._victims_linear()
            if not oracle:
                # nothing evictable (empty, or every survivor is pinned):
                # the heap must agree — an evict attempt changes nothing
                before = dict(store._entries)
                store._evict(1)
                assert dict(store._entries) == before
                break
            want = oracle[0].key
            used0 = store.used
            store._evict(1)  # evicts exactly the top-ranked victim
            assert want not in store._entries
            assert store.used == used0 - oracle[0].nbytes
            for e in oracle[1:]:  # nothing beyond the chosen victim went
                assert e.key in store._entries

    @settings(deadline=None, max_examples=100)
    @given(
        entries=st_.lists(
            st_.tuples(st_.integers(1, 64),
                       st_.sampled_from(["plain", "bitpack", "dict", "delta", "rle"])),
            min_size=2, max_size=10,
        ),
        overflow=st_.integers(1, 128),
    )
    def test_eviction_follows_cost_ranking(entries, overflow):
        """Force an eviction wave and check the evicted set is exactly the
        cheapest-ranked prefix (re-decode seconds per byte, LRU tie-break)
        of the resident entries."""
        capacity = sum(nb for nb, _ in entries)
        store = BlockStore(capacity_bytes=capacity)
        for i, (nb, enc) in enumerate(entries):
            assert store.put(i, _arr(nb), encoding=enc)
        ranked = sorted(store._entries.values(), key=lambda e: e.rank())
        trigger = min(overflow, capacity)
        expected_evicted, freed = [], 0
        for e in ranked:
            if store.used + trigger - freed <= capacity:
                break
            expected_evicted.append(e.key)
            freed += e.nbytes
        assert store.put("trigger", _arr(trigger), encoding="plain")
        for key in expected_evicted:
            assert key not in store
        for i in range(len(entries)):
            if i not in expected_evicted:
                assert i in store
        assert store.used <= capacity


# ---------------------------------------------------------------------------
# encoded-page tier (engine level)
# ---------------------------------------------------------------------------

def test_page_tier_skips_refetch_when_decoded_tier_evicts(lineitem):
    """Under capacity pressure the cost ranking keeps encoded pages (link
    latency makes them expensive per byte to re-fetch) while PLAIN decoded
    columns churn — so a repeat scan re-decodes but never re-fetches."""
    plan = ScanPlan("lineitem", ["l_extendedprice"])
    enc_total = sum(
        lineitem.row_group_meta(rg)["columns"]["l_extendedprice"]["encoded_bytes"]
        for rg in range(lineitem.n_row_groups)
    )
    cap = enc_total + int(1.5 * RG_ROWS * 4)  # all pages + ~1.5 decoded groups
    eng = DatapathEngine(backend="ref", cache=BlockCache(cap))
    r1 = eng.scan(lineitem, plan, offload="preloaded")
    assert r1.stats.encoded_bytes > 0
    r2 = eng.scan(lineitem, plan, offload="preloaded")
    assert r2.stats.encoded_bytes == 0  # every page served from the store
    assert r2.stats.page_hits > 0
    assert r2.stats.decoded_bytes_fresh > 0  # decoded tier really churned
    assert eng.cache.stats()["tiers"]["decoded"]["evictions"] > 0
    _assert_identical(r2, DatapathEngine(backend="ref").scan(lineitem, plan))
    assert eng.cache.used <= cap


# ---------------------------------------------------------------------------
# cross-tick retained reuse through the service (acceptance criterion)
# ---------------------------------------------------------------------------

PLAN_EARLY = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_shipdate", "between", (300, 700)))
PLAN_LATE = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                     Cmp("l_shipdate", "between", (350, 750)))


def _late_partner(hold_ticks, lineitem):
    """Drive the acceptance scenario: a scan dispatches (alone, at its hold
    deadline), THEN a compatible partner arrives within the hold window."""
    svc = _service(hold_ticks=hold_ticks)
    early = svc.submit("early", lineitem, PLAN_EARLY)
    while early.status == "queued":
        svc.tick()
    late = svc.submit("late", lineitem, PLAN_LATE)
    svc.drain()
    return svc, early, late


def test_late_partner_reuses_retained_decodes_bit_identical(lineitem):
    svc, early, late = _late_partner(2, lineitem)
    c = svc.telemetry.counters
    # the partner was dispatched against the retained window, not re-held
    assert c.get("retained_partner_dispatch", 0) >= 1
    assert late.done_tick - late.submitted_tick == 1
    # overlapping row groups came from the retained decoded tier: re-decode
    # seconds were actually saved vs the old tick-scoped pool
    assert c.get("retained_hits", 0) > 0
    assert c.get("retained_reuse_bytes", 0) > 0
    assert c.get("retained_redecode_saved_s", 0.0) > 0.0
    assert late.result.stats.pool_hits > 0
    # ...and the results are bit-identical to single-shot engine scans
    _assert_identical(early.result,
                      DatapathEngine(backend="ref").scan(lineitem, PLAN_EARLY))
    _assert_identical(late.result,
                      DatapathEngine(backend="ref").scan(lineitem, PLAN_LATE))


def test_tick_scoped_control_has_no_retained_reuse(lineitem):
    svc, _, late = _late_partner(0, lineitem)
    c = svc.telemetry.counters
    assert c.get("retained_hits", 0) == 0
    assert c.get("retained_reuse_bytes", 0) == 0
    assert late.result.stats.pool_hits == 0
    _assert_identical(late.result,
                      DatapathEngine(backend="ref").scan(lineitem, PLAN_LATE))


def test_raw_window_pins_leave_no_persistent_state(lineitem):
    """Raw stays raw beyond the window: once the retained pins expire, the
    ephemeral decodes drop from the store entirely."""
    svc, _, _ = _late_partner(2, lineitem)
    for _ in range(4):  # idle ticks past every window
        svc.tick()
    st = svc.store.stats()
    assert st["tiers"]["decoded"]["entries"] == 0
    assert st["tiers"]["decoded"]["expired"] > 0
    assert svc.store.used == st["tiers"]["encoded"]["bytes"] + \
        st["tiers"]["prefiltered"]["bytes"]


def test_retained_bytes_are_charged_into_wfq(lineitem):
    """Hoarding decodes is not free: window-retained bytes bill the owning
    tenant's virtual time and show up in the fairness ledger."""
    svc, early, _ = _late_partner(2, lineitem)
    c = svc.telemetry.counters
    assert c.get("retained_byte_ticks", 0) > 0
    assert c.get("retained_charge_seconds", 0.0) > 0.0
    fair = svc.telemetry.fairness()
    assert fair["tenant_retained_bytes"]["early"] > 0
    assert svc._vtime.get("early", 0.0) > 0.0


def test_store_ledger_in_snapshot_is_deterministic(lineitem):
    svc, _, _ = _late_partner(2, lineitem)
    import json

    snap = svc.telemetry.snapshot()
    assert set(snap["store"]["tiers"]) == {"encoded", "decoded", "prefiltered"}
    json.dumps(snap)  # plain, serializable types throughout


# ---------------------------------------------------------------------------
# per-(tenant, table) estimate scales
# ---------------------------------------------------------------------------

def test_per_table_scale_isolates_a_lying_table(lineitem, part):
    """A tenant under-estimating ONE table's costs 4x is re-priced on that
    table only; its honest table keeps (and unseen tables inherit) sane
    pricing instead of one blended scale."""
    svc = _service()
    svc.submit("t", lineitem, ScanPlan("lineitem", ["l_extendedprice"]))
    req = next(q for q in svc.queue if q.reader is lineitem)
    req.rg_costs = tuple(c / 4 for c in req.rg_costs)  # the lie
    svc.submit("t", part, ScanPlan("part", ["p_size"]))
    svc.drain()
    lying = svc._est_scale_table[("t", lineitem.path)]
    honest = svc._est_scale_table[("t", part.path)]
    assert lying > 1.5
    assert honest == pytest.approx(1.0)
    # dispatch-time pricing: the honest table uses its own scale, the
    # unseen table falls back to the tenant-level blend
    assert svc._scale_for("t", part.path) == pytest.approx(1.0)
    assert svc._scale_for("t", "never_seen") == svc._est_scale["t"]
    assert svc._est_scale["t"] > 1.0  # the blend still remembers the lie


# ---------------------------------------------------------------------------
# auto-tuned hold window
# ---------------------------------------------------------------------------

def test_auto_hold_opens_for_recurring_footprints(lineitem):
    svc = _service(hold_ticks="auto")
    assert svc.hold_ticks == 0
    for i in range(5):  # same footprint recurring a tick or two apart
        svc.submit(f"t{i}", lineitem, PLAN_EARLY)
        svc.drain()
    assert svc.hold_ticks >= 1
    assert svc.hold_ticks <= svc.HOLD_AUTO_MAX
    assert svc.telemetry.counters["hold_ticks_auto"] == float(svc.hold_ticks)


def test_auto_hold_stays_closed_for_one_off_footprints(lineitem):
    svc = _service(hold_ticks="auto")
    for i, day in enumerate((200, 900, 1600)):  # disjoint row-group windows
        plan = ScanPlan("lineitem", ["l_extendedprice"],
                        Cmp("l_shipdate", "between", (day, day + 150)))
        svc.submit(f"t{i}", lineitem, plan)
        svc.drain()
    assert svc.hold_ticks == 0
    assert svc.telemetry.counters.get("held_requests", 0) == 0
