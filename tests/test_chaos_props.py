"""Chaos property sweep for the storage fault plane (DESIGN.md §17).

The correctness bar: under ANY deterministic fault schedule, every scan
that completes is BIT-IDENTICAL to the fault-free run, nothing hangs
(the tick loop is bounded), nothing is silently dropped (every ticket
terminates done-or-typed-error), and the WFQ honesty invariant
(sched + recon == actual) holds with fault seconds folded in.

Fixed-seed configuration grids always run — scheduler (wfq/fifo) x
decode path (sequential/batched) x fabric width (1/2/4 pods) x fault
mix.  A hypothesis sweep over seeds and rates widens the net when
hypothesis is installed (same policy as tests/test_recon_props.py).
"""

import functools
import tempfile

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan, tpch
from repro.datapath import (
    DatapathService,
    FaultPlan,
    Overloaded,
    QueueFull,
    QuotaExceeded,
    RetryPolicy,
    ScanFabric,
    StorageFault,
)
from repro.lakeformat.integrity import CorruptPageError
from repro.lakeformat.reader import LakeReader

RG_ROWS = 2048
TICK_BYTES = 1 << 14
MAX_TICKS = 2000  # hang guard: orders of magnitude above any real drain


@functools.lru_cache(maxsize=1)
def _tables():
    d = tempfile.mkdtemp(prefix="tpch_chaos_")
    paths = tpch.write_tables(d, sf=0.05, seed=0, row_group_size=RG_ROWS)
    return {k: LakeReader(p) for k, p in paths.items()}


PLANS = [
    ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
             Cmp("l_quantity", "le", 25)),  # unprunable
    ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
             Cmp("l_shipdate", "between", (365, 729))),  # zone-map pruned
    ScanPlan("lineitem", ["l_quantity"], Cmp("l_quantity", "le", 3),
             compact=True),
    ScanPlan("part", ["p_partkey", "p_size"], Cmp("p_size", "le", 10)),
]

# recoverable mix: every fault kind, rates low enough that bounded
# retries always clear them (checked: retries_exhausted == 0 below)
RECOVERABLE = FaultPlan(seed=0, transient_rate=0.12, corrupt_rate=0.06,
                        short_read_rate=0.04, spike_rate=0.25, spike_s=1e-3)
POLICY = RetryPolicy(max_attempts=10, timeout_s=0.5, hedge_after_s=5e-4)


@functools.lru_cache(maxsize=None)
def _direct(idx):
    plan = PLANS[idx]
    return DatapathEngine(backend="ref").scan(_tables()[plan.table], plan)


def _assert_identical(got, want):
    assert int(got.count) == int(want.count)
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    assert set(got.columns) == set(want.columns)
    for name in want.columns:
        assert np.array_equal(
            np.asarray(got.columns[name]), np.asarray(want.columns[name])
        ), name


def _bounded_drain(obj):
    """Tick until idle with a hang guard — `drain()` without the ability
    to loop forever."""
    for _ in range(MAX_TICKS):
        obj.tick()
        pending = obj.active if hasattr(obj, "active") else obj.queue
        if not pending:
            return
    pytest.fail(f"no progress after {MAX_TICKS} ticks — hang")


def _check_honesty(telemetry):
    snap = telemetry.snapshot()
    for t, row in snap["cost"].items():
        assert row["est_s"] + row["recon_s"] == pytest.approx(
            row["actual_s"], abs=1e-9), (t, row)


# ---------------------------------------------------------------------------
# single pod: scheduler x decode-path grid under the recoverable mix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["wfq", "fifo"])
@pytest.mark.parametrize("batch", [True, False])
def test_pod_chaos_bit_identical(scheduler, batch):
    readers = _tables()
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        scheduler=scheduler, batch_decode=batch, tick_bytes=TICK_BYTES,
        fault_plan=RECOVERABLE, retry_policy=POLICY,
    )
    tickets = [(idx, svc.submit(f"t{idx}", readers[PLANS[idx].table],
                                PLANS[idx]))
               for idx in range(len(PLANS))]
    _bounded_drain(svc)
    for idx, tk in tickets:
        _assert_identical(svc.result(tk), _direct(idx))
    f = svc.telemetry.snapshot()["faults"]
    assert f["retries_exhausted"] == 0
    assert f["corrupt_detected"] == f["corrupt_injected"] + f["short_reads"]
    _check_honesty(svc.telemetry)


# ---------------------------------------------------------------------------
# fabric: pod-count grid, every pod faulty, plus a straggler pod
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pods", [1, 2, 4])
@pytest.mark.parametrize("scheduler,batch", [("wfq", True), ("fifo", False)])
def test_fabric_chaos_bit_identical(n_pods, scheduler, batch):
    readers = _tables()
    plan = RECOVERABLE
    if n_pods > 1:  # one whole-pod straggler exercises the hedge path
        plan = FaultPlan(seed=0, transient_rate=0.12, corrupt_rate=0.06,
                         short_read_rate=0.04, spike_rate=0.25, spike_s=1e-3,
                         straggler_pods={"pod1": 2e-3})
    fab = ScanFabric(n_pods=n_pods, scheduler=scheduler, batch_decode=batch,
                     tick_bytes=TICK_BYTES, fault_plan=plan,
                     retry_policy=POLICY)
    tickets = [(idx, fab.submit(f"t{idx}", readers[PLANS[idx].table],
                                PLANS[idx]))
               for idx in range(len(PLANS))]
    _bounded_drain(fab)
    for idx, tk in tickets:
        assert tk.status == "done", (idx, tk.status, tk.error)
        _assert_identical(tk.result, _direct(idx))
    for pid in fab.live_pods:
        f = fab.pods[pid].telemetry.snapshot()["faults"]
        assert f["retries_exhausted"] == 0
        _check_honesty(fab.pods[pid].telemetry)


# ---------------------------------------------------------------------------
# unrecoverable schedules: typed terminal errors, no hangs, no silent drops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,exc_types", [
    ("transient", (StorageFault,)),
    ("corrupt", (StorageFault, CorruptPageError)),
])
def test_fail_forever_terminates_typed_never_hangs(kind, exc_types):
    readers = _tables()
    rates = {"transient_rate": 1.0} if kind == "transient" else {
        "corrupt_rate": 1.0}
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        tick_bytes=TICK_BYTES,
        fault_plan=FaultPlan(fail_forever=True, **rates),
        retry_policy=RetryPolicy(max_attempts=3),
    )
    tickets = [svc.submit(f"t{i}", readers[p.table], p)
               for i, p in enumerate(PLANS)]
    _bounded_drain(svc)
    for tk in tickets:
        assert tk.status == "error", tk.status  # terminal, never dropped
        with pytest.raises(exc_types):
            svc.result(tk)


def test_every_rejection_is_typed():
    """Under chaos + pressure, every admission rejection is a typed error:
    QueueFull, QuotaExceeded, or Overloaded — never a bare exception,
    never a silent drop."""
    readers = _tables()
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        tick_bytes=TICK_BYTES, max_queue_depth=4,
        fault_plan=FaultPlan(transient_rate=1.0, fail_forever=True),
        retry_policy=RetryPolicy(max_attempts=5),
    )
    submitted, rejected = [], 0
    for i in range(16):
        try:
            submitted.append(svc.submit("t0", readers["lineitem"], PLANS[0]))
        except (QueueFull, QuotaExceeded, Overloaded):
            rejected += 1
        if i % 4 == 3:
            svc.tick()
    _bounded_drain(svc)
    assert rejected > 0
    assert svc.telemetry.counters["rejected_overloaded"] >= 1
    for tk in submitted:  # everything admitted reached a terminal state
        assert tk.status in ("done", "error")
        if tk.status == "error":
            assert isinstance(tk.error, (StorageFault, CorruptPageError))


def test_fabric_one_poisoned_pod_survivors_complete():
    """Fault schedules confined to one pod: the breaker-drain path removes
    it and every scan still completes bit-identically."""
    readers = _tables()
    fab = ScanFabric(n_pods=3, tick_bytes=TICK_BYTES)
    tickets = [(idx, fab.submit(f"t{idx}", readers[PLANS[idx].table],
                                PLANS[idx]))
               for idx in range(len(PLANS))]
    fab.inject_faults("pod2", FaultPlan(transient_rate=1.0,
                                        fail_forever=True),
                      RetryPolicy(max_attempts=5))
    _bounded_drain(fab)
    for idx, tk in tickets:
        assert tk.status == "done", (idx, tk.error)
        _assert_identical(tk.result, _direct(idx))
    assert "pod2" not in fab.live_pods
    assert fab.report()["breaker_drains"] >= 1


# ---------------------------------------------------------------------------
# hypothesis sweep: random seeds and rates, always-recoverable envelope
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=15)
    @given(
        seed=st.integers(0, 2**16),
        transient=st.floats(0.0, 0.2),
        corrupt=st.floats(0.0, 0.1),
        spike=st.floats(0.0, 0.5),
        n_pods=st.sampled_from([1, 2]),
        scheduler=st.sampled_from(["wfq", "fifo"]),
        batch=st.booleans(),
        idx=st.integers(0, len(PLANS) - 1),
    )
    def _hyp_chaos(seed, transient, corrupt, spike, n_pods, scheduler,
                   batch, idx):
        readers = _tables()
        fab = ScanFabric(
            n_pods=n_pods, scheduler=scheduler, batch_decode=batch,
            tick_bytes=TICK_BYTES,
            fault_plan=FaultPlan(seed=seed, transient_rate=transient,
                                 corrupt_rate=corrupt, spike_rate=spike,
                                 spike_s=1e-3),
            retry_policy=RetryPolicy(max_attempts=12, hedge_after_s=1e-3),
        )
        plan = PLANS[idx]
        t = fab.submit("t0", readers[plan.table], plan)
        _bounded_drain(fab)
        assert t.status == "done", t.error
        _assert_identical(t.result, _direct(idx))
        for pid in fab.live_pods:
            _check_honesty(fab.pods[pid].telemetry)

    def test_chaos_hypothesis_sweep():
        _hyp_chaos()

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_chaos_hypothesis_sweep():
        pass
