"""Checkpointing: atomic publish, checksum fallback, retention, bf16
roundtrip, elastic re-mesh restore (8 -> 4 devices, subprocess)."""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.train.checkpoint import CheckpointManager
from tests.util import run_with_devices


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b16": jnp.ones((5,), jnp.bfloat16) * 1.5},
        "opt": [jnp.zeros((2,), jnp.int32)],
    }


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree()
    m.save(3, tree, meta={"step": 3})
    out, manifest = m.restore_latest(tree)
    assert manifest["step"] == 3
    assert np.array_equal(np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"]))
    assert out["params"]["b16"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(out["params"]["b16"], dtype=np.float32),
                          np.full(5, 1.5, np.float32))


def test_corrupted_latest_falls_back(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree()
    m.save(1, tree, meta={"step": 1})
    m.save(2, tree, meta={"step": 2})
    # corrupt step 2's arrays
    with open(os.path.join(str(tmp_path), "step_00000002", "arrays.npz"), "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef" * 8)
    out, manifest = m.restore_latest(tree)
    assert manifest["step"] == 1  # fell back to the previous intact step


def test_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        m.save(s, tree)
    assert m.list_steps() == [3, 4]


def test_elastic_remesh_restore():
    """Save on an 8-device (4,2) mesh, restore onto (2,2): elastic shrink."""
    out = run_with_devices(
        """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.train.checkpoint import CheckpointManager, reshard
from repro.distributed.sharding import ShardingCtx

d = tempfile.mkdtemp()
devs = np.array(jax.devices())
mesh8 = Mesh(devs[:8].reshape(4, 2), ("data", "model"))
ctx8 = ShardingCtx(mesh=mesh8)
w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   NamedSharding(mesh8, P("data", "model")))
m = CheckpointManager(d)
m.save(1, {"w": w})

mesh4 = Mesh(devs[:4].reshape(2, 2), ("data", "model"))
ctx4 = ShardingCtx(mesh=mesh4)
tree, _ = m.restore_latest({"w": w}, ctx4, {"w": ("d", "ff")})
assert tree["w"].sharding.mesh.shape["data"] == 2
assert np.array_equal(np.asarray(tree["w"]), np.arange(64, dtype=np.float32).reshape(8, 8))
print("ELASTIC_OK")
""",
        n_devices=8,
    )
    assert "ELASTIC_OK" in out
