"""Calibrated encoding-aware cost model: pricing, persistence, calibration
fallback, honest per-row-group estimates (estimate == engine actuals, bit
for bit in the bytes domain), and the scheduler/netsim single-table
contract."""

import json

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan, tpch
from repro.core.plan import bind_expr
from repro.core.zonemap import prune_row_groups
from repro.datapath import (
    NOMINAL_RATES_GBPS,
    CostModel,
    DatapathService,
    DecodeModel,
    LinkModel,
    PrefetchPipeline,
    StaticPolicy,
)
from repro.lakeformat.encodings import padded_rows
from repro.lakeformat.reader import LakeReader


@pytest.fixture(scope="module")
def lineitem(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_cm")
    paths = tpch.write_tables(str(d), sf=0.05, seed=0, sorted_data=True,
                              row_group_size=8192)
    return LakeReader(paths["lineitem"])


# ---------------------------------------------------------------------------
# pricing + persistence
# ---------------------------------------------------------------------------

def test_nominal_pricing_and_unknown_encoding_fallback():
    cm = CostModel()
    assert cm.source == "nominal"
    for enc, rate in NOMINAL_RATES_GBPS.items():
        assert cm.decode_seconds(1 << 30, enc) == pytest.approx((1 << 30) / (rate * 1e9))
    # unknown encodings price at the plain rate instead of crashing
    assert cm.decode_seconds(1000, "zstd_frame") == cm.decode_seconds(1000, "plain")
    # seconds scale linearly in bytes
    assert cm.decode_seconds(2000, "rle") == pytest.approx(2 * cm.decode_seconds(1000, "rle"))


def test_save_load_round_trip(tmp_path):
    cm = CostModel(rates={"plain": 33.0, "rle": 44.0}, source="calibrated",
                   backend="ref", link_bandwidth_gbps=5.0, link_latency_us=3.0)
    path = cm.save(str(tmp_path / "cal.json"))
    back = CostModel.load(path)  # active backend is 'ref' on CPU
    assert back.rates == cm.rates
    assert back.source == "calibrated"
    assert back.link_model().bandwidth_gbps == 5.0
    assert back.link_model().latency_us == 3.0
    # the persisted file is per-backend JSON with sorted keys (diffable)
    d = json.loads(open(path).read())
    entry = d["backends"]["ref"]
    assert list(entry["rates_gbps"]) == sorted(entry["rates_gbps"])


def test_save_merges_per_backend_and_load_picks_the_active_one(tmp_path):
    """ref-jitted and pallas tables live side by side in one file; saving
    one backend must not clobber the other, and load() must refuse to
    price one backend with another's table."""
    path = str(tmp_path / "cal.json")
    ref_cm = CostModel(rates={"rle": 1.0}, source="calibrated", backend="ref",
                       launch_overhead_s=1e-5)
    pal_cm = CostModel(rates={"rle": 100.0}, source="calibrated",
                       backend="pallas", launch_overhead_s=1e-6)
    ref_cm.save(path)
    pal_cm.save(path)  # merge, not clobber
    assert CostModel.load(path, backend="ref").rates["rle"] == 1.0
    assert CostModel.load(path, backend="pallas").rates["rle"] == 100.0
    assert CostModel.load(path, backend="pallas").launch_overhead_s == 1e-6
    # no entry for an unknown backend -> KeyError, and load_or_nominal
    # degrades to nominal instead of borrowing the wrong table
    with pytest.raises(KeyError):
        CostModel.load(path, backend="tpu-v9")
    deg = CostModel.load_or_nominal(path, backend="tpu-v9")
    assert deg.source == "nominal"
    # default load resolves to the ACTIVE backend ('ref' off-TPU)
    assert CostModel.load(path).rates["rle"] == 1.0


def test_load_accepts_legacy_flat_format(tmp_path):
    legacy = {"rates_gbps": {"plain": 9.0}, "source": "calibrated",
              "backend": "ref", "launch_overhead_s": 2e-5}
    path = tmp_path / "old.json"
    path.write_text(json.dumps(legacy))
    back = CostModel.load(str(path))
    assert back.rates["plain"] == 9.0
    assert back.launch_overhead_s == 2e-5
    # saving on top folds the legacy entry into the per-backend format
    CostModel(rates={"plain": 5.0}, backend="pallas",
              source="calibrated").save(str(path))
    assert CostModel.load(str(path), backend="ref").rates["plain"] == 9.0
    assert CostModel.load(str(path), backend="pallas").rates["plain"] == 5.0


def test_load_or_nominal_degrades_gracefully(tmp_path):
    assert CostModel.load_or_nominal(None).source == "nominal"
    assert CostModel.load_or_nominal(str(tmp_path / "missing.json")).source == "nominal"
    bad = tmp_path / "corrupt.json"
    bad.write_text("{not json")
    assert CostModel.load_or_nominal(str(bad)).source == "nominal"


def test_nonpositive_rates_are_rejected():
    """A zero/negative measured rate (broken timer) must not poison the
    table — the nominal entry survives."""
    cm = CostModel(rates={"plain": 0.0, "rle": -3.0, "dict": 5.0})
    assert cm.rate_gbps("plain") == NOMINAL_RATES_GBPS["plain"]
    assert cm.rate_gbps("rle") == NOMINAL_RATES_GBPS["rle"]
    assert cm.rate_gbps("dict") == 5.0


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibrate_smoke_measures_every_encoding():
    cm = CostModel.calibrate(backend="ref", n=1 << 14, repeats=1)
    assert cm.source == "calibrated"
    assert set(cm.rates) >= set(NOMINAL_RATES_GBPS)
    for enc in NOMINAL_RATES_GBPS:
        assert cm.rates[enc] > 0, enc


def test_calibrate_falls_back_to_nominal_on_failure():
    cm = CostModel.calibrate(backend="ref", n=-5)  # invalid size -> kernel error
    assert cm.source == "nominal-fallback"
    assert cm.rates == NOMINAL_RATES_GBPS


# ---------------------------------------------------------------------------
# estimates: honest vs engine actuals
# ---------------------------------------------------------------------------

ESTIMATE_PLANS = [
    ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]),  # full scan
    ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
             Cmp("l_shipdate", "between", (300, 900))),  # pruned, not fused
    ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_quantity", "le", 10)),  # fused
]


@pytest.mark.parametrize("idx", range(len(ESTIMATE_PLANS)))
def test_estimated_bytes_equal_engine_actuals(lineitem, idx):
    """The bytes half of every RowGroupCost equals ScanStats.decoded_bytes
    for a direct raw scan — padded rows, true dtype widths, fused predicate
    column excluded.  Estimate == actual is what makes reconciliation a
    no-op for honest tenants."""
    plan = ESTIMATE_PLANS[idx]
    eng = DatapathEngine(backend="ref", cache=BlockCache(1 << 30))
    pred = bind_expr(plan.predicate, lineitem)
    rgs = prune_row_groups(lineitem, pred)
    costs = CostModel().estimate_row_groups(eng, lineitem, plan, rgs, pred=pred)
    res = DatapathEngine(backend="ref").scan(lineitem, plan, row_groups=rgs)
    assert sum(c.nbytes for c in costs) == res.stats.decoded_bytes
    assert all(c.seconds > 0 for c in costs)


def test_estimated_seconds_match_actual_decode_work(lineitem):
    """The seconds half prices the same work the engine records in
    ScanStats.decode_work (including the fused predicate column, which is
    processed but never materialized), through the same table."""
    cm = CostModel()
    eng = DatapathEngine(backend="ref", cache=BlockCache(1 << 30))
    for plan in ESTIMATE_PLANS:
        pred = bind_expr(plan.predicate, lineitem)
        rgs = prune_row_groups(lineitem, pred)
        est_s = sum(c.seconds for c in
                    cm.estimate_row_groups(eng, lineitem, plan, rgs, pred=pred))
        res = DatapathEngine(backend="ref").scan(lineitem, plan, row_groups=rgs)
        actual_s = sum(cm.decode_seconds(b, e) for e, b in res.stats.decode_work.items())
        assert est_s == pytest.approx(actual_s)


def test_fused_predicate_column_priced_but_not_materialized(lineitem):
    """A fused plan's estimate must carry decode-time for the predicate
    column while its byte estimate excludes it."""
    cm = CostModel()
    eng = DatapathEngine(backend="ref")
    fused = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_quantity", "le", 10))
    nofuse = ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
                      Cmp("l_quantity", "le", 10))  # pred col projected
    rgs = list(range(lineitem.n_row_groups))
    c_f = cm.estimate_row_groups(eng, lineitem, fused, rgs)
    c_n = cm.estimate_row_groups(eng, lineitem, nofuse, rgs)
    assert sum(c.nbytes for c in c_f) < sum(c.nbytes for c in c_n)  # one col vs two
    assert sum(c.seconds for c in c_f) == pytest.approx(
        sum(c.seconds for c in c_n))  # same decode work either way


def test_fused_decode_work_uses_footer_dtype_width(lineitem):
    """Regression: `scan_row_group` used to charge the fused predicate
    column's decode work at a hardcoded `L * 4` whatever the column's
    dtype; it must use the footer dtype width, exactly like
    `decode_footprint` sizes the estimate.  Pinned on a NON-float32 fused
    scan (int32 BITPACK predicate) by asserting the engine's per-encoding
    decode_work dict equals the footprint-derived bytes EXACTLY — so
    estimate == actual in both the bytes and the seconds domain."""
    plan = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_quantity", "le", 10))
    eng = DatapathEngine(backend="ref", cache=BlockCache(1 << 30))
    pred = bind_expr(plan.predicate, lineitem)
    rgs = prune_row_groups(lineitem, pred)
    res = eng.scan(lineitem, plan, row_groups=rgs)
    assert res.stats.fused  # precondition: the fast path really fused
    meta = lineitem.row_group_meta(rgs[0])["columns"]["l_quantity"]
    assert np.dtype(meta["dtype"]) == np.int32  # precondition: non-float32
    # footprint-derived ground truth: processed bytes by encoding, at the
    # footer dtype width (materialized or not)
    want = {}
    for fp in eng.decode_footprint(lineitem, plan, rgs, pred=pred):
        for col in fp["columns"].values():
            want[col["encoding"]] = want.get(col["encoding"], 0) + col["nbytes"]
    assert res.stats.decode_work == want
    # and the seconds estimate prices to exactly the same number
    cm = CostModel()
    est_s = sum(c.seconds for c in
                cm.estimate_row_groups(eng, lineitem, plan, rgs, pred=pred))
    actual_s = (sum(cm.decode_seconds(b, e) for e, b in res.stats.decode_work.items())
                + cm.launch_seconds(res.stats.kernel_launches))
    assert est_s == pytest.approx(actual_s)
    # the batched path records the identical decode_work
    res_b = DatapathEngine(backend="ref", cache=BlockCache(1 << 30)).scan(
        lineitem, plan, row_groups=rgs, batched=True)
    assert res_b.stats.decode_work == want


def test_estimates_use_padded_rows(lineitem):
    """The short last row group still bills a full PACK_BLOCK of output."""
    last = lineitem.n_row_groups - 1
    n = lineitem.row_group_meta(last)["n"]
    assert 0 < n < padded_rows(n)  # precondition: genuinely short
    plan = ScanPlan("lineitem", ["l_extendedprice"])
    (cost,) = CostModel().estimate_row_groups(
        DatapathEngine(backend="ref"), lineitem, plan, [last])
    assert cost.nbytes == padded_rows(n) * 4


# ---------------------------------------------------------------------------
# netsim unification
# ---------------------------------------------------------------------------

def test_decode_model_is_encoding_aware():
    dm = DecodeModel(decode_gbps=10.0, rates={"rle": 40.0})
    assert dm.decode_seconds(1 << 20, "rle") == pytest.approx(
        dm.decode_seconds(1 << 20) / 4)
    assert dm.decode_seconds(1 << 20, "bitpack") == dm.decode_seconds(1 << 20)


def test_default_decode_model_reads_the_registered_table():
    """A default-constructed DecodeModel/PrefetchPipeline must price from
    the process-default per-backend cost model (the one the service
    registers), not a stale module-level constant — after calibration the
    simulated overlap and the scheduler's charges come from one table."""
    from repro.datapath import costmodel as cmod

    prev = cmod.set_default_cost_model(None)
    try:
        dm = DecodeModel()  # no registration: the nominal table
        assert dm.rates == NOMINAL_RATES_GBPS
        assert dm.decode_gbps == NOMINAL_RATES_GBPS["plain"]
        cal = CostModel(rates={"plain": 3.0, "rle": 7.0}, source="calibrated",
                        launch_overhead_s=5e-6)
        cmod.set_default_cost_model(cal)
        dm2 = DecodeModel()
        assert dm2.rates == cal.rates
        assert dm2.decode_gbps == 3.0
        assert dm2.launch_overhead_s == 5e-6
        assert PrefetchPipeline().decode.rates == cal.rates
        # explicit scalar construction keeps the old scalar-model semantics
        dm3 = DecodeModel(decode_gbps=10.0)
        assert dm3.rates is None and dm3.launch_overhead_s == 0.0
    finally:
        cmod.set_default_cost_model(prev)


def test_pipeline_decode_seconds_override():
    pipe = PrefetchPipeline(LinkModel(bandwidth_gbps=1.0, latency_us=0.0))
    enc = [1 << 20] * 4
    dec = [1 << 20] * 4
    slow = pipe.simulate(enc, dec, decode_seconds=[1.0] * 4)
    fast = pipe.simulate(enc, dec, decode_seconds=[1e-6] * 4)
    assert slow["serial_s"] > fast["serial_s"]
    # identity still holds under the override
    assert abs(slow["serial_s"] - (slow["overlapped_s"] + slow["saved_s"])) < 1e-9


def test_service_and_netsim_share_one_table(lineitem):
    """DatapathService built with a calibrated table must hand the SAME
    per-encoding rates to its prefetch pipeline — scheduler and netsim
    agree on one model."""
    cm = CostModel(rates={"plain": 7.0, "rle": 9.0}, source="calibrated")
    svc = DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        policy=StaticPolicy("raw"), cost_model=cm)
    assert svc.pipeline.decode.rates == cm.rates
    assert svc.pipeline.link.bandwidth_gbps == cm.link_bandwidth_gbps
    # and the simulation actually runs through it end to end
    t = svc.submit("t", lineitem, ScanPlan("lineitem", ["l_extendedprice"]))
    svc.drain()
    assert t.status == "done"
    assert svc.telemetry.counters["sim_fetch_decoded_bytes"] > 0


def test_cli_smoke(tmp_path, capsys):
    from repro.datapath import costmodel

    out = tmp_path / "cal.json"
    assert costmodel.main(["--nominal", "--out", str(out)]) == 0
    assert CostModel.load(str(out)).rates == NOMINAL_RATES_GBPS
    assert "costmodel.plain" in capsys.readouterr().out
