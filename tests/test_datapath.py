"""Multi-tenant datapath service: bit-identity vs direct engine scans,
admission control / quotas, shared-scan coalescing, adaptive policy,
netsim pipeline math, telemetry quantiles."""

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, InSet, ScanPlan, and_
from repro.core import tpch
from repro.core.queries import QUERIES, run_via_service
from repro.datapath import (
    AdaptiveOffloadPolicy,
    DatapathService,
    DecodePool,
    LinkModel,
    PrefetchPipeline,
    QueueFull,
    QuotaExceeded,
    StaticPolicy,
    Telemetry,
    TenantQuota,
)
from repro.datapath.telemetry import quantile
from repro.lakeformat.reader import LakeReader


@pytest.fixture(scope="module")
def small_tables(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_dp")
    paths = tpch.write_tables(str(d), sf=0.05, seed=0, row_group_size=8192)
    return paths


@pytest.fixture(scope="module")
def readers(small_tables):
    return {k: LakeReader(p) for k, p in small_tables.items()}


def _service(**kw):
    kw.setdefault("engine", DatapathEngine(backend="ref", cache=BlockCache(1 << 30)))
    return DatapathService(**kw)


PLANS = [
    ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
             Cmp("l_shipdate", "between", (365, 729))),  # fused fast path
    ScanPlan("lineitem", ["l_quantity", "l_extendedprice"],
             and_(Cmp("l_shipdate", "between", (365, 729)),
                  Cmp("l_quantity", "lt", 25))),  # multi-column predicate
    ScanPlan("lineitem", ["l_quantity"], InSet("l_shipmode", ("MAIL", "SHIP"))),
    ScanPlan("lineitem", ["l_quantity"], Cmp("l_quantity", "le", 3), compact=True),
    ScanPlan("part", ["p_partkey", "p_size"], Cmp("p_size", "le", 10)),
]


def _assert_identical(got, want):
    assert int(got.count) == int(want.count)
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    assert set(got.columns) == set(want.columns)
    for name in want.columns:
        assert np.array_equal(
            np.asarray(got.columns[name]), np.asarray(want.columns[name])
        ), name


@pytest.mark.parametrize("idx", range(len(PLANS)))
def test_service_bit_identical_to_direct_scan(readers, idx):
    """(a) service results == direct DatapathEngine.scan(), bit for bit,
    with the adaptive policy free to pick any offload mode."""
    plan = PLANS[idx]
    direct = DatapathEngine(backend="ref").scan(readers[plan.table], plan)
    svc = _service(policy=AdaptiveOffloadPolicy())
    for _ in range(2):  # second pass may hit preloaded/prefiltered paths
        ticket = svc.submit("t0", readers[plan.table], plan)
        _assert_identical(svc.result(ticket), direct)


def test_service_queries_match_direct(readers):
    """All six queries through the service-client path == direct engine."""
    eng = DatapathEngine(backend="ref")
    svc = _service(batch_per_tick=8)
    for name in QUERIES:
        assert run_via_service(svc, name, readers, tenant=name) == QUERIES[name](eng, readers)


def test_quota_rejects_over_budget_tenant(readers):
    """(b) byte and row quotas both reject at admission; other tenants and
    later windows are unaffected."""
    svc = _service(
        quotas={"small": TenantQuota(max_bytes=1000), "narrow": TenantQuota(max_rows=10)},
        quota_window_ticks=4,
    )
    plan = PLANS[0]
    with pytest.raises(QuotaExceeded):
        svc.submit("small", readers["lineitem"], plan)
    with pytest.raises(QuotaExceeded):
        svc.submit("narrow", readers["lineitem"], plan)
    # unconstrained tenant still admitted
    t = svc.submit("big", readers["lineitem"], plan)
    assert int(svc.result(t).count) > 0
    assert svc.telemetry.counters["rejected_quota_bytes"] == 1
    assert svc.telemetry.counters["rejected_quota_rows"] == 1


def test_quota_window_refills(readers):
    plan = ScanPlan("part", ["p_size"], Cmp("p_size", "le", 5))
    est = DatapathEngine(backend="ref").estimate_scan_bytes(readers["part"], plan)
    svc = _service(quotas={"t": TenantQuota(max_bytes=int(est * 1.5))},
                   quota_window_ticks=2, batch_per_tick=1)
    svc.submit("t", readers["part"], plan)
    with pytest.raises(QuotaExceeded):  # same window, queue busy: rejected
        svc.submit("t", readers["part"], plan)
    svc.drain()  # tick 1
    svc.tick()  # tick 2 = window boundary, usage refills
    assert svc.submit("t", readers["part"], plan) is not None


def test_quota_refills_on_idle_service(readers):
    """An exhausted tenant must not be locked out forever once the queue is
    empty — idle submits fast-forward the window instead of requiring the
    caller to hand-crank tick()."""
    plan = ScanPlan("part", ["p_size"], Cmp("p_size", "le", 5))
    est = DatapathEngine(backend="ref").estimate_scan_bytes(readers["part"], plan)
    svc = _service(quotas={"t": TenantQuota(max_bytes=int(est * 1.5))},
                   quota_window_ticks=1000)
    svc.result(svc.submit("t", readers["part"], plan))  # exhausts the window
    # queue now empty: the next submit refills rather than raising
    t2 = svc.submit("t", readers["part"], plan)
    assert int(svc.result(t2).count) >= 0
    # but a request that no fresh window could ever afford still rejects
    with pytest.raises(QuotaExceeded):
        _service(quotas={"t": TenantQuota(max_bytes=10)}).submit(
            "t", readers["part"], plan
        )


def test_queue_depth_admission(readers):
    svc = _service(max_queue_depth=2)
    plan = PLANS[0]
    svc.submit("a", readers["lineitem"], plan)
    svc.submit("b", readers["lineitem"], plan)
    with pytest.raises(QueueFull):
        svc.submit("c", readers["lineitem"], plan)
    svc.drain()
    assert svc.submit("c", readers["lineitem"], plan) is not None


def test_coalescing_decodes_each_group_once(readers):
    """(c) two scans over the same row groups in one tick: every shared
    (row group, column) pair is decoded exactly once."""
    r = readers["lineitem"]
    plan_a = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_shipdate", "between", (365, 729)))
    plan_b = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                      Cmp("l_shipdate", "between", (400, 800)))
    svc = _service(batch_per_tick=2, policy=StaticPolicy("raw"))
    ta = svc.submit("a", r, plan_a)
    tb = svc.submit("b", r, plan_b)

    # drive one tick's batch by hand with an inspectable shared pool
    batch, svc.queue = svc.queue[:2], svc.queue[2:]
    pool = DecodePool()
    for req in batch:
        res = svc.engine.scan(req.reader, req.plan, blooms=req.blooms,
                              offload="raw", pool=pool)
        req.ticket.result = res
        req.ticket.status = "done"

    # both plans decode the same 2 projected columns over the same groups:
    # unique decodes == pool entries == puts; second scan only pool-hits
    assert pool.puts == len(pool)
    assert pool.hits > 0
    a, b = ta.result, tb.result
    assert a.stats.decoded_bytes_fresh > 0
    assert b.stats.decoded_bytes_fresh == 0  # fully served from the pool
    assert b.stats.pool_hits == len(plan_b.columns) * a.stats.row_groups_scanned

    # results still match independent direct scans
    _assert_identical(a, DatapathEngine(backend="ref").scan(r, plan_a))
    _assert_identical(b, DatapathEngine(backend="ref").scan(r, plan_b))


def test_coalescing_saves_decoded_bytes_for_four_tenants(readers):
    """Fresh decoded bytes through one coalesced tick << 4 independent scans."""
    from benchmarks.service_bench import _run_independent, _run_service, tenant_plans

    plans = tenant_plans(4)
    ind = _run_independent(readers, plans)
    svc = _run_service(readers, plans)
    svc_fresh = int(svc.telemetry.counters["decoded_bytes_fresh"])
    assert svc_fresh < ind
    assert int(svc.telemetry.counters["decoded_bytes_saved"]) > 0


def test_prefiltered_cache_keys_include_blooms(readers):
    """Two tenants, identical plan, DIFFERENT bloom bits: the recurring-
    signature promotion to 'prefiltered' must never serve one tenant's
    semijoin result to the other."""
    import jax.numpy as jnp

    from repro.core.plan import BloomProbe
    from repro.kernels import ops

    r = readers["lineitem"]
    plan = ScanPlan("lineitem", ["l_partkey"], BloomProbe("l_partkey", name="b"))
    bloom_a = ops.bloom_build(jnp.arange(0, 30, dtype=jnp.int32), 1 << 14)
    bloom_b = ops.bloom_build(jnp.arange(500, 530, dtype=jnp.int32), 1 << 14)
    eng = DatapathEngine(backend="ref")
    want_a = eng.scan(r, plan, blooms={"b": bloom_a})
    want_b = eng.scan(r, plan, blooms={"b": bloom_b})
    assert int(want_a.count) != int(want_b.count)  # distinct probe sets

    svc = _service(policy=AdaptiveOffloadPolicy(repeat_k=2))
    for _ in range(2):  # repeat to trigger prefiltered promotion
        got_a = svc.result(svc.submit("a", r, plan, blooms={"b": bloom_a}))
        got_b = svc.result(svc.submit("b", r, plan, blooms={"b": bloom_b}))
        _assert_identical(got_a, want_a)
        _assert_identical(got_b, want_b)


def test_failed_request_does_not_wedge_the_batch(readers):
    """A faulty request errors its own ticket; co-batched requests still
    complete, and result() raises instead of spinning forever."""
    svc = _service(batch_per_tick=2, policy=StaticPolicy("raw"))
    bad_plan = ScanPlan("lineitem", ["no_such_column"])
    good_plan = PLANS[0]
    t_bad = svc.submit("a", readers["lineitem"], bad_plan)
    t_good = svc.submit("b", readers["lineitem"], good_plan)
    svc.drain()
    assert t_bad.status == "error" and t_good.status == "done"
    with pytest.raises(KeyError):
        svc.result(t_bad)
    assert int(svc.result(t_good).count) > 0
    assert svc.telemetry.counters["failed"] == 1


def test_decode_pool_budget_is_enforced(readers):
    """A tiny pool budget refuses inserts instead of pinning unbounded
    decoded bytes; scans still return correct results."""
    r = readers["lineitem"]
    plan = PLANS[0]
    svc = _service(batch_per_tick=2, policy=StaticPolicy("raw"), pool_bytes=1024)
    ta = svc.submit("a", r, plan)
    tb = svc.submit("b", r, plan)
    svc.drain()
    assert svc.telemetry.counters["pool_rejected_puts"] > 0
    assert svc.telemetry.counters["decoded_bytes_saved"] == 0  # nothing pooled
    direct = DatapathEngine(backend="ref").scan(r, plan)
    _assert_identical(ta.result, direct)
    _assert_identical(tb.result, direct)


def test_pool_hit_still_populates_preloaded_cache(readers):
    """A 'preloaded' request served from the tick pool must still leave its
    decoded columns in the persistent BlockCache for future ticks."""
    r = readers["lineitem"]
    plan = ScanPlan("lineitem", ["l_extendedprice"],
                    Cmp("l_shipdate", "between", (365, 729)))
    eng = DatapathEngine(backend="ref", cache=BlockCache(1 << 30))
    pool = DecodePool()
    eng.scan(r, plan, offload="raw", pool=pool)  # raw: pool filled, cache not
    assert eng.cache.stats()["entries"] == 0
    res = eng.scan(r, plan, offload="preloaded", pool=pool)
    assert res.stats.pool_hits > 0 and res.stats.decoded_bytes_fresh == 0
    assert eng.cache.stats()["entries"] > 0  # persisted despite pool hits


def test_fully_pooled_scan_skips_encoded_fetch(readers):
    """A coalesced scan whose needed columns are all pool-resident reads
    zero encoded bytes — and still matches the direct scan bit for bit."""
    r = readers["lineitem"]
    # predicate column in the projection -> non-fused -> all columns pooled
    plan_a = ScanPlan("lineitem", ["l_quantity", "l_extendedprice"],
                      Cmp("l_quantity", "le", 10))
    plan_b = ScanPlan("lineitem", ["l_quantity", "l_extendedprice"],
                      Cmp("l_quantity", "le", 20))
    eng = DatapathEngine(backend="ref")
    pool = DecodePool()
    res_a = eng.scan(r, plan_a, offload="raw", pool=pool)
    res_b = eng.scan(r, plan_b, offload="raw", pool=pool)
    assert res_a.stats.encoded_bytes > 0
    assert res_b.stats.encoded_bytes == 0  # no fetch: fed entirely by the pool
    assert res_b.stats.decoded_bytes_fresh == 0
    _assert_identical(res_b, DatapathEngine(backend="ref").scan(r, plan_b))


def test_cache_bills_prefiltered_results_by_real_size(readers):
    """BlockCache must account a cached ScanResult at its array size (not a
    64-byte placeholder) so the LRU budget actually bounds service memory."""
    r = readers["lineitem"]
    plan = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_shipdate", "le", 1000))
    eng = DatapathEngine(backend="ref", offload="prefiltered", cache=BlockCache(1 << 30))
    res = eng.scan(r, plan)
    entry_bytes = eng.cache.used
    arrays = sum(int(a.nbytes) for a in res.columns.values()) + int(res.mask.nbytes)
    assert entry_bytes >= arrays  # plus the rg columns it preloaded


def test_adaptive_policy_promotes_recurring_scans(readers):
    svc = _service(policy=AdaptiveOffloadPolicy(repeat_k=2))
    plan = PLANS[0]
    for _ in range(3):
        svc.result(svc.submit("t", readers["lineitem"], plan))
    assert svc.policy.decisions.get("prefiltered", 0) >= 1
    assert svc.telemetry.counters.get("prefiltered_hits", 0) >= 1


def test_selectivity_estimates_rank_predicates(readers):
    eng = DatapathEngine(backend="ref")
    r = readers["lineitem"]
    narrow = eng.estimate_selectivity(
        r, ScanPlan("lineitem", ["l_quantity"], Cmp("l_shipdate", "between", (100, 120)))
    )
    broad = eng.estimate_selectivity(
        r, ScanPlan("lineitem", ["l_quantity"], Cmp("l_shipdate", "between", (0, 2000)))
    )
    everything = eng.estimate_selectivity(r, ScanPlan("lineitem", ["l_quantity"]))
    assert 0.0 <= narrow < broad <= 1.0
    assert everything == 1.0
    # eq/ne on a sub-unit float range (l_discount spans 0.0-0.1) must not
    # invert: ne keeps nearly everything, eq keeps little
    ne = eng.estimate_selectivity(
        r, ScanPlan("lineitem", ["l_quantity"], Cmp("l_discount", "ne", 0.05))
    )
    eq = eng.estimate_selectivity(
        r, ScanPlan("lineitem", ["l_quantity"], Cmp("l_discount", "eq", 0.05))
    )
    assert ne > 0.5 > eq


def test_preloaded_cache_resident_scan_skips_encoded_fetch(readers):
    """Steady-state preloaded mode: once decoded columns are BlockCache-
    resident, repeat scans fetch zero encoded bytes (no tick pool needed)."""
    r = readers["lineitem"]
    plan = ScanPlan("lineitem", ["l_quantity", "l_extendedprice"],
                    Cmp("l_quantity", "le", 10))
    eng = DatapathEngine(backend="ref", cache=BlockCache(1 << 30))
    first = eng.scan(r, plan, offload="preloaded")
    again = eng.scan(r, plan, offload="preloaded")
    assert first.stats.encoded_bytes > 0
    assert again.stats.encoded_bytes == 0
    _assert_identical(again, DatapathEngine(backend="ref").scan(r, plan))


def test_netsim_overlap_math():
    pipe = PrefetchPipeline(LinkModel(bandwidth_gbps=1.0, latency_us=0.0))
    enc = [1 << 20] * 8
    dec = [1 << 20] * 8
    sim = pipe.simulate(enc, dec)
    assert sim["overlapped_s"] < sim["serial_s"]
    assert abs(sim["serial_s"] - (sim["overlapped_s"] + sim["saved_s"])) < 1e-12
    # perfectly balanced stages hide all but the first fetch and last decode
    fetch = pipe.link.fetch_seconds(1 << 20)
    dec_t = pipe.decode.decode_seconds(1 << 20)
    expect = fetch + 7 * max(fetch, dec_t) + dec_t
    assert abs(sim["overlapped_s"] - expect) < 1e-12
    assert pipe.simulate([], [])["serial_s"] == 0.0


def test_telemetry_quantiles():
    t = Telemetry()
    for i in range(100):
        t.observe_latency("a", float(i))
    lat = t.tenant_latency("a")
    assert lat["n"] == 100
    assert abs(lat["p50_s"] - 50.0) <= 1.0
    assert lat["p99_s"] >= 97.0
    assert quantile([], 0.5) == 0.0


def test_quantile_small_sample_and_boundary_edges():
    """Nearest-rank edges: single/two-sample lists, q=0/q=1 boundaries, and
    half-up rounding (NOT banker's) so two-sample p50 is deterministic."""
    # single sample: every q returns the sample
    for q in (0.0, 0.25, 0.5, 0.99, 1.0):
        assert quantile([7.0], q) == 7.0
    # two samples: q=0 -> min, q=1 -> max, p50 rounds UP to the larger
    assert quantile([2.0, 1.0], 0.0) == 1.0
    assert quantile([2.0, 1.0], 1.0) == 2.0
    assert quantile([2.0, 1.0], 0.5) == 2.0
    # consistent half-up at every odd midpoint, regardless of list length
    assert quantile([1.0, 2.0, 3.0, 4.0], 0.5) == 3.0
    # out-of-range q clamps instead of indexing out of bounds
    assert quantile([1.0, 2.0], -0.5) == 1.0
    assert quantile([1.0, 2.0], 1.5) == 2.0


def test_fairness_counts_starved_tenants():
    """Regression: a tenant with zero decoded bytes used to be absent from
    the fairness report, so a fully-starved tenant RAISED the Jain index.
    Shares must range over every tenant the scheduler knows (sched charges
    or latency samples), with the starved tenant at share 0 dragging the
    index down."""
    t = Telemetry()
    t.observe_tenant_bytes("fed", 1000.0)
    t.observe_sched("starved", 0.0, 0.0)  # scheduler knows it; it never ran
    fair = t.fairness()
    assert fair["tenant_share"]["starved"] == 0.0
    assert fair["tenant_share"]["fed"] == 1.0
    assert fair["min_share"] == 0.0
    assert fair["jain_index"] == pytest.approx(0.5)  # 1/n for total starvation
    # a latency-only tenant (e.g. all its requests errored) also shows up
    t2 = Telemetry()
    t2.observe_tenant_bytes("fed", 1000.0)
    t2.observe_latency("unlucky", 0.1)
    assert t2.fairness()["tenant_share"]["unlucky"] == 0.0
    assert t2.fairness()["jain_index"] == pytest.approx(0.5)


def test_cost_report_tracks_estimate_error():
    """The honesty ledger: rel_err is signed (negative = under-estimate)
    and recon_s records the corrections applied."""
    t = Telemetry()
    t.observe_sched("u", 1.0, 100.0)
    t.observe_actual_cost("u", 4.0)
    t.observe_recon("u", 3.0)
    t.observe_sched("o", 2.0, 100.0)
    t.observe_actual_cost("o", 1.0)
    t.observe_recon("o", -1.0)
    rep = t.cost_report()
    assert rep["u"]["rel_err"] == pytest.approx(-0.75)
    assert rep["u"]["recon_s"] == 3.0
    assert rep["o"]["rel_err"] == pytest.approx(1.0)
    assert t.counters["recon_slices"] == 2
    assert t.counters["recon_abs_seconds"] == pytest.approx(4.0)
    # never-completed tenants divide by zero nowhere
    t.observe_sched("pending", 1.0, 10.0)
    assert t.cost_report()["pending"]["rel_err"] == 0.0


def test_snapshot_deterministic_for_empty_and_populated_telemetry():
    """Benchmark JSON must be stable run-to-run: empty deques collapse to
    fixed zeros and every dict is key-sorted regardless of insertion order."""
    import json

    empty_a, empty_b = Telemetry().snapshot(), Telemetry().snapshot()
    assert empty_a == empty_b
    assert json.dumps(empty_a) == json.dumps(empty_b)
    assert empty_a["tick_p50_s"] == empty_a["tick_p99_s"] == 0.0
    assert empty_a["queue_depth_max"] == 0 and empty_a["queue_depth_mean"] == 0.0
    assert empty_a["fairness"]["jain_index"] == 1.0

    # same observations in different orders serialize identically
    ta, tb = Telemetry(), Telemetry()
    for t in (ta, tb):
        t.observe_tick(0.25)
    ta.inc("x"); ta.inc("y", 2.0)
    tb.inc("y", 2.0); tb.inc("x")
    ta.observe_latency("t0", 1.0); ta.observe_latency("t1", 2.0)
    tb.observe_latency("t1", 2.0); tb.observe_latency("t0", 1.0)
    ta.observe_tenant_bytes("t0", 10.0); ta.observe_tenant_bytes("t1", 30.0)
    tb.observe_tenant_bytes("t1", 30.0); tb.observe_tenant_bytes("t0", 10.0)
    assert json.dumps(ta.snapshot()) == json.dumps(tb.snapshot())
    fair = ta.snapshot()["fairness"]
    assert fair["tenant_share"] == {"t0": 0.25, "t1": 0.75}
    assert 0.0 < fair["jain_index"] < 1.0
