"""Property-based DecodePool budget-accounting invariants.

DecodePool is now a compatibility wrapper over the unified BlockStore (a
never-expiring window view pinning every entry — see
repro/datapath/blockstore.py), so this suite doubles as a property test
of the store's pinned-put ledger through the old pool contract: the byte
bookkeeping must be exact — `used_bytes` is always the summed nbytes of
the kept entries, re-inserting an existing key bills only the size
delta, and a rejected (over-budget) put changes nothing.  Exercised over
random put sequences with a small key domain so re-insertions are
common.  (The store's own tier/pin/eviction properties live in
tests/test_blockstore.py.)

Module skips without `hypothesis` (same policy as tests/test_encodings.py).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.datapath import DecodePool  # noqa: E402


def _pool_ops():
    """(key, size-in-int32-words) put sequences over a small key domain so
    re-insertions of existing keys are common."""
    return st.lists(
        st.tuples(st.integers(0, 4), st.integers(0, 64)), min_size=1, max_size=40
    )


@settings(deadline=None, max_examples=200)
@given(ops=_pool_ops(), budget=st.integers(1, 512))
def test_used_bytes_matches_kept_entries(ops, budget):
    """used_bytes always equals the summed nbytes of the entries actually
    kept, and never exceeds the budget."""
    pool = DecodePool(max_bytes=budget)
    for key, nwords in ops:
        pool[key] = np.zeros(nwords, np.int32)
        assert pool.used_bytes == sum(int(v.nbytes) for v in pool.values())
        assert pool.used_bytes <= budget
        assert pool.puts == len(pool)  # one billed put per kept key


@settings(deadline=None, max_examples=200)
@given(ops=_pool_ops(), budget=st.integers(1, 512))
def test_reinsert_never_double_bills(ops, budget):
    """Re-inserting an existing key bills only the size delta: same-size
    replacement leaves used_bytes unchanged, never counts a second put."""
    pool = DecodePool(max_bytes=budget)
    for key, nwords in ops:
        pool[key] = np.zeros(nwords, np.int32)
    for key in list(pool):
        before_used, before_puts = pool.used_bytes, pool.puts
        pool[key] = np.asarray(pool[key])  # same-size re-insert
        assert pool.used_bytes == before_used
        assert pool.puts == before_puts
        assert pool.used_bytes == sum(int(v.nbytes) for v in pool.values())


@settings(deadline=None, max_examples=200)
@given(ops=_pool_ops(), budget=st.integers(1, 256))
def test_rejected_puts_never_decrease_used_bytes(ops, budget):
    """A rejected put is a no-op on the accounting: used_bytes unchanged,
    rejected_puts monotone, and the over-budget value is NOT kept."""
    pool = DecodePool(max_bytes=budget)
    for key, nwords in ops:
        before_used, before_rej = pool.used_bytes, pool.rejected_puts
        pool[key] = np.zeros(nwords, np.int32)
        assert pool.rejected_puts >= before_rej
        if pool.rejected_puts > before_rej:  # this put was refused
            assert pool.used_bytes == before_used
        assert pool.used_bytes == sum(int(v.nbytes) for v in pool.values())


@settings(deadline=None, max_examples=100)
@given(ops=_pool_ops(), budget=st.integers(1, 512))
def test_resized_reinsert_respects_budget(ops, budget):
    """A different-size re-insert either fits (delta billed) or is rejected
    with the OLD value still present — the pool never holds an unbilled or
    over-budget entry."""
    pool = DecodePool(max_bytes=budget)
    for key, nwords in ops:
        existing = key in pool
        old = int(pool[key].nbytes) if existing else None
        before_used = pool.used_bytes
        pool[key] = np.zeros(nwords, np.int32)
        if existing:
            assert key in pool  # rejection keeps the old entry
            new = int(pool[key].nbytes)
            assert pool.used_bytes == before_used - old + new or (
                new == old and pool.used_bytes == before_used
            )
        assert pool.used_bytes == sum(int(v.nbytes) for v in pool.values())
        assert pool.used_bytes <= budget
