"""Distributed runtime (subprocess, 8 fake devices): hierarchical psum
exactness, int8 compressed psum + error-feedback convergence, EP MoE parity
with the single-device path, sharding-rule divisibility guards."""

import pytest

from repro.distributed.sharding import ShardingCtx, spec_for
from tests.util import run_with_devices


def test_spec_for_divisibility_guard():
    ctx = ShardingCtx(mesh=None)
    assert spec_for(("batch", None), ctx) == ()  # no mesh: empty spec

    # guard logic is pure given axis sizes; emulate with a fake mesh via subprocess below


def test_hierarchical_psum_exact():
    out = run_with_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.collectives import hierarchical_psum
from repro.distributed.compat import make_mesh, shard_map
mesh = make_mesh((2, 4), ("pod", "data"))
x = jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)

def f(x):
    return hierarchical_psum(x, intra_axis="data", inter_axis="pod")

y = jax.jit(shard_map(f, mesh=mesh, in_specs=P(("pod","data"), None),
                      out_specs=P(("pod","data"), None)))(x)
# every shard's local x summed over all 8 shards => each row group identical
exp = x.reshape(8, 1, 6).sum(0, keepdims=True)  # local shards are rows
# per-shard local value is its row; sum over all shards = column sum broadcast
expected = np.tile(np.asarray(x).reshape(8,6).sum(0, keepdims=True)/1, (8,1))
# compare via psum reference
ref = jax.jit(shard_map(lambda v: jax.lax.psum(v, ("pod","data")), mesh=mesh,
              in_specs=P(("pod","data"), None), out_specs=P(("pod","data"), None)))(x)
assert np.allclose(np.asarray(y), np.asarray(ref)), (np.asarray(y)[:2], np.asarray(ref)[:2])
print("HIER_OK")
""",
        n_devices=8,
    )
    assert "HIER_OK" in out


def test_compressed_psum_error_feedback_converges():
    out = run_with_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.collectives import compressed_psum
from repro.distributed.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("pod",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 128)).astype(np.float32))

def one(gl, err):
    return compressed_psum(gl, err, "pod")

f = jax.jit(shard_map(one, mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
            out_specs=(P("pod", None), P("pod", None))))
err = jnp.zeros((8, 128), jnp.float32)
exact = np.asarray(g).reshape(8, 1, 128).sum(0)
acc_c = np.zeros((1, 128)); acc_e = np.zeros((1, 128))
for step in range(20):
    s, err = f(g, err)
    acc_c += np.asarray(s)[:1]
    acc_e += exact
rel = np.abs(acc_c - acc_e).max() / np.abs(acc_e).max()
# single-shot int8 error is ~1%, but with error feedback the ACCUMULATED
# sum stays tight (residual carried, not lost)
assert rel < 0.01, rel
print("EF_OK", rel)
""",
        n_devices=8,
    )
    assert "EF_OK" in out


def test_moe_2d_ep_matches_single_device():
    """2D expert parallelism (a2a + row broadcast + psum_scatter, and the
    weights-resident variant) vs the single-device oracle."""
    out = run_with_devices(
        """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke_config
from repro.distributed.compat import make_mesh, use_mesh
from repro.distributed.sharding import ShardingCtx
from repro.models.moe import moe_ffn
from repro.models.model import init_params

for moe_ff, tag in [(48, "2d"), (48, "resident")]:
    cfg = get_smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(cfg, moe_d_ff=moe_ff)
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = {k: v[0] for k, v in params["segments"][1].items()}
    moe_params = {k: lp[k] for k in ("router","e_wg","e_wu","e_wo",
                                     "shared_wg","shared_wu","shared_wo","ln2")}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 16, cfg.d_model)).astype(np.float32)*0.3,
                    jnp.bfloat16)
    y_ref, _ = moe_ffn(x, moe_params, cfg, ShardingCtx(mesh=None))
    mesh = make_mesh((2, 4), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, strategy="fsdp_ep")
    with use_mesh(mesh):
        y2d, _ = jax.jit(lambda x, p: moe_ffn(x, p, cfg, ctx))(x, moe_params)
    d = jnp.abs(y_ref.astype(jnp.float32) - y2d.astype(jnp.float32))
    frac = float(jnp.mean(d > 1e-2))
    assert frac < 0.06, (tag, frac, float(d.max()))
print("MOE_2D_OK")
""",
        n_devices=8,
    )
    assert "MOE_2D_OK" in out


def test_moe_ep_matches_single_device():
    out = run_with_devices(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.distributed.compat import make_mesh, use_mesh
from repro.distributed.sharding import ShardingCtx
from repro.models.moe import moe_ffn
from repro.models.model import init_params

cfg = get_smoke_config("deepseek-moe-16b")  # 8 experts, top-3, 2 shared
params = init_params(cfg, jax.random.PRNGKey(0))
moe_p = params["segments"][1]
lp = {k: v[0] for k, v in moe_p.items()}  # layer 0 of the moe segment
moe_params = {k: lp[k] for k in ("router", "e_wg", "e_wu", "e_wo",
                                 "shared_wg", "shared_wu", "shared_wo", "ln2")}
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)).astype(np.float32) * 0.3,
                jnp.bfloat16)

# single-device reference
y_ref, aux_ref = moe_ffn(x, moe_params, cfg, ShardingCtx(mesh=None))

# EP over (data=2, model=4): 2 experts per shard
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ShardingCtx(mesh=mesh)
with use_mesh(mesh):
    y_ep, aux_ep = jax.jit(lambda x, p: moe_ffn(x, p, cfg, ctx))(x, moe_params)
err = float(jnp.max(jnp.abs(y_ref.astype(jnp.float32) - y_ep.astype(jnp.float32))))
# capacity per shard differs from the single-device capacity, so token drops
# can differ at the margin; bulk outputs must agree
frac_diff = float(jnp.mean((jnp.abs(y_ref.astype(jnp.float32) - y_ep.astype(jnp.float32)) > 1e-2)))
assert frac_diff < 0.05, (err, frac_diff)
print("MOE_EP_OK", err, frac_diff)
""",
        n_devices=8,
    )
    assert "MOE_EP_OK" in out
