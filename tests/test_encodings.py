"""lakeformat encodings: exact roundtrips, hypothesis property tests,
file writer/reader integrity."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.lakeformat import encodings as E
from repro.lakeformat.encodings import (
    Encoding,
    bitpack_encode,
    bitpack_decode_np,
    decode_column_host,
    encode_column,
)
from repro.lakeformat.reader import LakeReader
from repro.lakeformat.schema import ColumnSchema, TableSchema
from repro.lakeformat.writer import write_table


@pytest.mark.parametrize("k", [1, 2, 3, 5, 7, 8, 11, 13, 16, 17, 18, 23, 24, 31, 32])
def test_bitpack_roundtrip_all_k(k):
    rng = np.random.default_rng(k)
    n = 4096 * 2 + 777
    hi = min((1 << k) - 1, 2**31 - 1)
    v = rng.integers(0, hi + 1, size=n, dtype=np.uint64)
    out = bitpack_decode_np(bitpack_encode(v, k), k, n)
    assert np.array_equal(out, v.astype(np.uint32))


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=31),
    st.integers(min_value=1, max_value=10_000),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_bitpack_roundtrip_property(k, n, seed):
    rng = np.random.default_rng(seed)
    v = rng.integers(0, 1 << k, size=n, dtype=np.uint64)
    out = bitpack_decode_np(bitpack_encode(v, k), k, n)
    assert np.array_equal(out, v.astype(np.uint32))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=-(2**31), max_value=2**31 - 1), min_size=1, max_size=3000))
def test_encode_column_roundtrip_property(values):
    """INVARIANT: decode(encode(x)) == x for any int32 column, any encoding
    the auto-chooser picks."""
    v = np.asarray(values, dtype=np.int64)
    v = np.clip(v, -(2**31), 2**31 - 1)
    col = encode_column(v.astype(np.int32))
    out = decode_column_host(col)
    assert np.array_equal(out.astype(np.int64), v)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=50),  # runs
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=0, max_value=100),
)
def test_rle_roundtrip_property(n_runs, max_len, seed):
    rng = np.random.default_rng(seed)
    v = np.repeat(
        rng.integers(0, 100, size=n_runs), rng.integers(1, max_len + 1, size=n_runs)
    ).astype(np.int32)
    bufs = E.rle_encode(v)
    if bufs is None:
        return  # window exceeded: writer falls back, by design
    out = E.rle_decode_np(bufs, len(v))
    assert np.array_equal(out, v)


def test_delta_roundtrip_sorted():
    rng = np.random.default_rng(0)
    v = np.cumsum(rng.integers(0, 50, size=10_000)).astype(np.int64)
    col = encode_column(v)
    assert col.encoding == Encoding.DELTA
    assert np.array_equal(decode_column_host(col).astype(np.int64), v)


def test_float_roundtrip_exact():
    rng = np.random.default_rng(0)
    v = rng.standard_normal(5000).astype(np.float32)
    col = encode_column(v)
    assert np.array_equal(decode_column_host(col), v)


def test_compression_wins():
    """Encoded bytes must beat plain int32 on representative columns."""
    rng = np.random.default_rng(0)
    low_card = rng.integers(0, 7, size=65536)
    col = encode_column(low_card)
    assert col.encoded_bytes() < 0.25 * col.plain_bytes()
    tokens = rng.integers(0, 202048, size=65536)
    col = encode_column(tokens)
    assert col.encoding == Encoding.BITPACK and col.k == 18
    assert col.encoded_bytes() < 0.6 * col.plain_bytes()


def test_writer_reader_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    schema = TableSchema(
        "t",
        [ColumnSchema("a", "int32"), ColumnSchema("b", "float32"), ColumnSchema("s", "str")],
    )
    n = 70_000
    cols = {
        "a": rng.integers(0, 1000, size=n),
        "b": rng.random(n).astype(np.float32),
        "s": [["x", "y", "z"][i] for i in rng.integers(0, 3, size=n)],
    }
    path = write_table(str(tmp_path / "t.lake"), schema, cols)
    r = LakeReader(path)
    assert r.n_rows == n and r.n_row_groups == 2
    enc = r.read_encoded(0)
    assert np.array_equal(decode_column_host(enc["a"]), np.asarray(cols["a"][:65536], np.int32))
    assert np.array_equal(decode_column_host(enc["b"]), cols["b"][:65536])
    # zone maps match data
    zm = r.zonemaps("a")[0]
    assert zm["min"] == int(cols["a"][:65536].min()) and zm["max"] == int(cols["a"][:65536].max())
    # string predicate folding (dictionary order is first-seen)
    assert r.string_code("s", "y") == r.string_dicts["s"].index("y")
    assert r.string_code("s", "nope") == -1


def test_truncated_file_detected(tmp_path):
    schema = TableSchema("t", [ColumnSchema("a", "int32")])
    path = write_table(str(tmp_path / "t.lake"), schema, {"a": np.arange(100)})
    raw = open(path, "rb").read()
    open(path, "wb").write(raw[:-5])
    with pytest.raises(ValueError):
        LakeReader(path)
