"""DatapathEngine: pushdown correctness vs numpy oracle, zone-map pruning,
fused fast path, compaction, offload cache modes, backend parity."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, InSet, ScanPlan, and_, or_
from repro.core.plan import BloomProbe
from repro.core import tpch
from repro.kernels import ops
from repro.lakeformat.reader import LakeReader


@pytest.fixture(scope="module")
def small_tables(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch")
    paths = tpch.write_tables(str(d), sf=0.05, seed=0, row_group_size=8192)
    data = tpch.gen_tables(0.05, 0)
    return paths, data


def _reader(paths, t="lineitem"):
    return LakeReader(paths[t])


def test_scan_matches_oracle(small_tables):
    paths, data = small_tables
    li = data["lineitem"]
    eng = DatapathEngine(backend="ref")
    plan = ScanPlan(
        "lineitem",
        ["l_quantity", "l_extendedprice"],
        and_(Cmp("l_shipdate", "between", (365, 729)), Cmp("l_quantity", "lt", 25)),
    )
    res = eng.scan(_reader(paths), plan)
    m = np.asarray(res.mask)
    exp = (li["l_shipdate"] >= 365) & (li["l_shipdate"] <= 729) & (li["l_quantity"] < 25)
    assert int(res.count) == exp.sum()
    got_q = np.asarray(res.columns["l_quantity"])[m]
    assert sorted(got_q.tolist()) == sorted(li["l_quantity"][exp].tolist())


def test_zonemap_pruning_sorted(small_tables, tmp_path):
    paths, _ = small_tables
    sorted_paths = tpch.write_tables(str(tmp_path), sf=0.05, seed=0,
                                     sorted_data=True, row_group_size=8192)
    eng = DatapathEngine(backend="ref")
    plan = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_shipdate", "between", (365, 729)))
    r_un = eng.scan(_reader(paths), plan)
    r_so = eng.scan(LakeReader(sorted_paths["lineitem"]), plan)
    assert r_un.stats.rows_out == r_so.stats.rows_out  # same answer
    assert r_so.stats.row_groups_scanned < r_un.stats.row_groups_scanned  # fewer groups
    assert r_so.stats.encoded_bytes < r_un.stats.encoded_bytes  # fewer bytes


def test_fused_fast_path(small_tables):
    paths, _ = small_tables
    eng = DatapathEngine(backend="ref")
    plan = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_shipdate", "between", (365, 729)))
    res = eng.scan(_reader(paths), plan)
    assert res.stats.fused  # predicate col not in projection -> fused decode+filter


def test_compaction(small_tables):
    paths, data = small_tables
    li = data["lineitem"]
    eng = DatapathEngine(backend="ref")
    plan = ScanPlan("lineitem", ["l_quantity"], Cmp("l_quantity", "le", 3), compact=True)
    res = eng.scan(_reader(paths), plan)
    n = int(res.count)
    exp = np.sort(li["l_quantity"][li["l_quantity"] <= 3])
    got = np.sort(np.asarray(res.columns["l_quantity"])[:n])
    assert np.array_equal(got, exp)


def test_string_predicate_binding(small_tables):
    paths, data = small_tables
    li = data["lineitem"]
    eng = DatapathEngine(backend="ref")
    plan = ScanPlan("lineitem", ["l_quantity"], InSet("l_shipmode", ("MAIL", "SHIP")))
    res = eng.scan(_reader(paths), plan)
    exp = sum(1 for m in li["l_shipmode"] if m in ("MAIL", "SHIP"))
    assert int(res.count) == exp


def test_bloom_pushdown_semijoin(small_tables):
    paths, data = small_tables
    li = data["lineitem"]
    eng = DatapathEngine(backend="ref")
    keys = np.unique(data["part"]["p_partkey"][:37]).astype(np.int32)
    bits = ops.bloom_build(jnp.asarray(keys), 1 << 14)
    plan = ScanPlan("lineitem", ["l_partkey"], BloomProbe("l_partkey", name="b"))
    res = eng.scan(_reader(paths), plan, blooms={"b": bits})
    m = np.asarray(res.mask)
    got = np.asarray(res.columns["l_partkey"])[m]
    exp_members = np.isin(li["l_partkey"], keys)
    # no false negatives: every true member survives
    assert np.isin(li["l_partkey"][exp_members], got).all()


def test_offload_modes_agree_and_cache(small_tables):
    paths, _ = small_tables
    plan = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_shipdate", "le", 1000))
    results = {}
    for offload in ("raw", "preloaded", "prefiltered"):
        eng = DatapathEngine(backend="ref", offload=offload, cache=BlockCache(1 << 30))
        r1 = eng.scan(_reader(paths), plan)
        r2 = eng.scan(_reader(paths), plan)
        results[offload] = int(r1.count)
        assert int(r1.count) == int(r2.count)
        if offload == "prefiltered":
            assert r2.stats.cache_hit
        if offload == "preloaded":
            assert eng.cache.hits > 0
    assert len(set(results.values())) == 1


def test_all_pruned_scan_keeps_schema_dtypes(small_tables):
    """Regression: the all-pruned empty result used jnp.zeros((0,)), which
    forces float32 for every column regardless of schema — breaking the
    dtype half of the sliced ≡ single-shot bit-identity contract.  Empty
    columns must match the dtypes a one-group scan produces."""
    paths, _ = small_tables
    r = _reader(paths)
    cols = ["l_extendedprice", "l_quantity", "l_shipmode"]  # f32, i32, str->i32
    impossible = ScanPlan("lineitem", cols, Cmp("l_shipdate", "between", (-20, -10)))
    eng = DatapathEngine(backend="ref")
    pruned = eng.scan(r, impossible)
    assert int(pruned.count) == 0
    assert all(a.shape[0] == 0 for a in pruned.columns.values())
    one_group = DatapathEngine(backend="ref").scan(
        r, ScanPlan("lineitem", cols), row_groups=[0])
    assert {c: a.dtype for c, a in pruned.columns.items()} == {
        c: a.dtype for c, a in one_group.columns.items()}
    assert pruned.mask.dtype == one_group.mask.dtype == jnp.bool_


def test_backend_parity(small_tables):
    paths, _ = small_tables
    plan = ScanPlan(
        "lineitem", ["l_extendedprice", "l_discount"],
        and_(Cmp("l_shipdate", "between", (300, 800)), Cmp("l_discount", "between", (0.04, 0.08))),
    )
    counts = {}
    for be in ("ref", "pallas", "host"):
        eng = DatapathEngine(backend=be)
        counts[be] = int(eng.scan(_reader(paths), plan).count)
    assert counts["ref"] == counts["pallas"] == counts["host"]


def test_cache_lru_eviction():
    c = BlockCache(capacity_bytes=1000)
    a = np.zeros(100, np.uint8)
    for i in range(20):
        c.put(("k", i), a)
    assert c.used <= 1000 and c.evictions > 0
    # most recent keys survive
    assert c.get(("k", 19)) is not None
    assert c.get(("k", 0)) is None
