"""Pod-sharded scan fabric: bit-identity vs the single-node engine across
pod counts / offload modes / schedulers / batched-vs-sequential decode,
catalog snapshot isolation, peer block-store fetch priced into WFQ,
fleet-wide fairness re-leveling, and mid-scan pod failure (explicit and
silent-heartbeat) with bit-identical replay.

Fixed configuration grids always run; a hypothesis sweep widens the
bit-identity net when hypothesis is installed (same policy as
tests/test_recon_props.py).
"""

import functools

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan, tpch
from repro.datapath import (
    AdaptiveOffloadPolicy,
    Catalog,
    Pod,
    ScanFabric,
    StaticPolicy,
)
from repro.datapath.costmodel import CostModel
from repro.lakeformat.reader import LakeReader

# 2048-row groups -> lineitem at sf=0.05 spans ~15 row groups, so every
# multi-pod split actually exercises routing, and tick_bytes below keeps
# scans multi-tick (preemptable mid-flight for the failure tests)
RG_ROWS = 2048
TICK_BYTES = 1 << 14


@pytest.fixture(scope="module")
def lakes(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_fabric")
    return tpch.write_tables(str(d), sf=0.05, seed=0, row_group_size=RG_ROWS)


@pytest.fixture(scope="module")
def readers(lakes):
    return {k: LakeReader(p) for k, p in lakes.items()}


PLANS = [
    ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
             Cmp("l_shipdate", "between", (365, 729))),  # zone-map pruned
    ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
             Cmp("l_quantity", "le", 25)),  # unprunable: every rg survives
    ScanPlan("lineitem", ["l_quantity"], Cmp("l_quantity", "le", 3),
             compact=True),  # global compaction over the merged stream
    ScanPlan("part", ["p_partkey", "p_size"], Cmp("p_size", "le", 10)),
]


def _assert_identical(got, want):
    assert int(got.count) == int(want.count)
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    assert set(got.columns) == set(want.columns)
    for name in want.columns:
        assert np.array_equal(
            np.asarray(got.columns[name]), np.asarray(want.columns[name])
        ), name


@functools.lru_cache(maxsize=None)
def _direct_cache():
    return {}


def _direct(readers, idx):
    memo = _direct_cache()
    if idx not in memo:
        plan = PLANS[idx]
        memo[idx] = DatapathEngine(backend="ref").scan(readers[plan.table], plan)
    return memo[idx]


# ---------------------------------------------------------------------------
# bit-identity sweep: N pods x offload mode x scheduler x batch decode
# ---------------------------------------------------------------------------

SWEEP = [
    # (n_pods, policy factory, scheduler, batch_decode)
    (1, None, "wfq", True),  # degenerate fabric == one pod
    (2, None, "wfq", True),
    (4, None, "wfq", True),
    (2, lambda: StaticPolicy("raw"), "fifo", False),
    (2, lambda: StaticPolicy("preloaded"), "wfq", True),
    (4, lambda: StaticPolicy("prefiltered"), "wfq", True),
    (4, lambda: AdaptiveOffloadPolicy(), "fifo", True),
    (3, lambda: StaticPolicy("raw"), "wfq", True),
    (2, lambda: AdaptiveOffloadPolicy(), "wfq", False),
]


@pytest.mark.parametrize("n_pods,policy,sched,batch", SWEEP)
def test_fabric_bit_identical_to_single_node(readers, n_pods, policy, sched, batch):
    kw = {"policy": policy()} if policy else {}
    fab = ScanFabric(n_pods=n_pods, scheduler=sched, batch_decode=batch, **kw)
    for idx, plan in enumerate(PLANS):
        # twice: the second pass may serve from preloaded/prefiltered tiers
        for _ in range(2):
            got = fab.scan(readers[plan.table], plan)
            _assert_identical(got, _direct(readers, idx))


def test_fabric_merged_stats_cover_whole_table(readers):
    fab = ScanFabric(n_pods=4)
    plan = PLANS[1]  # unprunable
    got = fab.scan(readers["lineitem"], plan)
    want = _direct(readers, 1)
    assert got.stats.row_groups_total == readers["lineitem"].n_row_groups
    assert got.stats.rows_total == readers["lineitem"].n_rows
    assert got.stats.row_groups_scanned == want.stats.row_groups_scanned
    assert got.stats.rows_out == int(want.count)


def test_fabric_routing_is_ring_derived(readers):
    fab = ScanFabric(n_pods=4)
    r = readers["lineitem"]
    t = fab.submit("t0", r, PLANS[1])
    for sub in t.subs.values():
        for rg in sub.rgs:
            assert fab.owner_of(r.path, rg) == sub.pod_id
    fab.drain()
    assert t.status == "done"


def test_fabric_all_pruned_is_engine_empty(readers):
    plan = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_quantity", "lt", -1))
    fab = ScanFabric(n_pods=2)
    got = fab.scan(readers["lineitem"], plan)
    want = DatapathEngine(backend="ref").scan(readers["lineitem"], plan)
    _assert_identical(got, want)
    assert got.mask.shape == (0,)
    assert not fab.active  # nothing lingers (zero-sub tickets merge at submit)


def test_fabric_concurrent_tenants_interleaved(readers):
    fab = ScanFabric(n_pods=2, tick_bytes=TICK_BYTES)
    tickets = [fab.submit(f"t{i % 3}", readers[PLANS[i].table], PLANS[i])
               for i in range(len(PLANS))]
    fab.drain()
    for i, t in enumerate(tickets):
        _assert_identical(t.result, _direct(readers, i))


# ---------------------------------------------------------------------------
# pod failure: explicit kill and silent heartbeat death, mid-scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("silent", [False, True])
@pytest.mark.parametrize("batch", [True, False])
def test_fabric_pod_failure_mid_scan_replays_bit_identical(
        readers, silent, batch):
    fab = ScanFabric(n_pods=3, tick_bytes=TICK_BYTES, batch_decode=batch,
                     heartbeat_timeout_ticks=2)
    r = readers["lineitem"]
    t = fab.submit("t0", r, PLANS[1])  # unprunable -> subs on several pods
    assert len(t.subs) >= 2
    fab.tick()  # some slices land; victim must still have queued work
    victims = [s.pod_id for s in t.subs.values() if s.ticket.status == "queued"]
    assert victims
    fab.fail_pod(victims[0], silent=silent)
    fab.drain()
    assert t.status == "done"
    assert t.replays >= 1
    assert victims[0] not in fab.live_pods
    rep = fab.report()
    assert rep["drains"] and rep["drains"][-1]["dead"] == victims[0]
    assert rep["drains"][-1]["replayed"] >= 1
    _assert_identical(t.result, _direct(readers, 1))
    # the fleet still works after the drain
    _assert_identical(fab.scan(r, PLANS[1]), _direct(readers, 1))


def test_fabric_last_pod_failure_raises(readers):
    fab = ScanFabric(n_pods=1)
    with pytest.raises(RuntimeError):
        fab.fail_pod("pod0")


# ---------------------------------------------------------------------------
# catalog: shared registry, snapshot isolation for in-flight scans
# ---------------------------------------------------------------------------

def test_catalog_versioning_and_pins():
    cat = Catalog()
    assert cat.version == 0 and cat.tables() == []
    v1 = cat.register("t", "readerA")
    snap = cat.pin()
    assert snap.version == v1 and snap.table("t") == "readerA"
    v2 = cat.register("t", "readerB")
    assert v2 == v1 + 1
    assert cat.resolve("t") == "readerB"  # latest...
    assert snap.table("t") == "readerA"  # ...but the pin still reads v1
    assert cat.pinned_versions() == [v1]
    cat.release(snap)
    assert cat.pinned_versions() == []
    cat.release(None)  # tolerated
    with pytest.raises(RuntimeError):
        cat.release(snap)  # double release is a bug
    cat.drop("t")
    with pytest.raises(KeyError):
        cat.resolve("t")
    with pytest.raises(KeyError):
        cat.drop("t")


def test_fabric_snapshot_isolation_mid_scan(readers, tmp_path_factory):
    # second lake with different data, same schema
    d = tmp_path_factory.mktemp("tpch_v2")
    paths2 = tpch.write_tables(str(d), sf=0.05, seed=1, row_group_size=RG_ROWS)
    r1, r2 = readers["lineitem"], LakeReader(paths2["lineitem"])
    eng = DatapathEngine(backend="ref")
    want1, want2 = eng.scan(r1, PLANS[1]), eng.scan(r2, PLANS[1])

    fab = ScanFabric(n_pods=2, tick_bytes=TICK_BYTES)
    fab.catalog.register("lineitem", r1)
    t_old = fab.submit("t0", "lineitem", PLANS[1])
    fab.tick()  # in flight...
    assert fab.catalog.pinned_versions() == [1]
    fab.catalog.register("lineitem", r2)  # ...when the table is swapped
    t_new = fab.submit("t0", "lineitem", PLANS[1])
    fab.drain()
    _assert_identical(t_old.result, want1)  # pinned: pre-swap data
    _assert_identical(t_new.result, want2)  # post-swap submission sees v2
    assert fab.catalog.pinned_versions() == []  # merge released the pins


def test_fabric_unknown_table_releases_pin(readers):
    fab = ScanFabric(n_pods=2)
    with pytest.raises(KeyError):
        fab.submit("t0", "nope", PLANS[0])
    assert fab.catalog.pinned_versions() == []


# ---------------------------------------------------------------------------
# peer fetch: warm siblings beat the storage hop, and the tenant pays
# ---------------------------------------------------------------------------

def test_peer_fetch_cheaper_than_storage_at_any_size():
    cm = CostModel()
    for nb in (1, 4096, 1 << 20, 1 << 28):
        assert cm.peer_fetch_seconds(nb) < cm.link_model().fetch_seconds(nb)


def test_fabric_scale_out_peer_fetches_from_warm_owners(readers):
    fab = ScanFabric(n_pods=2, policy=StaticPolicy("preloaded"))
    r = readers["lineitem"]
    got = fab.scan(r, PLANS[1])  # warm the original owners' decoded tiers
    _assert_identical(got, _direct(readers, 1))
    new_pid = fab.add_pod()
    got = fab.scan(r, PLANS[1])  # stolen arcs pull from old owners
    _assert_identical(got, _direct(readers, 1))
    store = fab.pods[new_pid].store
    assert store.peer_hits > 0 and store.peer_hit_bytes > 0
    assert got.stats.peer_bytes == store.peer_hit_bytes
    # ...and the hop was billed to the tenant that missed
    tel = fab.pods[new_pid].telemetry
    assert tel.tenant_peer_bytes.get("default", 0) > 0
    assert tel.counters.get("peer_fetch_seconds", 0) > 0
    # someone served it: fleet-wide serves match hits
    serves = sum(fab.pods[p].store.peer_serves for p in fab.live_pods)
    assert serves == store.peer_hits


def test_fabric_peer_fetch_disabled_is_isolated(readers):
    fab = ScanFabric(n_pods=2, policy=StaticPolicy("preloaded"),
                     peer_fetch=False)
    fab.scan(readers["lineitem"], PLANS[1])
    fab.add_pod()
    got = fab.scan(readers["lineitem"], PLANS[1])
    _assert_identical(got, _direct(readers, 1))  # identical, just pricier
    assert all(fab.pods[p].store.peer_hits == 0 for p in fab.live_pods)
    assert got.stats.peer_bytes == 0


# ---------------------------------------------------------------------------
# fleet fairness: a tenant cannot dodge its backlog across pod clocks
# ---------------------------------------------------------------------------

def test_fleet_vtime_releveling_charges_cross_pod_consumption(readers):
    fab = ScanFabric(n_pods=2, tick_bytes=TICK_BYTES)
    r = readers["lineitem"]
    # the hog has multi-tick work queued on BOTH pods at once, so while it
    # consumes on one pod the other must charge its local clock
    t_hog = [fab.submit("hog", r, PLANS[1]) for _ in range(2)]
    t_mouse = fab.submit("mouse", readers["part"], PLANS[3])
    fab.drain()
    for t in t_hog:
        _assert_identical(t.result, _direct(readers, 1))
    _assert_identical(t_mouse.result, _direct(readers, 3))
    charges = sum(fab.pods[p].telemetry.counters.get("fleet_vtime_charges", 0)
                  for p in fab.live_pods)
    assert charges > 0
    # and the re-level never touches fifo pods
    fifo = ScanFabric(n_pods=2, scheduler="fifo", tick_bytes=TICK_BYTES)
    for _ in range(2):
        fifo.submit("hog", r, PLANS[1])
    fifo.drain()
    assert all(
        p.telemetry.counters.get("fleet_vtime_charges", 0) == 0
        for p in fifo.pods.values()
    )


# ---------------------------------------------------------------------------
# cross-request bucket stacking (satellite: same-tick same-table requests
# decode through ONE bucket pass)
# ---------------------------------------------------------------------------

def _stacking_pod(**kw):
    return Pod(engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
               policy=StaticPolicy("raw"), **kw)


def test_cross_request_stacking_bit_identical_and_fewer_launches(readers):
    r = readers["lineitem"]
    p1 = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                  Cmp("l_quantity", "le", 25))
    p2 = ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
                  Cmp("l_quantity", "le", 10))
    eng = DatapathEngine(backend="ref")
    want = [eng.scan(r, p) for p in (p1, p2)]

    stacked = _stacking_pod(batch_decode=True)
    tks = [stacked.submit("a", r, p1), stacked.submit("b", r, p2)]
    stacked.drain()
    for tk, w in zip(tks, want):
        _assert_identical(tk.result, w)
    tel = stacked.telemetry.counters
    assert tel.get("xreq_groups", 0) >= 1
    assert tel.get("xreq_requests", 0) >= 2
    assert tel.get("xreq_fallback", 0) == 0

    seq = _stacking_pod(batch_decode=False)
    for p in (p1, p2):
        seq.submit("a", r, p)
    seq.drain()
    assert (stacked.telemetry.counters["decode_launches"]
            < seq.telemetry.counters["decode_launches"])


def test_fabric_stacks_across_requests_and_stays_identical(readers):
    fab = ScanFabric(n_pods=2, policy=StaticPolicy("raw"))
    r = readers["lineitem"]
    t1 = fab.submit("a", r, PLANS[0])
    t2 = fab.submit("b", r, PLANS[1])
    fab.drain()
    _assert_identical(t1.result, _direct(readers, 0))
    _assert_identical(t2.result, _direct(readers, 1))
    groups = sum(fab.pods[p].telemetry.counters.get("xreq_groups", 0)
                 for p in fab.live_pods)
    assert groups >= 1


# ---------------------------------------------------------------------------
# drain windows: a pod dies while a request is parked in the coalescing
# hold window, or mid-flight while peer fetches are feeding survivors —
# and the breaker-drain path (fetch faults, not heartbeats) replays too
# ---------------------------------------------------------------------------

def test_drain_while_request_parked_in_hold_window(readers):
    """A sub-scan still parked in its pod's coalescing hold window when
    the pod dies replays bit-identically on survivors — held requests
    are queued, undispatched state and must never be lost."""
    fab = ScanFabric(n_pods=3, tick_bytes=TICK_BYTES, hold_ticks=4,
                     heartbeat_timeout_ticks=2)
    r = readers["lineitem"]
    t = fab.submit("t0", r, PLANS[1])
    fab.tick()  # every sub is now HELD (a lone request has no partner)
    parked = [
        (pid, s) for pid, s in t.subs.items()
        if s.ticket.status == "queued"
        and any(q.held_ticks > 0 and not q.started
                for q in fab.pods[s.pod_id].queue
                if q.ticket is s.ticket)
    ]
    assert parked, "expected at least one sub parked in the hold window"
    fab.fail_pod(parked[0][1].pod_id, silent=True)
    fab.drain()
    assert t.status == "done" and t.replays >= 1
    _assert_identical(t.result, _direct(readers, 1))


def test_drain_mid_peer_fetch_falls_back_to_storage(readers):
    """Kill a warm pod SILENTLY mid-scan: until the heartbeat timeout
    expires, survivors' peer fetches still list the dead pod as a
    sibling, hit its dead store (ConnectionError), and must fall back to
    the next peer / storage — then the drain replays the dead pod's own
    work.  End state: bit-identical, no propagated peer error."""
    fab = ScanFabric(n_pods=3, tick_bytes=TICK_BYTES,
                     heartbeat_timeout_ticks=3)
    r = readers["lineitem"]
    fab.scan(r, PLANS[1])  # warm every pod's store
    t = fab.submit("t0", r, PLANS[1])
    fab.tick()
    victims = [s.pod_id for s in t.subs.values()
               if s.ticket.status == "queued"]
    assert victims
    victim = victims[0]
    assert fab.pods[victim].store.dead is False
    fab.fail_pod(victim, silent=True)
    assert fab.pods[victim].store.dead is True
    with pytest.raises(ConnectionError):
        fab.pods[victim].store.peek(("page", r.path, 0, "l_quantity"))
    fab.drain()
    assert t.status == "done"
    _assert_identical(t.result, _direct(readers, 1))
    # the fleet stays healthy for the next scan
    _assert_identical(fab.scan(r, PLANS[1]), _direct(readers, 1))


def test_breaker_open_pod_is_drained_and_replayed(readers):
    """A pod whose storage fetches trip its circuit breaker is treated
    like a heartbeat-silent pod: drained, its sub-scans replayed
    bit-identically on survivors whose storage paths are healthy."""
    from repro.datapath import FaultPlan, RetryPolicy

    fab = ScanFabric(n_pods=3, tick_bytes=TICK_BYTES)
    r = readers["lineitem"]
    t = fab.submit("t0", r, PLANS[1])
    victim = next(s.pod_id for s in t.subs.values())
    fab.inject_faults(victim, FaultPlan(transient_rate=1.0,
                                        fail_forever=True),
                      RetryPolicy(max_attempts=5))
    fab.drain()
    assert t.status == "done" and t.replays >= 1
    assert victim not in fab.live_pods
    assert fab.report()["breaker_drains"] >= 1
    _assert_identical(t.result, _direct(readers, 1))


def test_breaker_drain_never_takes_the_last_pod(readers):
    """A one-pod fleet with a tripped breaker degrades in place (typed
    error) rather than draining itself out of existence."""
    from repro.datapath import FaultPlan, FetchFailed, RetryPolicy

    fab = ScanFabric(n_pods=1, tick_bytes=TICK_BYTES)
    r = readers["lineitem"]
    fab.inject_faults("pod0", FaultPlan(transient_rate=1.0,
                                        fail_forever=True),
                      RetryPolicy(max_attempts=5))
    t = fab.submit("t0", r, PLANS[1])
    fab.drain()
    assert t.status == "error"
    assert isinstance(t.error, FetchFailed)
    assert fab.live_pods == ["pod0"]
    assert fab.report()["breaker_drains"] == 0


# ---------------------------------------------------------------------------
# hypothesis sweep (skips without hypothesis; the fixed grid above always
# runs, so bit-identity is never unguarded)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=12)
    @given(
        n_pods=st.sampled_from([1, 2, 4]),
        mode=st.sampled_from(["adaptive", "raw", "preloaded", "prefiltered"]),
        scheduler=st.sampled_from(["wfq", "fifo"]),
        batch=st.booleans(),
        kill=st.booleans(),
        idx=st.integers(0, len(PLANS) - 1),
    )
    def _hyp_fabric_identity(readers, n_pods, mode, scheduler, batch, kill, idx):
        policy = (AdaptiveOffloadPolicy() if mode == "adaptive"
                  else StaticPolicy(mode))
        fab = ScanFabric(n_pods=n_pods, policy=policy, scheduler=scheduler,
                         batch_decode=batch, tick_bytes=TICK_BYTES)
        plan = PLANS[idx]
        t = fab.submit("t0", readers[plan.table], plan)
        if kill and n_pods > 1:
            fab.tick()
            queued = [s.pod_id for s in t.subs.values()
                      if s.ticket.status == "queued"]
            if queued:
                fab.fail_pod(queued[0])
        fab.drain()
        _assert_identical(t.result, _direct(readers, idx))

    def test_fabric_identity_hypothesis_sweep(readers):
        _hyp_fabric_identity(readers)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fabric_identity_hypothesis_sweep():
        pass
