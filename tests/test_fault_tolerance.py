"""Fault-tolerance policies: heartbeat death detection + restart planning,
straggler detection, elastic mesh sizing."""

from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    plan_elastic_mesh,
    plan_pod_drain,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_death_and_plans_shrink():
    clock = FakeClock()
    hosts = [f"h{i}" for i in range(8)]
    mon = HeartbeatMonitor(hosts, timeout_s=60, spares=0, clock=clock)
    clock.t = 30
    for h in hosts:
        mon.beat(h)
    clock.t = 100
    for h in hosts[:6]:
        mon.beat(h)
    clock.t = 150  # h6,h7 silent for 120s; h0-5 for 50s (< timeout)
    plan = mon.plan((16, 16))
    assert set(plan.dead_hosts) == {"h6", "h7"}
    assert plan.action == "shrink"
    assert plan.new_mesh[1] == 16  # model axis preserved
    assert plan.new_mesh[0] <= 16 and plan.new_mesh[0] & (plan.new_mesh[0] - 1) == 0


def test_heartbeat_spares_restart_same():
    clock = FakeClock()
    mon = HeartbeatMonitor(["a", "b", "c"], timeout_s=10, spares=1, clock=clock)
    clock.t = 20
    mon.beat("a")
    mon.beat("b")
    plan = mon.plan((4, 4))
    assert plan.action == "restart_same" and plan.dead_hosts == ["c"]


def test_elastic_mesh_sizing():
    assert plan_elastic_mesh(64, (16, 16), chips_per_host=4) == (16, 16)
    assert plan_elastic_mesh(63, (16, 16), chips_per_host=4) == (8, 16)
    assert plan_elastic_mesh(9, (16, 16), chips_per_host=4) == (2, 16)


def test_straggler_detection_and_policy():
    det = StragglerDetector(factor=2.0, min_samples=5, policy="skip_batch")
    for step in range(6):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, step, 1.0 if h != "h2" else 3.5)
    assert det.stragglers() == ["h2"]
    assert det.action_for("h2") == "skip_batch"
    assert det.action_for("h0") == "none"
    rep = det.report()
    assert rep["h2"]["median_s"] > 3 and rep["stragglers"] == ["h2"]


# ---------------------------------------------------------------------------
# pod drain planning (the scan-fabric death path, in isolation)
# ---------------------------------------------------------------------------

def _ring(nodes):
    from repro.distributed.sharding import HashRing

    return HashRing(nodes)


def test_plan_pod_drain_reassigns_only_dead_arcs():
    from repro.distributed.sharding import rg_key

    ring = _ring(["pod0", "pod1", "pod2"])
    keys = [rg_key("/lake/l.lake", rg) for rg in range(64)]
    before = ring.owners(keys)
    owned = [k for k, o in before.items() if o == "pod1"]
    plan = plan_pod_drain("pod1", ring, owned, in_flight=[7, 9])
    assert plan.dead == "pod1"
    assert plan.survivors == ["pod0", "pod2"]
    assert plan.replay == [7, 9]
    # every dead-owned key re-homed to a survivor...
    assert set(plan.reassigned) == set(owned)
    assert all(o in ("pod0", "pod2") for o in plan.reassigned.values())
    # ...and the ring was mutated minimally: survivors keep their arcs
    after = ring.owners(keys)
    for k in keys:
        if before[k] != "pod1":
            assert after[k] == before[k], k
        else:
            assert after[k] == plan.reassigned[k]


def test_plan_pod_drain_last_pod_raises():
    import pytest

    ring = _ring(["pod0"])
    with pytest.raises(RuntimeError):
        plan_pod_drain("pod0", ring, [], [])


def test_plan_pod_drain_empty_workload():
    plan = plan_pod_drain("pod0", _ring(["pod0", "pod1"]), [], [])
    assert plan.reassigned == {} and plan.replay == []
    assert plan.survivors == ["pod1"]
