"""Storage fault plane (DESIGN.md §17): deterministic fault schedules,
per-page checksums end to end (writer stamp -> reader meta -> engine
verify -> quarantine), the retry/backoff/timeout/hedge loop with honest
WFQ billing, the per-target circuit breaker with typed Overloaded
load-shed, and the peer-fetch dead-sibling regression."""

import dataclasses

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan, tpch
from repro.datapath import (
    BlockStore,
    CircuitBreaker,
    DatapathService,
    FaultPlan,
    FetchFailed,
    Overloaded,
    PeerFetcher,
    Quarantined,
    RetryPolicy,
)
from repro.datapath.faults import FaultInjector, _flip_byte, _truncate
from repro.lakeformat.integrity import (
    CorruptPageError,
    page_checksum,
    verify_page,
)
from repro.lakeformat.reader import LakeReader

RG_ROWS = 2048
TICK_BYTES = 1 << 14


@pytest.fixture(scope="module")
def tables(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_faults")
    return tpch.write_tables(str(d), sf=0.05, seed=0,
                             row_group_size=RG_ROWS)


@pytest.fixture(scope="module")
def lineitem(tables):
    return LakeReader(tables["lineitem"])


PLAN = ScanPlan("lineitem", ["l_quantity", "l_extendedprice"],
                Cmp("l_quantity", "le", 25))  # unprunable: every rg survives


@pytest.fixture(scope="module")
def direct(lineitem):
    return DatapathEngine(backend="ref").scan(lineitem, PLAN)


def _assert_identical(got, want):
    assert int(got.count) == int(want.count)
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    for name in want.columns:
        assert np.array_equal(
            np.asarray(got.columns[name]), np.asarray(want.columns[name])
        ), name


def _service(**kw):
    kw.setdefault("engine",
                  DatapathEngine(backend="ref", cache=BlockCache(1 << 30)))
    kw.setdefault("tick_bytes", TICK_BYTES)
    return DatapathService(**kw)


# ---------------------------------------------------------------------------
# FaultPlan: a deterministic schedule, not a random stream
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic_and_path_stable():
    p = FaultPlan(seed=7, transient_rate=0.3, corrupt_rate=0.2,
                  spike_rate=0.5, spike_s=1e-3)
    a = [(p.transient("/a/lineitem.lake", rg, 0),
          p.corrupt("/a/lineitem.lake", rg, "c", 0),
          p.spike("/a/lineitem.lake", rg, 0)) for rg in range(64)]
    # same schedule when re-evaluated AND when the table moves directories
    b = [(p.transient("/elsewhere/lineitem.lake", rg, 0),
          p.corrupt("/elsewhere/lineitem.lake", rg, "c", 0),
          p.spike("/elsewhere/lineitem.lake", rg, 0)) for rg in range(64)]
    assert a == b
    assert any(t for t, _, _ in a) and not all(t for t, _, _ in a)
    # a different seed draws a different schedule
    q = dataclasses.replace(p, seed=8)
    assert a != [(q.transient("/a/lineitem.lake", rg, 0),
                  q.corrupt("/a/lineitem.lake", rg, "c", 0),
                  q.spike("/a/lineitem.lake", rg, 0)) for rg in range(64)]


def test_fault_plan_attempt_axis_and_fail_forever():
    p = FaultPlan(seed=1, transient_rate=0.5)
    rows = [rg for rg in range(200) if p.transient("t", rg, 0)]
    # by default the fault is per-attempt: some selected coordinates clear
    assert any(not p.transient("t", rg, 1) for rg in rows)
    forever = dataclasses.replace(p, fail_forever=True)
    hit = [rg for rg in range(200) if forever.transient("t", rg, 0)]
    assert all(forever.transient("t", rg, a) for rg in hit for a in range(6))


def test_retry_policy_backoff_is_exponential():
    pol = RetryPolicy(backoff_base_s=1e-4, backoff_mult=2.0)
    assert pol.backoff(0) == 0.0
    assert pol.backoff(1) == pytest.approx(1e-4)
    assert pol.backoff(3) == pytest.approx(4e-4)


# ---------------------------------------------------------------------------
# page integrity: stamp -> expose -> verify -> quarantine
# ---------------------------------------------------------------------------

def test_writer_stamps_checksums_and_reader_exposes_them(lineitem):
    r = lineitem
    for name in PLAN.columns:
        ck = r.page_checksum_meta(0, name)
        assert isinstance(ck, int) and 0 <= ck <= 0xFFFFFFFF
        col = r.read_encoded(0, [name])[name]
        assert page_checksum(col) == ck
        assert verify_page(col, ck)
    assert r.page_checksum_meta(0, "no_such_column") is None


def test_checksum_catches_flip_and_truncation(lineitem):
    col = lineitem.read_encoded(0, ["l_quantity"])["l_quantity"]
    ck = page_checksum(col)
    assert not verify_page(_flip_byte(col), ck)
    assert not verify_page(_truncate(col), ck)
    # legacy footer (no checksum) verifies trivially — unverified, not failed
    assert verify_page(_flip_byte(col), None)


def test_legacy_footer_without_checksums_still_scans(tables, direct):
    """Files written before the integrity stamp scan unverified."""
    r = LakeReader(tables["lineitem"])
    for rg in r.footer["row_groups"]:
        for cmeta in rg["columns"].values():
            cmeta.pop("checksum", None)
    assert r.page_checksum_meta(0, "l_quantity") is None
    eng = DatapathEngine(backend="ref")
    _assert_identical(eng.scan(r, PLAN), direct)
    svc = _service(fault_plan=FaultPlan())  # injector on, nothing to verify
    _assert_identical(svc.result(svc.submit("t0", r, PLAN)), direct)
    assert svc.telemetry.counters["unverified_pages"] > 0


def test_engine_detects_doctored_checksum_and_quarantines(tables):
    """A page whose bytes do not match the footer checksum never reaches a
    decode kernel: the bare engine raises typed CorruptPageError and the
    page is quarantined in the store."""
    r = LakeReader(tables["lineitem"])
    r.footer["row_groups"][0]["columns"]["l_quantity"]["checksum"] ^= 0x1
    svc = _service()  # no injector: the engine's own verify path
    with pytest.raises(CorruptPageError):
        svc.result(svc.submit("t0", r, PLAN))
    assert svc.store.stats()["quarantines"] >= 1


def test_blockstore_quarantine_and_absolving_put():
    st = BlockStore(1 << 20)
    st.put(("page", "t", 0, "c"), np.zeros(16), tier="encoded")
    st.quarantine(("page", "t", 0, "c"))
    assert ("page", "t", 0, "c") not in st
    assert st.get(("page", "t", 0, "c"), tier="encoded") is None
    s = st.stats()
    assert s["quarantines"] == 1 and s["quarantined_live"] == 1
    # a fresh put IS the verified re-fetch: the mark is absolved
    st.put(("page", "t", 0, "c"), np.zeros(16), tier="encoded")
    assert st.stats()["quarantined_live"] == 0
    assert st.get(("page", "t", 0, "c"), tier="encoded") is not None


# ---------------------------------------------------------------------------
# injector: recoverable faults recover bit-identically; terminal faults
# surface typed
# ---------------------------------------------------------------------------

def test_recoverable_faults_scan_bit_identical(lineitem, direct):
    svc = _service(
        fault_plan=FaultPlan(seed=3, transient_rate=0.15, corrupt_rate=0.08,
                             short_read_rate=0.05, spike_rate=0.3,
                             spike_s=1e-3),
        retry_policy=RetryPolicy(max_attempts=10),
    )
    _assert_identical(svc.result(svc.submit("t0", lineitem, PLAN)), direct)
    f = svc.telemetry.snapshot()["faults"]
    assert f["transient_errors"] > 0
    assert f["corrupt_detected"] == f["corrupt_injected"] + f["short_reads"]
    assert f["quarantined_pages"] == f["corrupt_detected"]
    assert f["retry_successes"] > 0
    assert f["retries_exhausted"] == 0


def test_corrupt_page_refetched_never_decoded(lineitem, direct):
    """Every injected corruption is checksum-detected, quarantined, and the
    page re-fetched — corrupt bytes never reach a decode kernel, so the
    result is bit-identical."""
    svc = _service(fault_plan=FaultPlan(seed=11, corrupt_rate=0.3),
                   retry_policy=RetryPolicy(max_attempts=10))
    _assert_identical(svc.result(svc.submit("t0", lineitem, PLAN)), direct)
    f = svc.telemetry.snapshot()["faults"]
    assert f["corrupt_injected"] > 0
    assert f["corrupt_detected"] == f["corrupt_injected"]
    assert svc.store.stats()["quarantines"] == f["quarantined_pages"]


def test_exhausted_transient_raises_typed_fetch_failed(lineitem):
    svc = _service(fault_plan=FaultPlan(seed=0, transient_rate=1.0,
                                        fail_forever=True),
                   retry_policy=RetryPolicy(max_attempts=3))
    with pytest.raises(FetchFailed):
        svc.result(svc.submit("t0", lineitem, PLAN))
    assert svc.telemetry.counters["fetch_retries_exhausted"] >= 1


def test_exhausted_corruption_raises_typed_quarantined(lineitem):
    svc = _service(fault_plan=FaultPlan(seed=0, corrupt_rate=1.0,
                                        fail_forever=True),
                   retry_policy=RetryPolicy(max_attempts=3))
    with pytest.raises(Quarantined):
        svc.result(svc.submit("t0", lineitem, PLAN))
    assert svc.store.stats()["quarantines"] >= 1


def test_timeout_retries_and_bills_the_full_wait(lineitem, direct):
    """A spiked attempt past timeout_s is billed the whole timeout and
    retried; the spike clears next attempt, so the scan completes."""
    svc = _service(
        fault_plan=FaultPlan(seed=5, spike_rate=0.4, spike_s=10.0),
        retry_policy=RetryPolicy(max_attempts=6, timeout_s=1.0),
    )
    _assert_identical(svc.result(svc.submit("t0", lineitem, PLAN)), direct)
    snap = svc.telemetry.snapshot()
    f = snap["faults"]
    assert f["fetch_timeouts"] > 0
    assert f["fault_seconds"]["timeout"] == pytest.approx(
        f["fetch_timeouts"] * 1.0)
    assert f["tenant_fault_seconds"]["t0"] >= f["fault_seconds"]["timeout"]


def test_hedged_read_caps_the_straggler_tail(lineitem, direct):
    """With a hedge threshold below the spike, the slice completes at the
    hedge's finish — the tail seconds saved are visible in telemetry and
    the billed wait is bounded by hedge_after_s per fetch."""
    svc = _service(
        fault_plan=FaultPlan(seed=9, spike_rate=1.0, spike_s=0.5),
        retry_policy=RetryPolicy(hedge_after_s=1e-3),
    )
    _assert_identical(svc.result(svc.submit("t0", lineitem, PLAN)), direct)
    f = svc.telemetry.snapshot()["faults"]
    assert f["hedged_fetches"] > 0 and f["hedge_wins"] > 0
    assert f["fault_seconds"]["hedge_saved"] > 0
    # every win pays <= hedge_after_s of extra wait instead of the spike
    assert (f["tenant_fault_seconds"]["t0"]
            <= f["hedged_fetches"] * (1e-3 + 1e-9))


def test_straggler_pod_term_applies_to_every_fetch(lineitem, direct):
    plan = FaultPlan(straggler_pods={"pod0": 2e-3})
    assert plan.straggle("pod0") == 2e-3 and plan.straggle("pod1") == 0.0
    svc = _service(fault_plan=plan)
    _assert_identical(svc.result(svc.submit("t0", lineitem, PLAN)), direct)
    assert svc.telemetry.snapshot()["faults"]["tenant_fault_seconds"]["t0"] > 0


def test_fault_seconds_reconciled_into_wfq_vtime(lineitem, direct):
    """The honesty invariant survives the fault plane: per tenant,
    sched + recon == actual, where actual now includes fault waits."""
    svc = _service(
        fault_plan=FaultPlan(seed=3, transient_rate=0.3, spike_rate=0.5,
                             spike_s=2e-3),
        retry_policy=RetryPolicy(max_attempts=6),
    )
    for t in ("a", "b"):
        _assert_identical(svc.result(svc.submit(t, lineitem, PLAN)), direct)
    snap = svc.telemetry.snapshot()
    assert snap["counters"]["fault_wait_seconds"] > 0
    for t, row in snap["cost"].items():
        assert row["est_s"] + row["recon_s"] == pytest.approx(
            row["actual_s"], abs=1e-9), t
        assert row["fault_s"] >= 0.0


# ---------------------------------------------------------------------------
# circuit breaker: state machine, degraded mode, typed load-shed
# ---------------------------------------------------------------------------

def test_breaker_state_machine():
    br = CircuitBreaker(fail_threshold=3, cooldown_ticks=5)
    t = "table.lake"
    assert br.state(t) == "closed"
    assert not br.record_failure(t, 0)
    assert not br.record_failure(t, 0)
    assert br.record_failure(t, 0)  # third consecutive failure trips
    assert br.state(t) == "open" and br.any_open()
    assert br.admit(t, 1) == "degraded"  # cooling down
    assert br.admit(t, 9) == "probe"  # cooldown elapsed -> half-open
    assert br.state(t) == "half-open"
    assert br.record_failure(t, 9)  # probe failure reopens immediately
    assert br.state(t) == "open"
    assert br.admit(t, 20) == "probe"
    br.record_success(t, 20)  # probe success closes
    assert br.state(t) == "closed" and not br.any_open()
    assert br.trips == 2 and br.probes == 2
    # success resets the consecutive-failure counter
    br.record_failure(t, 21)
    br.record_success(t, 21)
    assert not br.record_failure(t, 22) and br.state(t) == "closed"


def test_breaker_sheds_with_typed_overloaded_when_queue_near_full(lineitem):
    svc = _service(fault_plan=FaultPlan(transient_rate=1.0,
                                        fail_forever=True),
                   retry_policy=RetryPolicy(max_attempts=5),
                   max_queue_depth=4)
    with pytest.raises(FetchFailed):
        svc.result(svc.submit("t0", lineitem, PLAN))  # trips the breaker
    assert svc.breaker_open()
    for _ in range(3):  # park requests; queue_frac reaches 3/4
        svc.submit("t0", lineitem, PLAN)
    with pytest.raises(Overloaded):
        svc.submit("t0", lineitem, PLAN)
    assert svc.telemetry.counters["rejected_overloaded"] == 1
    assert svc.telemetry.snapshot()["faults"]["breaker_trips"] >= 1


def test_breaker_degrades_to_raw_then_probes_closed(lineitem, direct):
    """While open (queue healthy) requests still run — in degraded raw
    mode; after the cooldown the half-open probe's success closes the
    breaker and normal mode choice resumes."""
    svc = _service(fault_plan=FaultPlan(transient_rate=1.0,
                                        fail_forever=True),
                   retry_policy=RetryPolicy(max_attempts=5))
    with pytest.raises(FetchFailed):
        svc.result(svc.submit("t0", lineitem, PLAN))
    assert svc.breaker_open()
    svc.install_faults(FaultPlan())  # storage "recovers"; breaker remembers
    _assert_identical(svc.result(svc.submit("t0", lineitem, PLAN)), direct)
    c = svc.telemetry.counters
    assert c["breaker_degraded_admits"] >= 1
    assert c["breaker_degraded_dispatches"] >= 1
    # drive ticks past the cooldown so the next admission is the probe
    for _ in range(CircuitBreaker().cooldown_ticks + 1):
        svc.tick()
    _assert_identical(svc.result(svc.submit("t0", lineitem, PLAN)), direct)
    assert c["breaker_probes"] >= 1
    assert not svc.breaker_open()


# ---------------------------------------------------------------------------
# satellite: peer fetch vs a sibling that died after the liveness check
# ---------------------------------------------------------------------------

def test_peer_fetch_dead_sibling_falls_back_to_storage():
    """A sibling marked dead between the fabric's liveness check and the
    peek must read as a miss (fall back to storage), never propagate."""
    local, remote = BlockStore(1 << 20), BlockStore(1 << 20)
    key = ("page", "t.lake", 0, "c")
    remote.put(key, np.zeros(64), tier="encoded")
    pf = PeerFetcher("pod0", lambda: [("pod1", remote)])
    assert pf.fetch(key, into=local) is not None  # healthy sibling serves
    remote.dead = True
    with pytest.raises(ConnectionError):
        remote.peek(key)
    local2 = BlockStore(1 << 20)
    assert pf.fetch(key, into=local2) is None  # dead sibling -> miss
    assert local2.peer_errors == 1


def test_peer_fetch_membership_callback_failure_is_a_miss():
    local = BlockStore(1 << 20)

    def exploding_peers():
        raise ConnectionError("membership view lost")

    pf = PeerFetcher("pod0", exploding_peers)
    assert pf.fetch(("page", "t", 0, "c"), into=local) is None
    assert local.peer_errors == 1


# ---------------------------------------------------------------------------
# satellite: calibration without link entries warns once, visibly
# ---------------------------------------------------------------------------

def test_nominal_link_surfaces_in_snapshot(lineitem):
    svc = _service()
    snap = svc.telemetry.snapshot()
    assert snap["costmodel"]["nominal_link"] is True
    assert snap["costmodel"]["link_source"] == "nominal"
    assert "nominal_link" in snap["warnings"]
    assert svc.telemetry.counters["warnings"] == 1  # once, not per lookup
    svc.telemetry.note_costmodel(svc.cost_model)
    assert svc.telemetry.counters["warnings"] == 1


def test_calibrated_link_source_round_trips(tmp_path):
    from repro.datapath.costmodel import CostModel

    cm = CostModel(link_source="calibrated")
    p = str(tmp_path / "cal.json")
    cm.save(p)
    back = CostModel.load(p, backend=cm.backend)
    assert back.link_source == "calibrated"
    from repro.datapath import Telemetry

    t = Telemetry()
    t.note_costmodel(back)
    snap = t.snapshot()
    assert snap["costmodel"]["nominal_link"] is False
    assert "nominal_link" not in snap["warnings"]
