"""hlo_analysis: trip-aware FLOPs / collective-bytes accounting vs ground
truth (the calibration that backs §Roofline)."""

from tests.util import run_with_devices


def test_scan_and_nested_and_collectives():
    out = run_with_devices(
        """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from hlo_analysis import analyze_compiled
from repro.distributed.compat import make_mesh, use_mesh

M=K=N=256
def g(a, bs):
    def body(x, w): return jnp.tanh(x @ w), None
    return jax.lax.scan(body, a, bs)[0]
c = jax.jit(g).lower(jax.ShapeDtypeStruct((M,K),jnp.float32),
                     jax.ShapeDtypeStruct((12,K,N),jnp.float32)).compile()
r = analyze_compiled(c)
assert abs(r.flops/(12*2*M*K*N) - 1) < 1e-6, r.flops
# raw XLA undercounts scans (body counted once): our analyzer must not
ca = c.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # older jax: per-device list
assert ca["flops"] < r.flops / 5

def h(a, ws):
    def outer(x, wrow):
        def inner(y, w): return y @ w, None
        return jax.lax.scan(inner, x, wrow)[0], None
    return jax.lax.scan(outer, a, ws)[0]
c = jax.jit(h).lower(jax.ShapeDtypeStruct((M,K),jnp.float32),
                     jax.ShapeDtypeStruct((3,4,K,N),jnp.float32)).compile()
assert abs(analyze_compiled(c).flops/(12*2*M*K*N) - 1) < 1e-6

mesh = make_mesh((8,), ("x",))
def f4(a, bs):
    def body(x, w):
        return jax.lax.with_sharding_constraint(x @ w, NamedSharding(mesh, P())), None
    return jax.lax.scan(body, a, bs)[0]
with use_mesh(mesh):
    sa = jax.ShapeDtypeStruct((M,K), jnp.float32, sharding=NamedSharding(mesh, P(None,"x")))
    sb = jax.ShapeDtypeStruct((5,K,N), jnp.float32, sharding=NamedSharding(mesh, P(None,"x",None)))
    c = jax.jit(f4).lower(sa,sb).compile()
    r = analyze_compiled(c)
    assert abs(r.flops/(5*2*M*K*N/8) - 1) < 1e-6  # per-device
    assert abs(r.collective_bytes/(5*M*N*4*2) - 1) < 1e-6  # all-reduce 2x, x5 trips
    assert "all-reduce" in r.collective_by_kind
print("HLO_OK")
""",
        n_devices=8,
    )
    assert "HLO_OK" in out
