"""Rewritten decode cores vs kernels/ref.py oracles: randomized parity
sweeps (seeded always; hypothesis-driven when available) across code
widths k, int/float dtypes, ragged block counts sitting on the two-size
ladder's bucket boundaries — plus the dispatch-count and pad-waste
invariants that make ladder bucketing strictly no worse than pow2.

Bit-identity is the contract: the RLE rank lookup gathers the single
owning run, the DELTA carry ladder reassociates int32 adds (associative
mod 2^32), and the DICT select mux is pure selection — so every compare
here is array_equal, never allclose.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.lakeformat import encodings as E
from repro.lakeformat.encodings import (
    LANES, PACK_BLOCK, RLE_OUT_BLOCK, RLE_WINDOW,
)

BACKENDS = ("ref", "pallas")

# block counts straddling the two-size ladder's bucket boundaries
# {1,2,3,4,6,8,12,16,24,32}: each boundary, one past it, and ragged
# mid-octave counts
LADDER_NS = (1, 2, 3, 4, 5, 6, 7, 8, 9, 12, 13, 16, 17, 24, 25, 32, 33)


# ---------------------------------------------------------------------------
# generators (pure, seeded — shared by the fixed sweep and hypothesis)
# ---------------------------------------------------------------------------

def _rand_rle_blocks(rng, nb: int, float_vals: bool):
    """Writer-shaped RLE pages: per block, r <= RLE_WINDOW runs whose ends
    are strictly increasing cut points finishing at RLE_OUT_BLOCK, padding
    repeating the final value with end == RLE_OUT_BLOCK."""
    dtype = np.float32 if float_vals else np.int32
    vals = np.zeros((nb, RLE_WINDOW), dtype=dtype)
    ends = np.zeros((nb, RLE_WINDOW), dtype=np.int32)
    for b in range(nb):
        r = int(rng.integers(1, RLE_WINDOW + 1))
        cuts = np.sort(rng.choice(np.arange(1, RLE_OUT_BLOCK), size=r - 1,
                                  replace=False)) if r > 1 else np.empty(0, np.int64)
        e = np.concatenate([cuts, [RLE_OUT_BLOCK]]).astype(np.int32)
        v = (rng.standard_normal(r).astype(np.float32) if float_vals
             else rng.integers(-1000, 1000, r).astype(np.int32))
        vals[b, :r], ends[b, :r] = v, e
        vals[b, r:], ends[b, r:] = v[-1], RLE_OUT_BLOCK
    return vals, ends


def _check_rle(vals: np.ndarray, ends: np.ndarray):
    nb = vals.shape[0]
    want = E.rle_decode_np({"rle_values": vals, "rle_ends": ends},
                           nb * RLE_OUT_BLOCK).reshape(nb, RLE_OUT_BLOCK)
    for be in BACKENDS:
        got = np.asarray(ops.rle_decode_batch(vals, ends, backend=be))
        assert got.dtype == want.dtype, be
        assert np.array_equal(got, want), be
    # single-call path (jitted ref wrapper)
    one = np.asarray(ops.rle_decode(jnp.asarray(vals[:1]), jnp.asarray(ends[:1]),
                                    RLE_OUT_BLOCK))
    assert np.array_equal(one, want.reshape(-1)[:RLE_OUT_BLOCK])


def _rand_delta(rng, nb: int, k: int):
    """Random k-bit zigzag deltas + int32 bases (delta[0] need not be 0 —
    the decoder must not rely on the writer's convention)."""
    zz = rng.integers(0, np.uint64(1) << np.uint64(k), size=nb * PACK_BLOCK,
                      dtype=np.uint64)
    packed = E.bitpack_encode(zz, k)
    bases = rng.integers(-(1 << 20), 1 << 20, nb).astype(np.int64)
    deltas = E._unzigzag(zz).reshape(nb, PACK_BLOCK)
    want = (np.cumsum(deltas, axis=1, dtype=np.int64)
            + bases[:, None]).astype(np.int32).reshape(nb, -1)
    return packed, bases, want


def _check_delta(packed: np.ndarray, bases: np.ndarray, k: int, want: np.ndarray):
    for be in BACKENDS:
        got = np.asarray(ops.delta_decode_batch(packed, bases, k, backend=be))
        assert np.array_equal(got, want), (be, k)


def _rand_dict(rng, nb: int, k: int, float_vals: bool):
    """nb blocks of k-bit codes mapped onto P pages with per-page
    dictionaries; every code < the common dict size D <= 2^k."""
    D = int(rng.integers(1, min(1 << k, 4096) + 1))
    codes = rng.integers(0, D, size=nb * PACK_BLOCK, dtype=np.uint64)
    packed = E.bitpack_encode(codes, k)
    P = int(rng.integers(1, nb + 1))
    page = rng.integers(0, P, nb).astype(np.int32)
    dicts = (rng.standard_normal((P, D)).astype(np.float32) if float_vals
             else rng.integers(-10000, 10000, (P, D)).astype(np.int32))
    sizes = np.full(P, D, np.int32)
    want = dicts[page][
        np.arange(nb)[:, None], codes.reshape(nb, PACK_BLOCK).astype(np.int64)
    ].reshape(nb, E.SUBLANES, LANES)
    return packed, dicts, sizes, page, want


def _check_dict(packed, dicts, sizes, page, k: int, want):
    for be in BACKENDS:
        got = np.asarray(
            ops.dict_decode_batch(packed, dicts, sizes, page, k, backend=be))
        assert got.dtype == want.dtype, (be, k)
        assert np.array_equal(got, want), (be, k)


# ---------------------------------------------------------------------------
# fixed seeded sweeps (always run — hypothesis is optional in this image)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("float_vals", [False, True], ids=["int32", "float32"])
def test_rle_parity_across_ladder_boundaries(float_vals):
    rng = np.random.default_rng(0 if float_vals else 1)
    for nb in LADDER_NS:
        _check_rle(*_rand_rle_blocks(rng, nb, float_vals))


def test_delta_parity_across_k_and_ladder_boundaries():
    # writer caps delta widths at 30 bits (zigzag of int deltas)
    rng = np.random.default_rng(2)
    for i, k in enumerate(range(1, 31)):
        nb = LADDER_NS[i % len(LADDER_NS)]
        packed, bases, want = _rand_delta(rng, nb, k)
        _check_delta(packed, bases, k, want)


@pytest.mark.parametrize("float_vals", [False, True], ids=["int32", "float32"])
def test_dict_parity_across_k_and_ladder_boundaries(float_vals):
    # k sweeps the full code width range incl. the select-mux regime
    # (k <= SELECT_MAX_K), one-hot, and gather fallbacks
    rng = np.random.default_rng(3 if float_vals else 4)
    for i, k in enumerate(range(1, 33)):
        nb = LADDER_NS[i % len(LADDER_NS)]
        packed, dicts, sizes, page, want = _rand_dict(rng, nb, k, float_vals)
        _check_dict(packed, dicts, sizes, page, k, want)


def test_dict_single_call_select_mux_matches_oracle():
    """The arithmetic-select path (k <= SELECT_MAX_K) vs the take oracle,
    int and float dictionaries, including clip semantics for codes that
    are representable in k bits but >= the true dict size."""
    from repro.kernels.dict_decode import SELECT_MAX_K

    rng = np.random.default_rng(5)
    for k in range(1, SELECT_MAX_K + 1):
        for float_vals in (False, True):
            D = int(rng.integers(1, (1 << k) + 1))
            # codes deliberately cover the full k-bit range: codes >= D
            # must clip to the last entry on every path
            codes = rng.integers(0, 1 << k, size=PACK_BLOCK, dtype=np.uint64)
            packed = E.bitpack_encode(codes, k)
            d = (rng.standard_normal(D).astype(np.float32) if float_vals
                 else rng.integers(-100, 100, D).astype(np.int32))
            want = d[np.minimum(codes.astype(np.int64), D - 1)].reshape(
                E.SUBLANES, LANES)
            for be in BACKENDS:
                got = np.asarray(ops.dict_decode(
                    jnp.asarray(packed), jnp.asarray(d), k, PACK_BLOCK,
                    backend=be)).reshape(E.SUBLANES, LANES)
                assert np.array_equal(got, want), (be, k, float_vals)


def test_bitunpack_parity_full_k_range():
    rng = np.random.default_rng(6)
    for k in range(1, 33):
        v = rng.integers(0, np.uint64(1) << np.uint64(k), size=2 * PACK_BLOCK,
                         dtype=np.uint64)
        packed = E.bitpack_encode(v, k)
        want = np.asarray(ref.bitunpack(jnp.asarray(packed), k))
        for be in BACKENDS:
            got = np.asarray(ops.bitunpack_batch(packed, k, backend=be))
            assert np.array_equal(got, want), (be, k)


# ---------------------------------------------------------------------------
# ladder vs pow2: dispatch-count and pad-waste invariants
# ---------------------------------------------------------------------------

def test_ladder_launches_never_exceed_pow2():
    """Each batch call is exactly ONE dispatch in either bucketing mode,
    so over any workload the ladder's launch count equals (never exceeds)
    pow2's — the ladder buys its smaller pad waste for free."""
    rng = np.random.default_rng(7)
    workload = [int(rng.integers(1, 40)) for _ in range(12)]
    counts = {}
    for mode in ("ladder", "pow2"):
        prev = ops.set_bucket_mode(mode)
        try:
            ops.reset_dispatch_count()
            for nb in workload:
                vals, ends = _rand_rle_blocks(rng, nb, False)
                ops.rle_decode_batch(vals, ends, backend="ref")
            counts[mode] = ops.dispatch_count()
        finally:
            ops.set_bucket_mode(prev)
    assert counts["ladder"] == counts["pow2"] == len(workload)


def test_ladder_pad_waste_bounded_and_below_pow2():
    for n in range(1, 4097):
        lad = ops.bucket_blocks(n, mode="ladder")
        p2 = ops.bucket_blocks(n, mode="pow2")
        assert n <= lad <= p2, n              # never pads past pow2
        assert lad - n <= n, n                # waste bounded by ~50%
        assert (p2 & (p2 - 1)) == 0 and p2 >= n
    # distinct jit trace shapes per octave stay bounded: two sizes
    sizes = {ops.bucket_blocks(n) for n in range(33, 65)}
    assert sizes == {48, 64}


# ---------------------------------------------------------------------------
# hypothesis sweep (optional dependency — skipped when absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=25)
    @given(seed=st_.integers(0, 2**32 - 1),
           nb=st_.sampled_from(LADDER_NS),
           float_vals=st_.booleans())
    def test_rle_parity_hypothesis(seed, nb, float_vals):
        rng = np.random.default_rng(seed)
        _check_rle(*_rand_rle_blocks(rng, nb, float_vals))

    @settings(deadline=None, max_examples=25)
    @given(seed=st_.integers(0, 2**32 - 1),
           nb=st_.sampled_from(LADDER_NS),
           k=st_.integers(1, 30))
    def test_delta_parity_hypothesis(seed, nb, k):
        rng = np.random.default_rng(seed)
        packed, bases, want = _rand_delta(rng, nb, k)
        _check_delta(packed, bases, k, want)

    @settings(deadline=None, max_examples=25)
    @given(seed=st_.integers(0, 2**32 - 1),
           nb=st_.sampled_from(LADDER_NS),
           k=st_.integers(1, 32),
           float_vals=st_.booleans())
    def test_dict_parity_hypothesis(seed, nb, k, float_vals):
        rng = np.random.default_rng(seed)
        packed, dicts, sizes, page, want = _rand_dict(rng, nb, k, float_vals)
        _check_dict(packed, dicts, sizes, page, k, want)
