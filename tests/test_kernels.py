"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref vs host
oracle, swept over shapes, bit widths and dtypes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.lakeformat import encodings as E


@pytest.mark.parametrize("k", [1, 5, 8, 13, 18, 24, 32])
@pytest.mark.parametrize("n", [4096, 3 * 4096 + 100])
def test_bitunpack_backends(k, n):
    rng = np.random.default_rng(k * 1000 + n)
    hi = min((1 << k), 2**31)
    v = rng.integers(0, hi, size=n, dtype=np.uint64)
    p = jnp.asarray(E.bitpack_encode(v, k))
    host = E.bitpack_decode_np(np.asarray(p), k, n).astype(np.int32)
    for be in ("ref", "pallas"):
        got = np.asarray(ops.bitunpack(p, k, n, backend=be))
        np.testing.assert_array_equal(got, host, err_msg=f"backend={be} k={k}")


@pytest.mark.parametrize("dtype", ["int32", "float32"])
def test_dict_decode_backends(dtype):
    rng = np.random.default_rng(0)
    base = np.array([5, 900, 17, 123456, -44], dtype=np.int64)
    if dtype == "float32":
        base = (base / 7).astype(np.float32)
    v = rng.choice(base, size=9000)
    b = E.dict_encode(v)
    k = int(b.pop("_k")[0])
    host = E.dict_decode_np(b, k, len(v))
    d = b["dictionary"]
    d = jnp.asarray(d.astype(np.int32) if d.dtype.kind in "iu" else d)
    for be in ("ref", "pallas"):
        got = np.asarray(ops.dict_decode(jnp.asarray(b["packed"]), d, k, len(v), backend=be))
        np.testing.assert_array_equal(got, host.astype(got.dtype), err_msg=be)


@pytest.mark.parametrize("dtype", ["int32", "float32"])
def test_rle_decode_backends(dtype):
    rng = np.random.default_rng(1)
    v = np.repeat(rng.integers(0, 2000, 150), rng.integers(5, 200, 150))
    v = v.astype(dtype)
    b = E.rle_encode(v)
    host = E.rle_decode_np(b, len(v))
    for be in ("ref", "pallas"):
        got = np.asarray(ops.rle_decode(jnp.asarray(b["rle_values"]), jnp.asarray(b["rle_ends"]), len(v), backend=be))
        np.testing.assert_array_equal(got, host, err_msg=be)


def test_delta_decode_backends():
    rng = np.random.default_rng(2)
    v = np.cumsum(rng.integers(-5, 30, size=2 * 4096 + 99)).astype(np.int64)
    b = E.delta_encode(v)
    k = int(b.pop("_k")[0])
    host = E.delta_decode_np(b, k, len(v)).astype(np.int32)
    bases = jnp.asarray(b["bases"].astype(np.int32))
    for be in ("ref", "pallas"):
        got = np.asarray(ops.delta_decode(jnp.asarray(b["packed"]), bases, k, len(v), backend=be))
        np.testing.assert_array_equal(got, host, err_msg=be)


@pytest.mark.parametrize("dtype,hi", [("int32", 2**30), ("float32", 1)])
def test_filter_compact_backends(dtype, hi):
    rng = np.random.default_rng(3)
    if dtype == "int32":
        v = rng.integers(-hi, hi, size=(5, 1024)).astype(np.int32)
    else:
        v = rng.standard_normal((5, 1024)).astype(np.float32)
    m = rng.random((5, 1024)) < 0.37
    for be in ("ref", "pallas"):
        out, cnt = ops.filter_compact(jnp.asarray(v), jnp.asarray(m), backend=be)
        out, cnt = np.asarray(out), np.asarray(cnt)
        assert np.array_equal(cnt, m.sum(1))
        for i in range(5):
            np.testing.assert_array_equal(out[i, : cnt[i]], v[i][m[i]], err_msg=be)


def test_bloom_no_false_negatives():
    rng = np.random.default_rng(4)
    keys = rng.integers(0, 10**7, size=800).astype(np.int32)
    bits = ops.bloom_build(jnp.asarray(keys), 1 << 14)
    probe = rng.integers(0, 10**7, size=(4, 1024)).astype(np.int32)
    probe[0, :800] = keys
    for be in ("ref", "pallas"):
        got = np.asarray(ops.bloom_probe(jnp.asarray(probe), bits, backend=be))
        assert got[0, :800].all(), be  # never a false negative
        fp = got[~np.isin(probe, keys)].mean()
        assert fp < 0.05, (be, fp)
    r1 = np.asarray(ops.bloom_probe(jnp.asarray(probe), bits, backend="ref"))
    r2 = np.asarray(ops.bloom_probe(jnp.asarray(probe), bits, backend="pallas"))
    np.testing.assert_array_equal(r1, r2)


@pytest.mark.parametrize("k,lo,hi", [(13, 1000, 3000), (18, 0, 0), (8, 250, 255)])
def test_fused_scan_backends(k, lo, hi):
    rng = np.random.default_rng(k)
    v = rng.integers(0, 1 << k, size=2 * 4096 + 17, dtype=np.uint64)
    p = jnp.asarray(E.bitpack_encode(v, k))
    exp = (v >= lo) & (v <= hi)
    for be in ("ref", "pallas"):
        mask, cnt = ops.fused_scan(p, k, lo, hi, backend=be)
        got = np.asarray(mask).reshape(-1)[: len(v)]
        np.testing.assert_array_equal(got, exp, err_msg=be)
        assert int(np.asarray(cnt).sum()) >= exp.sum()  # padding rows only add


@pytest.mark.parametrize(
    "B,H,Hkv,S,D,win",
    [(2, 4, 2, 256, 64, None), (1, 8, 8, 256, 128, None), (1, 4, 1, 512, 64, 128),
     (1, 2, 2, 256, 256, None)],
)
def test_flash_attention_vs_ref(B, H, Hkv, S, D, win):
    rng = np.random.default_rng(B + H + S)
    q = jnp.asarray(rng.standard_normal((B, H, S, D)).astype(np.float32) * 0.3)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32) * 0.3)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32) * 0.3)
    o_ref = ops.flash_attention(q, k, v, causal=True, window=win, backend="ref")
    o_pal = ops.flash_attention(q, k, v, causal=True, window=win, backend="pallas",
                                bq=128, bk=128)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal), atol=3e-5, rtol=1e-4)
