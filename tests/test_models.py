"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness; prefill/decode == teacher-forced forward;
datapath (bit-packed) ingestion equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.lakeformat.encodings import bitpack_encode
from repro.models.model import (
    decode_step,
    forward_train,
    init_params,
    packed_token_shape,
    param_shapes,
    prefill,
    token_bits,
)
from repro.train.loop import make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

B, S = 2, 64


def _batch(cfg, rng, b=B, s=S):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: forward_train(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss), arch
    assert 3.0 < float(loss) < 12.0, (arch, float(loss))  # ~uniform over vocab at init
    # one optimizer step must decrease nothing NaN and change params
    opt = init_opt_state(params, OptConfig(warmup_steps=1, total_steps=10))
    step = jax.jit(make_train_step(cfg, OptConfig(warmup_steps=1, total_steps=10), None))
    p2, o2, m = step(params, opt, batch)
    assert jnp.isfinite(m["loss"])
    delta = float(jnp.abs(p2["embed"].astype(jnp.float32) - params["embed"].astype(jnp.float32)).max())
    assert delta > 0, arch


# bf16 accumulation tolerance, per arch.  Dense stacks hold 5e-2; the
# llama4 smoke config (top-1 routed MoE + shared expert: two bf16 expert
# sums and a router softmax on top of the dense path) measures 0.0636 at
# seed — real accumulation noise, not a routing flip (a flipped expert
# would miss by O(1)).  Bounded at 1e-1 so a genuine serve/train skew
# still fails.
PREFILL_DECODE_TOL = {"llama4_maverick_400b": 1e-1}


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    """serve path == train path: decode logits at position S must equal the
    prefill logits of the (S+1)-token prompt."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    extra = {k: v for k, v in _batch(cfg, rng).items() if k != "tokens"}
    lp, caches = prefill(params, {"tokens": jnp.asarray(toks[:, :S]), **extra}, cfg,
                         cache_len=S + 8)
    l_full, _ = prefill(params, {"tokens": jnp.asarray(toks[:, : S + 1]), **extra}, cfg,
                        cache_len=S + 8)
    l_dec, _ = decode_step(params, jnp.asarray(toks[:, S : S + 1]), caches,
                           jnp.int32(S), cfg)
    err = float(jnp.max(jnp.abs(l_dec.astype(jnp.float32) - l_full.astype(jnp.float32))))
    assert err < PREFILL_DECODE_TOL.get(arch, 5e-2), (arch, err)


def test_packed_ingestion_equals_tokens():
    """Datapath feature: bit-packed batches produce identical loss."""
    cfg = get_smoke_config("qwen3-1.7b")
    params = init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    s = 4096  # block-aligned
    toks = rng.integers(0, cfg.vocab, (B, s)).astype(np.int64)
    k = token_bits(cfg)
    packed = np.stack([bitpack_encode(toks[i], k) for i in range(B)])
    l1, _ = forward_train(params, {"tokens": jnp.asarray(toks, jnp.int32)}, cfg)
    l2, _ = forward_train(params, {"packed": jnp.asarray(packed)}, cfg)
    assert abs(float(l1) - float(l2)) < 1e-5
    assert packed_token_shape(cfg, B, s) == packed.shape


def test_sliding_window_ring_cache():
    """hymba ring cache: long decode must agree with full-context windowed
    attention (window semantics preserved past the buffer wrap)."""
    cfg = get_smoke_config("hymba-1.5b")  # window=32
    params = init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    n_total = 80  # > 2x window: cache wraps
    toks = rng.integers(0, cfg.vocab, (1, n_total)).astype(np.int32)
    # reference: prefill of all tokens, logits at last position
    l_ref, _ = prefill(params, {"tokens": jnp.asarray(toks)}, cfg, cache_len=n_total)
    # decode path: prefill 48, then decode the rest one by one (jit once)
    n0 = 48
    _, caches = prefill(params, {"tokens": jnp.asarray(toks[:, :n0])}, cfg, cache_len=n_total)
    step = jax.jit(lambda p, t, c, pos: decode_step(p, t, c, pos, cfg))
    logits = None
    for t in range(n0, n_total):
        logits, caches = step(params, jnp.asarray(toks[:, t : t + 1]), caches,
                              jnp.int32(t))
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - l_ref.astype(jnp.float32))))
    assert err < 5e-2, err


def test_param_shapes_match_init():
    for arch in ("llama4-maverick-400b-a17b", "mamba2-370m"):
        cfg = get_smoke_config(arch)
        shapes, dims = param_shapes(cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        is_shape = lambda x: isinstance(x, tuple) and all(isinstance(i, int) for i in x)
        flat_s = [tuple(s) for s in jax.tree.leaves(shapes, is_leaf=is_shape)]
        flat_p = [tuple(x.shape) for x in jax.tree.leaves(params)]
        assert sorted(flat_s) == sorted(flat_p)
